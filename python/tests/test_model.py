"""L2 correctness: model shapes, mask semantics, and GD-learns sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _aerofoil_batch(rng, n, p):
    """Synthetic regression batch padded to capacity p with a mask."""
    x = rng.standard_normal((p, model.AEROFOIL_FEATURES)).astype(np.float32)
    y = (np.sin(x[:, 0]) + 0.5 * x[:, 1] - 0.25 * x[:, 2] * x[:, 3]).astype(np.float32)
    mask = np.zeros(p, dtype=np.float32)
    mask[:n] = 1.0
    x[n:] = 0.0
    y[n:] = 0.0
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(mask)


def _mnist_batch(rng, n, p):
    """Synthetic 10-class image batch: class-dependent blocks + noise."""
    labels = rng.integers(0, 10, p)
    x = rng.standard_normal((p, 1, 28, 28)).astype(np.float32) * 0.3
    for i, c in enumerate(labels):
        r = (c // 5) * 14
        col = (c % 5) * 5
        x[i, 0, r : r + 14, col : col + 5] += 2.0
    mask = np.zeros(p, dtype=np.float32)
    mask[:n] = 1.0
    return jnp.asarray(x), jnp.asarray(labels.astype(np.float32)), jnp.asarray(mask)


# --------------------------------------------------------------------------
# Shapes & parameter inventories
# --------------------------------------------------------------------------


def test_fcn_param_inventory():
    params = model.fcn_init(0)
    assert [p.shape for p in params] == [
        (5, 64), (64,), (64, 32), (32,), (32, 1), (1,),
    ]
    assert all(p.dtype == np.float32 for p in params)


def test_lenet_param_inventory():
    params = model.lenet_init(0)
    assert [tuple(p.shape) for p in params] == [s for _, s in model.LENET_SHAPES]
    total = sum(int(np.prod(p.shape)) for p in params)
    # LeNet-5 on 28x28 valid convs (flatten 256, not the 32x32-input 400):
    # 25*6+6 + 150*16+16 + 256*120+120 + 120*84+84 + 84*10+10 = 44,426
    assert total == 44_426


def test_fcn_forward_shape():
    params = [jnp.asarray(p) for p in model.fcn_init(0)]
    x = jnp.zeros((17, 5))
    assert model.fcn_forward(params, x).shape == (17,)


def test_lenet_forward_shape():
    params = [jnp.asarray(p) for p in model.lenet_init(0)]
    x = jnp.zeros((3, 1, 28, 28))
    assert model.lenet_forward(params, x).shape == (3, 10)


def test_init_deterministic_per_seed():
    a, b = model.lenet_init(7), model.lenet_init(7)
    for pa, pb in zip(a, b):
        np.testing.assert_array_equal(pa, pb)
    c = model.lenet_init(8)
    assert any(not np.array_equal(pa, pc) for pa, pc in zip(a, c))


# --------------------------------------------------------------------------
# Mask semantics: padding must not change losses/metrics
# --------------------------------------------------------------------------


def test_fcn_loss_pad_invariant():
    rng = np.random.default_rng(0)
    params = [jnp.asarray(p) for p in model.fcn_init(0)]
    x, y, mask = _aerofoil_batch(rng, 20, 20)
    x2, y2, m2 = _aerofoil_batch(np.random.default_rng(0), 20, 64)
    l1 = model.fcn_loss(params, x, y, mask)
    l2 = model.fcn_loss(params, x2, y2, m2)
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_fcn_loss_ignores_garbage_in_padding():
    rng = np.random.default_rng(1)
    params = [jnp.asarray(p) for p in model.fcn_init(0)]
    x, y, mask = _aerofoil_batch(rng, 10, 32)
    x_dirty = x.at[10:].set(1e3)
    y_dirty = y.at[10:].set(-1e3)
    np.testing.assert_allclose(
        model.fcn_loss(params, x, y, mask),
        model.fcn_loss(params, x_dirty, y_dirty, mask),
        rtol=1e-5,
    )


def test_lenet_loss_pad_invariant():
    params = [jnp.asarray(p) for p in model.lenet_init(0)]
    x, y, mask = _mnist_batch(np.random.default_rng(2), 12, 12)
    x2 = jnp.pad(x, ((0, 20), (0, 0), (0, 0), (0, 0)))
    y2 = jnp.pad(y, (0, 20))
    m2 = jnp.pad(mask, (0, 20))
    np.testing.assert_allclose(
        model.lenet_loss(params, x, y, mask),
        model.lenet_loss(params, x2, y2, m2),
        rtol=1e-5,
    )


def test_eval_counts_match_mask():
    params = [jnp.asarray(p) for p in model.lenet_init(0)]
    x, y, mask = _mnist_batch(np.random.default_rng(3), 9, 24)
    nll_sum, correct, cnt = model.lenet_eval(params, x, y, mask)
    assert float(cnt) == 9.0
    assert 0.0 <= float(correct) <= 9.0
    assert np.isfinite(float(nll_sum))


# --------------------------------------------------------------------------
# GD-learns sanity: a few epochs of the exact train step reduce the loss
# --------------------------------------------------------------------------


def test_fcn_train_epoch_reduces_loss():
    rng = np.random.default_rng(4)
    params = [jnp.asarray(p) for p in model.fcn_init(0)]
    x, y, mask = _aerofoil_batch(rng, 48, 64)
    step = jax.jit(model.fcn_train_epoch)
    first = None
    for _ in range(40):
        *params, loss = step(params, x, y, mask, jnp.float32(0.05))
        first = first if first is not None else float(loss)
    assert float(loss) < 0.7 * first


def test_lenet_train_epoch_reduces_loss():
    rng = np.random.default_rng(5)
    params = [jnp.asarray(p) for p in model.lenet_init(0)]
    x, y, mask = _mnist_batch(rng, 48, 64)
    step = jax.jit(model.lenet_train_epoch)
    first = None
    for _ in range(15):
        *params, loss = step(params, x, y, mask, jnp.float32(0.05))
        first = first if first is not None else float(loss)
    assert float(loss) < 0.8 * first


def test_train_epoch_preserves_param_shapes():
    params = [jnp.asarray(p) for p in model.lenet_init(0)]
    x, y, mask = _mnist_batch(np.random.default_rng(6), 8, 16)
    out = model.lenet_train_epoch(params, x, y, mask, jnp.float32(0.01))
    assert len(out) == len(params) + 1
    for old, new in zip(params, out[:-1]):
        assert old.shape == new.shape


def test_zero_lr_is_identity():
    params = [jnp.asarray(p) for p in model.fcn_init(0)]
    x, y, mask = _aerofoil_batch(np.random.default_rng(7), 16, 32)
    out = model.fcn_train_epoch(params, x, y, mask, jnp.float32(0.0))
    for old, new in zip(params, out[:-1]):
        np.testing.assert_allclose(old, new, atol=1e-7)
