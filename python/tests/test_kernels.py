"""L1 correctness: Pallas kernels vs pure-jnp oracles (pytest + hypothesis).

Hypothesis sweeps shapes (both the single-block and the gridded/padded
paths) and dtypes; every case asserts allclose against ref.py. Gradients
are checked through the custom VJPs so the backward kernels are covered by
the same sweeps.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

COMMON = dict(max_examples=20, deadline=None)


def _arr(rng, shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(dtype))


dims_small = st.integers(min_value=1, max_value=40)
# > 128 exercises the grid + edge-tile padding path.
dims_grid = st.integers(min_value=129, max_value=300)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------


@settings(**COMMON)
@given(m=dims_small, k=dims_small, n=dims_small, seed=seeds)
def test_matmul_small_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a, b = _arr(rng, (m, k)), _arr(rng, (k, n))
    np.testing.assert_allclose(
        K.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=6, deadline=None)
@given(m=dims_grid, n=dims_grid, seed=seeds)
def test_matmul_grid_path(m, n, seed):
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, 64))
    a, b = _arr(rng, (m, k)), _arr(rng, (k, n))
    np.testing.assert_allclose(
        K.matmul(a, b), ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.standard_normal((33, 17)), dtype=dtype)
    b = jnp.asarray(rng.standard_normal((17, 9)), dtype=dtype)
    out = K.matmul(a, b)
    assert out.dtype == a.dtype
    tol = 1e-5 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, dtype=np.float32),
        np.asarray(ref.matmul_ref(a, b), dtype=np.float32),
        rtol=tol,
        atol=tol,
    )


def test_matmul_rejects_contraction_mismatch():
    a, b = jnp.zeros((3, 4)), jnp.zeros((5, 2))
    with pytest.raises(AssertionError):
        K.matmul(a, b)


# --------------------------------------------------------------------------
# fused dense (fwd + custom VJP)
# --------------------------------------------------------------------------


@settings(**COMMON)
@given(
    m=dims_small,
    k=dims_small,
    n=dims_small,
    act=st.sampled_from(["linear", "relu", "tanh"]),
    seed=seeds,
)
def test_dense_forward(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (m, k)), _arr(rng, (k, n)), _arr(rng, (n,))
    np.testing.assert_allclose(
        K.dense(x, w, b, act), ref.dense_ref(x, w, b, act), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 24),
    k=st.integers(2, 24),
    n=st.integers(2, 24),
    act=st.sampled_from(["linear", "tanh"]),  # relu grad is kink-sensitive
    seed=seeds,
)
def test_dense_grads_match_oracle(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = _arr(rng, (m, k)), _arr(rng, (k, n)), _arr(rng, (n,))

    def loss_k(x, w, b):
        return jnp.sum(K.dense(x, w, b, act) ** 2)

    def loss_r(x, w, b):
        return jnp.sum(ref.dense_ref(x, w, b, act) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gk, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_dense_relu_grad_at_positive_preacts():
    # Deterministic check away from the ReLU kink.
    x = jnp.ones((4, 3))
    w = jnp.full((3, 2), 0.5)
    b = jnp.full((2,), 0.25)
    g = jax.grad(lambda x: jnp.sum(K.dense(x, w, b, "relu")))(x)
    ge = jax.grad(lambda x: jnp.sum(ref.dense_ref(x, w, b, "relu")))(x)
    np.testing.assert_allclose(g, ge, rtol=1e-6, atol=1e-6)


def test_dense_grid_path_forward():
    rng = np.random.default_rng(3)
    x, w, b = _arr(rng, (260, 150)), _arr(rng, (150, 140)), _arr(rng, (140,))
    np.testing.assert_allclose(
        K.dense(x, w, b, "relu"), ref.dense_ref(x, w, b, "relu"), rtol=1e-4, atol=1e-4
    )


def test_dense_unknown_activation_raises():
    with pytest.raises(ValueError):
        K.dense(jnp.zeros((2, 2)), jnp.zeros((2, 2)), jnp.zeros((2,)), "gelu")


# --------------------------------------------------------------------------
# softmax_nll (fwd + custom VJP)
# --------------------------------------------------------------------------


@settings(**COMMON)
@given(b=st.integers(1, 64), c=st.integers(2, 20), seed=seeds)
def test_softmax_nll_forward(b, c, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (b, c), scale=3.0)
    y = jax.nn.one_hot(rng.integers(0, c, b), c, dtype=jnp.float32)
    np.testing.assert_allclose(
        K.softmax_nll(x, y), ref.softmax_nll_ref(x, y), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 48), c=st.integers(2, 16), seed=seeds)
def test_softmax_nll_grad(b, c, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (b, c), scale=3.0)
    y = jax.nn.one_hot(rng.integers(0, c, b), c, dtype=jnp.float32)
    gw = jnp.asarray(rng.standard_normal(b).astype(np.float32))
    gk = jax.grad(lambda x: jnp.sum(K.softmax_nll(x, y) * gw))(x)
    ge = ref.softmax_nll_grad_ref(x, y, gw)
    np.testing.assert_allclose(gk, ge, rtol=1e-4, atol=1e-4)


def test_softmax_nll_numerically_stable_large_logits():
    x = jnp.asarray([[1000.0, 0.0, -1000.0], [500.0, 500.0, 500.0]])
    y = jnp.asarray([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0]])
    out = K.softmax_nll(x, y)
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_allclose(out, ref.softmax_nll_ref(x, y), rtol=1e-6, atol=1e-6)


def test_softmax_nll_grid_path():
    rng = np.random.default_rng(5)
    x = _arr(rng, (300, 10), scale=2.0)
    y = jax.nn.one_hot(rng.integers(0, 10, 300), 10, dtype=jnp.float32)
    np.testing.assert_allclose(
        K.softmax_nll(x, y), ref.softmax_nll_ref(x, y), rtol=1e-5, atol=1e-5
    )
