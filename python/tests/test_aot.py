"""AOT pipeline: artifacts exist, manifest is consistent, HLO text is sane,
and a lowered module re-executes with the right numerics via xla_client."""

import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


def _manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_tasks_and_files():
    man = _manifest()
    assert set(man["tasks"]) == {"aerofoil", "mnist"}
    for task, entry in man["tasks"].items():
        for fname in list(entry["train_buckets"].values()) + list(
            entry["eval_buckets"].values()
        ):
            path = os.path.join(ART, fname)
            assert os.path.exists(path), f"missing artifact {fname}"
            assert os.path.getsize(path) > 1000
        assert os.path.exists(os.path.join(ART, entry["init_npz"]))


def test_manifest_param_shapes_match_model():
    man = _manifest()
    lenet = man["tasks"]["mnist"]["params"]
    assert [tuple(p["shape"]) for p in lenet] == [s for _, s in model.LENET_SHAPES]
    fcn = man["tasks"]["aerofoil"]["params"]
    assert tuple(fcn[0]["shape"]) == (5, 64)


def test_hlo_text_structure():
    man = _manifest()
    entry = man["tasks"]["mnist"]
    fname = list(entry["train_buckets"].values())[0]
    text = open(os.path.join(ART, fname)).read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # tuple return: n_params + 1 outputs
    n_out = len(entry["params"]) + 1
    assert f"(f32[" in text


def test_init_npz_roundtrip():
    man = _manifest()
    entry = man["tasks"]["aerofoil"]
    with np.load(os.path.join(ART, entry["init_npz"])) as z:
        names = sorted(z.files)
        assert names == [f"p{i:03d}" for i in range(len(entry["params"]))]
        for i, p in enumerate(entry["params"]):
            assert list(z[names[i]].shape) == p["shape"]


def test_lowered_train_step_matches_eager():
    """The exact lowering path used by aot.py reproduces eager numerics."""
    from jax._src.lib import xla_client as xc

    params = [jnp.asarray(p) for p in model.fcn_init(3)]
    p = 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((p, 5)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal(p).astype(np.float32))
    mask = jnp.ones(p, dtype=jnp.float32)
    lr = jnp.float32(0.1)

    eager = model.fcn_train_epoch(params, x, y, mask, lr)

    lowered = jax.jit(model.fcn_train_epoch).lower(
        [jax.ShapeDtypeStruct(q.shape, q.dtype) for q in params],
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.ShapeDtypeStruct(y.shape, y.dtype),
        jax.ShapeDtypeStruct(mask.shape, mask.dtype),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")

    compiled = lowered.compile()
    out = compiled(params, x, y, mask, lr)
    for a, e in zip(out, eager):
        np.testing.assert_allclose(a, e, rtol=1e-5, atol=1e-6)
