"""Schedule-invariance: kernel outputs must not depend on block ceilings.

The §Perf pass retuned the interpret-mode block ceilings (128 -> 4096);
this test pins that any ceiling choice — including ones that force the
multi-step grid + edge-padding path on small shapes — produces identical
numerics. This is the safety net for future block-shape tuning (and the
TPU-shaped 128-tile schedule documented in DESIGN.md §Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import fused_dense, ref
# `compile.kernels.__init__` rebinds the attribute `softmax_nll` to the
# function; fetch the real module from sys.modules for ceiling patching.
import importlib

softmax_nll_mod = importlib.import_module("compile.kernels.softmax_nll")


@pytest.fixture
def restore_blocks():
    saved = (fused_dense._MAX_BLOCK_M, fused_dense._MAX_BLOCK_N)
    saved_b = softmax_nll_mod._MAX_BLOCK_B
    yield
    fused_dense._MAX_BLOCK_M, fused_dense._MAX_BLOCK_N = saved
    softmax_nll_mod._MAX_BLOCK_B = saved_b


@pytest.mark.parametrize("bm,bn", [(8, 8), (16, 64), (128, 128), (4096, 512)])
def test_dense_invariant_under_block_ceilings(restore_blocks, bm, bn):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((50, 37)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((37, 29)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(29).astype(np.float32))
    fused_dense._MAX_BLOCK_M = bm
    fused_dense._MAX_BLOCK_N = bn
    out = fused_dense.dense(x, w, b, "tanh")
    np.testing.assert_allclose(
        out, ref.dense_ref(x, w, b, "tanh"), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("bm", [4, 32, 4096])
def test_dense_grad_invariant_under_block_ceilings(restore_blocks, bm):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((20, 12)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((12, 9)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal(9).astype(np.float32))
    fused_dense._MAX_BLOCK_M = bm
    fused_dense._MAX_BLOCK_N = bm
    g = jax.grad(lambda x, w, b: jnp.sum(fused_dense.dense(x, w, b, "tanh") ** 2),
                 argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(lambda x, w, b: jnp.sum(ref.dense_ref(x, w, b, "tanh") ** 2),
                  argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(g, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bb", [4, 64, 1024])
def test_softmax_nll_invariant_under_block_ceilings(restore_blocks, bb):
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((45, 10)).astype(np.float32))
    y = jax.nn.one_hot(rng.integers(0, 10, 45), 10, dtype=jnp.float32)
    softmax_nll_mod._MAX_BLOCK_B = bb
    np.testing.assert_allclose(
        softmax_nll_mod.softmax_nll(x, y),
        ref.softmax_nll_ref(x, y),
        rtol=1e-5,
        atol=1e-5,
    )


def test_vmem_estimate_of_tpu_tiles():
    """DESIGN.md §Hardware-Adaptation: at the TPU-shaped 128-tile schedule,
    the largest working set of the paper's models fits VMEM comfortably."""
    # fc1 of LeNet: x[128, 256] tile + w[256, 120] + out/pre[128, 120] f32.
    tile_bytes = (128 * 256 + 256 * 120 + 2 * 128 * 120) * 4
    assert tile_bytes < 1 << 20  # « 16 MB VMEM
