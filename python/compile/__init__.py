"""Build-time compile package: L1 Pallas kernels + L2 JAX models + AOT lowering.

Nothing in this package is imported at runtime — `make artifacts` runs
`python -m compile.aot` once, and the Rust coordinator only touches the
emitted `artifacts/` files from then on.
"""
