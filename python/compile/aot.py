"""AOT lowering: JAX (L2, calling L1 Pallas kernels) -> HLO text artifacts.

Run once via `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Emits, per task and per batch-capacity bucket:

    artifacts/<task>_train_p<P>.hlo.txt   (params..., x, y, mask, lr,
                                          epochs:i32) -> (params'..., loss)
                                          — the epoch loop is a lax.fori_loop
                                          inside the HLO (one PJRT call per
                                          client-round)
    artifacts/<task>_eval_b<B>.hlo.txt    (params..., x, y, mask) -> 3 sums
    artifacts/<task>_init.npz             initial parameters (p000, p001, ...)
    artifacts/manifest.json               shapes, param order, bucket sizes

Interchange format is **HLO text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Bucketed capacities: HLO is static-shaped, client partitions are not. We
compile each train step at several capacities P and let the Rust runtime
pick the smallest bucket that fits a client's partition — the same idiom
serving systems use for batched executables. Python never runs after this
script completes.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# Batch-capacity buckets per task. The scaled experiment presets use the
# small buckets; the paper-scale presets use the large ones.
TRAIN_BUCKETS = {"aerofoil": [64, 192], "mnist": [64, 256]}
EVAL_BUCKETS = {"aerofoil": [256], "mnist": [256]}

TASKS = {
    "aerofoil": dict(
        init=model.fcn_init,
        train=model.fcn_train_epochs,
        evaluate=model.fcn_eval,
        x_dims=(model.AEROFOIL_FEATURES,),
        eval_outputs=["sq_err_sum", "abs_err_sum", "count"],
        param_names=[f"{n}{i}" for i in range(3) for n in ("w", "b")],
    ),
    "mnist": dict(
        init=model.lenet_init,
        train=model.lenet_train_epochs,
        evaluate=model.lenet_eval,
        x_dims=(1, model.MNIST_HW, model.MNIST_HW),
        eval_outputs=["nll_sum", "correct", "count"],
        param_names=[n for n, _ in model.LENET_SHAPES],
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO module -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _specs(shapes, dtype=jnp.float32):
    return [jax.ShapeDtypeStruct(s, dtype) for s in shapes]


def lower_task(task: str, out_dir: str, seed: int) -> dict:
    """Lower all buckets for one task; write artifacts; return manifest entry."""
    cfg = TASKS[task]
    params = cfg["init"](seed)
    param_shapes = [list(p.shape) for p in params]
    p_specs = _specs([tuple(p.shape) for p in params])
    entry = {
        "params": [
            {"name": n, "shape": s}
            for n, s in zip(cfg["param_names"], param_shapes)
        ],
        "x_dims": list(cfg["x_dims"]),
        "eval_outputs": cfg["eval_outputs"],
        "train_buckets": {},
        "eval_buckets": {},
        "init_npz": f"{task}_init.npz",
        "seed": seed,
    }

    for p in TRAIN_BUCKETS[task]:
        batch = _specs([(p, *cfg["x_dims"]), (p,), (p,)])
        lr = jax.ShapeDtypeStruct((), jnp.float32)
        epochs = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jax.jit(cfg["train"]).lower(p_specs, *batch, lr, epochs)
        fname = f"{task}_train_p{p}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        entry["train_buckets"][str(p)] = fname
        print(f"  lowered {fname}")

    for b in EVAL_BUCKETS[task]:
        batch = _specs([(b, *cfg["x_dims"]), (b,), (b,)])
        lowered = jax.jit(cfg["evaluate"]).lower(p_specs, *batch)
        fname = f"{task}_eval_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        entry["eval_buckets"][str(b)] = fname
        print(f"  lowered {fname}")

    # Initial parameters: zero-padded names keep npz iteration order stable.
    np.savez(
        os.path.join(out_dir, entry["init_npz"]),
        **{f"p{i:03d}": p for i, p in enumerate(params)},
    )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--seed", type=int, default=42, help="param init seed")
    ap.add_argument(
        "--tasks", default="aerofoil,mnist", help="comma-separated task subset"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"format": 1, "tasks": {}}
    for task in args.tasks.split(","):
        print(f"[aot] lowering task {task}")
        manifest["tasks"][task] = lower_task(task, args.out, args.seed)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote manifest.json with {len(manifest['tasks'])} tasks")


if __name__ == "__main__":
    main()
