"""L2 JAX models: the paper's two on-device workloads (Table II).

* Task 1 — Aerofoil: fully-connected regressor (5 -> 64 -> 32 -> 1, tanh),
  masked MSE loss.
* Task 2 — MNIST: LeNet-5 (conv 1->6 k5, pool, conv 6->16 k5, pool,
  fc 256->120->84->10), masked NLL loss. Convolutions are lowered as
  im2col + the L1 Pallas fused-dense kernel, so the MXU-shaped matmul
  kernel carries all of the FLOPs.

Every dense contraction and the log-softmax/NLL loss go through the L1
Pallas kernels (`kernels.dense`, `kernels.softmax_nll`), so `jax.grad`
differentiates through their custom VJPs and the whole train step lowers
into a single HLO module per (task, batch-capacity) that the Rust PJRT
runtime executes.

Fixed-shape + mask convention
-----------------------------
HLO is static-shaped but client partitions vary, so every batch is padded
to a capacity P and accompanied by a {0,1} mask; all losses/metrics are
mask-weighted. Padded label rows are ignored by construction.

Train step = one full-batch gradient-descent epoch (paper Alg. 1 runs tau
GD epochs per round; the Rust coordinator calls this step tau times).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels as K

Params = Sequence[jnp.ndarray]

# ---------------------------------------------------------------------------
# Task 1: Aerofoil FCN
# ---------------------------------------------------------------------------

AEROFOIL_FEATURES = 5
FCN_LAYERS = [(AEROFOIL_FEATURES, 64), (64, 32), (32, 1)]
FCN_ACTS = ["tanh", "tanh", "linear"]


def fcn_init(seed: int = 0) -> List[np.ndarray]:
    """Glorot-uniform FCN parameters as the flat [w0,b0,w1,b1,w2,b2] list."""
    rng = np.random.default_rng(seed)
    params: List[np.ndarray] = []
    for fan_in, fan_out in FCN_LAYERS:
        lim = np.sqrt(6.0 / (fan_in + fan_out))
        params.append(rng.uniform(-lim, lim, (fan_in, fan_out)).astype(np.float32))
        params.append(np.zeros((fan_out,), dtype=np.float32))
    return params


def fcn_forward(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """FCN forward: x[B,5] -> prediction [B]."""
    h = x
    for li, act in enumerate(FCN_ACTS):
        h = K.dense(h, params[2 * li], params[2 * li + 1], act)
    return jnp.squeeze(h, -1)


def fcn_loss(params: Params, x, y, mask) -> jnp.ndarray:
    """Masked-mean MSE."""
    pred = fcn_forward(params, x)
    se = (pred - y) ** 2
    return jnp.sum(se * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _train_epochs(loss_fn, params: Params, x, y, mask, lr, epochs):
    """`epochs` full-batch GD steps as a single lowered computation.

    The epoch loop lives *inside* the HLO (lax.fori_loop with a runtime
    trip count), so the Rust coordinator makes exactly one PJRT call per
    client-round — no host round-trips between local epochs. Returns
    (*new_params, loss_before_last_step).
    """

    def body(_, carry):
        ps, _ = carry
        loss, grads = jax.value_and_grad(loss_fn)(ps, x, y, mask)
        return ([p - lr * g for p, g in zip(ps, grads)], loss)

    final, last_loss = jax.lax.fori_loop(
        0, epochs, body, (list(params), jnp.float32(0.0))
    )
    return tuple(final) + (last_loss,)


def fcn_train_epoch(params: Params, x, y, mask, lr) -> Tuple[jnp.ndarray, ...]:
    """One full-batch GD epoch. Returns (*new_params, loss_before_step)."""
    loss, grads = jax.value_and_grad(fcn_loss)(list(params), x, y, mask)
    new = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new) + (loss,)


def fcn_train_epochs(params: Params, x, y, mask, lr, epochs) -> Tuple[jnp.ndarray, ...]:
    """`epochs` GD epochs in one call (the AOT-exported entry point)."""
    return _train_epochs(fcn_loss, params, x, y, mask, lr, epochs)


def fcn_eval(params: Params, x, y, mask) -> Tuple[jnp.ndarray, ...]:
    """Masked sums for regression metrics: (sq_err_sum, abs_err_sum, count).

    The coordinator turns these into MSE / the paper-style regression
    "accuracy" (1 - normalized MAE) across eval chunks.
    """
    pred = fcn_forward(params, x)
    err = pred - y
    sse = jnp.sum(err * err * mask)
    sae = jnp.sum(jnp.abs(err) * mask)
    cnt = jnp.sum(mask)
    return (sse, sae, cnt)


# ---------------------------------------------------------------------------
# Task 2: MNIST LeNet-5
# ---------------------------------------------------------------------------

MNIST_CLASSES = 10
MNIST_HW = 28
_K = 5  # conv kernel edge

# (name, shape) in flat parameter order. Conv weights are stored im2col-ready
# as [C_in*k*k, C_out].
LENET_SHAPES = [
    ("conv1_w", (1 * _K * _K, 6)),
    ("conv1_b", (6,)),
    ("conv2_w", (6 * _K * _K, 16)),
    ("conv2_b", (16,)),
    ("fc1_w", (256, 120)),
    ("fc1_b", (120,)),
    ("fc2_w", (120, 84)),
    ("fc2_b", (84,)),
    ("fc3_w", (84, 10)),
    ("fc3_b", (10,)),
]


def lenet_init(seed: int = 0) -> List[np.ndarray]:
    """Glorot-uniform LeNet-5 parameters in LENET_SHAPES order."""
    rng = np.random.default_rng(seed)
    params: List[np.ndarray] = []
    for _, shape in LENET_SHAPES:
        if len(shape) == 2:
            lim = np.sqrt(6.0 / (shape[0] + shape[1]))
            params.append(rng.uniform(-lim, lim, shape).astype(np.float32))
        else:
            params.append(np.zeros(shape, dtype=np.float32))
    return params


def _im2col(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """[B,C,H,W] -> [B*Ho*Wo, C*k*k] valid-conv patches (C-major layout)."""
    b, c, h, w = x.shape
    ho, wo = h - k + 1, w - k + 1
    cols = jnp.stack(
        [x[:, :, i : i + ho, j : j + wo] for i in range(k) for j in range(k)],
        axis=2,
    )  # [B, C, k*k, Ho, Wo]
    return cols.transpose(0, 3, 4, 1, 2).reshape(b * ho * wo, c * k * k)


def _conv_relu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Valid 5x5 conv + ReLU via im2col + the Pallas fused-dense kernel."""
    bb, c, h, _ = x.shape
    ho = h - _K + 1
    cols = _im2col(x, _K)
    out = K.dense(cols, w, b, "relu")  # [B*Ho*Wo, OC]
    return out.reshape(bb, ho, ho, -1).transpose(0, 3, 1, 2)


def _maxpool2(x: jnp.ndarray) -> jnp.ndarray:
    b, c, h, w = x.shape
    return x.reshape(b, c, h // 2, 2, w // 2, 2).max(axis=(3, 5))


def lenet_forward(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    """LeNet-5 forward: x[B,1,28,28] -> logits [B,10]."""
    h = _conv_relu(x, params[0], params[1])  # [B,6,24,24]
    h = _maxpool2(h)  # [B,6,12,12]
    h = _conv_relu(h, params[2], params[3])  # [B,16,8,8]
    h = _maxpool2(h)  # [B,16,4,4]
    h = h.reshape(h.shape[0], -1)  # [B,256]
    h = K.dense(h, params[4], params[5], "relu")
    h = K.dense(h, params[6], params[7], "relu")
    return K.dense(h, params[8], params[9], "linear")


def lenet_loss(params: Params, x, y, mask) -> jnp.ndarray:
    """Masked-mean NLL via the Pallas softmax_nll kernel. y is float labels."""
    logits = lenet_forward(params, x)
    y1h = jax.nn.one_hot(y.astype(jnp.int32), MNIST_CLASSES, dtype=logits.dtype)
    nll = K.softmax_nll(logits, y1h * mask[:, None])
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lenet_train_epoch(params: Params, x, y, mask, lr) -> Tuple[jnp.ndarray, ...]:
    """One full-batch GD epoch. Returns (*new_params, loss_before_step)."""
    loss, grads = jax.value_and_grad(lenet_loss)(list(params), x, y, mask)
    new = [p - lr * g for p, g in zip(params, grads)]
    return tuple(new) + (loss,)


def lenet_train_epochs(params: Params, x, y, mask, lr, epochs) -> Tuple[jnp.ndarray, ...]:
    """`epochs` GD epochs in one call (the AOT-exported entry point)."""
    return _train_epochs(lenet_loss, params, x, y, mask, lr, epochs)


def lenet_eval(params: Params, x, y, mask) -> Tuple[jnp.ndarray, ...]:
    """Masked sums: (nll_sum, correct_count, count)."""
    logits = lenet_forward(params, x)
    yi = y.astype(jnp.int32)
    y1h = jax.nn.one_hot(yi, MNIST_CLASSES, dtype=logits.dtype)
    nll = K.softmax_nll(logits, y1h * mask[:, None])
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == yi).astype(jnp.float32) * mask)
    return (jnp.sum(nll * mask), correct, jnp.sum(mask))
