"""L1 Pallas kernels: tiled matmul and fused dense (matmul + bias + activation).

TPU-shaped tiling, CPU-interpret execution
------------------------------------------
The kernels tile the output over a ``(M/bm, N/bn)`` grid with the contraction
dimension K resident per program instance — the classic TPU schedule where
each grid step keeps an ``x`` row-block and a ``w`` column-block in VMEM and
feeds the MXU with an f32-accumulating ``jnp.dot``. BlockSpec expresses the
HBM→VMEM movement; edge tiles are handled by zero-padding in the wrappers so
block shapes always divide the padded operand shapes.

All ``pallas_call`` sites run with ``interpret=True``: on this CPU-only image
the Mosaic TPU backend is unavailable, and interpret mode lowers to plain HLO
ops so the kernels AOT-compile into the same ``artifacts/*.hlo.txt`` the Rust
PJRT runtime loads. Real-TPU performance is estimated analytically in
DESIGN.md §Hardware-Adaptation (the shapes used by the paper's models fit
VMEM whole, so the grid only engages on the large synthetic sweeps).

The backward pass is wired with ``jax.custom_vjp`` so that ``jax.grad`` of
the L2 model differentiates *through* the Pallas kernels: dgrad/wgrad are the
same tiled matmul kernel on transposed operands, and the activation gradient
is a fused elementwise Pallas kernel.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Ceiling on block edge; shapes smaller than this run as a single program
# instance (whole operand resident), larger shapes get a grid.
#
# CPU-interpret tuning: every grid step lowers to one iteration of an HLO
# while-loop with dynamic-slice traffic, so small tiles drown in loop
# overhead (measured 1.35 s -> ~0.1 s per LeNet train step when moving
# from 128-row to 4096-row blocks; EXPERIMENTS.md §Perf). On a real TPU
# these ceilings would be the VMEM-shaped 128/256 — see DESIGN.md
# §Hardware-Adaptation; the numbers below are the CPU-path schedule.
_MAX_BLOCK_M = 4096
_MAX_BLOCK_N = 512


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, ceiling: int) -> int:
    """Block edge: whole dim if small, otherwise the ceiling tile."""
    return dim if dim <= ceiling else ceiling


# --------------------------------------------------------------------------
# Tiled matmul kernel
# --------------------------------------------------------------------------


def _matmul_kernel(a_ref, b_ref, o_ref):
    # One (bm, K) x (K, bn) -> (bm, bn) MXU tile, f32 accumulation.
    o_ref[...] = jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Tiled Pallas matmul: ``a[M,K] @ b[K,N] -> [M,N]``.

    Pads M and N up to block multiples (K stays resident), launches a
    ``(M/bm, N/bn)`` grid, and slices the result back to the true shape.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"
    bm = _pick_block(m, _MAX_BLOCK_M)
    bn = _pick_block(n, _MAX_BLOCK_N)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, 0))) if mp != m else a
    b_p = jnp.pad(b, ((0, 0), (0, np_ - n))) if np_ != n else b
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), a.dtype),
        interpret=True,
    )(a_p, b_p)
    return out[:m, :n]


# --------------------------------------------------------------------------
# Fused dense: act(x @ w + b), custom VJP
# --------------------------------------------------------------------------


def _dense_fwd_kernel(x_ref, w_ref, b_ref, o_ref, pre_ref, *, activation: str):
    pre = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :]
    )
    pre_ref[...] = pre.astype(pre_ref.dtype)
    o_ref[...] = ref.apply_activation(pre, activation).astype(o_ref.dtype)


def _dense_fwd_pallas(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out, pre) — pre-activation saved for the VJP."""
    m, k = x.shape
    _, n = w.shape
    bm = _pick_block(m, _MAX_BLOCK_M)
    bn = _pick_block(n, _MAX_BLOCK_N)
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    x_p = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    w_p = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w
    b_p = jnp.pad(b, (0, np_ - n)) if np_ != n else b
    out, pre = pl.pallas_call(
        functools.partial(_dense_fwd_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), x.dtype),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=True,
    )(x_p, w_p, b_p)
    return out[:m, :n], pre[:m, :n]


def _act_grad_kernel(g_ref, pre_ref, o_ref, *, activation: str):
    # Fused elementwise: g * act'(pre). One row-block per program instance.
    o_ref[...] = (g_ref[...] * ref.activation_grad(pre_ref[...], activation)).astype(
        o_ref.dtype
    )


def _act_grad_pallas(g: jnp.ndarray, pre: jnp.ndarray, activation: str) -> jnp.ndarray:
    m, n = g.shape
    bm = _pick_block(m, _MAX_BLOCK_M)
    mp = _round_up(m, bm)
    g_p = jnp.pad(g, ((0, mp - m), (0, 0))) if mp != m else g
    pre_p = jnp.pad(pre, ((0, mp - m), (0, 0))) if mp != m else pre
    out = pl.pallas_call(
        functools.partial(_act_grad_kernel, activation=activation),
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), g.dtype),
        interpret=True,
    )(g_p, pre_p)
    return out[:m]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str = "linear"):
    """Fused dense layer ``act(x @ w + b)`` as a Pallas kernel with custom VJP.

    Args:
      x: [B, K] input batch.
      w: [K, N] weights.
      b: [N] bias.
      activation: 'linear' | 'relu' | 'tanh'.
    Returns:
      [B, N] activations.
    """
    out, _ = _dense_fwd_pallas(x, w, b, activation)
    return out


def _dense_vjp_fwd(x, w, b, activation):
    out, pre = _dense_fwd_pallas(x, w, b, activation)
    return out, (x, w, pre)


def _dense_vjp_bwd(activation, res, g):
    x, w, pre = res
    gp = _act_grad_pallas(g, pre, activation)  # [B, N]
    dx = matmul(gp, w.T)  # [B, K]
    dw = matmul(x.T, gp)  # [K, N]
    db = jnp.sum(gp, axis=0)  # [N]
    return dx, dw, db


dense.defvjp(_dense_vjp_fwd, _dense_vjp_bwd)
