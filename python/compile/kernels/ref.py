"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: every Pallas kernel in this package
must match its `*_ref` counterpart to float32 tolerance under pytest +
hypothesis sweeps (see python/tests/). They are also used directly by the
L2 model as a fallback when `HYBRIDFL_NO_PALLAS=1` (debug aid only — the
shipped artifacts always go through the Pallas path).
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Plain f32 matmul oracle: [M,K] @ [K,N] -> [M,N]."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def apply_activation(pre: jnp.ndarray, activation: str) -> jnp.ndarray:
    """Activation used by the fused dense kernel. 'linear'|'relu'|'tanh'."""
    if activation == "linear":
        return pre
    if activation == "relu":
        return jnp.maximum(pre, 0.0)
    if activation == "tanh":
        return jnp.tanh(pre)
    raise ValueError(f"unknown activation: {activation}")


def activation_grad(pre: jnp.ndarray, activation: str) -> jnp.ndarray:
    """d act(pre) / d pre, evaluated at the saved pre-activation."""
    if activation == "linear":
        return jnp.ones_like(pre)
    if activation == "relu":
        return (pre > 0.0).astype(pre.dtype)
    if activation == "tanh":
        t = jnp.tanh(pre)
        return 1.0 - t * t
    raise ValueError(f"unknown activation: {activation}")


def dense_ref(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, activation: str = "linear"
) -> jnp.ndarray:
    """Fused dense oracle: act(x @ w + b)."""
    return apply_activation(matmul_ref(x, w) + b[None, :], activation)


def softmax_nll_ref(logits: jnp.ndarray, y_onehot: jnp.ndarray) -> jnp.ndarray:
    """Per-row negative log-likelihood oracle.

    loss_i = logsumexp(logits_i) - <logits_i, y_i>   for one-hot y.
    Returns shape [B].
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.squeeze(m, -1) + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    return lse - jnp.sum(logits * y_onehot, axis=-1)


def softmax_nll_grad_ref(
    logits: jnp.ndarray, y_onehot: jnp.ndarray, g: jnp.ndarray
) -> jnp.ndarray:
    """d(sum g_i * loss_i)/d logits = g[:,None] * (softmax(logits) - y)."""
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    sm = e / jnp.sum(e, axis=-1, keepdims=True)
    return g[:, None] * (sm - y_onehot)
