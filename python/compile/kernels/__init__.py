"""Pallas kernel package (L1). See fused_dense.py / softmax_nll.py / ref.py."""

from . import ref  # noqa: F401
from .fused_dense import dense, matmul  # noqa: F401
from .softmax_nll import softmax_nll  # noqa: F401
