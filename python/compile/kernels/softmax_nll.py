"""L1 Pallas kernel: row-wise fused log-softmax + negative log-likelihood.

Computes ``loss_i = logsumexp(logits_i) - <logits_i, y_i>`` for one-hot
labels in a single VMEM-resident pass per row-block (max, exp, sum, dot all
fused — no [B,C] intermediate ever round-trips to HBM), plus the matching
backward kernel ``g_i * (softmax(logits_i) - y_i)`` wired via
``jax.custom_vjp``.

Same interpret-mode caveat as fused_dense.py: on this CPU image the kernels
lower to plain HLO so they embed into the AOT artifacts the Rust runtime
executes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_MAX_BLOCK_B = 1024


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, ceiling: int) -> int:
    return dim if dim <= ceiling else ceiling


def _nll_fwd_kernel(x_ref, y_ref, loss_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    lse = jnp.squeeze(m, -1) + jnp.log(jnp.sum(jnp.exp(x - m), axis=-1))
    loss_ref[...] = (lse - jnp.sum(x * y_ref[...], axis=-1)).astype(loss_ref.dtype)


def _nll_bwd_kernel(x_ref, y_ref, g_ref, dx_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    sm = e / jnp.sum(e, axis=-1, keepdims=True)
    dx_ref[...] = (g_ref[...][:, None] * (sm - y_ref[...])).astype(dx_ref.dtype)


def _call_rowwise(kernel, outs_shape, b: int, c: int, *args):
    bb = _pick_block(b, _MAX_BLOCK_B)
    bp = _round_up(b, bb)
    padded = []
    for a in args:
        if a.shape[0] != bp:
            pad = [(0, bp - b)] + [(0, 0)] * (a.ndim - 1)
            a = jnp.pad(a, pad)
        padded.append(a)
    in_specs = [
        # nd bound per-arg (default-arg trick: avoids late-binding closure).
        pl.BlockSpec((bb,) + a.shape[1:], lambda i, nd=a.ndim: (i,) + (0,) * (nd - 1))
        for a in padded
    ]
    out = pl.pallas_call(
        kernel,
        grid=(bp // bb,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (bb,) + outs_shape[1:], lambda i: (i,) + (0,) * (len(outs_shape) - 1)
        ),
        out_shape=jax.ShapeDtypeStruct((bp,) + outs_shape[1:], jnp.float32),
        interpret=True,
    )(*padded)
    return out[:b]


@jax.custom_vjp
def softmax_nll(logits: jnp.ndarray, y_onehot: jnp.ndarray) -> jnp.ndarray:
    """Per-row NLL of ``softmax(logits)`` against one-hot labels.

    Args:
      logits: [B, C] raw scores.
      y_onehot: [B, C] one-hot labels (rows may be all-zero for padded
        samples — those rows yield ``loss = logsumexp(logits)`` and must be
        masked out by the caller, which the L2 model does).
    Returns:
      [B] per-sample loss.
    """
    b, c = logits.shape
    return _call_rowwise(_nll_fwd_kernel, (b,), b, c, logits, y_onehot)


def _nll_vjp_fwd(logits, y_onehot):
    return softmax_nll(logits, y_onehot), (logits, y_onehot)


def _nll_vjp_bwd(res, g):
    logits, y_onehot = res
    b, c = logits.shape
    dx = _call_rowwise(_nll_bwd_kernel, (b, c), b, c, logits, y_onehot, g)
    return dx, jnp.zeros_like(y_onehot)


softmax_nll.defvjp(_nll_vjp_fwd, _nll_vjp_bwd)
