//! Dynamic-reliability subsystem tests (ISSUE 5):
//!
//! * `Stationary` churn is byte-identical to the historical frozen-world
//!   behavior on both backends (the plumbing never perturbs a run);
//! * each built-in process visibly moves the ground-truth availability
//!   series while staying deterministic in the seed;
//! * the slack estimator *re-converges* after a scripted drop-out step
//!   change — the dynamic Fig. 2 analogue;
//! * `--record-fates` → `--replay-fates` is a fixed point, and
//!   hand-written traces drive the world verbatim;
//! * client mobility reroutes selection on the virtual clock and is a
//!   loud error on the live backend.

use hybridfl::churn::{ChurnModel, FateRecord, FateTrace, FaultEvent};
use hybridfl::config::{ProtocolKind, RegionSpec};
use hybridfl::env::{CutoffPolicy, FlEnvironment as _, Selection, Starts, VirtualClockEnv};
use hybridfl::scenario::{Backend, Scenario};
use hybridfl::sim::test_support::{markov_churn as markov, two_region_cfg};
use hybridfl::snapshot::run_result_bytes;

fn tmp_path(name: &str) -> std::path::PathBuf {
    hybridfl::sim::test_support::tmp_path("churn_dynamics", name)
}

// ---------------------------------------------------------------------------
// Stationarity: churn plumbing must not perturb frozen-world runs.
// ---------------------------------------------------------------------------

/// On the sim backend, the default config, an explicit `Stationary` churn
/// model, the legacy `FlRun` path, and a run with fate recording enabled
/// all produce byte-identical `RunResult`s: the subsystem is invisible
/// until a non-stationary model is asked for.
#[test]
fn stationary_is_byte_identical_across_entry_points_sim() {
    let cfg = two_region_cfg(0.3);
    let default_bytes =
        run_result_bytes(&Scenario::from_config(cfg.clone()).run().unwrap());
    let explicit = Scenario::from_config(cfg.clone())
        .churn(ChurnModel::Stationary)
        .run()
        .unwrap();
    assert_eq!(default_bytes, run_result_bytes(&explicit));
    let flrun = hybridfl::sim::FlRun::new(cfg.clone()).unwrap().run().unwrap();
    assert_eq!(default_bytes, run_result_bytes(&flrun));

    let recorded_path = tmp_path("stationary_record.json");
    let recorded = Scenario::from_config(cfg)
        .record_fates(&recorded_path)
        .run()
        .unwrap();
    assert_eq!(
        default_bytes,
        run_result_bytes(&recorded),
        "fate recording perturbed the run"
    );
    let _ = std::fs::remove_file(&recorded_path);
}

/// Same bar on the live threaded backend (small fleet + generous time
/// scale, the regime `tests/resume_determinism.rs` pins for byte
/// stability against scheduler jitter).
#[test]
fn stationary_is_byte_identical_live() {
    let mut cfg = two_region_cfg(0.25);
    cfg.n_clients = 12;
    cfg.regions = vec![
        RegionSpec { n_clients: 6, dropout_mean: 0.25 },
        RegionSpec { n_clients: 6, dropout_mean: 0.25 },
    ];
    cfg.dataset_size = 360;
    cfg.t_max = 3;
    cfg.seed = 42;
    let scale = 1e-2;
    let a = Scenario::from_config(cfg.clone())
        .backend(Backend::Live)
        .time_scale(scale)
        .run()
        .unwrap();
    let b = Scenario::from_config(cfg)
        .backend(Backend::Live)
        .time_scale(scale)
        .churn(ChurnModel::Stationary)
        .run()
        .unwrap();
    assert_eq!(run_result_bytes(&a), run_result_bytes(&b));
}

// ---------------------------------------------------------------------------
// The built-in processes move the world, deterministically.
// ---------------------------------------------------------------------------

#[test]
fn markov_churn_is_deterministic_and_changes_the_world() {
    let cfg = two_region_cfg(0.2);
    let run = |churn: ChurnModel| {
        Scenario::from_config(cfg.clone()).churn(churn).run().unwrap()
    };
    let a = run(markov());
    let b = run(markov());
    assert_eq!(
        run_result_bytes(&a),
        run_result_bytes(&b),
        "same seed + same churn must be byte-identical"
    );
    let stationary = run(ChurnModel::Stationary);
    assert_ne!(
        run_result_bytes(&a),
        run_result_bytes(&stationary),
        "markov churn left no trace on the run"
    );
    // Ground truth: some round must show depressed availability (a down
    // client carries dropout 0.97 against a 0.2 base).
    let min_avail = a
        .rounds
        .iter()
        .flat_map(|r| r.avail.iter())
        .cloned()
        .fold(f64::MAX, f64::min);
    assert!(
        min_avail < 0.75,
        "no outage visible in the availability series: min {min_avail}"
    );
}

#[test]
fn diurnal_availability_oscillates() {
    let mut cfg = two_region_cfg(0.3);
    cfg.t_max = 20;
    let result = Scenario::from_config(cfg)
        .churn(ChurnModel::Diurnal {
            amplitude: 0.3,
            period: 10,
            region_phase: vec![0.0, 0.0],
        })
        .run()
        .unwrap();
    let series: Vec<f64> = result.rounds.iter().map(|r| r.avail[0]).collect();
    let max = series.iter().cloned().fold(f64::MIN, f64::max);
    let min = series.iter().cloned().fold(f64::MAX, f64::min);
    assert!(
        max - min > 0.4,
        "diurnal modulation not visible: range {:.3} in {series:?}",
        max - min
    );
    // One full period apart, the availability repeats exactly.
    assert!((series[0] - series[10]).abs() < 1e-12);
}

#[test]
fn battery_drain_depresses_availability_in_waves() {
    let mut cfg = two_region_cfg(0.2);
    cfg.t_max = 30;
    let result = Scenario::from_config(cfg)
        .churn(ChurnModel::BatteryDrain {
            drain_per_round: 0.25,
            recharge_p: 0.4,
            depleted_dropout: 0.99,
        })
        .run()
        .unwrap();
    let min_avail = result
        .rounds
        .iter()
        .flat_map(|r| r.avail.iter())
        .cloned()
        .fold(f64::MAX, f64::min);
    assert!(
        min_avail < 0.55,
        "no depletion wave visible: min avail {min_avail}"
    );
    // Recharges must pull availability back up at some round: the series
    // has to swing, not sink monotonically.
    let max_avail = result
        .rounds
        .iter()
        .flat_map(|r| r.avail.iter())
        .cloned()
        .fold(f64::MIN, f64::max);
    assert!(
        max_avail > min_avail + 0.15,
        "no recovery after depletion: max {max_avail} vs min {min_avail}"
    );
}

#[test]
fn regional_blackout_zeroes_the_region_for_its_window() {
    let mut cfg = two_region_cfg(0.2);
    cfg.t_max = 8;
    let result = Scenario::from_config(cfg)
        .churn(ChurnModel::FaultScript {
            events: vec![FaultEvent::RegionBlackout {
                region: 0,
                from_round: 3,
                until_round: 6,
            }],
        })
        .run()
        .unwrap();
    for row in &result.rounds {
        if (3..6).contains(&row.t) {
            assert_eq!(row.alive[0], 0, "round {}: blackout leaked", row.t);
            assert_eq!(row.submissions[0], 0, "round {}", row.t);
            assert!(row.avail[0] < 1e-12, "round {}: avail {}", row.t, row.avail[0]);
        } else {
            assert!(row.avail[0] > 0.5, "round {}: avail {}", row.t, row.avail[0]);
        }
        // The untouched region never blacks out.
        assert!(row.avail[1] > 0.5, "round {}", row.t);
    }
    // Before and after the window the region participates again.
    let t2 = &result.rounds[1];
    let t6 = &result.rounds[5];
    assert!(t2.alive[0] > 0);
    assert!(t6.alive[0] > 0);
}

// ---------------------------------------------------------------------------
// The dynamic Fig. 2 analogue: slack re-convergence after a regime shift.
// ---------------------------------------------------------------------------

/// A scripted drop-out step change hits region 1 at round 50 (+0.35 on
/// every client). The slack estimator only ever sees submission counts,
/// yet: participation collapses right after the shift, selection ramps
/// up to compensate, and the per-region alive fraction re-converges to
/// the cloud's target C within the run — the paper's Fig. 2 story, made
/// dynamic.
#[test]
fn dropout_step_change_reconverges_selected_proportion() {
    let mut cfg = two_region_cfg(0.3);
    cfg.t_max = 250;
    let shift_at = 50usize;
    let result = Scenario::from_config(cfg)
        .churn(ChurnModel::FaultScript {
            events: vec![FaultEvent::DropoutShift {
                region: Some(1),
                at_round: shift_at,
                delta: 0.35,
            }],
        })
        .run()
        .unwrap();

    let n_r = 20.0;
    let c = 0.3;
    let alive_frac = |rows: &[hybridfl::env::RoundTrace]| -> f64 {
        rows.iter().map(|r| r.alive[1] as f64 / n_r).sum::<f64>() / rows.len() as f64
    };
    let selected_mean = |rows: &[hybridfl::env::RoundTrace]| -> f64 {
        rows.iter().map(|r| r.selected[1] as f64).sum::<f64>() / rows.len() as f64
    };

    // rounds[i] carries t = i + 1; the shift applies from t = 50 on.
    let pre = &result.rounds[29..49]; // t in 30..49, converged stationary
    let post = &result.rounds[50..70]; // t in 51..70, right after the shift
    let tail = &result.rounds[200..250]; // t in 201..250, re-converged

    // Pre-shift: steered to the target.
    let pre_alive = alive_frac(pre);
    assert!(
        (pre_alive - c).abs() < 0.12,
        "pre-shift alive fraction {pre_alive} should hover near C={c}"
    );
    // The shift bites: participation collapses before adaptation.
    let post_alive = alive_frac(post);
    assert!(
        post_alive < pre_alive - 0.04,
        "step change did not depress participation: pre {pre_alive}, post {post_alive}"
    );
    // Re-convergence: the tail is steered back toward C...
    let tail_alive = alive_frac(tail);
    assert!(
        (tail_alive - c).abs() < 0.12,
        "no re-convergence: tail alive fraction {tail_alive} vs C={c}"
    );
    assert!(
        tail_alive > post_alive,
        "tail {tail_alive} should recover above the post-shift dip {post_alive}"
    );
    // ...because selection in the degraded region ramped up.
    let pre_sel = selected_mean(pre);
    let tail_sel = selected_mean(tail);
    assert!(
        tail_sel > pre_sel + 2.0,
        "selection did not compensate: pre {pre_sel}, tail {tail_sel}"
    );
    // Ground truth confirms the regime shift itself.
    assert!(result.rounds[30].avail[1] > 0.6);
    assert!(result.rounds[60].avail[1] < 0.45);
}

// ---------------------------------------------------------------------------
// Fate-trace record / replay.
// ---------------------------------------------------------------------------

/// The acceptance bar: record a churning run's ground truth, replay it,
/// record the replay — the two traces are identical (a fixed point), and
/// the replayed run reproduces the recorded run's observable trajectory.
#[test]
fn record_then_replay_is_a_fixed_point() {
    let mut cfg = two_region_cfg(0.25);
    cfg.t_max = 12;
    let p1 = tmp_path("fixed_point_1.json");
    let p2 = tmp_path("fixed_point_2.json");

    let original = Scenario::from_config(cfg.clone())
        .churn(ChurnModel::Composed {
            layers: vec![
                markov(),
                ChurnModel::FaultScript {
                    events: vec![FaultEvent::RegionBlackout {
                        region: 1,
                        from_round: 4,
                        until_round: 6,
                    }],
                },
            ],
        })
        .record_fates(&p1)
        .run()
        .unwrap();
    let trace1 = FateTrace::load(&p1).unwrap();
    assert_eq!(trace1.n_rounds(), 12);

    let replayed = Scenario::from_config(cfg)
        .replay_fates(&p1)
        .record_fates(&p2)
        .run()
        .unwrap();
    let trace2 = FateTrace::load(&p2).unwrap();
    assert_eq!(trace1, trace2, "replay is not a fixed point");

    // The replayed world reproduces every observable of the original run.
    // `avail` is compared by its replay semantics: the original reports
    // the churned fleet's mean no-abort probability, the replay reports
    // the *realized* availability of the forced fates — so the replayed
    // value must equal alive/selected exactly.
    assert_eq!(original.rounds.len(), replayed.rounds.len());
    for (a, b) in original.rounds.iter().zip(replayed.rounds.iter()) {
        assert_eq!(a.selected, b.selected, "round {}", a.t);
        assert_eq!(a.alive, b.alive, "round {}", a.t);
        assert_eq!(a.submissions, b.submissions, "round {}", a.t);
        for r in 0..b.avail.len() {
            if b.selected[r] == 0 {
                assert!(b.avail[r].is_nan(), "round {} region {r}", a.t);
            } else {
                let realized = b.alive[r] as f64 / b.selected[r] as f64;
                assert_eq!(
                    b.avail[r].to_bits(),
                    realized.to_bits(),
                    "round {} region {r}",
                    a.t
                );
            }
        }
        assert_eq!(a.round_len.to_bits(), b.round_len.to_bits(), "round {}", a.t);
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "round {}", a.t);
        assert_eq!(
            a.cum_energy_j.to_bits(),
            b.cum_energy_j.to_bits(),
            "round {}",
            a.t
        );
    }
    let _ = std::fs::remove_file(&p1);
    let _ = std::fs::remove_file(&p2);
}

/// Hand-written traces drive the world verbatim: a trace scripting one
/// round of total silence produces exactly one deadline-bound round with
/// zero submissions.
#[test]
fn handwritten_trace_scripts_a_round_of_silence() {
    let mut cfg = two_region_cfg(0.0);
    cfg.protocol = ProtocolKind::FedAvg;
    cfg.t_max = 4;
    let mut trace = FateTrace::new();
    for t in 1..=4usize {
        for k in 0..cfg.n_clients {
            let dropped = t == 2;
            trace.insert(
                t,
                k,
                FateRecord {
                    region: if k < 20 { 0 } else { 1 },
                    dropped,
                    completion: if dropped { f64::INFINITY } else { 50.0 },
                },
            );
        }
    }
    let path = tmp_path("handwritten.json");
    trace.save(&path).unwrap();

    let result = Scenario::from_config(cfg).replay_fates(&path).run().unwrap();
    for row in &result.rounds {
        let subs: usize = row.submissions.iter().sum();
        let sel: usize = row.selected.iter().sum();
        if row.t == 2 {
            assert_eq!(subs, 0, "scripted silence leaked submissions");
            assert!(row.deadline_hit);
        } else {
            assert_eq!(subs, sel, "round {}", row.t);
            assert!(!row.deadline_hit);
            // Every scripted completion is 50 s; FedAvg waits for all.
            assert!((row.round_len - 50.0).abs() < 1e-9, "round {}", row.t);
        }
    }
    let _ = std::fs::remove_file(&path);
}

/// A selected client the trace does not list is treated as unavailable:
/// an empty trace silences the entire run.
#[test]
fn empty_trace_means_everyone_is_down() {
    let mut cfg = two_region_cfg(0.0);
    cfg.t_max = 3;
    let path = tmp_path("empty.json");
    FateTrace::new().save(&path).unwrap();
    let result = Scenario::from_config(cfg).replay_fates(&path).run().unwrap();
    for row in &result.rounds {
        assert_eq!(row.submissions.iter().sum::<usize>(), 0);
        assert!(row.deadline_hit);
    }
    let _ = std::fs::remove_file(&path);
}

/// Recording a resumed run would miss every round the snapshot restored
/// instead of executing — rejected loudly, never a silent partial trace.
#[test]
fn record_fates_on_resumed_run_is_rejected() {
    let err = Scenario::from_config(two_region_cfg(0.1))
        .resume_from("/nonexistent/snap.hflsnap")
        .record_fates(tmp_path("never_written.json"))
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("partial trace"), "{err}");
}

#[test]
fn replay_missing_file_is_a_loud_error() {
    let err = Scenario::from_config(two_region_cfg(0.1))
        .replay_fates("/nonexistent/trace.json")
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("/nonexistent/trace.json"), "{err}");
}

// ---------------------------------------------------------------------------
// Client mobility.
// ---------------------------------------------------------------------------

/// On the virtual clock, a migration event reroutes the client: the
/// per-region selection histogram shifts from [20, 20] to [19, 21] the
/// round the move lands.
#[test]
fn migration_reroutes_selection_on_the_virtual_clock() {
    let mut cfg = two_region_cfg(0.0);
    cfg.churn = ChurnModel::FaultScript {
        events: vec![FaultEvent::Migrate {
            client: 0,
            at_round: 2,
            to_region: 1,
        }],
    };
    let mut env = VirtualClockEnv::new(cfg).unwrap();
    let model = env.init_model();
    // Ask for more clients than any region holds: selection saturates at
    // the region's current size, which is exactly the membership count.
    let out1 = env
        .run_round(
            1,
            Selection::PerRegion(vec![25, 25]),
            Starts::Global(&model),
            CutoffPolicy::AllPerRegion,
        )
        .unwrap();
    assert_eq!(out1.selected, vec![20, 20]);
    let out2 = env
        .run_round(
            2,
            Selection::PerRegion(vec![25, 25]),
            Starts::Global(&model),
            CutoffPolicy::AllPerRegion,
        )
        .unwrap();
    assert_eq!(out2.selected, vec![19, 21], "migration did not reroute");
}

/// The live fabric binds client threads to edge channels at spawn, so
/// migration scenarios are rejected loudly there.
#[test]
fn migration_is_rejected_on_the_live_backend() {
    let mut cfg = two_region_cfg(0.1);
    cfg.t_max = 2;
    let err = Scenario::from_config(cfg)
        .churn(ChurnModel::FaultScript {
            events: vec![FaultEvent::Migrate {
                client: 3,
                at_round: 1,
                to_region: 1,
            }],
        })
        .backend(Backend::Live)
        .time_scale(1e-3)
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("live backend"), "{err}");
    assert!(err.contains("virtual clock"), "{err}");
}
