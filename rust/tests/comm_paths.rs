//! End-to-end coverage of the communication subsystem (`hybridfl::comm`)
//! through the public `Scenario` surface, on both backends:
//!
//! * the dense default is byte-identical to an explicitly-configured
//!   `dense` codec (sim and live) — the no-regression guarantee for every
//!   pre-codec seeded run;
//! * compressed codecs run end-to-end and actually cut the bytes moved,
//!   with the sim and live backends agreeing on the per-round byte
//!   accounting;
//! * relay-assisted upload shortens straggler-bound (wait-for-all)
//!   rounds over a bandwidth-heterogeneous fleet;
//! * a `topk:0.05+ef` run checkpoints and resumes byte-identically (the
//!   per-client error-feedback residuals ride in the snapshot);
//! * the live backend rejects `+ef` up front (client threads are
//!   stateless between rounds).

use hybridfl::comm::CommConfig;
use hybridfl::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind};
use hybridfl::scenario::{Backend, Scenario};
use hybridfl::sim::test_support::hetero_two_region_cfg;
use hybridfl::sim::RunResult;
use hybridfl::snapshot::run_result_bytes;

fn sim_cfg(t_max: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = hetero_two_region_cfg(0.2, 0.4);
    cfg.t_max = t_max;
    cfg.seed = seed;
    cfg
}

fn live_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = ProtocolKind::HybridFl;
    cfg.n_clients = 20;
    cfg.n_edges = 2;
    cfg.dataset_size = 800;
    cfg.eval_size = 50;
    cfg.dropout = Dist::new(0.25, 0.02);
    cfg.t_max = 4;
    cfg.seed = seed;
    cfg
}

fn run_sim(cfg: ExperimentConfig, spec: Option<&str>) -> RunResult {
    let mut sc = Scenario::from_config(cfg);
    if let Some(spec) = spec {
        sc = sc.comm(CommConfig::parse_spec(spec).unwrap());
    }
    sc.run().unwrap()
}

fn run_live(cfg: ExperimentConfig, spec: Option<&str>) -> RunResult {
    let mut sc = Scenario::from_config(cfg)
        .backend(Backend::Live)
        .time_scale(5e-3);
    if let Some(spec) = spec {
        sc = sc.comm(CommConfig::parse_spec(spec).unwrap());
    }
    sc.run().unwrap()
}

fn total_bytes(result: &RunResult) -> u64 {
    result.rounds.iter().map(|r| r.bytes_moved).sum()
}

/// `comm = dense` (explicit) must be *byte*-identical to the untouched
/// default config on both backends — the codec layer may not perturb a
/// single draw, completion time, or energy term of pre-codec runs.
#[test]
fn dense_default_is_byte_identical_to_explicit_dense_on_both_backends() {
    let default_sim = run_sim(sim_cfg(8, 77), None);
    let explicit_sim = run_sim(sim_cfg(8, 77), Some("dense"));
    assert_eq!(
        run_result_bytes(&default_sim),
        run_result_bytes(&explicit_sim),
        "sim: explicit dense diverged from the default config"
    );
    assert!(total_bytes(&default_sim) > 0);

    // Live: the thread fabric's wall clock is not bit-reproducible at the
    // folding margin, so pin the deterministic observables (the same set
    // the sim/live agreement suite pins) rather than raw result bytes.
    let default_live = run_live(live_cfg(77), None);
    let explicit_live = run_live(live_cfg(77), Some("dense"));
    assert_eq!(default_live.rounds.len(), explicit_live.rounds.len());
    for (a, b) in default_live.rounds.iter().zip(explicit_live.rounds.iter()) {
        assert_eq!(a.selected, b.selected, "live selection diverged at {}", a.t);
        assert_eq!(
            a.deadline_hit, b.deadline_hit,
            "live quota behavior diverged at {}",
            a.t
        );
        assert_eq!(
            a.bytes_moved, b.bytes_moved,
            "live byte accounting diverged at {}",
            a.t
        );
    }
}

/// Every compressed codec completes a full run and moves fewer bytes
/// than dense; `topk:0.05+ef` cuts them by at least 4× (structurally:
/// 8 B × k kept coordinates vs 4 B × n).
#[test]
fn compressed_codecs_run_end_to_end_and_cut_bytes() {
    let dense = run_sim(sim_cfg(10, 5), Some("dense"));
    let dense_bytes = total_bytes(&dense);
    assert!(dense_bytes > 0);

    for spec in ["f16", "i8", "topk:0.05", "topk:0.05+ef"] {
        let result = run_sim(sim_cfg(10, 5), Some(spec));
        assert_eq!(result.rounds.len(), 10, "{spec}: run truncated");
        assert!(
            result.summary.best_accuracy > 0.0,
            "{spec}: training never progressed"
        );
        let bytes = total_bytes(&result);
        assert!(
            bytes > 0 && bytes < dense_bytes,
            "{spec}: moved {bytes} bytes vs dense {dense_bytes}"
        );
        if spec.starts_with("topk") {
            assert!(
                dense_bytes as f64 / bytes as f64 >= 4.0,
                "{spec}: only {dense_bytes}/{bytes} byte reduction"
            );
        }
    }
}

/// The live fabric ships real encoded frames, and both backends compute
/// `bytes_moved` from the same ground truth: folded submissions × the
/// codec's per-update wire bytes against the config-level model size.
/// (Exact sim↔live equality of the folded *set* is not pinned — the
/// thread fabric's folding margin is wall-clock — but the accounting
/// formula must hold on every row of both backends.)
#[test]
fn sim_and_live_agree_on_byte_accounting() {
    use hybridfl::timing::TimingModel;
    let cfg = live_cfg(42);
    let comm = CommConfig::parse_spec("i8").unwrap();
    let wire = comm.codec.wire_bytes(TimingModel::new(&cfg).n_model_values());

    let sim = run_sim(cfg.clone(), Some("i8"));
    let live = run_live(cfg, Some("i8"));
    assert_eq!(sim.rounds.len(), live.rounds.len());
    for row in sim.rounds.iter().chain(live.rounds.iter()) {
        let folded: usize = row.submissions.iter().sum();
        assert_eq!(
            row.bytes_moved,
            folded as u64 * wire,
            "round {}: bytes_moved must equal folded submissions x wire bytes",
            row.t
        );
        assert!(row.bytes_moved > 0, "round {} moved no bytes", row.t);
    }
}

/// Relay-assisted upload: on a wait-for-all protocol (FedAvg's
/// `AllSelected` cut — the round ends with its slowest survivor) over a
/// fleet with strongly heterogeneous bandwidths, handing the weakest
/// quantile's uploads to fast relays must shorten the average round.
#[test]
fn relay_shortens_straggler_bound_rounds() {
    let cfg = || {
        let mut cfg = sim_cfg(12, 9);
        cfg.protocol = ProtocolKind::FedAvg;
        cfg.bw_mhz = Dist::new(0.5, 0.3);
        cfg
    };
    let no_relay = run_sim(cfg(), Some("dense"));
    let with_relay = run_sim(cfg(), Some("relay:0.25"));
    assert!(
        with_relay.summary.avg_round_len < no_relay.summary.avg_round_len,
        "relay rounds averaged {:.2}s vs {:.2}s without",
        with_relay.summary.avg_round_len,
        no_relay.summary.avg_round_len
    );
}

/// Checkpoint/resume through the stateful codec: the error-feedback
/// residuals are part of the snapshot, so a `topk:0.05+ef` run resumed
/// mid-stream must be byte-identical to the uninterrupted run.
#[test]
fn topk_ef_resume_is_byte_identical_through_checkpoints() {
    let spec = "topk:0.05+ef";
    let full = run_sim(sim_cfg(8, 21), Some(spec));
    let full_bytes = run_result_bytes(&full);

    let dir = std::env::temp_dir().join("hybridfl_comm_paths_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let checkpointed = Scenario::from_config(sim_cfg(8, 21))
        .comm(CommConfig::parse_spec(spec).unwrap())
        .checkpoint_dir(&dir)
        .checkpoint_every(3)
        .run()
        .unwrap();
    assert_eq!(full_bytes, run_result_bytes(&checkpointed));

    let resumed = Scenario::from_config(sim_cfg(8, 21))
        .comm(CommConfig::parse_spec(spec).unwrap())
        .resume_from(dir.join("snapshot_round_000003.hflsnap"))
        .run()
        .unwrap();
    assert_eq!(
        full_bytes,
        run_result_bytes(&resumed),
        "resumed +ef run diverged from the uninterrupted run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `+ef` needs per-client state that survives rounds; live client threads
/// are stateless between Train messages, so the live backend must refuse
/// the configuration up front rather than silently dropping residuals.
#[test]
fn live_backend_rejects_error_feedback() {
    let err = Scenario::from_config(live_cfg(3))
        .comm(CommConfig::parse_spec("topk:0.05+ef").unwrap())
        .backend(Backend::Live)
        .time_scale(5e-3)
        .run()
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("live backend"),
        "error should name the live backend: {msg}"
    );
}
