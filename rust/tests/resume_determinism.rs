//! The checkpoint/replay subsystem's acceptance bar: a seeded run
//! checkpointed at round k, with all process state discarded, must resume
//! to a `RunResult` **byte-identical** to the uninterrupted run's — on
//! both backends, for every protocol, with either codec.
//!
//! "Byte-identical" is literal: `snapshot::run_result_bytes` serializes a
//! `RunResult` with raw IEEE-754 bits, and the encodings are compared as
//! byte vectors.

use std::path::{Path, PathBuf};

use hybridfl::churn::ChurnModel;
use hybridfl::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind};
use hybridfl::scenario::{Backend, Scenario};
use hybridfl::snapshot::{run_result_bytes, CodecKind};

fn mock_cfg(protocol: ProtocolKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = protocol;
    cfg.n_clients = 20;
    cfg.n_edges = 2;
    cfg.dataset_size = 400;
    cfg.eval_size = 50;
    cfg.t_max = 9;
    cfg.dropout = Dist::new(0.25, 0.05);
    cfg.seed = 11;
    cfg
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn snap_file(dir: &Path, round: usize, ext: &str) -> PathBuf {
    dir.join(format!("snapshot_round_{round:06}.{ext}"))
}

/// Sim backend, all three protocols: uninterrupted vs checkpointed vs
/// resumed-from-k must all be byte-identical. HierFAVG runs with κ₂ = 3
/// so the resume point (round 3, a cloud round) and the resumed segment
/// both cross cloud-aggregation boundaries.
#[test]
fn sim_resume_is_byte_identical_for_every_protocol() {
    for protocol in ProtocolKind::ALL {
        let mut cfg = mock_cfg(protocol);
        cfg.hier_kappa2 = 3;
        let full = Scenario::from_config(cfg.clone()).run().unwrap();
        let full_bytes = run_result_bytes(&full);

        let dir = fresh_dir(&format!("hybridfl_resume_sim_{}", protocol.as_str()));
        let checkpointed = Scenario::from_config(cfg.clone())
            .checkpoint_dir(&dir)
            .checkpoint_every(3)
            .run()
            .unwrap();
        // Checkpointing itself must not perturb the run.
        assert_eq!(
            full_bytes,
            run_result_bytes(&checkpointed),
            "{protocol:?}: checkpointing changed the run"
        );

        // "Process state discarded": a brand-new Scenario (fresh env,
        // fresh protocol, fresh driver) resumes from the on-disk bytes.
        for round in [3usize, 6] {
            let resumed = Scenario::from_config(cfg.clone())
                .resume_from(snap_file(&dir, round, "hflsnap"))
                .run()
                .unwrap();
            assert_eq!(
                full_bytes,
                run_result_bytes(&resumed),
                "{protocol:?}: resume from round {round} diverged"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Churning worlds meet the same bar: a run under stateful reliability
/// dynamics (Markov on/off flags, battery charge levels), checkpointed
/// and resumed with all process state discarded, reproduces the
/// uninterrupted run byte for byte — the snapshot carries the churn
/// state, so the resumed world continues the exact reliability
/// trajectory.
#[test]
fn sim_resume_under_stateful_churn_is_byte_identical() {
    let churns = [
        ChurnModel::MarkovOnOff {
            p_fail: 0.3,
            p_recover: 0.35,
            down_dropout: 0.97,
            region_scale: Vec::new(),
        },
        ChurnModel::BatteryDrain {
            drain_per_round: 0.3,
            recharge_p: 0.4,
            depleted_dropout: 0.99,
        },
    ];
    for churn in churns {
        let mut cfg = mock_cfg(ProtocolKind::HybridFl);
        cfg.churn = churn.clone();
        let full = Scenario::from_config(cfg.clone()).run().unwrap();
        let full_bytes = run_result_bytes(&full);

        let dir = fresh_dir(&format!("hybridfl_resume_churn_{}", churn.kind_str()));
        let checkpointed = Scenario::from_config(cfg.clone())
            .checkpoint_dir(&dir)
            .checkpoint_every(3)
            .run()
            .unwrap();
        assert_eq!(
            full_bytes,
            run_result_bytes(&checkpointed),
            "{}: checkpointing changed the run",
            churn.kind_str()
        );
        for round in [3usize, 6] {
            let resumed = Scenario::from_config(cfg.clone())
                .resume_from(snap_file(&dir, round, "hflsnap"))
                .run()
                .unwrap();
            assert_eq!(
                full_bytes,
                run_result_bytes(&resumed),
                "{}: resume from round {round} diverged",
                churn.kind_str()
            );
        }
        // The JSON debug codec meets the same bar for churn state.
        let json_dir = fresh_dir(&format!("hybridfl_resume_churn_json_{}", churn.kind_str()));
        Scenario::from_config(cfg.clone())
            .checkpoint_dir(&json_dir)
            .checkpoint_every(4)
            .snapshot_codec(CodecKind::Json)
            .run()
            .unwrap();
        let resumed = Scenario::from_config(cfg)
            .resume_from(snap_file(&json_dir, 4, "json"))
            .run()
            .unwrap();
        assert_eq!(full_bytes, run_result_bytes(&resumed), "{}", churn.kind_str());
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&json_dir);
    }
}

/// Same bar on the live threaded backend under MarkovOnOff churn (the
/// jitter-safe regime of `live_resume_is_byte_identical`).
#[test]
fn live_resume_under_markov_churn_is_byte_identical() {
    let mut cfg = mock_cfg(ProtocolKind::HybridFl);
    cfg.n_clients = 12;
    cfg.dataset_size = 360;
    cfg.t_max = 3;
    cfg.seed = 42;
    cfg.churn = ChurnModel::MarkovOnOff {
        p_fail: 0.3,
        p_recover: 0.35,
        down_dropout: 0.97,
        region_scale: Vec::new(),
    };
    let scale = 1e-2;

    let full = Scenario::from_config(cfg.clone())
        .backend(Backend::Live)
        .time_scale(scale)
        .run()
        .unwrap();
    let full_bytes = run_result_bytes(&full);

    let dir = fresh_dir("hybridfl_resume_live_churn");
    let checkpointed = Scenario::from_config(cfg.clone())
        .backend(Backend::Live)
        .time_scale(scale)
        .checkpoint_dir(&dir)
        .run()
        .unwrap();
    assert_eq!(full_bytes, run_result_bytes(&checkpointed));

    let resumed = Scenario::from_config(cfg)
        .backend(Backend::Live)
        .time_scale(scale)
        .resume_from(snap_file(&dir, 2, "hflsnap"))
        .run()
        .unwrap();
    assert_eq!(full_bytes, run_result_bytes(&resumed));
    let _ = std::fs::remove_dir_all(&dir);
}

/// The JSON debug codec meets the same bar on the sim backend.
#[test]
fn sim_resume_via_json_codec_is_byte_identical() {
    let cfg = mock_cfg(ProtocolKind::HybridFl);
    let full = Scenario::from_config(cfg.clone()).run().unwrap();

    let dir = fresh_dir("hybridfl_resume_sim_json");
    Scenario::from_config(cfg.clone())
        .checkpoint_dir(&dir)
        .checkpoint_every(4)
        .snapshot_codec(CodecKind::Json)
        .run()
        .unwrap();
    let resumed = Scenario::from_config(cfg)
        .resume_from(snap_file(&dir, 4, "json"))
        .run()
        .unwrap();
    assert_eq!(run_result_bytes(&full), run_result_bytes(&resumed));
    let _ = std::fs::remove_dir_all(&dir);
}

/// The live threaded backend: same world enacted by real threads.
/// Fold order at an edge is arrival order, so byte-identity across runs
/// needs every within-region completion-time gap to dwarf scheduler
/// jitter: a small fleet (few near-coincident completions) at a very
/// generous time scale (1e-2 — a 1-virtual-second gap is 10 ms of wall
/// clock, two orders of magnitude above sleep-wakeup jitter). This is
/// the same regime `tests/live_runtime.rs` pins for sim/live parity,
/// widened further.
#[test]
fn live_resume_is_byte_identical() {
    let mut cfg = mock_cfg(ProtocolKind::HybridFl);
    cfg.n_clients = 12;
    cfg.dataset_size = 360;
    cfg.t_max = 3;
    cfg.seed = 42;
    let scale = 1e-2;

    let full = Scenario::from_config(cfg.clone())
        .backend(Backend::Live)
        .time_scale(scale)
        .run()
        .unwrap();
    let full_bytes = run_result_bytes(&full);

    let dir = fresh_dir("hybridfl_resume_live");
    let checkpointed = Scenario::from_config(cfg.clone())
        .backend(Backend::Live)
        .time_scale(scale)
        .checkpoint_dir(&dir)
        .run()
        .unwrap();
    assert_eq!(full_bytes, run_result_bytes(&checkpointed));

    let resumed = Scenario::from_config(cfg)
        .backend(Backend::Live)
        .time_scale(scale)
        .resume_from(snap_file(&dir, 2, "hflsnap"))
        .run()
        .unwrap();
    assert_eq!(full_bytes, run_result_bytes(&resumed));
    let _ = std::fs::remove_dir_all(&dir);
}

/// A snapshot refuses to cross backends: the trace would silently mix
/// wall-clock and virtual-clock rounds.
#[test]
fn resume_rejects_backend_mismatch() {
    let cfg = mock_cfg(ProtocolKind::HybridFl);
    let dir = fresh_dir("hybridfl_resume_backend_mismatch");
    Scenario::from_config(cfg.clone())
        .checkpoint_dir(&dir)
        .checkpoint_every(3)
        .run()
        .unwrap();
    let err = Scenario::from_config(cfg)
        .backend(Backend::Live)
        .time_scale(5e-3)
        .resume_from(snap_file(&dir, 3, "hflsnap"))
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("backend"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite bugfix: resuming with a diverged config is a hard error that
/// names the diverging fields — never an inconsistent hybrid run.
#[test]
fn resume_rejects_config_divergence_naming_fields() {
    let cfg = mock_cfg(ProtocolKind::HybridFl);
    let dir = fresh_dir("hybridfl_resume_cfg_mismatch");
    Scenario::from_config(cfg.clone())
        .checkpoint_dir(&dir)
        .checkpoint_every(3)
        .run()
        .unwrap();

    let mut diverged = cfg.clone();
    diverged.c_fraction = 0.45;
    diverged.dropout.mean = 0.6;
    let err = Scenario::from_config(diverged)
        .resume_from(snap_file(&dir, 3, "hflsnap"))
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("c_fraction"), "{err}");
    assert!(err.contains("dropout.mean"), "{err}");

    // A different protocol is also a config divergence (and is caught
    // before any protocol state could be misapplied).
    let mut other_proto = cfg;
    other_proto.protocol = ProtocolKind::FedAvg;
    let err = Scenario::from_config(other_proto)
        .resume_from(snap_file(&dir, 3, "hflsnap"))
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("protocol"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Defense in depth under the fingerprint: a protocol refuses state of
/// the wrong kind even when handed to it directly.
#[test]
fn protocol_restore_rejects_wrong_kind() {
    use hybridfl::env::{FlEnvironment as _, VirtualClockEnv};
    use hybridfl::protocols::{FedAvg, HierFavg, Protocol as _};

    let cfg = mock_cfg(ProtocolKind::FedAvg);
    let env = VirtualClockEnv::new(cfg.clone()).unwrap();
    let fedavg = FedAvg::new(env.init_model());
    let state = fedavg.snapshot_state();
    let mut hier = HierFavg::new(&cfg, env.n_regions(), env.init_model());
    let err = hier.restore_state(state).unwrap_err().to_string();
    assert!(err.contains("fedavg"), "{err}");
    assert!(err.contains("hierfavg"), "{err}");
}

/// Resuming from the final round's snapshot runs zero further rounds and
/// still reproduces the uninterrupted result exactly.
#[test]
fn resume_at_final_round_is_a_noop_replay() {
    let cfg = mock_cfg(ProtocolKind::FedAvg);
    let full = Scenario::from_config(cfg.clone()).run().unwrap();
    let dir = fresh_dir("hybridfl_resume_final");
    Scenario::from_config(cfg.clone())
        .checkpoint_dir(&dir)
        .run()
        .unwrap();
    let resumed = Scenario::from_config(cfg.clone())
        .resume_from(snap_file(&dir, cfg.t_max, "hflsnap"))
        .run()
        .unwrap();
    assert_eq!(run_result_bytes(&full), run_result_bytes(&resumed));
    let _ = std::fs::remove_dir_all(&dir);
}
