//! End-to-end integration over the real PJRT runtime: full FL runs with
//! actual AOT-compiled JAX/Pallas training, asserting the learning
//! outcomes the paper's evaluation relies on. Skipped when `make
//! artifacts` has not run.

use hybridfl::config::{ProtocolKind, TaskKind};
use hybridfl::sim::test_support::e2e_cfg;
use hybridfl::sim::FlRun;

fn have_artifacts() -> bool {
    hybridfl::runtime::pjrt_available()
}

#[test]
fn aerofoil_all_protocols_learn() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    for proto in ProtocolKind::ALL {
        let mut cfg = e2e_cfg(TaskKind::Aerofoil, 120);
        cfg.protocol = proto;
        let result = FlRun::new(cfg).unwrap().run().unwrap();
        assert!(
            result.summary.best_accuracy > 0.45,
            "{}: best acc {}",
            proto.as_str(),
            result.summary.best_accuracy
        );
        // Loss must have dropped substantially from the untrained model.
        let first = result.rounds.first().unwrap().eval_loss;
        let last_best = result
            .rounds
            .iter()
            .map(|r| r.eval_loss)
            .fold(f64::MAX, f64::min);
        assert!(
            last_best < first * 0.6,
            "{}: loss {first} -> {last_best}",
            proto.as_str()
        );
    }
}

#[test]
fn mnist_hybridfl_reaches_target_quickly() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = e2e_cfg(TaskKind::Mnist, 40);
    cfg.target_accuracy = Some(0.9);
    let result = FlRun::new(cfg).unwrap().run().unwrap();
    assert!(
        result.summary.rounds_to_target.is_some(),
        "LeNet should cross 0.9 within 40 rounds; best {}",
        result.summary.best_accuracy
    );
}

/// The paper's headline comparison, end to end at reduced scale: under
/// heavy drop-out HybridFL reaches the accuracy target in less virtual
/// time than both baselines (the "up to 12x" claim, shape-checked).
#[test]
fn hybridfl_fastest_to_target_under_heavy_dropout() {
    if !have_artifacts() {
        return;
    }
    let mut times = std::collections::HashMap::new();
    for proto in ProtocolKind::ALL {
        let mut cfg = e2e_cfg(TaskKind::Aerofoil, 500);
        cfg.protocol = proto;
        cfg.dropout.mean = 0.6;
        cfg.c_fraction = 0.1;
        cfg.target_accuracy = Some(0.65);
        let result = FlRun::new(cfg).unwrap().run().unwrap();
        let t = result.summary.time_to_target.unwrap_or(f64::MAX);
        times.insert(proto.as_str(), t);
    }
    let hybrid = times["hybridfl"];
    assert!(
        hybrid < times["fedavg"] && hybrid < times["hierfavg"],
        "time-to-0.65 under E[dr]=0.6: {times:?}"
    );
}

#[test]
fn run_is_deterministic_with_real_training() {
    if !have_artifacts() {
        return;
    }
    let cfg = e2e_cfg(TaskKind::Aerofoil, 15);
    let a = FlRun::new(cfg.clone()).unwrap().run().unwrap();
    let b = FlRun::new(cfg).unwrap().run().unwrap();
    // XLA CPU math is deterministic; the whole pipeline must be too.
    for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
        assert_eq!(ra.accuracy, rb.accuracy);
        assert_eq!(ra.submissions, rb.submissions);
    }
}

/// Regional (literal eq. 17) vs Fresh cache ablation: the EMA variant must
/// trail per-round on identical seeds — the deviation DESIGN.md documents.
#[test]
fn cache_ablation_regional_trails_fresh() {
    if !have_artifacts() {
        return;
    }
    let mut accs = Vec::new();
    for mode in [
        hybridfl::config::CacheMode::Fresh,
        hybridfl::config::CacheMode::Regional,
    ] {
        let mut cfg = e2e_cfg(TaskKind::Aerofoil, 150);
        cfg.cache_mode = mode;
        let result = FlRun::new(cfg).unwrap().run().unwrap();
        accs.push(result.summary.best_accuracy);
    }
    assert!(
        accs[0] > accs[1],
        "fresh {} should beat regional {} per-round",
        accs[0],
        accs[1]
    );
}
