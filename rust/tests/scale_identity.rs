//! The fleet-scale data plane's acceptance bar: every fast path the
//! virtual clock grew for million-client rounds — lazy fate/availability
//! sweeps, the parallel per-region fold, Arc-shared residual snapshots —
//! must be **byte-identical** to its slow reference path on seeded runs.
//!
//! "Byte-identical" is literal, as in `resume_determinism`:
//! `snapshot::run_result_bytes` serializes a `RunResult` with raw
//! IEEE-754 bits and the encodings are compared as byte vectors. The
//! reference paths are reachable through the `Scenario` debug knobs
//! (`serial_fold`, `eager_sweeps`), so these tests drive the public API
//! end to end.

use hybridfl::churn::{ChurnModel, FaultEvent};
use hybridfl::comm::CommConfig;
use hybridfl::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind};
use hybridfl::scenario::Scenario;
use hybridfl::snapshot::run_result_bytes;

/// A fleet big enough that every round clears the parallel fold's
/// survivor threshold on all three protocols, with real drop-outs so
/// region partitions are non-trivial.
fn scale_cfg(protocol: ProtocolKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = protocol;
    cfg.n_clients = 48;
    cfg.n_edges = 3;
    cfg.dataset_size = 480;
    cfg.eval_size = 50;
    cfg.t_max = 6;
    cfg.c_fraction = 0.4;
    cfg.dropout = Dist::new(0.15, 0.05);
    cfg.seed = 2024;
    cfg
}

/// A pure fault script: drives the boundary-scheduled O(dirty-region)
/// reset path (no stochastic layer forces a full-fleet rewrite).
fn script_only() -> ChurnModel {
    ChurnModel::FaultScript {
        events: vec![
            FaultEvent::RegionBlackout {
                region: 1,
                from_round: 2,
                until_round: 4,
            },
            FaultEvent::DropoutShift {
                region: Some(0),
                at_round: 3,
                delta: 0.2,
            },
        ],
    }
}

/// A churn composition that exercises both the boundary-scheduled script
/// path and the every-round stochastic (full-rewrite) path.
fn churny() -> ChurnModel {
    ChurnModel::Composed {
        layers: vec![
            ChurnModel::MarkovOnOff {
                p_fail: 0.25,
                p_recover: 0.4,
                down_dropout: 0.95,
                region_scale: Vec::new(),
            },
            script_only(),
        ],
    }
}

/// The parallel per-region fold reproduces the serial streaming loop
/// byte for byte, for every protocol (each exercises a different
/// start-model / cutoff shape through the fold).
#[test]
fn parallel_fold_matches_serial_for_every_protocol() {
    for protocol in ProtocolKind::ALL {
        let cfg = scale_cfg(protocol);
        let parallel = Scenario::from_config(cfg.clone()).run().unwrap();
        let serial = Scenario::from_config(cfg).serial_fold(true).run().unwrap();
        assert_eq!(
            run_result_bytes(&parallel),
            run_result_bytes(&serial),
            "{protocol:?}: parallel fold diverged from the serial reference"
        );
    }
}

/// Same bar under a compressed (non-error-feedback) codec: the parallel
/// workers frame and fold encoded updates exactly as the serial loop
/// does, including the per-client comm substream draws.
#[test]
fn parallel_fold_matches_serial_under_compression() {
    let mut cfg = scale_cfg(ProtocolKind::HybridFl);
    cfg.comm = CommConfig::parse_spec("topk:0.25").unwrap();
    let parallel = Scenario::from_config(cfg.clone()).run().unwrap();
    let serial = Scenario::from_config(cfg).serial_fold(true).run().unwrap();
    assert_eq!(
        run_result_bytes(&parallel),
        run_result_bytes(&serial),
        "compressed parallel fold diverged from the serial reference"
    );
}

/// The incremental availability cache and the O(dirty) churn reset
/// reproduce the full-fleet recompute byte for byte across a churny run
/// — on every protocol, with the parallel fold active too (the knobs
/// compose).
#[test]
fn lazy_sweeps_match_eager_reference_under_churn() {
    for (protocol, churn) in ProtocolKind::ALL
        .into_iter()
        .flat_map(|p| [(p, script_only()), (p, churny())])
    {
        let mut cfg = scale_cfg(protocol);
        cfg.churn = churn;
        let lazy = Scenario::from_config(cfg.clone()).run().unwrap();
        let eager = Scenario::from_config(cfg.clone())
            .eager_sweeps(true)
            .run()
            .unwrap();
        assert_eq!(
            run_result_bytes(&lazy),
            run_result_bytes(&eager),
            "{protocol:?}: lazy sweeps diverged from the eager reference"
        );
        // And the full cross: serial + eager (the pre-refactor execution
        // shape) against the default fast path.
        let reference = Scenario::from_config(cfg)
            .serial_fold(true)
            .eager_sweeps(true)
            .run()
            .unwrap();
        assert_eq!(
            run_result_bytes(&lazy),
            run_result_bytes(&reference),
            "{protocol:?}: fast path diverged from the serial+eager reference"
        );
    }
}

/// Snapshots are interchangeable across fold paths: a run checkpointed
/// under the serial fold, resumed with the default (parallel-eligible)
/// path, lands byte-identical to the uninterrupted default run — the
/// knobs are execution strategy, not world state.
#[test]
fn resume_crosses_fold_paths_byte_identically() {
    let mut cfg = scale_cfg(ProtocolKind::HybridFl);
    cfg.churn = churny();
    let full = Scenario::from_config(cfg.clone()).run().unwrap();

    let dir = std::env::temp_dir().join("hybridfl_scale_identity_resume");
    let _ = std::fs::remove_dir_all(&dir);
    Scenario::from_config(cfg.clone())
        .serial_fold(true)
        .eager_sweeps(true)
        .checkpoint_dir(&dir)
        .checkpoint_every(3)
        .run()
        .unwrap();
    let resumed = Scenario::from_config(cfg)
        .resume_from(dir.join("snapshot_round_000003.hflsnap"))
        .run()
        .unwrap();
    assert_eq!(
        run_result_bytes(&full),
        run_result_bytes(&resumed),
        "serial-checkpointed run resumed on the parallel path diverged"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
