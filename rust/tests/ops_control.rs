//! Acceptance tests for the operations control plane (`hybridfl::ops`):
//!
//! * a live `/metrics` scrape of a paused 10k-client sim reports exactly
//!   the gauges of the round the run is paused at (values compared
//!   verbatim — f64 `Display` is shortest-round-trip, so the scrape text
//!   must match `to_string()` of the trace fields bit for bit);
//! * `pause → checkpoint-now → resume` over the control socket is
//!   byte-identical to the uninterrupted run on both backends, and the
//!   on-demand snapshot is itself a valid resume point;
//! * a fault injected over the control socket replays byte-identically
//!   to the same event pre-scripted as a `ChurnModel::FaultScript`;
//! * the scrape's histogram families (round length, per-region
//!   submission latency, per-phase duration) hold `_sum`/`_count`
//!   value-exact against the round trace, and neither histograms nor
//!   `--trace-out` Chrome-trace export perturb the run on either
//!   backend;
//! * a configured `--ops-token` gates both the scrape (`?token=`) and
//!   control sessions (`auth TOKEN` first line).
//!
//! Sequencing is deterministic without polling: commands sent before the
//! run starts queue in the server's channel and are serviced at the first
//! round boundary, and a control reply certifies the command's *effect*
//! (the driver executed it), not just receipt. So `pause` sent pre-run
//! always lands at the round-1 boundary, and everything after it happens
//! against a world frozen at round 1.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use hybridfl::churn::{ChurnModel, FaultEvent};
use hybridfl::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind};
use hybridfl::env::FlEnvironment;
use hybridfl::ops::OpsServer;
use hybridfl::scenario::{Backend, Scenario};
use hybridfl::snapshot::run_result_bytes;

fn mock_cfg(protocol: ProtocolKind) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = protocol;
    cfg.n_clients = 20;
    cfg.n_edges = 2;
    cfg.dataset_size = 400;
    cfg.eval_size = 50;
    cfg.t_max = 9;
    cfg.dropout = Dist::new(0.25, 0.05);
    cfg.seed = 11;
    cfg
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A control-protocol client: one line out, one reply line back.
struct Control {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Control {
    fn connect(addr: SocketAddr) -> Control {
        let stream = TcpStream::connect(addr).unwrap();
        Control {
            reader: BufReader::new(stream.try_clone().unwrap()),
            writer: stream,
        }
    }

    /// Send a command without waiting for the reply (used to queue
    /// commands before the run starts).
    fn send(&mut self, line: &str) {
        writeln!(self.writer, "{line}").unwrap();
        self.writer.flush().unwrap();
    }

    /// Block until the next reply line arrives.
    fn recv(&mut self) -> String {
        let mut s = String::new();
        self.reader.read_line(&mut s).unwrap();
        s.trim_end().to_string()
    }

    fn cmd(&mut self, line: &str) -> String {
        self.send(line);
        self.recv()
    }
}

/// One HTTP GET against the ops listener; returns the response body.
fn http_get(addr: SocketAddr, path: &str) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: ops\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap(); // server closes after one response
    let body = raw.find("\r\n\r\n").expect("missing header terminator") + 4;
    raw[body..].to_string()
}

/// Scrape a paused 10k-client sim and hold every gauge to the round
/// trace, value-exact.
#[test]
fn live_scrape_matches_round_trace_at_10k_clients() {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = ProtocolKind::HybridFl;
    cfg.n_clients = 10_000;
    cfg.n_edges = 4;
    cfg.dataset_size = 60_000;
    cfg.eval_size = 50;
    cfg.c_fraction = 0.3;
    cfg.dropout = Dist::new(0.2, 0.05);
    cfg.t_max = 3;
    cfg.seed = 4242;

    // Protocol-visible region sizes — the selected-proportion denominators.
    let region_sizes: Vec<usize> = {
        let env = hybridfl::env::VirtualClockEnv::new(cfg.clone()).unwrap();
        (0..env.n_regions()).map(|r| env.region_size(r)).collect()
    };

    let mut server = OpsServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    // An unattached server already scrapes (round 0, no run_info).
    let idle = http_get(addr, "/metrics");
    assert!(idle.contains("hybridfl_round 0\n"), "{idle}");
    assert!(!idle.contains("hybridfl_run_info"), "{idle}");
    assert!(http_get(addr, "/other").contains("try /metrics"));

    let mut ctl = Control::connect(addr);
    ctl.send("pause"); // queued; lands at the round-1 boundary
    let run = {
        let sc = Scenario::from_config(cfg.clone());
        std::thread::spawn(move || sc.run_with_ops(server).unwrap())
    };
    assert_eq!(ctl.recv(), "ok paused");
    assert_eq!(ctl.cmd("status"), "ok round=1 paused=true");

    let text = http_get(addr, "/metrics");
    assert_eq!(ctl.cmd("resume"), "ok resumed");
    let result = run.join().unwrap();

    // The scrape happened frozen at the round-1 boundary: every gauge
    // must equal the corresponding round-1 trace field, textually.
    let row = &result.rounds[0];
    assert_eq!(row.t, 1);
    let mut expected = vec![
        "hybridfl_round 1\n".to_string(),
        "hybridfl_paused 1\n".to_string(),
        "hybridfl_finished 0\n".to_string(),
        format!("hybridfl_accuracy {}\n", row.accuracy),
        format!("hybridfl_best_accuracy {}\n", row.best_accuracy),
        format!("hybridfl_bytes_moved_total {}\n", row.bytes_moved),
        format!(
            "hybridfl_quota_rounds_total {}\n",
            u8::from(!row.deadline_hit)
        ),
        format!(
            "hybridfl_deadline_rounds_total {}\n",
            u8::from(row.deadline_hit)
        ),
        "hybridfl_run_info{backend=\"sim\",protocol=\"hybridfl\"} 1\n".to_string(),
    ];
    for (r, &avail) in row.avail.iter().enumerate() {
        expected.push(format!(
            "hybridfl_region_availability{{region=\"{r}\"}} {avail}\n"
        ));
    }
    for (r, (&sel, &size)) in row.selected.iter().zip(&region_sizes).enumerate() {
        expected.push(format!(
            "hybridfl_region_selected_proportion{{region=\"{r}\"}} {}\n",
            sel as f64 / size as f64
        ));
    }
    let slack = row.slack.as_ref().expect("HybridFL exposes slack telemetry");
    for (r, s) in slack.iter().enumerate() {
        expected.push(format!(
            "hybridfl_region_slack_theta{{region=\"{r}\"}} {}\n",
            s.theta
        ));
    }
    for needle in &expected {
        assert!(text.contains(needle), "missing {needle:?} in scrape:\n{text}");
    }
    // Process-level observables are present (values are scrape-time).
    assert!(text.contains("hybridfl_arena_models_peak "), "{text}");
    if hybridfl::benchkit::peak_rss_bytes().is_some() {
        assert!(text.contains("hybridfl_peak_rss_bytes "), "{text}");
    }

    // The ops endpoint never perturbs the run.
    let plain = Scenario::from_config(cfg).run().unwrap();
    assert_eq!(run_result_bytes(&plain), run_result_bytes(&result));
}

/// Drive `pause → checkpoint-now DIR → resume` over the control socket and
/// return the finished result plus the snapshot path the reply certified.
fn run_with_midflight_checkpoint(sc: Scenario, dir: &std::path::Path) -> (hybridfl::env::RunResult, PathBuf) {
    let server = OpsServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let mut ctl = Control::connect(addr);
    ctl.send("pause");
    let run = std::thread::spawn(move || sc.run_with_ops(server).unwrap());
    assert_eq!(ctl.recv(), "ok paused");
    let reply = ctl.cmd(&format!("checkpoint-now {}", dir.display()));
    let path = reply
        .strip_prefix("ok ")
        .unwrap_or_else(|| panic!("checkpoint-now failed: {reply}"));
    let path = PathBuf::from(path);
    assert!(path.is_file(), "certified path {} is not on disk", path.display());
    assert_eq!(ctl.cmd("resume"), "ok resumed");
    (run.join().unwrap(), path)
}

/// Sim backend: the pause → checkpoint-now → resume maneuver neither
/// perturbs the run nor writes a snapshot that would.
#[test]
fn sim_pause_checkpoint_resume_is_byte_identical() {
    let cfg = mock_cfg(ProtocolKind::HybridFl);
    let full = Scenario::from_config(cfg.clone()).run().unwrap();
    let full_bytes = run_result_bytes(&full);

    let dir = fresh_dir("hybridfl_ops_ckpt_sim");
    let (steered, snap) = run_with_midflight_checkpoint(Scenario::from_config(cfg.clone()), &dir);
    assert_eq!(full_bytes, run_result_bytes(&steered), "pause/checkpoint/resume perturbed the run");

    // The on-demand snapshot resumes to the same bytes in a new process
    // image (fresh env, protocol, driver).
    let resumed = Scenario::from_config(cfg)
        .resume_from(&snap)
        .run()
        .unwrap();
    assert_eq!(full_bytes, run_result_bytes(&resumed), "checkpoint-now snapshot diverged on resume");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The same maneuver on the live threaded backend (the jitter-safe regime
/// of `tests/resume_determinism.rs`).
#[test]
fn live_pause_checkpoint_resume_is_byte_identical() {
    let mut cfg = mock_cfg(ProtocolKind::HybridFl);
    cfg.n_clients = 12;
    cfg.dataset_size = 360;
    cfg.t_max = 3;
    cfg.seed = 42;
    let scale = 1e-2;

    let full = Scenario::from_config(cfg.clone())
        .backend(Backend::Live)
        .time_scale(scale)
        .run()
        .unwrap();
    let full_bytes = run_result_bytes(&full);

    let dir = fresh_dir("hybridfl_ops_ckpt_live");
    let sc = Scenario::from_config(cfg.clone())
        .backend(Backend::Live)
        .time_scale(scale);
    let (steered, snap) = run_with_midflight_checkpoint(sc, &dir);
    assert_eq!(full_bytes, run_result_bytes(&steered));

    let resumed = Scenario::from_config(cfg)
        .backend(Backend::Live)
        .time_scale(scale)
        .resume_from(&snap)
        .run()
        .unwrap();
    assert_eq!(full_bytes, run_result_bytes(&resumed));
    let _ = std::fs::remove_dir_all(&dir);
}

/// A blackout injected over the control socket is indistinguishable from
/// the same event pre-scripted as churn config: byte-identical results.
#[test]
fn injected_blackout_matches_scripted_fault() {
    let event = FaultEvent::RegionBlackout {
        region: 1,
        from_round: 4,
        until_round: 8,
    };

    let mut scripted_cfg = mock_cfg(ProtocolKind::HybridFl);
    scripted_cfg.churn = ChurnModel::FaultScript {
        events: vec![event.clone()],
    };
    let scripted = Scenario::from_config(scripted_cfg).run().unwrap();

    // Same config, stationary churn; the event arrives over the wire at
    // the round-1 boundary instead.
    let server = OpsServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let mut ctl = Control::connect(addr);
    ctl.send("pause");
    let sc = Scenario::from_config(mock_cfg(ProtocolKind::HybridFl));
    let run = std::thread::spawn(move || sc.run_with_ops(server).unwrap());
    assert_eq!(ctl.recv(), "ok paused");

    // Rejected: an event whose window has already begun cannot be
    // injected retroactively.
    let past = ctl.cmd(r#"inject {"kind":"region_blackout","region":1,"from_round":1,"until_round":8}"#);
    assert!(past.starts_with("err "), "{past}");
    // Rejected: malformed payloads never reach the driver.
    let bad = ctl.cmd("inject {not json");
    assert!(bad.starts_with("err "), "{bad}");

    assert_eq!(
        ctl.cmd(r#"inject {"kind":"region_blackout","region":1,"from_round":4,"until_round":8}"#),
        "ok injected"
    );
    assert_eq!(ctl.cmd("resume"), "ok resumed");
    let injected = run.join().unwrap();

    assert_eq!(
        run_result_bytes(&scripted),
        run_result_bytes(&injected),
        "live-injected blackout diverged from the scripted equivalent"
    );
    // The blackout actually bit: region 1 availability collapses inside
    // the window.
    let in_window = &injected.rounds[4]; // t = 5 ∈ [4, 8)
    assert!(
        in_window.avail[1] < 0.05,
        "round 5 region-1 availability {} — blackout did not take effect",
        in_window.avail[1]
    );
}

/// Injection composes with checkpointing: a snapshot taken *after* an
/// injection carries the spliced script, so a resumed run replays the
/// injected world, not the configured one.
#[test]
fn snapshot_after_injection_carries_the_injected_fault() {
    let dir = fresh_dir("hybridfl_ops_inject_snapshot");
    let server = OpsServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let mut ctl = Control::connect(addr);
    ctl.send("pause");
    let sc = Scenario::from_config(mock_cfg(ProtocolKind::HybridFl));
    let run = std::thread::spawn(move || sc.run_with_ops(server).unwrap());
    assert_eq!(ctl.recv(), "ok paused");
    assert_eq!(
        ctl.cmd(r#"inject {"kind":"region_blackout","region":1,"from_round":4,"until_round":8}"#),
        "ok injected"
    );
    let reply = ctl.cmd(&format!("checkpoint-now {}", dir.display()));
    let snap = PathBuf::from(reply.strip_prefix("ok ").expect("checkpoint-now after inject"));
    assert_eq!(ctl.cmd("resume"), "ok resumed");
    let injected = run.join().unwrap();

    // Resuming demands the *injected* config fingerprint...
    let err = Scenario::from_config(mock_cfg(ProtocolKind::HybridFl))
        .resume_from(&snap)
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("churn"), "{err}");

    // ...and with it, resumes into the injected world byte for byte.
    let mut resumed_cfg = mock_cfg(ProtocolKind::HybridFl);
    resumed_cfg.churn = ChurnModel::FaultScript {
        events: vec![FaultEvent::RegionBlackout {
            region: 1,
            from_round: 4,
            until_round: 8,
        }],
    };
    let resumed = Scenario::from_config(resumed_cfg)
        .resume_from(&snap)
        .run()
        .unwrap();
    assert_eq!(run_result_bytes(&injected), run_result_bytes(&resumed));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Tentpole acceptance: scrape a run paused at the round-1 boundary and
/// hold the histogram families' `_sum`/`_count` value-exact against the
/// round trace (f64 `Display` is shortest-round-trip, so textual equality
/// is bit equality) — and pin that the histogram machinery never perturbs
/// the run.
#[test]
fn histogram_scrape_matches_round_trace() {
    let cfg = mock_cfg(ProtocolKind::HybridFl);
    // The cloud-agg span charges exactly the config-derived edge↔cloud
    // RTT as its virtual duration.
    let rtt = hybridfl::env::VirtualClockEnv::new(cfg.clone())
        .unwrap()
        .t_c2e2c();

    let server = OpsServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    // No rounds closed yet: no histogram families in the scrape.
    let idle = http_get(addr, "/metrics");
    assert!(!idle.contains("_bucket{le="), "{idle}");

    let mut ctl = Control::connect(addr);
    ctl.send("pause"); // lands at the round-1 boundary
    let run = {
        let sc = Scenario::from_config(cfg.clone());
        std::thread::spawn(move || sc.run_with_ops(server).unwrap())
    };
    assert_eq!(ctl.recv(), "ok paused");

    let text = http_get(addr, "/metrics");
    assert_eq!(ctl.cmd("resume"), "ok resumed");
    let result = run.join().unwrap();
    let row = &result.rounds[0];

    for needle in [
        // Round-length family: exactly one observation — round 1.
        format!("hybridfl_round_length_seconds_sum {}\n", row.round_len),
        "hybridfl_round_length_seconds_count 1\n".to_string(),
        "hybridfl_round_length_seconds_bucket{le=\"+Inf\"} 1\n".to_string(),
        // Per-phase virtual durations: cloud agg charges the RTT,
        // bookkeeping phases ran exactly once, regional agg once per edge.
        format!("hybridfl_phase_duration_seconds_sum{{phase=\"cloud_agg\"}} {rtt}\n"),
        "hybridfl_phase_duration_seconds_count{phase=\"cloud_agg\"} 1\n".to_string(),
        "hybridfl_phase_duration_seconds_count{phase=\"train_fold\"} 1\n".to_string(),
        "hybridfl_phase_duration_seconds_count{phase=\"selection\"} 1\n".to_string(),
        "hybridfl_phase_duration_seconds_count{phase=\"fate_draw\"} 1\n".to_string(),
        "hybridfl_phase_duration_seconds_count{phase=\"churn_step\"} 1\n".to_string(),
        "hybridfl_phase_duration_seconds_count{phase=\"regional_agg\"} 2\n".to_string(),
    ] {
        assert!(
            text.contains(needle.as_str()),
            "missing {needle:?} in scrape:\n{text}"
        );
    }
    // Per-region submission-latency counts equal the trace's submission
    // counts; a region with zero in-time submissions has no series
    // (empty histograms are elided, not rendered as zeros).
    for (r, &subs) in row.submissions.iter().enumerate() {
        let series = format!("hybridfl_submission_latency_seconds_count{{region=\"{r}\"}}");
        if subs > 0 {
            let needle = format!("{series} {subs}\n");
            assert!(text.contains(&needle), "missing {needle:?} in scrape:\n{text}");
        } else {
            assert!(!text.contains(&series), "{text}");
        }
    }
    // Wall-time histograms are present but profiling-only: counts match
    // the span stream, values are host-dependent and unasserted.
    assert!(
        text.contains("hybridfl_phase_wall_seconds_count{phase=\"train_fold\"} 1\n"),
        "{text}"
    );

    // Histograms are observer-side state: the run is byte-identical to a
    // plain one.
    let plain = Scenario::from_config(cfg).run().unwrap();
    assert_eq!(run_result_bytes(&plain), run_result_bytes(&result));
}

/// `--trace-out` writes a parseable Chrome trace-event JSON covering
/// every round phase, and tracing is byte-invisible to the result — on
/// both backends.
#[test]
fn trace_out_is_valid_chrome_json_and_never_perturbs() {
    use hybridfl::jsonx::Json;

    let assert_valid_trace = |path: &std::path::Path, n_rounds: usize| {
        let raw = std::fs::read_to_string(path).unwrap();
        let doc = Json::parse(&raw).unwrap();
        let events = match doc.req("traceEvents").unwrap() {
            Json::Arr(v) => v,
            other => panic!("traceEvents must be an array, got {other:?}"),
        };
        // Phase complete-events: ≥ 7 per round (churn, selection, fate,
        // train+fold, 2× regional agg, cloud agg) plus metadata events.
        let complete: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("ph").map(|p| p.as_str().unwrap()) == Some("X"))
            .collect();
        assert!(
            complete.len() >= 7 * n_rounds,
            "{} complete events for {n_rounds} rounds",
            complete.len()
        );
        for phase in [
            "churn_step",
            "selection",
            "fate_draw",
            "train_fold",
            "regional_agg",
            "cloud_agg",
        ] {
            assert!(
                complete
                    .iter()
                    .any(|e| e.get("name").map(|n| n.as_str().unwrap()) == Some(phase)),
                "no {phase} event in {}",
                path.display()
            );
        }
        // Region-scoped spans carry pid = region + 1; the metadata names
        // the coordinator process.
        assert!(
            complete
                .iter()
                .any(|e| e.get("pid").map(|p| p.as_usize().unwrap()) == Some(2)),
            "no region-1 (pid 2) span"
        );
        assert!(
            events
                .iter()
                .any(|e| e.get("name").map(|n| n.as_str().unwrap()) == Some("process_name")),
            "missing process_name metadata"
        );
    };

    let dir = fresh_dir("hybridfl_trace_out");
    std::fs::create_dir_all(&dir).unwrap();

    // Sim backend.
    let cfg = mock_cfg(ProtocolKind::HybridFl);
    let plain = Scenario::from_config(cfg.clone()).run().unwrap();
    let sim_path = dir.join("sim_trace.json");
    let traced = Scenario::from_config(cfg)
        .trace_out(&sim_path)
        .run()
        .unwrap();
    assert_eq!(
        run_result_bytes(&plain),
        run_result_bytes(&traced),
        "tracing perturbed the sim run"
    );
    assert_valid_trace(&sim_path, traced.rounds.len());

    // Live backend (jitter-safe regime).
    let mut live_cfg = mock_cfg(ProtocolKind::HybridFl);
    live_cfg.n_clients = 12;
    live_cfg.dataset_size = 360;
    live_cfg.t_max = 3;
    live_cfg.seed = 42;
    let plain_live = Scenario::from_config(live_cfg.clone())
        .backend(Backend::Live)
        .time_scale(1e-2)
        .run()
        .unwrap();
    let live_path = dir.join("live_trace.json");
    let traced_live = Scenario::from_config(live_cfg)
        .backend(Backend::Live)
        .time_scale(1e-2)
        .trace_out(&live_path)
        .run()
        .unwrap();
    assert_eq!(
        run_result_bytes(&plain_live),
        run_result_bytes(&traced_live),
        "tracing perturbed the live run"
    );
    assert_valid_trace(&live_path, traced_live.rounds.len());

    let _ = std::fs::remove_dir_all(&dir);
}

/// The live backend rejects an injected `migrate` with the typed
/// sim-only error naming the virtual-clock constraint (matching the
/// churn/oracle construction-time precedent), instead of a generic
/// failure.
#[test]
fn live_inject_migrate_names_the_virtual_clock_constraint() {
    let mut cfg = mock_cfg(ProtocolKind::HybridFl);
    cfg.n_clients = 12;
    cfg.dataset_size = 360;
    cfg.t_max = 3;
    let server = OpsServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let mut ctl = Control::connect(addr);
    ctl.send("pause");
    let sc = Scenario::from_config(cfg)
        .backend(Backend::Live)
        .time_scale(1e-2);
    let run = std::thread::spawn(move || sc.run_with_ops(server).unwrap());
    assert_eq!(ctl.recv(), "ok paused");

    let reply = ctl.cmd(r#"inject {"kind":"migrate","client":3,"at_round":2,"to_region":1}"#);
    assert!(reply.starts_with("err "), "{reply}");
    assert!(
        reply.contains("virtual clock"),
        "the reply should name the virtual-clock constraint: {reply}"
    );
    assert!(reply.contains("migrate"), "{reply}");

    assert_eq!(ctl.cmd("resume"), "ok resumed");
    run.join().unwrap();
}

/// A configured token gates both faces of the endpoint: `/metrics` wants
/// `?token=`, control sessions must open with `auth TOKEN`.
#[test]
fn token_gates_scrape_and_control_sessions() {
    let server = OpsServer::bind_with_token("127.0.0.1:0", Some("s3cret".to_string())).unwrap();
    let addr = server.local_addr();

    // Scrape: 401 without (or with a wrong) token, body with it.
    let denied = http_get(addr, "/metrics");
    assert!(denied.contains("token"), "{denied}");
    assert!(!denied.contains("hybridfl_round"), "{denied}");
    let wrong = http_get(addr, "/metrics?token=nope");
    assert!(!wrong.contains("hybridfl_round"), "{wrong}");
    let ok = http_get(addr, "/metrics?token=s3cret");
    assert!(ok.contains("hybridfl_round 0\n"), "{ok}");

    // Control: anything but `auth TOKEN` as the first line is refused
    // and the session closed.
    let mut unauth = Control::connect(addr);
    let reply = unauth.cmd("status");
    assert!(reply.starts_with("err auth required"), "{reply}");
    let mut wrong_tok = Control::connect(addr);
    let reply = wrong_tok.cmd("auth nope");
    assert!(reply.starts_with("err auth required"), "{reply}");

    let mut authed = Control::connect(addr);
    assert_eq!(authed.cmd("auth s3cret"), "ok authenticated");
    // Past the handshake the vocabulary is unchanged; a stray re-auth is
    // a helpful error served without touching the driver queue.
    let reply = authed.cmd("auth s3cret");
    assert!(reply.starts_with("err "), "{reply}");
    assert!(reply.contains("first line"), "{reply}");
}
