//! Selection-strategy zoo integration tests (ISSUE 6):
//!
//! * the default (`slack`) selector reproduces the pre-zoo behavior byte
//!   for byte on seeded runs — the trait refactor is invisible;
//! * the oracle is what its name claims: a round-length lower bound for
//!   every adversarial matrix scenario;
//! * steady-state selected proportions order oracle ≤ slack ≤ random —
//!   the slack estimator sits between the cheating bound and the
//!   over-provisioning control;
//! * `fedcs` and `random` run on both backends with identical result
//!   shape (the zoo is backend-agnostic where it promises to be);
//! * the oracle on the live backend is a loud constructor error naming
//!   the constraint.

use hybridfl::config::ProtocolKind;
use hybridfl::harness::matrix;
use hybridfl::scenario::{Backend, Scenario};
use hybridfl::selection::SelectorKind;
use hybridfl::sim::test_support::two_region_cfg;
use hybridfl::snapshot::run_result_bytes;

// ---------------------------------------------------------------------------
// The refactor pin: slack-behind-the-trait is the historical behavior.
// ---------------------------------------------------------------------------

/// The acceptance bar for the refactor: a seeded run with no selector
/// configured, one with `slack` spelled out, and the legacy `FlRun`
/// entry point are all byte-identical — and the zoo is not a no-op,
/// because a different selector does move the run.
#[test]
fn default_selector_is_byte_identical_to_pre_zoo_runs() {
    let cfg = two_region_cfg(0.3);
    let default_bytes =
        run_result_bytes(&Scenario::from_config(cfg.clone()).run().unwrap());
    let explicit = Scenario::from_config(cfg.clone()).selector(SelectorKind::Slack).run().unwrap();
    assert_eq!(
        default_bytes,
        run_result_bytes(&explicit),
        "an explicit --selector slack perturbed the run"
    );
    let flrun = hybridfl::sim::FlRun::new(cfg.clone()).unwrap().run().unwrap();
    assert_eq!(
        default_bytes,
        run_result_bytes(&flrun),
        "the FlRun entry point diverged from the Scenario path"
    );
    let random = Scenario::from_config(cfg).selector(SelectorKind::Random).run().unwrap();
    assert_ne!(
        default_bytes,
        run_result_bytes(&random),
        "the random selector left no trace — the zoo is not wired through"
    );
}

#[test]
fn every_selector_is_deterministic_per_seed() {
    for sel in SelectorKind::ALL {
        let run = || {
            let mut cfg = two_region_cfg(0.3);
            cfg.t_max = 10;
            Scenario::from_config(cfg).selector(sel).run().unwrap()
        };
        assert_eq!(
            run_result_bytes(&run()),
            run_result_bytes(&run()),
            "{}: same seed must be byte-identical",
            sel.as_str()
        );
    }
}

// ---------------------------------------------------------------------------
// The oracle bound, across the adversarial matrix.
// ---------------------------------------------------------------------------

/// Ground-truth foresight must dominate on time: in every matrix
/// scenario the oracle's mean round length is a lower bound on every
/// other selector's (small tolerance for fate-draw noise between runs).
#[test]
fn oracle_round_length_is_a_lower_bound_in_every_matrix_scenario() {
    let rounds = 60;
    for sc in matrix::scenarios(rounds) {
        let avg_len = |sel: SelectorKind| -> f64 {
            let mut cfg = matrix::base_cfg(rounds, 7);
            cfg.selector = sel;
            Scenario::from_config(cfg)
                .churn(sc.churn.clone())
                .run()
                .unwrap()
                .summary
                .avg_round_len
        };
        let oracle = avg_len(SelectorKind::Oracle);
        for sel in [SelectorKind::Slack, SelectorKind::FedCs, SelectorKind::Random] {
            let other = avg_len(sel);
            assert!(
                oracle <= other * 1.05,
                "{}: oracle avg round {oracle:.2}s beaten by {} at {other:.2}s",
                sc.name,
                sel.as_str()
            );
        }
    }
}

/// Steady state on the stationary fleet: the oracle wakes ≈ C of the
/// fleet, random over-provisions toward (C+1)/2, and the slack
/// estimator sits in between — HybridFL's selected proportion is
/// bracketed by the cheating bound and the control.
#[test]
fn selected_proportion_orders_oracle_slack_random() {
    let proportion = |sel: SelectorKind| -> f64 {
        let mut cfg = two_region_cfg(0.3);
        cfg.t_max = 120;
        let result = Scenario::from_config(cfg).selector(sel).run().unwrap();
        let tail = &result.rounds[20..];
        tail.iter()
            .map(|r| r.selected.iter().sum::<usize>() as f64 / 40.0)
            .sum::<f64>()
            / tail.len() as f64
    };
    let oracle = proportion(SelectorKind::Oracle);
    let slack = proportion(SelectorKind::Slack);
    let random = proportion(SelectorKind::Random);
    assert!(
        oracle <= slack + 0.02,
        "oracle wakes more of the fleet than slack: {oracle:.3} vs {slack:.3}"
    );
    assert!(
        slack <= random + 0.02,
        "slack over-provisions past the random control: {slack:.3} vs {random:.3}"
    );
    assert!(
        (oracle - 0.3).abs() < 0.05,
        "oracle proportion {oracle:.3} should sit at C = 0.3"
    );
}

// ---------------------------------------------------------------------------
// Backend parity and the oracle's loud sim-only constraint.
// ---------------------------------------------------------------------------

/// `fedcs` and `random` are deployable estimators: every protocol runs
/// under them on both backends with the same result shape (mirror of
/// `every_protocol_runs_on_both_backends` in tests/scenario_api.rs).
#[test]
fn fedcs_and_random_run_on_both_backends() {
    for sel in [SelectorKind::FedCs, SelectorKind::Random] {
        for proto in ProtocolKind::ALL {
            for backend in [Backend::Sim, Backend::Live] {
                let result = Scenario::task1()
                    .mock()
                    .protocol(proto)
                    .selector(sel)
                    .clients(16)
                    .edges(2)
                    .dataset_size(640)
                    .rounds(3)
                    .backend(backend)
                    .run()
                    .unwrap_or_else(|e| {
                        panic!("{} / {proto:?} on {backend:?}: {e}", sel.as_str())
                    });
                assert_eq!(result.rounds.len(), 3, "{} on {backend:?}", sel.as_str());
                assert_eq!(result.summary.protocol, proto.as_str());
                for row in &result.rounds {
                    let selected: usize = row.selected.iter().sum();
                    let submitted: usize = row.submissions.iter().sum();
                    assert!(
                        selected >= 1 && submitted <= selected,
                        "{} / {proto:?} on {backend:?}",
                        sel.as_str()
                    );
                    assert!(row.round_len > 0.0);
                }
            }
        }
    }
}

/// The oracle reads ground-truth fates that exist only as the virtual
/// clock's pre-drawable table — the live backend must refuse at
/// construction, naming the constraint (like churn `Migrate`).
#[test]
fn oracle_on_live_backend_is_rejected_loudly() {
    let mut cfg = two_region_cfg(0.1);
    cfg.t_max = 2;
    let err = Scenario::from_config(cfg)
        .selector(SelectorKind::Oracle)
        .backend(Backend::Live)
        .time_scale(1e-3)
        .run()
        .unwrap_err()
        .to_string();
    assert!(err.contains("oracle"), "{err}");
    assert!(err.contains("live backend"), "{err}");
    assert!(err.contains("virtual clock"), "{err}");
}
