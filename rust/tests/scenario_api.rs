//! The unified execution API end to end: `Scenario` builder validation,
//! cross-backend result shape, and determinism of the threaded sweep.

use hybridfl::config::{ProtocolKind, TaskKind};
use hybridfl::harness::sweep::{render_energy, render_table};
use hybridfl::harness::{run_task_sweep, SweepOpts};
use hybridfl::scenario::{Backend, Scenario};

#[test]
fn builder_rejects_invalid_fraction_and_quota_combos() {
    // cfg.validate() fires before any backend is built.
    assert!(Scenario::task1().mock().c_fraction(0.0).run().is_err());
    assert!(Scenario::task1().mock().c_fraction(1.5).run().is_err());
    assert!(Scenario::task1().mock().dropout(1.0).run().is_err());
    assert!(Scenario::task1().mock().rounds(0).run().is_err());
    assert!(Scenario::task1().mock().theta_init(0.0).run().is_err());
    // Explicit regions must sum to n_clients.
    let bad = Scenario::task1().mock().tune(|cfg| {
        cfg.regions = vec![hybridfl::config::RegionSpec {
            n_clients: 3,
            dropout_mean: 0.1,
        }];
    });
    assert!(bad.run().is_err());
}

#[test]
fn every_protocol_runs_on_both_backends() {
    for proto in ProtocolKind::ALL {
        for backend in [Backend::Sim, Backend::Live] {
            let result = Scenario::task1()
                .mock()
                .protocol(proto)
                .clients(16)
                .edges(2)
                .dataset_size(640)
                .rounds(3)
                .backend(backend)
                .run()
                .unwrap_or_else(|e| panic!("{proto:?} on {backend:?}: {e}"));
            assert_eq!(result.rounds.len(), 3, "{proto:?} on {backend:?}");
            assert_eq!(result.summary.protocol, proto.as_str());
            for row in &result.rounds {
                let sel: usize = row.selected.iter().sum();
                let sub: usize = row.submissions.iter().sum();
                assert!(sel >= 1 && sub <= sel, "{proto:?} on {backend:?}");
                assert!(row.round_len > 0.0);
            }
        }
    }
}

#[test]
fn scenario_is_deterministic_per_seed() {
    let run = || {
        Scenario::task1()
            .mock()
            .dropout(0.3)
            .seed(11)
            .rounds(15)
            .run()
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.summary.best_accuracy, b.summary.best_accuracy);
    assert_eq!(a.summary.total_time, b.summary.total_time);
}

/// The tentpole perf claim: a parallel sweep must produce cell-for-cell,
/// byte-for-byte identical artifacts to the serial schedule.
#[test]
fn parallel_sweep_matches_serial_byte_for_byte() {
    let root = std::env::temp_dir().join("hybridfl_scenario_api_sweep");
    let _ = std::fs::remove_dir_all(&root);
    let serial_dir = root.join("serial");
    let parallel_dir = root.join("parallel");

    let base = SweepOpts {
        quick: true,
        mock: true,
        target: Some(0.3),
        ..Default::default()
    };
    let serial = run_task_sweep(
        TaskKind::Aerofoil,
        &SweepOpts { parallel: false, ..base.clone() },
        &serial_dir,
    )
    .unwrap();
    let parallel = run_task_sweep(
        TaskKind::Aerofoil,
        &SweepOpts { parallel: true, ..base },
        &parallel_dir,
    )
    .unwrap();

    // Rendered tables identical.
    assert_eq!(render_table(&serial), render_table(&parallel));
    assert_eq!(render_energy(&serial), render_energy(&parallel));

    // Emitted artifacts identical byte for byte.
    for name in ["table3.txt", "fig5_energy.txt", "sweep_aerofoil.json"] {
        let a = std::fs::read(serial_dir.join(name)).unwrap();
        let b = std::fs::read(parallel_dir.join(name)).unwrap();
        assert_eq!(a, b, "{name} differs between serial and parallel sweeps");
    }
    // Including every per-cell trace CSV.
    for cell in &serial.cells {
        let name = format!(
            "trace_aerofoil-{}-dr{:.1}-c{:.1}.csv",
            cell.protocol.as_str(),
            cell.e_dr,
            cell.c
        );
        let a = std::fs::read(serial_dir.join(&name)).unwrap();
        let b = std::fs::read(parallel_dir.join(&name)).unwrap();
        assert_eq!(a, b, "{name} differs");
    }
    let _ = std::fs::remove_dir_all(&root);
}
