//! The paper's core constraint: client reliability is *agnostic* — no
//! protocol decision may depend on anything but observable submission
//! counts and round outcomes. These tests pin that boundary.

use hybridfl::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind, RegionSpec};
use hybridfl::selection::SlackEstimator;
use hybridfl::sim::FlRun;

/// Two "worlds" with completely different client reliability that happen
/// to produce the same observable submission-count sequence must drive the
/// estimator to identical decisions — the estimator cannot possibly be
/// using anything else (its API admits nothing else).
#[test]
fn slack_decisions_depend_only_on_observables() {
    let seq: &[(usize, bool)] = &[
        (3, true),
        (2, true),
        (4, false),
        (3, true),
        (0, false),
        (5, true),
        (3, true),
    ];
    let mut world_a = SlackEstimator::new(12, 0.3, 0.5);
    let mut world_b = SlackEstimator::new(12, 0.3, 0.5);
    for &(subs, censored) in seq {
        assert_eq!(world_a.c_r(), world_b.c_r());
        assert_eq!(world_a.selection_count(), world_b.selection_count());
        world_a.observe(subs, censored);
        world_b.observe(subs, censored);
    }
    assert_eq!(world_a.theta(), world_b.theta());
}

/// Estimation works without ever identifying clients: two regions with the
/// same aggregate reliability but totally different per-client profiles
/// (uniform vs bimodal) steer to similar selection proportions.
#[test]
fn distribution_free_within_same_mean() {
    // Uniform region: everyone drops at 0.5. Bimodal region: half the
    // clients at 0.1, half at 0.9 (same mean 0.5).
    let run = |regions: Vec<RegionSpec>, std: f64| {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.engine = EngineKind::Mock;
        cfg.n_clients = regions.iter().map(|r| r.n_clients).sum();
        cfg.n_edges = regions.len();
        cfg.regions = regions;
        cfg.dropout = Dist::new(0.5, std);
        cfg.dataset_size = 2000;
        cfg.eval_size = 40;
        cfg.t_max = 200;
        cfg.protocol = ProtocolKind::HybridFl;
        let result = FlRun::new(cfg).unwrap().run().unwrap();
        let tail = &result.rounds[100..];
        tail.iter()
            .map(|r| r.slack.as_ref().unwrap()[0].c_r)
            .sum::<f64>()
            / tail.len() as f64
    };
    let uniform = run(vec![RegionSpec { n_clients: 40, dropout_mean: 0.5 }], 0.0);
    // Bimodal via huge sigma: 𝓝(0.5, 0.45²) clamped — mass piles near the
    // 0/0.99 edges, same mean.
    let bimodal = run(vec![RegionSpec { n_clients: 40, dropout_mean: 0.5 }], 0.45);
    assert!(
        (uniform - bimodal).abs() < 0.22,
        "C_r should depend on aggregate reliability, not its shape: \
         uniform={uniform:.3} bimodal={bimodal:.3}"
    );
}

/// End-to-end: HybridFL adapts selection to unreliability it was never
/// told about — higher drop-out must yield a strictly higher converged
/// selection proportion.
#[test]
fn selection_proportion_rises_with_hidden_dropout() {
    let mut cs = Vec::new();
    for dr in [0.1, 0.5, 0.8] {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.engine = EngineKind::Mock;
        cfg.n_clients = 40;
        cfg.n_edges = 2;
        cfg.dataset_size = 1200;
        cfg.eval_size = 40;
        cfg.dropout = Dist::new(dr, 0.03);
        cfg.t_max = 150;
        cfg.protocol = ProtocolKind::HybridFl;
        let result = FlRun::new(cfg).unwrap().run().unwrap();
        let tail = &result.rounds[75..];
        let mean_sel: f64 = tail
            .iter()
            .map(|r| r.selected.iter().sum::<usize>() as f64 / 40.0)
            .sum::<f64>()
            / tail.len() as f64;
        cs.push(mean_sel);
    }
    assert!(
        cs[0] < cs[1] && cs[1] < cs[2],
        "selection must rise with drop-out: {cs:?}"
    );
}
