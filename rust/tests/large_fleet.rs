//! Large-fleet smoke: 50k- and 1M-client HybridFL scenarios on the
//! virtual clock with a tiny (mock) model, proving the streaming data
//! plane keeps peak resident model state O(regions) — and, at the 1M
//! cell, that whole-process memory stays bounded (`VmHWM` ceiling) while
//! hundreds of thousands of clients are selected per round. Ignored by
//! default; run with:
//!
//! ```text
//! cargo test --release --test large_fleet -- --ignored --test-threads=1
//! ```
//!
//! Single-threaded matters twice: the arena counters are process-global,
//! and `VmHWM` is a process-lifetime high-water mark, so the million
//! cell's name sorts after the 50k cells to keep the ceiling meaningful.
//!
//! The memory claim is checked with the arena instrumentation in
//! `hybridfl::model`: every live `ModelParams` allocation (not handle)
//! counts toward `arena_count`, and `arena_peak` records the high-water
//! mark. A buffered round would hold one model per in-time submission
//! (quota = C·n = 15 000 here); the streaming round must stay within a
//! small constant of the region count.

use hybridfl::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind};
use hybridfl::model;
use hybridfl::scenario::Scenario;
use hybridfl::snapshot::run_result_bytes;

const N: usize = 50_000;
const M: usize = 8;

fn fleet_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = ProtocolKind::HybridFl;
    cfg.n_clients = N;
    cfg.n_edges = M;
    cfg.dataset_size = N * 6; // tiny partitions, large fleet
    cfg.eval_size = 50;
    cfg.c_fraction = 0.3;
    cfg.dropout = Dist::new(0.2, 0.05);
    cfg.t_max = 3;
    cfg.seed = 4242;
    cfg
}

#[test]
#[ignore = "large-fleet smoke (~50k clients); run with --ignored --release"]
fn fifty_thousand_clients_stream_with_flat_model_memory() {
    let cfg = fleet_cfg();

    model::reset_arena_peak();
    let baseline = model::arena_count();
    let result = Scenario::from_config(cfg.clone()).run().unwrap();
    let peak = model::arena_peak();

    assert_eq!(result.rounds.len(), 3);
    let quota = cfg.quota();
    assert_eq!(quota, 15_000);
    for row in &result.rounds {
        let subs: usize = row.submissions.iter().sum();
        assert!(
            subs >= 1_000,
            "round {}: expected thousands of submissions, got {subs}",
            row.t
        );
    }

    // The memory headline: a buffered data plane would peak at one arena
    // per in-time submission (≥ quota = 15 000 above baseline). The
    // streaming plane holds the per-region accumulators, the protocol's
    // regional/global models and a handful of transients — bounded by a
    // small multiple of the region count, independent of fleet size.
    let resident = peak - baseline;
    assert!(
        resident < 16 * M + 64,
        "peak resident model arenas {resident} should be O(regions={M}), \
         not O(submissions={quota})"
    );
}

/// The resume path at fleet scale: checkpoint the 50k-client run at round
/// 2, discard all process state, resume — the `RunResult` must be
/// byte-identical to the uninterrupted run's, and the resumed segment
/// must keep the O(regions) arena-peak property (a snapshot restore that
/// buffered models would show up here).
#[test]
#[ignore = "large-fleet resume (~50k clients); run with --ignored --release"]
fn fifty_thousand_clients_checkpoint_resume_byte_identical() {
    let cfg = fleet_cfg();
    let full = Scenario::from_config(cfg.clone()).run().unwrap();
    let full_bytes = run_result_bytes(&full);

    let dir = std::env::temp_dir().join("hybridfl_large_fleet_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let checkpointed = Scenario::from_config(cfg.clone())
        .checkpoint_dir(&dir)
        .checkpoint_every(2)
        .run()
        .unwrap();
    assert_eq!(full_bytes, run_result_bytes(&checkpointed));

    model::reset_arena_peak();
    let baseline = model::arena_count();
    let resumed = Scenario::from_config(cfg)
        .resume_from(dir.join("snapshot_round_000002.hflsnap"))
        .run()
        .unwrap();
    let resident = model::arena_peak() - baseline;
    assert_eq!(full_bytes, run_result_bytes(&resumed));
    assert!(
        resident < 16 * M + 64,
        "resumed segment peaked at {resident} arenas; must stay O(regions={M})"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// The compressed data plane at fleet scale: a `topk:0.05+ef` run must
/// keep the same O(regions) arena peak as the dense streaming plane.
/// Compressed folds go decode-and-axpy straight into the per-region
/// accumulators — an implementation that materialised a dense model per
/// decoded frame would peak at one arena per in-time submission and fail
/// here. (Error-feedback residuals are plain `Vec<f32>`s outside the
/// arena accounting, so they don't mask a regression in model arenas.)
#[test]
#[ignore = "large-fleet compressed smoke (~50k clients); run with --ignored --release"]
fn fifty_thousand_clients_topk_ef_keeps_flat_model_memory() {
    let mut cfg = fleet_cfg();
    cfg.comm = hybridfl::comm::CommConfig::parse_spec("topk:0.05+ef").unwrap();

    model::reset_arena_peak();
    let baseline = model::arena_count();
    let result = Scenario::from_config(cfg).run().unwrap();
    let peak = model::arena_peak();

    assert_eq!(result.rounds.len(), 3);
    for row in &result.rounds {
        let subs: usize = row.submissions.iter().sum();
        assert!(
            subs >= 1_000,
            "round {}: expected thousands of submissions, got {subs}",
            row.t
        );
        assert!(
            row.bytes_moved > 0,
            "round {}: compressed submissions must report wire bytes",
            row.t
        );
    }

    let resident = peak - baseline;
    assert!(
        resident < 16 * M + 64,
        "compressed-fold peak resident model arenas {resident} should be \
         O(regions={M}), not O(submissions)"
    );
}

/// The million-client cell: one full HybridFL round over a 1M-client,
/// 16-region fleet. Beyond the O(regions) arena bar, this pins a hard
/// whole-process memory ceiling: the SoA fleet, lazy fate draws and
/// O(dirty) dynamics keep per-round state proportional to the *selected*
/// set, so the process must fit comfortably in a few GiB — an
/// accidentally revived O(n)-per-round allocation (eager fate vectors, a
/// fleet-wide sort, a profile clone per sweep) shows up here first.
#[test]
#[ignore = "million-client round (~1M clients); run with --ignored --release --test-threads=1"]
fn million_clients_complete_a_round_within_memory_ceiling() {
    let mut cfg = fleet_cfg();
    cfg.n_clients = 1_000_000;
    cfg.n_edges = 16;
    cfg.dataset_size = 2_000_000; // 2 samples per client
    cfg.t_max = 1;

    model::reset_arena_peak();
    let baseline = model::arena_count();
    let result = Scenario::from_config(cfg.clone()).run().unwrap();
    let peak = model::arena_peak();

    assert_eq!(result.rounds.len(), 1);
    let subs: usize = result.rounds[0].submissions.iter().sum();
    assert!(
        subs >= 100_000,
        "expected ~C·n submissions at 1M clients, got {subs}"
    );

    let resident = peak - baseline;
    assert!(
        resident < 16 * 16 + 64,
        "peak resident model arenas {resident} should be O(regions), \
         independent of the 1M fleet"
    );

    // VmHWM covers everything this process ever held — corpus,
    // partitions, fleet arrays, the round's transients, and the smaller
    // cells that ran before this one. The structures above total well
    // under 1 GiB; 4 GiB of headroom means "no O(n) blow-up", not a
    // tight fit.
    if let Some(rss) = hybridfl::benchkit::peak_rss_bytes() {
        let ceiling = 4 * 1024 * 1024 * 1024u64;
        assert!(
            rss < ceiling,
            "peak RSS {} MiB exceeds the {} MiB million-client ceiling",
            rss / (1024 * 1024),
            ceiling / (1024 * 1024)
        );
    }
}

/// Checkpointing must not deep-clone error-feedback residuals: the
/// snapshot shares each residual vector with the environment by `Arc`
/// (pointer equality, not just value equality), so `capture_state()` on a
/// 50k-client `topk+ef` run is O(clients) refcount bumps rather than a
/// transient doubling of residual memory. Small fleet — the sharing
/// property is scale-independent, so this runs in tier-1.
#[test]
fn comm_state_snapshots_share_residuals_by_reference() {
    use hybridfl::comm::CommState;
    use hybridfl::env::{run_to_completion, FlEnvironment, VirtualClockEnv};
    use hybridfl::protocols::protocol_for;

    let mut cfg = fleet_cfg();
    cfg.n_clients = 24;
    cfg.n_edges = 3;
    cfg.dataset_size = 240;
    cfg.comm = hybridfl::comm::CommConfig::parse_spec("topk:0.25+ef").unwrap();

    let mut env = VirtualClockEnv::new(cfg).unwrap();
    let mut protocol = protocol_for(&env);
    run_to_completion(&mut env, protocol.as_mut()).unwrap();

    let (a, b) = (env.capture_state().comm, env.capture_state().comm);
    let (CommState::Residuals { clients: a }, CommState::Residuals { clients: b }) = (a, b) else {
        panic!("a topk+ef run must carry residual state after 3 rounds");
    };
    assert!(!a.is_empty());
    for ((ka, ra), (kb, rb)) in a.iter().zip(&b) {
        assert_eq!(ka, kb);
        assert!(
            std::sync::Arc::ptr_eq(ra, rb),
            "client {ka}: snapshot cloned the residual instead of sharing it"
        );
    }
}
