//! Property tests for the snapshot codecs (ISSUE 4 satellite):
//!
//! * arbitrary `ModelParams` shapes round-trip bit-exactly through the
//!   binary and JSON codecs;
//! * truncated / corrupted / wrong-version byte streams come back as
//!   typed `SnapshotError`s — never a panic;
//! * `RunSnapshot` round-trips with the RNG streams intact (a restored
//!   generator continues the original draw sequence exactly).

use hybridfl::churn::ChurnState;
use hybridfl::comm::CommState;
use hybridfl::config::ExperimentConfig;
use hybridfl::env::DriverState;
use hybridfl::model::ModelParams;
use hybridfl::protocols::ProtocolState;
use hybridfl::rng::{Rng, RngState};
use hybridfl::selection::SlackEstimator;
use hybridfl::snapshot::{
    decode_snapshot, fnv1a64, BinaryCodec, CodecKind, JsonCodec, RunSnapshot, SnapshotCodec,
    SnapshotError,
};

/// Random parameter set: 0–5 tensors with 0–3 dims each (zero-sized
/// dims included), finite values.
fn arbitrary_params(rng: &mut Rng) -> ModelParams {
    let n_tensors = rng.below(6);
    let mut tensors = Vec::with_capacity(n_tensors);
    let mut shapes = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let ndims = rng.below(4);
        let shape: Vec<usize> = (0..ndims).map(|_| rng.below(5)).collect();
        let count: usize = shape.iter().product();
        let values: Vec<f32> = (0..count).map(|_| rng.normal(0.0, 10.0) as f32).collect();
        tensors.push(values);
        shapes.push(shape);
    }
    ModelParams::new(tensors, shapes)
}

/// Wrap a protocol state in a structurally-valid snapshot (real config,
/// consistent fingerprint, fresh driver, stateless world).
fn snap_with(protocol: ProtocolState, rng_state: RngState) -> RunSnapshot {
    snap_with_churn(protocol, rng_state, ChurnState::Stateless)
}

fn snap_with_churn(
    protocol: ProtocolState,
    rng_state: RngState,
    churn: ChurnState,
) -> RunSnapshot {
    snap_with_comm(protocol, rng_state, churn, CommState::Stateless)
}

fn snap_with_comm(
    protocol: ProtocolState,
    rng_state: RngState,
    churn: ChurnState,
    comm: CommState,
) -> RunSnapshot {
    let config_json = ExperimentConfig::fig2().to_json().dump();
    RunSnapshot {
        backend: "sim".into(),
        fingerprint: fnv1a64(config_json.as_bytes()),
        config_json,
        rng: rng_state,
        churn,
        comm,
        protocol,
        driver: DriverState::fresh(),
    }
}

/// An arbitrary churn state, shape-varied by seed (every enum variant
/// appears across the seed range, composed nesting included).
fn arbitrary_churn(rng: &mut Rng) -> ChurnState {
    match rng.below(4) {
        0 => ChurnState::Stateless,
        1 => ChurnState::Markov {
            up: (0..rng.below(40)).map(|_| rng.bernoulli(0.7)).collect(),
        },
        2 => ChurnState::Battery {
            level: (0..rng.below(40)).map(|_| rng.uniform()).collect(),
        },
        _ => ChurnState::Composed {
            layers: (0..1 + rng.below(3))
                .map(|_| match rng.below(3) {
                    0 => ChurnState::Stateless,
                    1 => ChurnState::Markov {
                        up: (0..rng.below(10)).map(|_| rng.bernoulli(0.5)).collect(),
                    },
                    _ => ChurnState::Battery {
                        level: (0..rng.below(10)).map(|_| rng.uniform()).collect(),
                    },
                })
                .collect(),
        },
    }
}

fn rng_state(seed: u64) -> RngState {
    let mut r = Rng::new(seed);
    for _ in 0..seed % 13 {
        r.next_u64();
    }
    if seed % 2 == 0 {
        let _ = r.gaussian(); // park a Box–Muller spare in the state
    }
    r.state()
}

/// Equality oracle: two snapshots are identical iff their canonical
/// binary encodings are identical (bit-exact floats included).
fn assert_same(a: &RunSnapshot, b: &RunSnapshot) {
    assert_eq!(BinaryCodec.encode(a), BinaryCodec.encode(b));
}

#[test]
fn arbitrary_params_roundtrip_bit_exactly_both_codecs() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed);
        let global = arbitrary_params(&mut rng);
        let regionals: Vec<ModelParams> =
            (0..rng.below(4)).map(|_| arbitrary_params(&mut rng)).collect();
        let mut est = SlackEstimator::new(10 + rng.below(40), 0.3, 0.5);
        for t in 0..rng.below(20) {
            est.observe(t % 5, t % 2 == 0);
        }
        let churn = arbitrary_churn(&mut rng);
        let snap = snap_with_churn(
            ProtocolState::HybridFl {
                global,
                regionals,
                slack: vec![est.snapshot()],
            },
            rng_state(seed),
            churn,
        );
        for codec in [&BinaryCodec as &dyn SnapshotCodec, &JsonCodec] {
            let bytes = codec.encode(&snap);
            let back = codec
                .decode(&bytes)
                .unwrap_or_else(|e| panic!("{} decode (seed {seed}): {e}", codec.name()));
            assert_same(&snap, &back);
            // Format sniffing must route to the right codec too.
            assert_same(&snap, &decode_snapshot(&bytes).unwrap());
        }
    }
}

/// The binary codec must preserve *any* f32 bit pattern — NaN payloads
/// and infinities included (the JSON codec documents NaN collapsing, so
/// this is binary-only).
#[test]
fn binary_preserves_non_finite_bit_patterns() {
    let weird = ModelParams::new(
        vec![vec![
            f32::NAN,
            f32::from_bits(0x7fc0_dead), // NaN with a payload
            f32::INFINITY,
            f32::NEG_INFINITY,
            -0.0,
            f32::MIN_POSITIVE,
        ]],
        vec![vec![6]],
    );
    let snap = snap_with(ProtocolState::FedAvg { global: weird }, rng_state(3));
    let back = BinaryCodec.decode(&BinaryCodec.encode(&snap)).unwrap();
    let (a, b) = match (&snap.protocol, &back.protocol) {
        (ProtocolState::FedAvg { global: a }, ProtocolState::FedAvg { global: b }) => (a, b),
        _ => unreachable!(),
    };
    for (x, y) in a.values().iter().zip(b.values().iter()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn rng_state_survives_roundtrip_and_continues_sequence() {
    let mut original = Rng::new(99);
    for _ in 0..7 {
        original.next_u64();
    }
    let _ = original.gaussian(); // spare cached
    let snap = snap_with(
        ProtocolState::FedAvg {
            global: ModelParams::new(vec![vec![1.0]], vec![vec![1]]),
        },
        original.state(),
    );
    for codec in [&BinaryCodec as &dyn SnapshotCodec, &JsonCodec] {
        let back = codec.decode(&codec.encode(&snap)).unwrap();
        let mut restored = Rng::from_state(back.rng);
        let mut reference = Rng::from_state(original.state());
        for _ in 0..50 {
            assert_eq!(restored.gaussian().to_bits(), reference.gaussian().to_bits());
            assert_eq!(restored.next_u64(), reference.next_u64());
        }
    }
}

#[test]
fn every_truncation_is_a_typed_error_binary() {
    let snap = snap_with(
        ProtocolState::HierFavg {
            global: ModelParams::new(vec![vec![1.0, 2.0]], vec![vec![2]]),
            regionals: vec![ModelParams::new(vec![vec![3.0]], vec![vec![1]])],
            region_data: vec![10.0],
        },
        rng_state(1),
    );
    let bytes = BinaryCodec.encode(&snap);
    assert!(BinaryCodec.decode(&bytes).is_ok());
    for len in 0..bytes.len() {
        let err = BinaryCodec
            .decode(&bytes[..len])
            .expect_err(&format!("prefix of {len} bytes must not decode"));
        assert!(
            matches!(
                err,
                SnapshotError::BadMagic
                    | SnapshotError::Truncated { .. }
                    | SnapshotError::ChecksumMismatch { .. }
                    | SnapshotError::Malformed(_)
            ),
            "prefix {len}: unexpected error {err:?}"
        );
    }
}

#[test]
fn every_truncation_is_a_typed_error_json() {
    let snap = snap_with(
        ProtocolState::FedAvg {
            global: ModelParams::new(vec![vec![1.5, -2.5]], vec![vec![2]]),
        },
        rng_state(2),
    );
    let bytes = JsonCodec.encode(&snap);
    assert!(JsonCodec.decode(&bytes).is_ok());
    for len in 0..bytes.len() {
        assert!(
            JsonCodec.decode(&bytes[..len]).is_err(),
            "JSON prefix of {len} bytes must not decode"
        );
    }
}

/// Single-byte corruption anywhere in a binary snapshot must be caught —
/// in the payload by the checksum, in the header by the field checks.
#[test]
fn every_single_byte_corruption_is_detected_binary() {
    let snap = snap_with(
        ProtocolState::FedAvg {
            global: ModelParams::new(vec![vec![0.5; 8]], vec![vec![8]]),
        },
        rng_state(4),
    );
    let bytes = BinaryCodec.encode(&snap);
    for i in 0..bytes.len() {
        let mut corrupt = bytes.clone();
        corrupt[i] ^= 0x55;
        assert!(
            BinaryCodec.decode(&corrupt).is_err(),
            "flip at byte {i} went undetected"
        );
    }
}

#[test]
fn wrong_version_is_rejected_not_misparsed() {
    let snap = snap_with(
        ProtocolState::FedAvg {
            global: ModelParams::new(vec![vec![1.0]], vec![vec![1]]),
        },
        rng_state(5),
    );
    let mut bytes = BinaryCodec.encode(&snap);
    bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
    match BinaryCodec.decode(&bytes) {
        Err(SnapshotError::UnsupportedVersion { found: 99, supported }) => {
            assert_eq!(supported, hybridfl::snapshot::FORMAT_VERSION);
        }
        other => panic!("expected UnsupportedVersion, got {other:?}"),
    }

    // Same policy for the JSON codec's format field.
    let text = String::from_utf8(JsonCodec.encode(&snap)).unwrap();
    let bumped = text.replace(
        &format!(
            "\"snapshot_format\": {}",
            hybridfl::snapshot::FORMAT_VERSION
        ),
        "\"snapshot_format\": 99",
    );
    assert_ne!(text, bumped, "test must actually change the version field");
    assert!(matches!(
        JsonCodec.decode(bumped.as_bytes()),
        Err(SnapshotError::UnsupportedVersion { found: 99, .. })
    ));
}

#[test]
fn json_missing_keys_and_garbage_are_malformed() {
    assert!(matches!(
        JsonCodec.decode(b"{\"kind\": \"hybridfl-run-snapshot\"}"),
        Err(SnapshotError::Malformed(_))
    ));
    assert!(JsonCodec.decode(b"{not json").is_err());
    // A JSON document of the wrong kind is "not a snapshot", not malformed.
    assert!(matches!(
        JsonCodec.decode(b"{\"kind\": \"something-else\"}"),
        Err(SnapshotError::BadMagic)
    ));
}

/// The config-fingerprint guard: a snapshot refuses to resume into a
/// diverging config, and the error names the fields that moved.
#[test]
fn config_mismatch_names_the_diverging_fields() {
    let snap = snap_with(
        ProtocolState::FedAvg {
            global: ModelParams::new(vec![vec![1.0]], vec![vec![1]]),
        },
        rng_state(6),
    );
    let mut changed = ExperimentConfig::fig2();
    changed.c_fraction = 0.5;
    changed.dropout.mean = 0.1;
    let err = snap.ensure_config_matches(&changed).unwrap_err();
    match err {
        SnapshotError::ConfigMismatch { ref diverging } => {
            assert!(diverging.contains(&"c_fraction".to_string()), "{diverging:?}");
            assert!(diverging.contains(&"dropout.mean".to_string()), "{diverging:?}");
            assert!(!diverging.contains(&"t_max".to_string()), "{diverging:?}");
        }
        other => panic!("expected ConfigMismatch, got {other:?}"),
    }
    let msg = err.to_string();
    assert!(msg.contains("c_fraction"), "{msg}");
    assert!(msg.contains("dropout.mean"), "{msg}");

    // The matching config passes.
    assert!(snap.ensure_config_matches(&ExperimentConfig::fig2()).is_ok());
}

/// Churn state round-trips bit-exactly through both codecs in every
/// shape (Markov flags, battery levels, composed layers).
#[test]
fn churn_state_roundtrips_both_codecs() {
    let states = vec![
        ChurnState::Stateless,
        ChurnState::Markov {
            up: vec![true, false, true, true],
        },
        ChurnState::Battery {
            level: vec![1.0, 0.25, -0.017, 0.1 + 0.2],
        },
        ChurnState::Composed {
            layers: vec![
                ChurnState::Markov { up: vec![false] },
                ChurnState::Stateless,
                ChurnState::Battery { level: vec![0.5] },
            ],
        },
    ];
    for (i, churn) in states.into_iter().enumerate() {
        let snap = snap_with_churn(
            ProtocolState::FedAvg {
                global: ModelParams::new(vec![vec![1.0]], vec![vec![1]]),
            },
            rng_state(i as u64),
            churn.clone(),
        );
        for codec in [&BinaryCodec as &dyn SnapshotCodec, &JsonCodec] {
            let back = codec.decode(&codec.encode(&snap)).unwrap();
            assert_eq!(back.churn, churn, "{} codec, state {i}", codec.name());
            assert_same(&snap, &back);
        }
    }
}

/// Communication state (the `topk+ef` residuals) round-trips bit-exactly
/// through both codecs — finite values only in the shared case, since the
/// JSON codec documents NaN collapsing.
#[test]
fn comm_state_roundtrips_both_codecs() {
    let states = vec![
        CommState::Stateless,
        CommState::Residuals { clients: vec![] },
        CommState::Residuals {
            clients: vec![
                (3, std::sync::Arc::new(vec![0.5, -1.25, 0.0, 1e-30])),
                (17, std::sync::Arc::new(vec![f32::MAX, f32::MIN_POSITIVE, -0.0])),
            ],
        },
    ];
    for (i, comm) in states.into_iter().enumerate() {
        let snap = snap_with_comm(
            ProtocolState::FedAvg {
                global: ModelParams::new(vec![vec![1.0]], vec![vec![1]]),
            },
            rng_state(10 + i as u64),
            ChurnState::Stateless,
            comm.clone(),
        );
        for codec in [&BinaryCodec as &dyn SnapshotCodec, &JsonCodec] {
            let back = codec.decode(&codec.encode(&snap)).unwrap();
            assert_eq!(back.comm, comm, "{} codec, state {i}", codec.name());
            assert_same(&snap, &back);
        }
    }
}

/// A snapshot written by a real checkpointing run loads back through the
/// public file API with either codec.
#[test]
fn file_save_load_roundtrip_both_codecs() {
    use hybridfl::snapshot::{load_snapshot, save_snapshot};
    let snap = snap_with(
        ProtocolState::FedAvg {
            global: ModelParams::new(vec![vec![2.0, 4.0]], vec![vec![2]]),
        },
        rng_state(7),
    );
    let dir = std::env::temp_dir().join("hybridfl_snapshot_file_roundtrip");
    let _ = std::fs::remove_dir_all(&dir);
    for kind in [CodecKind::Binary, CodecKind::Json] {
        let path = dir.join(format!("snap.{}", kind.codec().extension()));
        save_snapshot(&path, kind, &snap).unwrap();
        let back = load_snapshot(&path).unwrap();
        assert_same(&snap, &back);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
