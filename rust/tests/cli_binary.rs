//! Black-box tests of the `hybridfl` binary (the launcher a user actually
//! invokes).

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_hybridfl"))
}

#[test]
fn no_args_prints_usage() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("commands:"));
    assert!(text.contains("table3"));
}

#[test]
fn unknown_command_fails_loudly() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn config_command_emits_valid_json() {
    let out = bin()
        .args(["config", "--preset", "task2-scaled", "--set", "c=0.5"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    let json = hybridfl::jsonx::Json::parse(&text).unwrap();
    assert_eq!(json.get("task").unwrap().as_str().unwrap(), "mnist");
    assert_eq!(json.get("c_fraction").unwrap().as_f64().unwrap(), 0.5);
}

#[test]
fn unknown_option_is_rejected_with_its_value() {
    // '--portocol hybridfl' must not silently become a switch plus a stray
    // positional (the old Args footgun).
    let out = bin()
        .args(["run", "--portocol", "hybridfl"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown option"), "{err}");
    assert!(err.contains("--portocol"), "{err}");
}

#[test]
fn run_live_backend_smoke() {
    let out = bin()
        .args([
            "run",
            "--preset",
            "fig2",
            "--set",
            "t_max=4",
            "--backend",
            "live",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("best accuracy"));
    assert!(text.contains("backend live"));
}

#[test]
fn bad_override_reports_key() {
    let out = bin()
        .args(["config", "--set", "nonsense_key=1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("nonsense_key"));
}

#[test]
fn run_mock_roundtrip_with_trace() {
    let dir = std::env::temp_dir().join("hybridfl_cli_test");
    let _ = std::fs::create_dir_all(&dir);
    let trace = dir.join("trace.csv");
    let out = bin()
        .args([
            "run",
            "--preset",
            "fig2",
            "--set",
            "t_max=10",
            "--out",
            trace.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("best accuracy"));
    let csv = std::fs::read_to_string(&trace).unwrap();
    assert_eq!(csv.lines().count(), 11); // header + 10 rounds
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_then_resume_via_cli() {
    let dir = std::env::temp_dir().join("hybridfl_cli_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let out = bin()
        .args([
            "run",
            "--preset",
            "fig2",
            "--set",
            "t_max=6",
            "--checkpoint-dir",
            dir.to_str().unwrap(),
            "--checkpoint-every",
            "3",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let snap = dir.join("snapshot_round_000003.hflsnap");
    assert!(snap.exists());
    assert!(dir.join("snapshot_round_000006.hflsnap").exists());

    let out = bin()
        .args([
            "run",
            "--preset",
            "fig2",
            "--set",
            "t_max=6",
            "--resume",
            snap.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("best accuracy"));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite bugfix: `--resume` against a config that diverges from the
/// snapshot's fingerprint must fail loudly, naming the diverging fields,
/// instead of running an inconsistent hybrid run.
#[test]
fn resume_with_diverging_config_names_the_fields() {
    let dir = std::env::temp_dir().join("hybridfl_cli_ckpt_mismatch");
    let _ = std::fs::remove_dir_all(&dir);
    let out = bin()
        .args([
            "run",
            "--preset",
            "fig2",
            "--set",
            "t_max=4",
            "--checkpoint-dir",
            dir.to_str().unwrap(),
            "--checkpoint-every",
            "2",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());

    let out = bin()
        .args([
            "run",
            "--preset",
            "fig2",
            "--set",
            "t_max=4",
            "--set",
            "c=0.5",
            "--set",
            "e_dr=0.1",
            "--resume",
            dir.join("snapshot_round_000002.hflsnap").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("c_fraction"), "{err}");
    assert!(err.contains("dropout.mean"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_every_without_dir_fails_loudly() {
    let out = bin()
        .args([
            "run",
            "--preset",
            "fig2",
            "--set",
            "t_max=2",
            "--checkpoint-every",
            "1",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("checkpoint_dir"),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn run_with_comm_codec_and_bad_spec() {
    let out = bin()
        .args(["run", "--preset", "fig2", "--set", "t_max=3", "--comm", "i8"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(String::from_utf8_lossy(&out.stdout).contains("best accuracy"));

    let out = bin()
        .args(["run", "--preset", "fig2", "--comm", "gzip"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(
        String::from_utf8_lossy(&out.stderr).contains("gzip"),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
}

#[test]
fn fig2_command_writes_traces() {
    let dir = std::env::temp_dir().join("hybridfl_cli_fig2");
    let _ = std::fs::remove_dir_all(&dir);
    let out = bin()
        .args(["fig2", "--out", dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("theta"));
    assert!(dir.join("fig2_traces.csv").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn table3_quick_mock_grid() {
    let dir = std::env::temp_dir().join("hybridfl_cli_table3");
    let _ = std::fs::remove_dir_all(&dir);
    let out = bin()
        .args([
            "table3",
            "--quick",
            "--mock",
            "--target",
            "0.3",
            "--out",
            dir.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "stderr: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("Table III"));
    assert!(text.contains("hybridfl"));
    assert!(dir.join("table3.txt").exists());
    assert!(dir.join("sweep_aerofoil.json").exists());
    let _ = std::fs::remove_dir_all(&dir);
}
