//! Property-style invariant tests over the protocol/round engine.
//!
//! The offline vendor set has no `proptest`, so this module hand-rolls the
//! same discipline: generate many random configurations (population,
//! topology, reliability, C, protocol) from a seeded RNG and assert the
//! coordinator's invariants on every round of every run. ~100 runs ×
//! dozens of rounds each = thousands of checked rounds per test binary.

use hybridfl::config::{CacheMode, Dist, EngineKind, ExperimentConfig, ProtocolKind};
use hybridfl::rng::Rng;
use hybridfl::sim::FlRun;

/// Draw a random (but valid) experiment config on the mock engine.
fn random_config(rng: &mut Rng) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.n_clients = 6 + rng.below(60);
    cfg.n_edges = 1 + rng.below(5.min(cfg.n_clients));
    cfg.dataset_size = cfg.n_clients * (10 + rng.below(50));
    cfg.eval_size = 40;
    cfg.c_fraction = 0.05 + 0.9 * rng.uniform();
    cfg.dropout = Dist::new(rng.uniform() * 0.9, 0.05);
    cfg.t_max = 10 + rng.below(30);
    cfg.local_epochs = 1 + rng.below(8);
    cfg.protocol = ProtocolKind::ALL[rng.below(3)];
    cfg.cache_mode = if rng.bernoulli(0.5) {
        CacheMode::Regional
    } else {
        CacheMode::Fresh
    };
    cfg.seed = rng.next_u64();
    cfg
}

#[test]
fn rounds_satisfy_structural_invariants() {
    let mut meta = Rng::new(0xBEEF);
    for case in 0..60 {
        let cfg = random_config(&mut meta);
        let quota = cfg.quota();
        let n = cfg.n_clients;
        let label = format!(
            "case {case}: proto={} n={} m={} C={:.2} dr={:.2}",
            cfg.protocol.as_str(),
            n,
            cfg.n_edges,
            cfg.c_fraction,
            cfg.dropout.mean
        );
        let result = FlRun::new(cfg.clone()).unwrap().run().unwrap();
        assert_eq!(result.rounds.len(), cfg.t_max, "{label}");

        let mut prev_time = 0.0;
        let mut prev_best = f64::MIN;
        for row in &result.rounds {
            // Counting chains: submissions ⊆ alive ⊆ selected, per region.
            for r in 0..cfg.n_edges {
                assert!(
                    row.submissions[r] <= row.alive[r],
                    "{label} t={} region {r}: S > X",
                    row.t
                );
                assert!(
                    row.alive[r] <= row.selected[r],
                    "{label} t={} region {r}: X > U",
                    row.t
                );
                assert!(row.selected[r] <= n, "{label}");
            }
            let total_sel: usize = row.selected.iter().sum();
            assert!(total_sel >= 1 && total_sel <= n, "{label}");

            // HybridFL quota semantics: |S(t)| = min(quota-ish, |X(t)|)
            // (ties at the cutoff can push it slightly above the quota).
            if cfg.protocol == ProtocolKind::HybridFl {
                let subs: usize = row.submissions.iter().sum();
                let alive: usize = row.alive.iter().sum();
                if !row.deadline_hit {
                    assert!(subs >= quota, "{label} t={}: quota met but S<q", row.t);
                }
                assert!(subs <= alive, "{label}");
            }

            // Clock and accounting sanity.
            assert!(row.round_len > 0.0 && row.round_len.is_finite(), "{label}");
            assert!(row.cum_time > prev_time, "{label}");
            prev_time = row.cum_time;
            assert!(row.best_accuracy >= prev_best, "{label}");
            prev_best = row.best_accuracy;
            assert!(row.cum_energy_j >= 0.0, "{label}");
            assert!((0.0..=1.0).contains(&row.accuracy), "{label}");
        }
    }
}

#[test]
fn round_length_bounded_by_deadline_plus_rtt() {
    let mut meta = Rng::new(0xCAFE);
    for _ in 0..30 {
        let cfg = random_config(&mut meta);
        let run = FlRun::new(cfg.clone()).unwrap();
        let bound = run.tm.t_lim + run.tm.t_c2e2c + 1e-9;
        let result = run.run().unwrap();
        for row in &result.rounds {
            assert!(
                row.round_len <= bound,
                "{}: round {} len {} exceeds T_lim+RTT {}",
                cfg.protocol.as_str(),
                row.t,
                row.round_len,
                bound
            );
        }
    }
}

#[test]
fn energy_monotone_and_scales_with_selection() {
    // More selected clients (larger C) must never consume less energy
    // under identical seeds and reliability.
    let mut base = ExperimentConfig::task1_scaled();
    base.engine = EngineKind::Mock;
    base.n_clients = 30;
    base.n_edges = 3;
    base.dataset_size = 900;
    base.eval_size = 40;
    base.t_max = 25;
    base.dropout = Dist::new(0.2, 0.02);
    base.protocol = ProtocolKind::FedAvg;

    let mut prev = 0.0;
    for c in [0.1, 0.3, 0.6, 0.9] {
        let mut cfg = base.clone();
        cfg.c_fraction = c;
        let result = FlRun::new(cfg).unwrap().run().unwrap();
        let wh = result.summary.mean_device_energy_wh;
        assert!(wh > prev, "energy must grow with C: C={c} wh={wh} prev={prev}");
        prev = wh;
    }
}

#[test]
fn identical_seeds_reproduce_bitwise_metrics() {
    let mut meta = Rng::new(0xD00D);
    for _ in 0..10 {
        let cfg = random_config(&mut meta);
        let a = FlRun::new(cfg.clone()).unwrap().run().unwrap();
        let b = FlRun::new(cfg).unwrap().run().unwrap();
        assert_eq!(a.summary.best_accuracy, b.summary.best_accuracy);
        assert_eq!(a.summary.total_time, b.summary.total_time);
        for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
            assert_eq!(ra.submissions, rb.submissions);
            assert_eq!(ra.round_len, rb.round_len);
            assert_eq!(ra.cum_energy_j, rb.cum_energy_j);
        }
    }
}

#[test]
fn hybridfl_participation_tracks_c_under_any_reliability() {
    // The selection target (eq. 1): with slack modulation converged, mean
    // |X(t)|/n should track C regardless of the (agnostic) drop-out level.
    for dr in [0.1, 0.4, 0.7] {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.engine = EngineKind::Mock;
        cfg.n_clients = 60;
        cfg.n_edges = 3;
        cfg.dataset_size = 1800;
        cfg.eval_size = 40;
        cfg.c_fraction = 0.3;
        cfg.dropout = Dist::new(dr, 0.05);
        cfg.t_max = 150;
        cfg.protocol = ProtocolKind::HybridFl;
        let result = FlRun::new(cfg).unwrap().run().unwrap();
        let tail = &result.rounds[75..];
        let mean_alive: f64 = tail
            .iter()
            .map(|r| r.alive.iter().sum::<usize>() as f64 / 60.0)
            .sum::<f64>()
            / tail.len() as f64;
        assert!(
            (mean_alive - 0.3).abs() < 0.13,
            "dr={dr}: participation {mean_alive} should track C=0.3"
        );
    }
}

#[test]
fn extreme_configs_do_not_panic() {
    // Degenerate corners: single edge, tiny C, near-total drop-out, one
    // local epoch, single-client regions.
    let corners = [
        (1usize, 0.05, 0.0),
        (1, 1.0, 0.95),
        (5, 0.05, 0.95),
        (5, 1.0, 0.0),
    ];
    for (m, c, dr) in corners {
        for proto in ProtocolKind::ALL {
            let mut cfg = ExperimentConfig::task1_scaled();
            cfg.engine = EngineKind::Mock;
            cfg.n_clients = 8;
            cfg.n_edges = m;
            cfg.dataset_size = 240;
            cfg.eval_size = 40;
            cfg.c_fraction = c;
            cfg.dropout = Dist::new(dr, 0.01);
            cfg.t_max = 8;
            cfg.protocol = proto;
            let result = FlRun::new(cfg).unwrap().run().unwrap();
            assert_eq!(result.rounds.len(), 8);
        }
    }
}
