//! Live threaded runtime under stress: larger fleets, mixed reliability,
//! repeated start/stop — the coordination must neither deadlock nor leak
//! rounds.

use hybridfl::config::{Dist, ExperimentConfig, RegionSpec};
use hybridfl::live::{LiveCluster, LiveOpts};

fn base(n: usize, m: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.n_clients = n;
    cfg.n_edges = m;
    cfg.dataset_size = n * 40;
    cfg.eval_size = 50;
    cfg
}

#[test]
fn hundred_clients_eight_edges() {
    let mut cfg = base(100, 8);
    cfg.dropout = Dist::new(0.3, 0.05);
    let cluster = LiveCluster::new(cfg).unwrap();
    let stats = cluster
        .run(&LiveOpts { rounds: 5, time_scale: 1e-4 })
        .unwrap();
    assert_eq!(stats.len(), 5);
    assert!(stats.iter().filter(|s| s.quota_met).count() >= 3);
    assert!(stats.last().unwrap().global_progress > 0.0);
}

#[test]
fn mixed_reliability_regions_adapt_live() {
    let mut cfg = base(60, 3);
    cfg.regions = vec![
        RegionSpec { n_clients: 20, dropout_mean: 0.1 },
        RegionSpec { n_clients: 20, dropout_mean: 0.5 },
        RegionSpec { n_clients: 20, dropout_mean: 0.85 },
    ];
    cfg.dropout = Dist::new(0.5, 0.02);
    let cluster = LiveCluster::new(cfg).unwrap();
    let stats = cluster
        .run(&LiveOpts { rounds: 12, time_scale: 1e-4 })
        .unwrap();
    assert_eq!(stats.len(), 12);
    // The unreliable region must still contribute in later rounds (slack
    // compensation) — not necessarily every round, but not never.
    let late_sub_r2: usize = stats[6..].iter().map(|s| s.submissions[2]).sum();
    assert!(late_sub_r2 > 0, "region 3 never submitted: {stats:?}");
}

#[test]
fn repeated_clusters_are_clean() {
    // Spawn/teardown in a loop: thread or channel leaks would blow up fast.
    for i in 0..3 {
        let mut cfg = base(24, 2);
        cfg.seed = 100 + i;
        let cluster = LiveCluster::new(cfg).unwrap();
        let stats = cluster
            .run(&LiveOpts { rounds: 3, time_scale: 1e-4 })
            .unwrap();
        assert_eq!(stats.len(), 3);
    }
}

#[test]
fn zero_reliability_fleet_still_terminates() {
    let mut cfg = base(20, 2);
    cfg.dropout = Dist::new(0.98, 0.0);
    let cluster = LiveCluster::new(cfg).unwrap();
    let t0 = std::time::Instant::now();
    let stats = cluster
        .run(&LiveOpts { rounds: 3, time_scale: 1e-4 })
        .unwrap();
    assert_eq!(stats.len(), 3);
    // All rounds deadline-bound, yet wall time stays near 3 × scaled T_lim.
    assert!(t0.elapsed().as_secs() < 30);
    assert!(stats.iter().all(|s| !s.quota_met));
}
