//! Live threaded backend under stress — larger fleets, mixed reliability,
//! repeated start/stop (the coordination must neither deadlock nor leak
//! rounds) — plus the headline guarantee of the `FlEnvironment` redesign:
//! the *same* protocol implementation produces the same selection counts
//! and quota behavior whether rounds run on the virtual clock or on the
//! live thread/mpsc fabric.

use hybridfl::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind, RegionSpec};
use hybridfl::scenario::{Backend, Scenario};

fn base(n: usize, m: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = ProtocolKind::HybridFl;
    cfg.n_clients = n;
    cfg.n_edges = m;
    cfg.dataset_size = n * 40;
    cfg.eval_size = 50;
    cfg
}

fn live(cfg: ExperimentConfig, rounds: usize, time_scale: f64) -> hybridfl::sim::RunResult {
    Scenario::from_config(cfg)
        .rounds(rounds)
        .backend(Backend::Live)
        .time_scale(time_scale)
        .run()
        .unwrap()
}

/// Same seed ⇒ identical per-round selection counts and quota outcomes on
/// both backends. The live run is the same random world *enacted*: fates
/// and completions are shared draws, so with a generous time scale (ample
/// wall-clock gaps between scaled completion times) the thread fabric must
/// reproduce the simulator's observables round for round.
#[test]
fn sim_and_live_agree_on_selection_counts_and_quota() {
    let mut cfg = base(20, 2);
    cfg.dropout = Dist::new(0.25, 0.02);
    cfg.t_max = 5;
    cfg.seed = 42;

    let sim = Scenario::from_config(cfg.clone()).run().unwrap();
    let live = live(cfg, 5, 5e-3);

    assert_eq!(sim.rounds.len(), live.rounds.len());
    for (a, b) in sim.rounds.iter().zip(live.rounds.iter()) {
        assert_eq!(a.selected, b.selected, "selection diverged at round {}", a.t);
        assert_eq!(
            a.deadline_hit, b.deadline_hit,
            "quota behavior diverged at round {}",
            a.t
        );
    }
}

#[test]
fn hundred_clients_eight_edges() {
    let mut cfg = base(100, 8);
    cfg.dropout = Dist::new(0.3, 0.05);
    let stats = live(cfg, 5, 1e-4);
    assert_eq!(stats.rounds.len(), 5);
    // Reliable-enough fleet: the quota should be met in most rounds.
    let met = stats.rounds.iter().filter(|r| !r.deadline_hit).count();
    assert!(met >= 3, "quota met only {met}/5 rounds");
    // Training flowed through the full distributed path.
    assert!(stats.summary.best_accuracy > 0.0);
}

#[test]
fn mixed_reliability_regions_adapt_live() {
    let mut cfg = base(60, 3);
    cfg.regions = vec![
        RegionSpec { n_clients: 20, dropout_mean: 0.1 },
        RegionSpec { n_clients: 20, dropout_mean: 0.5 },
        RegionSpec { n_clients: 20, dropout_mean: 0.85 },
    ];
    cfg.dropout = Dist::new(0.5, 0.02);
    let stats = live(cfg, 12, 1e-4);
    assert_eq!(stats.rounds.len(), 12);
    // The unreliable region must still contribute in later rounds (slack
    // compensation) — not necessarily every round, but not never.
    let late_sub_r2: usize = stats.rounds[6..].iter().map(|s| s.submissions[2]).sum();
    assert!(late_sub_r2 > 0, "region 3 never submitted");
}

#[test]
fn repeated_clusters_are_clean() {
    // Spawn/teardown in a loop: thread or channel leaks would blow up fast.
    for i in 0..3 {
        let mut cfg = base(24, 2);
        cfg.seed = 100 + i;
        let stats = live(cfg, 3, 1e-4);
        assert_eq!(stats.rounds.len(), 3);
    }
}

#[test]
fn zero_reliability_fleet_still_terminates() {
    let mut cfg = base(20, 2);
    cfg.dropout = Dist::new(0.98, 0.0);
    let t0 = std::time::Instant::now();
    let stats = live(cfg, 3, 1e-4);
    assert_eq!(stats.rounds.len(), 3);
    // All rounds deadline-bound, yet wall time stays near 3 × scaled T_lim.
    assert!(t0.elapsed().as_secs() < 30);
    assert!(stats.rounds.iter().all(|s| s.deadline_hit));
}

/// The wait-for-all baselines run unchanged on the live fabric too: with
/// drop-outs, FedAvg rounds stall to the deadline exactly as in the sim.
#[test]
fn fedavg_live_stalls_to_deadline_under_dropout() {
    let mut cfg = base(16, 2);
    cfg.protocol = ProtocolKind::FedAvg;
    cfg.dropout = Dist::new(0.8, 0.02);
    let stats = live(cfg, 3, 1e-4);
    assert_eq!(stats.rounds.len(), 3);
    assert!(stats.rounds.iter().all(|r| r.deadline_hit));
}
