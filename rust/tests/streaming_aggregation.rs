//! Property tests pinning the streaming aggregator to the batch math: for
//! random models, weights and region layouts, `StreamingAggregator` must
//! match `regional_with_cache` + `edc_cloud` within 1e-5 *regardless of
//! fold order*, including the empty-region and zero-EDC
//! keep-previous-model edges. The offline vendor set has no `proptest`,
//! so this hand-rolls the discipline with the seeded `Rng`.

use hybridfl::aggregation::{
    edc_cloud, fedavg, fedavg_from_regions, regional_with_cache, RegionAccumulator,
    StreamingAggregator,
};
use hybridfl::model::ModelParams;
use hybridfl::rng::Rng;

fn shapes() -> Vec<Vec<usize>> {
    vec![vec![4, 3], vec![3], vec![7]]
}

fn rand_model(rng: &mut Rng) -> ModelParams {
    let shapes = shapes();
    let tensors = shapes
        .iter()
        .map(|s| {
            (0..s.iter().product::<usize>())
                .map(|_| rng.normal(0.0, 1.0) as f32)
                .collect()
        })
        .collect();
    ModelParams::new(tensors, shapes)
}

/// One random round: submissions per region (region 0 forced empty on odd
/// cases), region data sizes strictly above coverage, random previous
/// regional models.
struct Case {
    m: usize,
    submissions: Vec<(usize, ModelParams, f64)>,
    region_data: Vec<f64>,
    prevs: Vec<ModelParams>,
}

fn random_case(rng: &mut Rng, case: usize) -> Case {
    let m = 1 + rng.below(4);
    let mut submissions = Vec::new();
    let mut region_data = vec![0.0f64; m];
    let prevs: Vec<ModelParams> = (0..m).map(|_| rand_model(rng)).collect();
    for r in 0..m {
        let k = if case % 2 == 1 && r == 0 { 0 } else { rng.below(7) };
        let mut covered = 0.0;
        for _ in 0..k {
            let d = (1 + rng.below(50)) as f64;
            covered += d;
            submissions.push((r, rand_model(rng), d));
        }
        region_data[r] = covered + (1 + rng.below(100)) as f64;
    }
    Case {
        m,
        submissions,
        region_data,
        prevs,
    }
}

/// Batch reference: regional cache rule per region + EDC cloud weighting.
fn batch_reference(c: &Case) -> (Vec<(ModelParams, f64)>, Option<ModelParams>) {
    let mut regionals = Vec::with_capacity(c.m);
    for r in 0..c.m {
        let models: Vec<(&ModelParams, f64)> = c
            .submissions
            .iter()
            .filter(|(rr, _, _)| *rr == r)
            .map(|(_, w, d)| (w, *d))
            .collect();
        let edc: f64 = models.iter().map(|(_, d)| *d).sum();
        let w = regional_with_cache(&models, c.region_data[r], &c.prevs[r]).unwrap();
        regionals.push((w, edc));
    }
    let refs: Vec<(&ModelParams, f64)> = regionals.iter().map(|(w, e)| (w, *e)).collect();
    let cloud = edc_cloud(&refs);
    (regionals, cloud)
}

fn streamed_in_order(c: &Case, order: &[usize]) -> StreamingAggregator {
    let template = c.prevs[0].zeros_like();
    let mut agg = StreamingAggregator::for_regions(&c.region_data, &template);
    for &i in order {
        let (r, w, d) = &c.submissions[i];
        agg.fold(*r, w, *d, 0.0).unwrap();
    }
    agg
}

#[test]
fn streaming_matches_batch_regardless_of_fold_order() {
    let mut rng = Rng::new(0x5EED_CA5E);
    for case in 0..40 {
        let c = random_case(&mut rng, case);
        let (batch_regionals, batch_cloud) = batch_reference(&c);

        // Three fold orders per case: forward, reverse, shuffled.
        let n = c.submissions.len();
        let forward: Vec<usize> = (0..n).collect();
        let reverse: Vec<usize> = (0..n).rev().collect();
        let mut shuffled: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut shuffled);

        for order in [&forward, &reverse, &shuffled] {
            let agg = streamed_in_order(&c, order);
            // Per-region: finished cache rule and EDC must match batch.
            for (r, acc) in agg.regions().iter().enumerate() {
                let w = acc.finish_cached(&c.prevs[r]).unwrap();
                let dist = w.l2_distance(&batch_regionals[r].0);
                assert!(
                    dist < 1e-5,
                    "case {case} region {r}: streamed vs batch regional l2={dist}"
                );
                assert!((acc.edc() - batch_regionals[r].1).abs() < 1e-9, "case {case}");
            }
            // Cloud: same model (or both keep-previous).
            let stream_cloud = agg.cloud_with_cache(&c.prevs).unwrap();
            match (&stream_cloud, &batch_cloud) {
                (Some(s), Some(b)) => {
                    let dist = s.l2_distance(b);
                    assert!(dist < 1e-5, "case {case}: cloud l2={dist}");
                }
                (None, None) => {}
                _ => panic!("case {case}: cloud keep-previous decision diverged"),
            }
        }
    }
}

/// Streamed global FedAvg (per-region partial sums recombined) must match
/// the one-shot weighted average over all submissions.
#[test]
fn fedavg_recombination_matches_flat_fedavg() {
    let mut rng = Rng::new(0xFEDA_0001);
    for case in 0..25 {
        let c = random_case(&mut rng, case);
        let flat: Vec<(&ModelParams, f64)> =
            c.submissions.iter().map(|(_, w, d)| (w, *d)).collect();
        let batch = fedavg(&flat);
        let mut shuffled: Vec<usize> = (0..c.submissions.len()).collect();
        rng.shuffle(&mut shuffled);
        let agg = streamed_in_order(&c, &shuffled);
        let streamed = fedavg_from_regions(agg.regions());
        match (&streamed, &batch) {
            (Some(s), Some(b)) => {
                let dist = s.l2_distance(b);
                assert!(dist < 1e-5, "case {case}: fedavg l2={dist}");
            }
            (None, None) => {}
            _ => panic!("case {case}: fedavg emptiness diverged"),
        }
    }
}

/// Zero-EDC edges: with no submissions anywhere, every region's finished
/// model is exactly its previous model and the cloud keeps w(t−1) (None).
#[test]
fn zero_edc_keeps_previous_models() {
    let mut rng = Rng::new(7);
    let prevs: Vec<ModelParams> = (0..3).map(|_| rand_model(&mut rng)).collect();
    let template = prevs[0].zeros_like();
    let agg = StreamingAggregator::for_regions(&[100.0; 3], &template);
    for (r, acc) in agg.regions().iter().enumerate() {
        assert_eq!(acc.count(), 0);
        assert_eq!(acc.edc(), 0.0);
        let w = acc.finish_cached(&prevs[r]).unwrap();
        assert!(w.l2_distance(&prevs[r]) < 1e-6);
        assert!(acc.fedavg().is_none());
    }
    assert!(agg.cloud_with_cache(&prevs).unwrap().is_none());
    assert!(fedavg_from_regions(agg.regions()).is_none());
}

/// The satellite clamp fix: folded data sizes exceeding |D^r| must be an
/// error from both the batch function and the streamed finisher — not a
/// silent `.max(0.0)`.
#[test]
fn overcoverage_errors_in_both_forms() {
    let mut rng = Rng::new(11);
    let prev = rand_model(&mut rng);
    let w = rand_model(&mut rng);
    assert!(regional_with_cache(&[(&w, 150.0)], 100.0, &prev).is_err());
    let mut acc = RegionAccumulator::new(0, 100.0, &prev);
    acc.fold(&w, 150.0, 0.0).unwrap();
    assert!(acc.finish_cached(&prev).is_err());
    // Exact full coverage stays fine.
    assert!(regional_with_cache(&[(&w, 100.0)], 100.0, &prev).is_ok());
}
