//! Property tests for the slack estimator (`selection::slack`): the
//! §III.A invariants that must hold for *arbitrary* observation
//! sequences, not just the trajectories the unit tests happen to walk.
//!
//! Three families, each fuzzed over seeded random `(n_r, C, θ_init)`
//! draws and random `observe()` streams:
//!
//! 1. θ̂ never escapes its clamp band and the derived quantities stay in
//!    their definitional ranges.
//! 2. The O(1) running-sum LSE is exactly the full-history recomputation
//!    (the optimization changes nothing, to 1e-12).
//! 3. Deadline rounds (q̂ = 1) are unbiased samples: a stream of them
//!    monotonically pulls θ̂ toward the empirical delivery rate.

use hybridfl::rng::Rng;
use hybridfl::selection::SlackEstimator;

/// θ̂'s clamp band (slack.rs THETA_MIN/THETA_MAX — pinned here so a
/// silent change to the band fails a test, not just a doc).
const THETA_MIN: f64 = 0.05;
const THETA_MAX: f64 = 1.0;

/// Draw a random but valid estimator setup.
fn random_setup(rng: &mut Rng) -> (usize, f64, f64) {
    let n_r = 1 + rng.below(120);
    let c = rng.uniform_in(0.05, 0.9);
    let theta_init = rng.uniform_in(0.01, 1.5); // deliberately allows out-of-band inits
    (n_r, c, theta_init)
}

/// One random observation: submissions may exceed the selection count
/// (the estimator must tolerate any usize the environment reports) and
/// censoring is a coin flip.
fn random_observation(rng: &mut Rng, n_r: usize) -> (usize, bool) {
    (rng.below(2 * n_r + 1), rng.bernoulli(0.5))
}

#[test]
fn theta_stays_in_clamped_bounds_under_arbitrary_observations() {
    let seeds = Rng::new(0x51ac);
    for trial in 0..50 {
        let mut rng = seeds.split(trial);
        let (n_r, c, theta_init) = random_setup(&mut rng);
        let mut e = SlackEstimator::new(n_r, c, theta_init);
        for round in 0..200 {
            assert!(
                (THETA_MIN..=THETA_MAX).contains(&e.theta()),
                "trial {trial} round {round}: theta {} out of [{THETA_MIN}, {THETA_MAX}] \
                 (n_r={n_r}, c={c}, theta_init={theta_init})",
                e.theta()
            );
            assert!(
                ((c - 1e-12)..=(1.0 + 1e-12)).contains(&e.c_r()),
                "trial {trial} round {round}: c_r {} out of [C, 1] (c={c})",
                e.c_r()
            );
            let count = e.selection_count();
            assert!(
                (1..=n_r).contains(&count),
                "trial {trial} round {round}: selection count {count} out of [1, {n_r}]"
            );
            let (s, censored) = random_observation(&mut rng, n_r);
            e.observe(s, censored);
            let last = e.last_state().unwrap();
            assert!(
                (0.0..=1.0).contains(&last.q_r),
                "q_r {} out of [0, 1]",
                last.q_r
            );
        }
        assert_eq!(e.rounds_observed(), 200);
    }
}

/// Reference θ̂: recompute eq. 15 from the *entire* history each round,
/// with the same clamp and the same all-zero guard as the running-sum
/// implementation.
fn theta_from_full_history(
    n_r: usize,
    history: &[(f64, f64, f64)], // (c_r at observe time, q, s)
    fallback: f64,
) -> f64 {
    let num: f64 = history.iter().map(|(c_r, q, s)| c_r * q * s).sum();
    let den: f64 = history.iter().map(|(c_r, q, _)| (c_r * q) * (c_r * q)).sum();
    if den > 1e-12 {
        (num / (n_r as f64 * den)).clamp(THETA_MIN, THETA_MAX)
    } else {
        fallback
    }
}

#[test]
fn running_sums_match_full_history_recompute() {
    let seeds = Rng::new(0xf011);
    for trial in 0..30 {
        let mut rng = seeds.split(trial);
        let (n_r, c, theta_init) = random_setup(&mut rng);
        let mut e = SlackEstimator::new(n_r, c, theta_init);
        let mut history: Vec<(f64, f64, f64)> = Vec::new();
        let theta_start = e.theta(); // post-clamp init, the den==0 fallback
        for round in 0..150 {
            let (s, censored) = random_observation(&mut rng, n_r);
            // Reconstruct the sample exactly as observe() will ingest it.
            let q = if censored {
                (s as f64 / (c * n_r as f64)).min(1.0)
            } else {
                1.0
            };
            history.push((e.c_r(), q, s as f64));
            e.observe(s, censored);
            let reference = theta_from_full_history(n_r, &history, theta_start);
            assert!(
                (e.theta() - reference).abs() <= 1e-12,
                "trial {trial} round {round}: running-sum theta {} deviates from \
                 full-history recompute {} (n_r={n_r}, c={c})",
                e.theta(),
                reference
            );
        }
    }
}

#[test]
fn deadline_rounds_pull_theta_toward_empirical_delivery_rate() {
    let n_r = 100;
    let c = 0.3;
    for p in [0.35, 0.6, 0.85] {
        let mut e = SlackEstimator::new(n_r, c, 0.5);
        let mut prev_gap = (e.theta() - p).abs();
        for round in 0..300 {
            // Deterministic delivery at exactly rate p: every deadline
            // round is an unbiased sample s = p·selected, q̂ = 1.
            let s = (p * e.selection_count() as f64).round() as usize;
            e.observe(s, false);
            let gap = (e.theta() - p).abs();
            assert!(
                gap <= prev_gap + 0.02,
                "p={p} round {round}: |theta - p| grew {prev_gap} -> {gap}"
            );
            prev_gap = gap;
        }
        assert!(
            (e.theta() - p).abs() < 0.05,
            "p={p}: theta {} should settle near the delivery rate",
            e.theta()
        );
    }
}
