//! `hybridfl` — the coordinator CLI / experiment launcher.
//!
//! ```text
//! hybridfl run    [--preset P] [--config f.json] [--set k=v]... [--out trace.csv]
//! hybridfl fig2   [--out dir] [--seed N]
//! hybridfl table3 [--full|--quick] [--mock] [--target A] [--out dir]
//! hybridfl table4 [--full|--quick] [--mock] [--target A] [--out dir]
//! hybridfl live   [--rounds N] [--set k=v]...
//! hybridfl config [--preset P] [--set k=v]...      # print resolved JSON
//! ```
//!
//! `table3`/`table4` regenerate the paper's tables **and** the trace CSVs
//! behind Figs. 4/6 and the energy tables of Figs. 5/7 (one sweep produces
//! all three artifacts — see `harness::sweep`).

use std::path::PathBuf;
use std::process::ExitCode;

use hybridfl::cli::Args;
use hybridfl::config::{ExperimentConfig, TaskKind};
use hybridfl::harness::{self, run_fig2, run_task_sweep, SweepOpts};
use hybridfl::live::{LiveCluster, LiveOpts};
use hybridfl::metrics;
use hybridfl::sim::FlRun;

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn real_main() -> hybridfl::Result<()> {
    let args = Args::from_env()?;
    match args.command() {
        Some("run") => cmd_run(&args),
        Some("fig2") => cmd_fig2(&args),
        Some("table3") => cmd_table(TaskKind::Aerofoil, &args),
        Some("table4") => cmd_table(TaskKind::Mnist, &args),
        Some("ablation") => cmd_ablation(&args),
        Some("live") => cmd_live(&args),
        Some("config") => cmd_config(&args),
        Some(other) => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "\
hybridfl — federated learning over reliability-agnostic clients in MEC
commands:
  run     one FL run (--preset task1|task1-scaled|task2|task2-scaled|fig2,
          --config cfg.json, --set key=value ..., --out trace.csv)
  fig2    slack-factor traces (paper Fig. 2) -> reports/fig2_traces.csv
  table3  Task-1 sweep: Table III + Fig. 4 traces + Fig. 5 energy
  table4  Task-2 sweep: Table IV + Fig. 6 traces + Fig. 7 energy
          (--full paper scale, --quick smoke grid, --mock no-PJRT,
           --target A, --out dir)
  ablation cache-rule / theta_init / kappa2 / slack-contribution sweeps
          (--mock for dynamics-only; default real PJRT)
  live    threaded cloud/edge/client cluster demo (--rounds N)
  config  print the resolved config as JSON";

/// Resolve a config from --preset / --config plus --set overrides.
fn resolve_config(args: &Args) -> hybridfl::Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::load(std::path::Path::new(path))?
    } else {
        ExperimentConfig::preset(args.get("preset").unwrap_or("task1-scaled"))?
    };
    if let Some(seed) = args.get_parsed::<u64>("seed")? {
        cfg.seed = seed;
    }
    let overrides = args.all("set");
    hybridfl::config::apply_overrides(&mut cfg, &overrides)?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> hybridfl::Result<()> {
    let cfg = resolve_config(args)?;
    println!(
        "running {} ({} / {})",
        cfg.name,
        cfg.protocol.as_str(),
        cfg.engine.as_str()
    );
    let result = FlRun::new(cfg)?.run()?;
    let s = &result.summary;
    println!("rounds run          : {}", s.rounds_run);
    println!("best accuracy       : {:.4}", s.best_accuracy);
    println!("avg round length    : {:.2} s", s.avg_round_len);
    println!("total virtual time  : {:.1} s", s.total_time);
    println!("mean device energy  : {:.4} Wh", s.mean_device_energy_wh);
    if let Some(rt) = s.rounds_to_target {
        println!("rounds to target    : {rt}");
        println!(
            "time to target      : {:.1} s",
            s.time_to_target.unwrap_or(f64::NAN)
        );
    }
    if let Some(out) = args.get("out") {
        metrics::write_csv(std::path::Path::new(out), &result.rounds)?;
        println!("trace written to {out}");
    }
    Ok(())
}

fn cmd_fig2(args: &Args) -> hybridfl::Result<()> {
    let out = out_dir(args);
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let (_, stats) = run_fig2(&out, seed)?;
    print!("{}", harness::fig2::render_stats(&stats));
    println!("traces -> {}", out.join("fig2_traces.csv").display());
    Ok(())
}

fn cmd_table(task: TaskKind, args: &Args) -> hybridfl::Result<()> {
    let out = out_dir(args);
    let opts = SweepOpts {
        full: args.has("full"),
        quick: args.has("quick"),
        mock: args.has("mock"),
        target: args.get_parsed::<f64>("target")?,
        t_max: args.get_parsed::<usize>("rounds")?,
        seed: args.get_parsed::<u64>("seed")?.unwrap_or(42),
    };
    let sweep = run_task_sweep(task, &opts, &out)?;
    print!("{}", harness::sweep::render_table(&sweep));
    println!();
    print!("{}", harness::sweep::render_energy(&sweep));
    println!("artifacts -> {}", out.display());
    Ok(())
}

fn cmd_ablation(args: &Args) -> hybridfl::Result<()> {
    let families = harness::ablation::run_all(args.has("mock"))?;
    for (name, rows) in &families {
        print!("{}", harness::ablation::render(name, rows));
        println!();
    }
    Ok(())
}

fn cmd_live(args: &Args) -> hybridfl::Result<()> {
    let cfg = resolve_config(args)?;
    let rounds = args.get_parsed::<usize>("rounds")?.unwrap_or(10);
    println!(
        "live cluster: {} clients / {} edges, {} rounds (time scale 1e-4)",
        cfg.n_clients, cfg.n_edges, rounds
    );
    let cluster = LiveCluster::new(cfg)?;
    let stats = cluster.run(&LiveOpts { rounds, time_scale: 1e-4 })?;
    for s in &stats {
        println!(
            "round {:>3}  wall {:>8.1?}  submissions {:?}  quota_met {}  progress {:.2}",
            s.t, s.wall, s.submissions, s.quota_met, s.global_progress
        );
    }
    Ok(())
}

fn cmd_config(args: &Args) -> hybridfl::Result<()> {
    let cfg = resolve_config(args)?;
    println!("{}", cfg.to_json().pretty());
    Ok(())
}

fn out_dir(args: &Args) -> PathBuf {
    args.get("out")
        .map(PathBuf::from)
        .unwrap_or_else(harness::default_out_dir)
}
