//! `hybridfl` — the coordinator CLI / experiment launcher.
//!
//! ```text
//! hybridfl run    [--preset P] [--config f.json] [--set k=v]...
//!                 [--backend sim|live] [--scale S] [--out trace.csv]
//!                 [--checkpoint-dir D [--checkpoint-every N]]
//!                 [--resume snapshot.hflsnap]
//!                 [--churn SPEC] [--record-fates f.json]
//!                 [--replay-fates f.json] [--ops-listen ADDR]
//!                 [--ops-token TOKEN] [--trace-out trace.json]
//! hybridfl fig2   [--out dir] [--seed N]
//! hybridfl table3 [--full|--quick] [--mock] [--serial] [--target A] [--out dir]
//! hybridfl table4 [--full|--quick] [--mock] [--serial] [--target A] [--out dir]
//! hybridfl live   [--rounds N] [--scale S] [--set k=v]...
//! hybridfl config [--preset P] [--set k=v]...      # print resolved JSON
//! ```
//!
//! `table3`/`table4` regenerate the paper's tables **and** the trace CSVs
//! behind Figs. 4/6 and the energy tables of Figs. 5/7 (one sweep produces
//! all three artifacts — see `harness::sweep`; grid cells run on worker
//! threads unless `--serial`).

use std::path::PathBuf;
use std::process::ExitCode;

use hybridfl::cli::Args;
use hybridfl::config::{ExperimentConfig, TaskKind};
use hybridfl::harness::{self, run_fig2, run_task_sweep, SweepOpts};
use hybridfl::metrics;
use hybridfl::scenario::{Backend, Scenario};
use hybridfl::sim::RunResult;

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn real_main() -> hybridfl::Result<()> {
    let args = Args::from_env()?;
    match args.command() {
        Some("run") => cmd_run(&args),
        Some("fig2") => cmd_fig2(&args),
        Some("table3") => cmd_table(TaskKind::Aerofoil, &args),
        Some("table4") => cmd_table(TaskKind::Mnist, &args),
        Some("ablation") => cmd_ablation(&args),
        Some("live") => cmd_live(&args),
        Some("config") => cmd_config(&args),
        Some(other) => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "\
hybridfl — federated learning over reliability-agnostic clients in MEC
commands:
  run     one FL run (--preset task1|task1-scaled|task2|task2-scaled|fig2,
          --config cfg.json, --set key=value ..., --backend sim|live,
          --scale S wall-clock seconds per virtual second for live,
          --out trace.csv,
          --checkpoint-dir DIR write a resumable snapshot at round
          boundaries [--checkpoint-every N widens the cadence],
          --resume FILE continue a snapshotted run; the config must
          match the snapshot's fingerprint exactly,
          --churn SPEC time-varying reliability: stationary | markov |
          diurnal | battery | script:events.json | replay:trace.json,
          options as k=v after ':', compose layers with '+'
          (e.g. markov:p_fail=0.1+script:blackout.json),
          --record-fates FILE export the run's ground-truth per-round
          fates as a replayable JSON trace,
          --replay-fates FILE drive the world from a recorded or
          hand-written fate trace instead of drawing fates,
          --selector slack|fedcs|oracle|random client-selection strategy
          (slack = the paper's estimator, default; oracle is sim-only),
          --comm SPEC upload codec: dense | f16 | i8 | topk:RATIO,
          '+ef' adds error feedback (sim-only), '+relay:Q' hands the
          weakest Q quantile's uploads to strong relays
          (e.g. topk:0.05+ef, i8+relay:0.25),
          --ops-listen ADDR serve the operations control plane while the
          run is in flight: GET /metrics is a Prometheus-text scrape
          (gauges, counters, and round-length / submission-latency /
          phase-duration histograms), anything else is a line-oriented
          control session
          (status | pause | resume | checkpoint-now [DIR] | inject JSON),
          --ops-token TOKEN guard the ops endpoint: /metrics needs
          ?token=TOKEN and control sessions must open with 'auth TOKEN';
          required when --ops-listen is not a loopback address,
          --trace-out FILE write a Chrome trace-event JSON of every
          round-phase span at run end (open in Perfetto))
  fig2    slack-factor traces (paper Fig. 2) -> reports/fig2_traces.csv
  table3  Task-1 sweep: Table III + Fig. 4 traces + Fig. 5 energy
  table4  Task-2 sweep: Table IV + Fig. 6 traces + Fig. 7 energy
          (--full paper scale, --quick smoke grid, --mock no-PJRT,
           --serial disable the threaded sweep, --target A, --out dir)
  ablation cache-rule / theta_init / kappa2 / slack-contribution sweeps
          (--mock for dynamics-only; default real PJRT)
  live    threaded cloud/edge/client cluster run (--rounds N, --scale S);
          shorthand for run --backend live
  config  print the resolved config as JSON";

/// Resolve a config from --preset / --config plus --set overrides.
fn resolve_config(args: &Args) -> hybridfl::Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = args.get("config") {
        ExperimentConfig::load(std::path::Path::new(path))?
    } else {
        ExperimentConfig::preset(args.get("preset").unwrap_or("task1-scaled"))?
    };
    if let Some(seed) = args.get_parsed::<u64>("seed")? {
        cfg.seed = seed;
    }
    let overrides = args.all("set");
    hybridfl::config::apply_overrides(&mut cfg, &overrides)?;
    Ok(cfg)
}

/// Build a Scenario from the CLI flags shared by `run` and `live`.
fn resolve_scenario(args: &Args, default_backend: Backend) -> hybridfl::Result<Scenario> {
    let cfg = resolve_config(args)?;
    let backend = match args.get("backend") {
        Some(s) => Backend::parse(s)?,
        None => default_backend,
    };
    let mut sc = Scenario::from_config(cfg).backend(backend);
    if let Some(scale) = args.get_parsed::<f64>("scale")? {
        sc = sc.time_scale(scale);
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        sc = sc.checkpoint_dir(dir);
    }
    if let Some(every) = args.get_parsed::<usize>("checkpoint-every")? {
        // Scenario::run rejects the combination without a directory.
        sc = sc.checkpoint_every(every);
    }
    if let Some(path) = args.get("resume") {
        sc = sc.resume_from(path);
    }
    if let Some(spec) = args.get("churn") {
        sc = sc.churn(hybridfl::churn::ChurnModel::parse_spec(spec)?);
    }
    if let Some(s) = args.get("selector") {
        sc = sc.selector(hybridfl::selection::SelectorKind::parse(s)?);
    }
    if let Some(spec) = args.get("comm") {
        sc = sc.comm(hybridfl::comm::CommConfig::parse_spec(spec)?);
    }
    if let Some(path) = args.get("replay-fates") {
        // Guard against *any* configured churn model — whether it came
        // from --churn, --set churn=..., or a --config file — not just
        // the flag: silently discarding one would run a different world
        // than the user asked for.
        let configured = &sc.config().churn;
        anyhow::ensure!(
            matches!(configured, hybridfl::churn::ChurnModel::Stationary),
            "--replay-fates replaces the churn model, but a '{}' model is \
             already configured (via --churn, --set churn=..., or the config \
             file); drop one of the two",
            configured.kind_str()
        );
        sc = sc.replay_fates(path);
    }
    if let Some(path) = args.get("record-fates") {
        sc = sc.record_fates(path);
    }
    if let Some(addr) = args.get("ops-listen") {
        sc = sc.ops_listen(addr);
    }
    if let Some(token) = args.get("ops-token") {
        sc = sc.ops_token(token);
    }
    if let Some(path) = args.get("trace-out") {
        sc = sc.trace_out(path);
    }
    Ok(sc)
}

fn print_summary(result: &RunResult) {
    let s = &result.summary;
    println!("rounds run          : {}", s.rounds_run);
    println!("best accuracy       : {:.4}", s.best_accuracy);
    println!("avg round length    : {:.2} s", s.avg_round_len);
    println!("total virtual time  : {:.1} s", s.total_time);
    println!("mean device energy  : {:.4} Wh", s.mean_device_energy_wh);
    if let Some(rt) = s.rounds_to_target {
        println!("rounds to target    : {rt}");
        println!(
            "time to target      : {:.1} s",
            s.time_to_target.unwrap_or(f64::NAN)
        );
    }
}

fn cmd_run(args: &Args) -> hybridfl::Result<()> {
    let sc = resolve_scenario(args, Backend::Sim)?;
    let cfg = sc.config();
    println!(
        "running {} ({} / {} / backend {})",
        cfg.name,
        cfg.protocol.as_str(),
        cfg.engine.as_str(),
        args.get("backend").unwrap_or("sim"),
    );
    if let Some(addr) = args.get("ops-listen") {
        println!("ops endpoint on {addr} (GET /metrics, or a control session)");
    }
    // --out streams row by row as a RunObserver on the round-boundary
    // event stream (the same events the ops endpoint consumes), instead
    // of rendering post-hoc from the final result.
    let mut sink = args
        .get("out")
        .map(|out| metrics::ReportSink::new(cfg).csv(out));
    let result = match sink.as_mut() {
        Some(sink) => {
            let mut observers: [&mut dyn hybridfl::ops::RunObserver; 1] = [sink];
            sc.run_observed(&mut observers)?
        }
        None => sc.run()?,
    };
    print_summary(&result);
    if let Some(out) = args.get("out") {
        println!("trace written to {out}");
    }
    Ok(())
}

fn cmd_fig2(args: &Args) -> hybridfl::Result<()> {
    let out = out_dir(args);
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let (_, stats) = run_fig2(&out, seed)?;
    print!("{}", harness::fig2::render_stats(&stats));
    println!("traces -> {}", out.join("fig2_traces.csv").display());
    Ok(())
}

fn cmd_table(task: TaskKind, args: &Args) -> hybridfl::Result<()> {
    let out = out_dir(args);
    let opts = SweepOpts {
        full: args.has("full"),
        quick: args.has("quick"),
        mock: args.has("mock"),
        target: args.get_parsed::<f64>("target")?,
        t_max: args.get_parsed::<usize>("rounds")?,
        seed: args.get_parsed::<u64>("seed")?.unwrap_or(42),
        parallel: !args.has("serial"),
    };
    let sweep = run_task_sweep(task, &opts, &out)?;
    print!("{}", harness::sweep::render_table(&sweep));
    println!();
    print!("{}", harness::sweep::render_energy(&sweep));
    println!("artifacts -> {}", out.display());
    Ok(())
}

fn cmd_ablation(args: &Args) -> hybridfl::Result<()> {
    let families = harness::ablation::run_all(args.has("mock"))?;
    for (name, rows) in &families {
        print!("{}", harness::ablation::render(name, rows));
        println!();
    }
    Ok(())
}

fn cmd_live(args: &Args) -> hybridfl::Result<()> {
    let mut sc = resolve_scenario(args, Backend::Live)?;
    let t_max_overridden = args
        .all("set")
        .iter()
        .any(|kv| kv.trim_start().starts_with("t_max"));
    if let Some(rounds) = args.get_parsed::<usize>("rounds")? {
        sc = sc.rounds(rounds);
    } else if !t_max_overridden {
        // Presets carry hundreds of rounds; a live demo defaults to 10
        // unless the user asked for more via --rounds or --set t_max=N.
        sc = sc.rounds(10);
    }
    let cfg = sc.config();
    println!(
        "live cluster: {} clients / {} edges, {} rounds",
        cfg.n_clients, cfg.n_edges, cfg.t_max
    );
    let result = sc.run()?;
    for row in &result.rounds {
        println!(
            "round {:>3}  len {:>8.1}s  submissions {:?}  quota_met {}  acc {:.3}",
            row.t,
            row.round_len,
            row.submissions,
            !row.deadline_hit,
            row.accuracy
        );
    }
    print_summary(&result);
    Ok(())
}

fn cmd_config(args: &Args) -> hybridfl::Result<()> {
    let cfg = resolve_config(args)?;
    println!("{}", cfg.to_json().pretty());
    Ok(())
}

fn out_dir(args: &Args) -> PathBuf {
    args.get("out")
        .map(PathBuf::from)
        .unwrap_or_else(harness::default_out_dir)
}
