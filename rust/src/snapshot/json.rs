//! Human-readable JSON snapshot codec (debugging / inspection).
//!
//! Same information as the binary codec, rendered through
//! [`crate::jsonx`] so a checkpoint can be inspected with standard
//! tooling. Bit-exactness notes:
//!
//! * f64 fields round-trip exactly: the writer emits Rust's
//!   shortest-roundtrip formatting and the parser reads it back to the
//!   identical bits. `NaN` (e.g. `last_loss` before the first
//!   evaluation) is written as `null` and restored as the canonical NaN.
//! * u64 words that can exceed 2^53 (RNG state, the config fingerprint)
//!   are encoded as fixed-width hex *strings*, never JSON numbers.
//! * f32 arena values pass through f64 losslessly (every f32 is exactly
//!   representable). The one caveat vs. the binary codec: a NaN arena
//!   value loses its payload bits (JSON has no NaN literal) — model
//!   arenas are finite in any healthy run, and the binary codec is the
//!   production format.

use std::collections::BTreeMap;

use crate::churn::ChurnState;
use crate::comm::CommState;
use crate::env::{DriverState, RoundTrace};
use crate::jsonx::Json;
use crate::model::ModelParams;
use crate::protocols::ProtocolState;
use crate::rng::RngState;
use crate::selection::slack::{SlackEstimatorState, SlackState};
use crate::snapshot::{as_obj, fnv1a64, RunSnapshot, SnapshotCodec, SnapshotError, FORMAT_VERSION};

/// Value of the `kind` discriminator field.
const KIND: &str = "hybridfl-run-snapshot";

/// The human-readable debug codec.
pub struct JsonCodec;

impl SnapshotCodec for JsonCodec {
    fn name(&self) -> &'static str {
        "json"
    }

    fn extension(&self) -> &'static str {
        "json"
    }

    fn encode(&self, snap: &RunSnapshot) -> Vec<u8> {
        // The config is embedded as a parsed object (readability); its
        // canonical dump is what the fingerprint hashes, and jsonx's
        // BTreeMap keys make dump(parse(dump(x))) == dump(x).
        let config = Json::parse(&snap.config_json).unwrap_or_else(|_| {
            // A RunSnapshot built by `capture` always embeds valid JSON;
            // fall back to the raw string rather than failing encode.
            Json::Str(snap.config_json.clone())
        });
        let j = Json::obj()
            .set("kind", KIND)
            .set("snapshot_format", FORMAT_VERSION as u64)
            .set("backend", snap.backend.as_str())
            .set("config", config)
            .set("fingerprint", hex64(snap.fingerprint))
            .set("rng", rng_to_json(&snap.rng))
            .set("churn", churn_to_json(&snap.churn))
            .set("comm", comm_to_json(&snap.comm))
            .set("protocol", protocol_to_json(&snap.protocol))
            .set("driver", driver_to_json(&snap.driver));
        j.pretty().into_bytes()
    }

    fn decode(&self, bytes: &[u8]) -> Result<RunSnapshot, SnapshotError> {
        let text = std::str::from_utf8(bytes)
            .map_err(|e| SnapshotError::Malformed(format!("invalid UTF-8: {e}")))?;
        let j = Json::parse(text).map_err(|e| SnapshotError::Malformed(format!("{e:#}")))?;
        let obj = as_obj(&j, "snapshot")?;
        match obj.get("kind") {
            Some(Json::Str(k)) if k == KIND => {}
            _ => return Err(SnapshotError::BadMagic),
        }
        let version = req_u64(obj, "snapshot_format")? as u32;
        if version != FORMAT_VERSION {
            return Err(SnapshotError::UnsupportedVersion {
                found: version,
                supported: FORMAT_VERSION,
            });
        }
        let backend = req_str(obj, "backend")?;
        let config_json = match obj.get("config") {
            Some(cfg @ Json::Obj(_)) => cfg.dump(),
            Some(Json::Str(raw)) => raw.clone(),
            _ => return Err(SnapshotError::Malformed("config: expected object".into())),
        };
        let fingerprint = req_hex64(obj, "fingerprint")?;
        if fnv1a64(config_json.as_bytes()) != fingerprint {
            return Err(SnapshotError::Malformed(
                "stored fingerprint does not hash the embedded config".into(),
            ));
        }
        Ok(RunSnapshot {
            backend,
            config_json,
            fingerprint,
            rng: rng_from_json(req(obj, "rng")?)?,
            churn: churn_from_json(req(obj, "churn")?, 0)?,
            comm: comm_from_json(req(obj, "comm")?)?,
            protocol: protocol_from_json(req(obj, "protocol")?)?,
            driver: driver_from_json(req(obj, "driver")?)?,
        })
    }
}

// ---------------------------------------------------------------------------
// Encode helpers.
// ---------------------------------------------------------------------------

fn hex64(v: u64) -> String {
    format!("{v:016x}")
}

/// f64 → Json. The NaN→`null` mapping happens inside jsonx's number
/// writer at dump time (JSON has no NaN literal); [`f64_of`] is the
/// decode-side inverse.
fn num(v: f64) -> Json {
    Json::Num(v)
}

fn rng_to_json(rng: &RngState) -> Json {
    Json::obj()
        .set(
            "s",
            Json::Arr(rng.s.iter().map(|&w| Json::Str(hex64(w))).collect()),
        )
        .set(
            "gauss_spare",
            rng.gauss_spare.map_or(Json::Null, Json::Num),
        )
}

fn churn_to_json(c: &ChurnState) -> Json {
    match c {
        ChurnState::Stateless => Json::obj().set("kind", "stateless"),
        ChurnState::Markov { up } => Json::obj()
            .set("kind", "markov")
            .set("up", Json::Arr(up.iter().map(|&b| Json::Bool(b)).collect())),
        ChurnState::Battery { level } => Json::obj()
            .set("kind", "battery")
            .set(
                "level",
                Json::Arr(level.iter().map(|&l| num(l)).collect()),
            ),
        ChurnState::Composed { layers } => Json::obj()
            .set("kind", "composed")
            .set(
                "layers",
                Json::Arr(layers.iter().map(churn_to_json).collect()),
            ),
    }
}

fn comm_to_json(c: &CommState) -> Json {
    match c {
        CommState::Stateless => Json::obj().set("kind", "stateless"),
        CommState::Residuals { clients } => Json::obj()
            .set("kind", "residuals")
            .set(
                "clients",
                Json::Arr(
                    clients
                        .iter()
                        .map(|(client, residual)| {
                            Json::obj().set("client", *client).set(
                                "residual",
                                Json::Arr(
                                    residual.iter().map(|&v| Json::Num(v as f64)).collect(),
                                ),
                            )
                        })
                        .collect(),
                ),
            ),
    }
}

fn params_to_json(p: &ModelParams) -> Json {
    Json::obj()
        .set(
            "shapes",
            Json::Arr(
                p.shapes()
                    .iter()
                    .map(|s| Json::Arr(s.iter().map(|&d| Json::Num(d as f64)).collect()))
                    .collect(),
            ),
        )
        .set(
            "values",
            Json::Arr(p.values().iter().map(|&v| Json::Num(v as f64)).collect()),
        )
}

fn params_vec_to_json(ps: &[ModelParams]) -> Json {
    Json::Arr(ps.iter().map(params_to_json).collect())
}

fn slack_state_to_json(s: &SlackState) -> Json {
    Json::obj()
        .set("theta", num(s.theta))
        .set("c_r", num(s.c_r))
        .set("q_r", num(s.q_r))
        .set("submissions", s.submissions)
}

fn estimator_to_json(e: &SlackEstimatorState) -> Json {
    Json::obj()
        .set("n_r", e.n_r)
        .set("c", num(e.c))
        .set("num", num(e.num))
        .set("den", num(e.den))
        .set("theta", num(e.theta))
        .set("c_r", num(e.c_r))
        .set(
            "last",
            e.last.as_ref().map_or(Json::Null, slack_state_to_json),
        )
        .set("rounds_observed", e.rounds_observed)
}

fn protocol_to_json(p: &ProtocolState) -> Json {
    match p {
        ProtocolState::FedAvg { global } => Json::obj()
            .set("kind", "fedavg")
            .set("global", params_to_json(global)),
        ProtocolState::HierFavg {
            global,
            regionals,
            region_data,
        } => Json::obj()
            .set("kind", "hierfavg")
            .set("global", params_to_json(global))
            .set("regionals", params_vec_to_json(regionals))
            .set(
                "region_data",
                Json::Arr(region_data.iter().map(|&d| Json::Num(d)).collect()),
            ),
        ProtocolState::HybridFl {
            global,
            regionals,
            slack,
        } => Json::obj()
            .set("kind", "hybridfl")
            .set("global", params_to_json(global))
            .set("regionals", params_vec_to_json(regionals))
            .set("slack", Json::Arr(slack.iter().map(estimator_to_json).collect())),
    }
}

fn counts_to_json(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn trace_to_json(row: &RoundTrace) -> Json {
    Json::obj()
        .set("t", row.t)
        .set("round_len", num(row.round_len))
        .set("cum_time", num(row.cum_time))
        .set("accuracy", num(row.accuracy))
        .set("best_accuracy", num(row.best_accuracy))
        .set("eval_loss", num(row.eval_loss))
        .set("selected", counts_to_json(&row.selected))
        .set("alive", counts_to_json(&row.alive))
        .set("submissions", counts_to_json(&row.submissions))
        .set(
            "avail",
            Json::Arr(row.avail.iter().map(|&a| num(a)).collect()),
        )
        .set("cum_energy_j", num(row.cum_energy_j))
        .set("bytes_moved", row.bytes_moved)
        .set("deadline_hit", row.deadline_hit)
        .set("cloud_aggregated", row.cloud_aggregated)
        .set(
            "slack",
            row.slack.as_ref().map_or(Json::Null, |states| {
                Json::Arr(states.iter().map(slack_state_to_json).collect())
            }),
        )
}

fn driver_to_json(d: &DriverState) -> Json {
    Json::obj()
        .set("rounds_done", d.rounds_done)
        .set("cum_time", num(d.cum_time))
        .set("cum_energy", num(d.cum_energy))
        .set("best_acc", num(d.best_acc))
        .set("last_acc", num(d.last_acc))
        .set("last_loss", num(d.last_loss))
        .set("rounds", Json::Arr(d.rounds.iter().map(trace_to_json).collect()))
}

// ---------------------------------------------------------------------------
// Decode helpers — every failure is a typed Malformed, never a panic.
// ---------------------------------------------------------------------------

fn req<'a>(
    obj: &'a BTreeMap<String, Json>,
    key: &str,
) -> Result<&'a Json, SnapshotError> {
    obj.get(key)
        .ok_or_else(|| SnapshotError::Malformed(format!("missing key '{key}'")))
}

fn req_str(obj: &BTreeMap<String, Json>, key: &str) -> Result<String, SnapshotError> {
    match req(obj, key)? {
        Json::Str(s) => Ok(s.clone()),
        _ => Err(SnapshotError::Malformed(format!("'{key}': expected string"))),
    }
}

/// f64 with the NaN convention: `null` decodes to NaN.
fn f64_of(j: &Json, what: &str) -> Result<f64, SnapshotError> {
    match j {
        Json::Num(n) => Ok(*n),
        Json::Null => Ok(f64::NAN),
        _ => Err(SnapshotError::Malformed(format!("'{what}': expected number"))),
    }
}

fn req_f64(obj: &BTreeMap<String, Json>, key: &str) -> Result<f64, SnapshotError> {
    f64_of(req(obj, key)?, key)
}

fn req_u64(obj: &BTreeMap<String, Json>, key: &str) -> Result<u64, SnapshotError> {
    let f = req_f64(obj, key)?;
    if !f.is_finite() || f < 0.0 || f.fract() != 0.0 {
        return Err(SnapshotError::Malformed(format!(
            "'{key}': expected non-negative integer, got {f}"
        )));
    }
    Ok(f as u64)
}

fn req_usize(obj: &BTreeMap<String, Json>, key: &str) -> Result<usize, SnapshotError> {
    Ok(req_u64(obj, key)? as usize)
}

fn req_bool(obj: &BTreeMap<String, Json>, key: &str) -> Result<bool, SnapshotError> {
    match req(obj, key)? {
        Json::Bool(b) => Ok(*b),
        _ => Err(SnapshotError::Malformed(format!("'{key}': expected bool"))),
    }
}

fn req_arr<'a>(
    obj: &'a BTreeMap<String, Json>,
    key: &str,
) -> Result<&'a [Json], SnapshotError> {
    match req(obj, key)? {
        Json::Arr(v) => Ok(v),
        _ => Err(SnapshotError::Malformed(format!("'{key}': expected array"))),
    }
}

fn hex64_of(j: &Json, what: &str) -> Result<u64, SnapshotError> {
    match j {
        Json::Str(s) => u64::from_str_radix(s, 16)
            .map_err(|_| SnapshotError::Malformed(format!("'{what}': bad hex '{s}'"))),
        _ => Err(SnapshotError::Malformed(format!(
            "'{what}': expected hex string"
        ))),
    }
}

fn req_hex64(obj: &BTreeMap<String, Json>, key: &str) -> Result<u64, SnapshotError> {
    hex64_of(req(obj, key)?, key)
}

fn rng_from_json(j: &Json) -> Result<RngState, SnapshotError> {
    let obj = as_obj(j, "rng")?;
    let words = req_arr(obj, "s")?;
    if words.len() != 4 {
        return Err(SnapshotError::Malformed(format!(
            "rng.s: expected 4 words, got {}",
            words.len()
        )));
    }
    let mut s = [0u64; 4];
    for (i, w) in words.iter().enumerate() {
        s[i] = hex64_of(w, "rng.s")?;
    }
    let gauss_spare = match req(obj, "gauss_spare")? {
        Json::Null => None,
        Json::Num(n) => Some(*n),
        _ => {
            return Err(SnapshotError::Malformed(
                "rng.gauss_spare: expected number or null".into(),
            ))
        }
    };
    Ok(RngState { s, gauss_spare })
}

fn churn_from_json(j: &Json, depth: u8) -> Result<ChurnState, SnapshotError> {
    let obj = as_obj(j, "churn")?;
    match req_str(obj, "kind")?.as_str() {
        "stateless" => Ok(ChurnState::Stateless),
        "markov" => Ok(ChurnState::Markov {
            up: req_arr(obj, "up")?
                .iter()
                .map(|v| match v {
                    Json::Bool(b) => Ok(*b),
                    _ => Err(SnapshotError::Malformed(
                        "churn.up: expected booleans".into(),
                    )),
                })
                .collect::<Result<_, _>>()?,
        }),
        "battery" => Ok(ChurnState::Battery {
            level: req_arr(obj, "level")?
                .iter()
                .map(|v| f64_of(v, "churn.level"))
                .collect::<Result<_, _>>()?,
        }),
        "composed" => {
            if depth >= 2 {
                return Err(SnapshotError::Malformed(
                    "churn state nests deeper than any valid model".into(),
                ));
            }
            Ok(ChurnState::Composed {
                layers: req_arr(obj, "layers")?
                    .iter()
                    .map(|l| churn_from_json(l, depth + 1))
                    .collect::<Result<_, _>>()?,
            })
        }
        k => Err(SnapshotError::Malformed(format!(
            "unknown churn-state kind '{k}'"
        ))),
    }
}

fn comm_from_json(j: &Json) -> Result<CommState, SnapshotError> {
    let obj = as_obj(j, "comm")?;
    match req_str(obj, "kind")?.as_str() {
        "stateless" => Ok(CommState::Stateless),
        "residuals" => Ok(CommState::Residuals {
            clients: req_arr(obj, "clients")?
                .iter()
                .map(|entry| {
                    let e = as_obj(entry, "comm client")?;
                    let client = req_usize(e, "client")?;
                    let residual: Vec<f32> = match req(e, "residual")? {
                        Json::Arr(v) => v
                            .iter()
                            .map(|x| f64_of(x, "residual").map(|f| f as f32))
                            .collect::<Result<_, _>>()?,
                        _ => {
                            return Err(SnapshotError::Malformed(
                                "residual: expected array".into(),
                            ))
                        }
                    };
                    Ok((client, std::sync::Arc::new(residual)))
                })
                .collect::<Result<_, SnapshotError>>()?,
        }),
        k => Err(SnapshotError::Malformed(format!(
            "unknown comm-state kind '{k}'"
        ))),
    }
}

fn params_from_json(j: &Json) -> Result<ModelParams, SnapshotError> {
    let obj = as_obj(j, "params")?;
    let mut shapes = Vec::new();
    let mut total = 0usize;
    for s in req_arr(obj, "shapes")? {
        let dims = match s {
            Json::Arr(d) => d,
            _ => return Err(SnapshotError::Malformed("shapes: expected arrays".into())),
        };
        let mut shape = Vec::with_capacity(dims.len());
        let mut prod = 1usize;
        for d in dims {
            let f = f64_of(d, "shape dim")?;
            if !f.is_finite() || f < 0.0 || f.fract() != 0.0 {
                return Err(SnapshotError::Malformed(format!("bad shape dim {f}")));
            }
            let d = f as usize;
            prod = prod
                .checked_mul(d)
                .ok_or_else(|| SnapshotError::Malformed("shape product overflow".into()))?;
            shape.push(d);
        }
        total = total
            .checked_add(prod)
            .ok_or_else(|| SnapshotError::Malformed("arena size overflow".into()))?;
        shapes.push(shape);
    }
    let raw = req_arr(obj, "values")?;
    if raw.len() != total {
        return Err(SnapshotError::Malformed(format!(
            "arena holds {} value(s) but the shapes require {total}",
            raw.len()
        )));
    }
    let mut values = Vec::with_capacity(raw.len());
    for v in raw {
        values.push(f64_of(v, "arena value")? as f32);
    }
    Ok(ModelParams::from_flat(values, shapes))
}

fn params_vec_from_json(j: &Json) -> Result<Vec<ModelParams>, SnapshotError> {
    match j {
        Json::Arr(v) => v.iter().map(params_from_json).collect(),
        _ => Err(SnapshotError::Malformed("expected model array".into())),
    }
}

fn slack_state_from_json(j: &Json) -> Result<SlackState, SnapshotError> {
    let obj = as_obj(j, "slack state")?;
    Ok(SlackState {
        theta: req_f64(obj, "theta")?,
        c_r: req_f64(obj, "c_r")?,
        q_r: req_f64(obj, "q_r")?,
        submissions: req_usize(obj, "submissions")?,
    })
}

fn estimator_from_json(j: &Json) -> Result<SlackEstimatorState, SnapshotError> {
    let obj = as_obj(j, "slack estimator")?;
    Ok(SlackEstimatorState {
        n_r: req_usize(obj, "n_r")?,
        c: req_f64(obj, "c")?,
        num: req_f64(obj, "num")?,
        den: req_f64(obj, "den")?,
        theta: req_f64(obj, "theta")?,
        c_r: req_f64(obj, "c_r")?,
        last: match req(obj, "last")? {
            Json::Null => None,
            s => Some(slack_state_from_json(s)?),
        },
        rounds_observed: req_usize(obj, "rounds_observed")?,
    })
}

fn protocol_from_json(j: &Json) -> Result<ProtocolState, SnapshotError> {
    let obj = as_obj(j, "protocol")?;
    match req_str(obj, "kind")?.as_str() {
        "fedavg" => Ok(ProtocolState::FedAvg {
            global: params_from_json(req(obj, "global")?)?,
        }),
        "hierfavg" => Ok(ProtocolState::HierFavg {
            global: params_from_json(req(obj, "global")?)?,
            regionals: params_vec_from_json(req(obj, "regionals")?)?,
            region_data: req_arr(obj, "region_data")?
                .iter()
                .map(|v| f64_of(v, "region_data"))
                .collect::<Result<_, _>>()?,
        }),
        "hybridfl" => Ok(ProtocolState::HybridFl {
            global: params_from_json(req(obj, "global")?)?,
            regionals: params_vec_from_json(req(obj, "regionals")?)?,
            slack: req_arr(obj, "slack")?
                .iter()
                .map(estimator_from_json)
                .collect::<Result<_, _>>()?,
        }),
        k => Err(SnapshotError::Malformed(format!(
            "unknown protocol-state kind '{k}'"
        ))),
    }
}

fn counts_from_json(j: &Json, what: &str) -> Result<Vec<usize>, SnapshotError> {
    match j {
        Json::Arr(v) => v
            .iter()
            .map(|x| {
                let f = f64_of(x, what)?;
                if !f.is_finite() || f < 0.0 || f.fract() != 0.0 {
                    return Err(SnapshotError::Malformed(format!("'{what}': bad count {f}")));
                }
                Ok(f as usize)
            })
            .collect(),
        _ => Err(SnapshotError::Malformed(format!("'{what}': expected array"))),
    }
}

fn trace_from_json(j: &Json) -> Result<RoundTrace, SnapshotError> {
    let obj = as_obj(j, "round trace")?;
    Ok(RoundTrace {
        t: req_usize(obj, "t")?,
        round_len: req_f64(obj, "round_len")?,
        cum_time: req_f64(obj, "cum_time")?,
        accuracy: req_f64(obj, "accuracy")?,
        best_accuracy: req_f64(obj, "best_accuracy")?,
        eval_loss: req_f64(obj, "eval_loss")?,
        selected: counts_from_json(req(obj, "selected")?, "selected")?,
        alive: counts_from_json(req(obj, "alive")?, "alive")?,
        submissions: counts_from_json(req(obj, "submissions")?, "submissions")?,
        avail: match req(obj, "avail")? {
            Json::Arr(v) => v
                .iter()
                .map(|x| f64_of(x, "avail"))
                .collect::<Result<_, _>>()?,
            _ => return Err(SnapshotError::Malformed("avail: expected array".into())),
        },
        cum_energy_j: req_f64(obj, "cum_energy_j")?,
        bytes_moved: req_u64(obj, "bytes_moved")?,
        deadline_hit: req_bool(obj, "deadline_hit")?,
        cloud_aggregated: req_bool(obj, "cloud_aggregated")?,
        slack: match req(obj, "slack")? {
            Json::Null => None,
            Json::Arr(v) => Some(
                v.iter()
                    .map(slack_state_from_json)
                    .collect::<Result<_, _>>()?,
            ),
            _ => {
                return Err(SnapshotError::Malformed(
                    "slack: expected array or null".into(),
                ))
            }
        },
    })
}

fn driver_from_json(j: &Json) -> Result<DriverState, SnapshotError> {
    let obj = as_obj(j, "driver")?;
    let rounds_done = req_usize(obj, "rounds_done")?;
    let rounds: Vec<RoundTrace> = req_arr(obj, "rounds")?
        .iter()
        .map(trace_from_json)
        .collect::<Result<_, _>>()?;
    if rounds.len() != rounds_done {
        return Err(SnapshotError::Malformed(format!(
            "driver claims {rounds_done} completed round(s) but carries {} trace row(s)",
            rounds.len()
        )));
    }
    Ok(DriverState {
        rounds_done,
        cum_time: req_f64(obj, "cum_time")?,
        cum_energy: req_f64(obj, "cum_energy")?,
        best_acc: req_f64(obj, "best_acc")?,
        last_acc: req_f64(obj, "last_acc")?,
        last_loss: req_f64(obj, "last_loss")?,
        rounds,
    })
}
