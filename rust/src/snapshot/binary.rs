//! The versioned binary snapshot codec — the production on-disk format.
//!
//! # Frame layout
//!
//! ```text
//! offset  size  field
//! 0       8     magic            b"HFLSNAP\0"
//! 8       4     format version   u32 LE
//! 12      8     payload length   u64 LE
//! 20      8     payload checksum u64 LE (FNV-1a 64 over the payload)
//! 28      ...   payload
//! ```
//!
//! The payload is a flat little-endian field sequence (no self-describing
//! tags — the format version *is* the schema version): strings and
//! vectors are length-prefixed with a `u64`; `Option<T>` is a `u8`
//! presence flag followed by `T`; floats are raw IEEE-754 bits, so every
//! value round-trips bit-exactly (NaN payloads included).
//!
//! A [`crate::model::ModelParams`] is written as its logical shape table
//! followed by the contiguous arena verbatim:
//!
//! ```text
//! u32 n_tensors
//! per tensor:  u32 ndims, u64 dim...
//! u64 n_values, f32 LE × n_values      // the arena, one memcpy-shaped run
//! ```
//!
//! The offset table is *not* stored — it is recomputed from the shapes on
//! decode, and a shape/arena size inconsistency is a typed
//! [`SnapshotError::Malformed`], never a panic.
//!
//! # Versioning policy
//!
//! Any change to the payload layout bumps
//! [`crate::snapshot::FORMAT_VERSION`]. Readers reject versions they do
//! not know ([`SnapshotError::UnsupportedVersion`]). Old versions are
//! retired, not kept: every bump so far rode a config-schema change
//! (v2: `churn`, v3: `comm`), so a pre-bump snapshot cannot pass the
//! config-fingerprint check anyway and a legacy decode path would be
//! dead code (see [`crate::snapshot::FORMAT_VERSION`]). The checksum
//! covers only the payload: a flipped bit anywhere in the body surfaces
//! as [`SnapshotError::ChecksumMismatch`] before any field is
//! interpreted.

use crate::churn::ChurnState;
use crate::comm::CommState;
use crate::env::{DriverState, RoundTrace};
use crate::model::ModelParams;
use crate::protocols::ProtocolState;
use crate::rng::RngState;
use crate::selection::slack::{SlackEstimatorState, SlackState};
use crate::snapshot::{fnv1a64, RunSnapshot, SnapshotCodec, SnapshotError, FORMAT_VERSION};

/// Leading signature of every binary snapshot.
pub const MAGIC: &[u8; 8] = b"HFLSNAP\0";

/// Header size: magic + version + payload length + checksum.
const HEADER_LEN: usize = 8 + 4 + 8 + 8;

/// The versioned length-prefixed binary codec.
pub struct BinaryCodec;

impl SnapshotCodec for BinaryCodec {
    fn name(&self) -> &'static str {
        "binary"
    }

    fn extension(&self) -> &'static str {
        "hflsnap"
    }

    fn encode(&self, snap: &RunSnapshot) -> Vec<u8> {
        let mut w = Writer::new();
        w.str(&snap.backend);
        w.str(&snap.config_json);
        w.u64(snap.fingerprint);
        write_rng(&mut w, &snap.rng);
        write_churn(&mut w, &snap.churn);
        write_comm(&mut w, &snap.comm);
        write_protocol(&mut w, &snap.protocol);
        write_driver(&mut w, &snap.driver);
        let payload = w.into_bytes();

        let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    fn decode(&self, bytes: &[u8]) -> Result<RunSnapshot, SnapshotError> {
        if bytes.len() < 8 || &bytes[..8] != MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        if bytes.len() < HEADER_LEN {
            return Err(SnapshotError::Truncated {
                offset: bytes.len(),
                needed: HEADER_LEN - bytes.len(),
                len: bytes.len(),
            });
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != FORMAT_VERSION {
            return Err(SnapshotError::UnsupportedVersion {
                found: version,
                supported: FORMAT_VERSION,
            });
        }
        let payload_len = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        let checksum = u64::from_le_bytes(bytes[20..28].try_into().unwrap());
        let payload = &bytes[HEADER_LEN..];
        if payload.len() < payload_len {
            return Err(SnapshotError::Truncated {
                offset: bytes.len(),
                needed: payload_len - payload.len(),
                len: bytes.len(),
            });
        }
        if payload.len() > payload_len {
            return Err(SnapshotError::Malformed(format!(
                "{} trailing byte(s) after the declared payload",
                payload.len() - payload_len
            )));
        }
        let actual = fnv1a64(payload);
        if actual != checksum {
            return Err(SnapshotError::ChecksumMismatch {
                expected: checksum,
                actual,
            });
        }

        let mut r = Reader::new(payload);
        let backend = r.str()?;
        let config_json = r.str()?;
        let fingerprint = r.u64()?;
        if fnv1a64(config_json.as_bytes()) != fingerprint {
            return Err(SnapshotError::Malformed(
                "stored fingerprint does not hash the embedded config".into(),
            ));
        }
        let rng = read_rng(&mut r)?;
        let churn = read_churn(&mut r, 0)?;
        let comm = read_comm(&mut r)?;
        let protocol = read_protocol(&mut r)?;
        let driver = read_driver(&mut r)?;
        r.finish()?;
        Ok(RunSnapshot {
            backend,
            config_json,
            fingerprint,
            rng,
            churn,
            comm,
            protocol,
            driver,
        })
    }
}

// ---------------------------------------------------------------------------
// Field-level encode/decode.
// ---------------------------------------------------------------------------

fn write_rng(w: &mut Writer, rng: &RngState) {
    for word in rng.s {
        w.u64(word);
    }
    w.opt_f64(rng.gauss_spare);
}

fn read_rng(r: &mut Reader<'_>) -> Result<RngState, SnapshotError> {
    let mut s = [0u64; 4];
    for word in &mut s {
        *word = r.u64()?;
    }
    Ok(RngState {
        s,
        gauss_spare: r.opt_f64()?,
    })
}

const CHURN_STATELESS: u8 = 0;
const CHURN_MARKOV: u8 = 1;
const CHURN_BATTERY: u8 = 2;
const CHURN_COMPOSED: u8 = 3;

/// Composed states nest one level in the model, but decode defensively
/// against deeper (corrupted) nesting anyway.
const CHURN_MAX_DEPTH: u8 = 2;

fn write_churn(w: &mut Writer, c: &ChurnState) {
    match c {
        ChurnState::Stateless => w.u8(CHURN_STATELESS),
        ChurnState::Markov { up } => {
            w.u8(CHURN_MARKOV);
            w.u64(up.len() as u64);
            for &b in up {
                w.u8(b as u8);
            }
        }
        ChurnState::Battery { level } => {
            w.u8(CHURN_BATTERY);
            w.u64(level.len() as u64);
            for &l in level {
                w.f64(l);
            }
        }
        ChurnState::Composed { layers } => {
            w.u8(CHURN_COMPOSED);
            w.u64(layers.len() as u64);
            for l in layers {
                write_churn(w, l);
            }
        }
    }
}

fn read_churn(r: &mut Reader<'_>, depth: u8) -> Result<ChurnState, SnapshotError> {
    match r.u8()? {
        CHURN_STATELESS => Ok(ChurnState::Stateless),
        CHURN_MARKOV => {
            let n = r.u64()? as usize;
            r.check_remaining(n, 1, "markov flags")?;
            let up = (0..n).map(|_| r.bool()).collect::<Result<_, _>>()?;
            Ok(ChurnState::Markov { up })
        }
        CHURN_BATTERY => {
            let n = r.u64()? as usize;
            r.check_remaining(n, 8, "battery levels")?;
            let level = (0..n).map(|_| r.f64()).collect::<Result<_, _>>()?;
            Ok(ChurnState::Battery { level })
        }
        CHURN_COMPOSED => {
            if depth >= CHURN_MAX_DEPTH {
                return Err(SnapshotError::Malformed(
                    "churn state nests deeper than any valid model".into(),
                ));
            }
            let n = r.u64()? as usize;
            r.check_remaining(n, 1, "churn layers")?;
            let layers = (0..n)
                .map(|_| read_churn(r, depth + 1))
                .collect::<Result<_, _>>()?;
            Ok(ChurnState::Composed { layers })
        }
        tag => Err(SnapshotError::Malformed(format!(
            "unknown churn-state tag {tag}"
        ))),
    }
}

const COMM_STATELESS: u8 = 0;
const COMM_RESIDUALS: u8 = 1;

fn write_comm(w: &mut Writer, c: &CommState) {
    match c {
        CommState::Stateless => w.u8(COMM_STATELESS),
        CommState::Residuals { clients } => {
            w.u8(COMM_RESIDUALS);
            w.u64(clients.len() as u64);
            for (client, residual) in clients {
                w.u64(*client as u64);
                w.u64(residual.len() as u64);
                w.f32s(residual);
            }
        }
    }
}

fn read_comm(r: &mut Reader<'_>) -> Result<CommState, SnapshotError> {
    match r.u8()? {
        COMM_STATELESS => Ok(CommState::Stateless),
        COMM_RESIDUALS => {
            let n = r.u64()? as usize;
            r.check_remaining(n, 16, "comm residuals")?;
            let clients = (0..n)
                .map(|_| {
                    let client = r.u64()? as usize;
                    let len = r.u64()? as usize;
                    Ok((client, std::sync::Arc::new(r.f32s(len)?)))
                })
                .collect::<Result<_, SnapshotError>>()?;
            Ok(CommState::Residuals { clients })
        }
        tag => Err(SnapshotError::Malformed(format!(
            "unknown comm-state tag {tag}"
        ))),
    }
}

pub(crate) fn write_params(w: &mut Writer, p: &ModelParams) {
    w.u32(p.n_tensors() as u32);
    for shape in p.shapes() {
        w.u32(shape.len() as u32);
        for &d in shape {
            w.u64(d as u64);
        }
    }
    let values = p.values();
    w.u64(values.len() as u64);
    w.f32s(values);
}

pub(crate) fn read_params(r: &mut Reader<'_>) -> Result<ModelParams, SnapshotError> {
    let n_tensors = r.u32()? as usize;
    r.check_remaining(n_tensors, 4, "tensor shape table")?;
    let mut shapes = Vec::with_capacity(n_tensors);
    let mut total = 0usize;
    for _ in 0..n_tensors {
        let ndims = r.u32()? as usize;
        r.check_remaining(ndims, 8, "shape dims")?;
        let mut shape = Vec::with_capacity(ndims);
        let mut prod = 1usize;
        for _ in 0..ndims {
            let d = r.u64()? as usize;
            prod = prod
                .checked_mul(d)
                .ok_or_else(|| SnapshotError::Malformed("shape product overflow".into()))?;
            shape.push(d);
        }
        total = total
            .checked_add(prod)
            .ok_or_else(|| SnapshotError::Malformed("arena size overflow".into()))?;
        shapes.push(shape);
    }
    let n_values = r.u64()? as usize;
    if n_values != total {
        return Err(SnapshotError::Malformed(format!(
            "arena holds {n_values} value(s) but the shapes require {total}"
        )));
    }
    let values = r.f32s(n_values)?;
    Ok(ModelParams::from_flat(values, shapes))
}

fn write_params_vec(w: &mut Writer, ps: &[ModelParams]) {
    w.u64(ps.len() as u64);
    for p in ps {
        write_params(w, p);
    }
}

fn read_params_vec(r: &mut Reader<'_>) -> Result<Vec<ModelParams>, SnapshotError> {
    let n = r.u64()? as usize;
    r.check_remaining(n, 4, "model list")?;
    (0..n).map(|_| read_params(r)).collect()
}

fn write_slack_state(w: &mut Writer, s: &SlackState) {
    w.f64(s.theta);
    w.f64(s.c_r);
    w.f64(s.q_r);
    w.u64(s.submissions as u64);
}

fn read_slack_state(r: &mut Reader<'_>) -> Result<SlackState, SnapshotError> {
    Ok(SlackState {
        theta: r.f64()?,
        c_r: r.f64()?,
        q_r: r.f64()?,
        submissions: r.u64()? as usize,
    })
}

fn write_estimator(w: &mut Writer, e: &SlackEstimatorState) {
    w.u64(e.n_r as u64);
    w.f64(e.c);
    w.f64(e.num);
    w.f64(e.den);
    w.f64(e.theta);
    w.f64(e.c_r);
    match e.last {
        Some(ref s) => {
            w.u8(1);
            write_slack_state(w, s);
        }
        None => w.u8(0),
    }
    w.u64(e.rounds_observed as u64);
}

fn read_estimator(r: &mut Reader<'_>) -> Result<SlackEstimatorState, SnapshotError> {
    Ok(SlackEstimatorState {
        n_r: r.u64()? as usize,
        c: r.f64()?,
        num: r.f64()?,
        den: r.f64()?,
        theta: r.f64()?,
        c_r: r.f64()?,
        last: if r.bool()? {
            Some(read_slack_state(r)?)
        } else {
            None
        },
        rounds_observed: r.u64()? as usize,
    })
}

const TAG_FEDAVG: u8 = 0;
const TAG_HIERFAVG: u8 = 1;
const TAG_HYBRIDFL: u8 = 2;

fn write_protocol(w: &mut Writer, p: &ProtocolState) {
    match p {
        ProtocolState::FedAvg { global } => {
            w.u8(TAG_FEDAVG);
            write_params(w, global);
        }
        ProtocolState::HierFavg {
            global,
            regionals,
            region_data,
        } => {
            w.u8(TAG_HIERFAVG);
            write_params(w, global);
            write_params_vec(w, regionals);
            w.u64(region_data.len() as u64);
            for &d in region_data {
                w.f64(d);
            }
        }
        ProtocolState::HybridFl {
            global,
            regionals,
            slack,
        } => {
            w.u8(TAG_HYBRIDFL);
            write_params(w, global);
            write_params_vec(w, regionals);
            w.u64(slack.len() as u64);
            for e in slack {
                write_estimator(w, e);
            }
        }
    }
}

fn read_protocol(r: &mut Reader<'_>) -> Result<ProtocolState, SnapshotError> {
    match r.u8()? {
        TAG_FEDAVG => Ok(ProtocolState::FedAvg {
            global: read_params(r)?,
        }),
        TAG_HIERFAVG => {
            let global = read_params(r)?;
            let regionals = read_params_vec(r)?;
            let n = r.u64()? as usize;
            r.check_remaining(n, 8, "region data sizes")?;
            let region_data = (0..n).map(|_| r.f64()).collect::<Result<_, _>>()?;
            Ok(ProtocolState::HierFavg {
                global,
                regionals,
                region_data,
            })
        }
        TAG_HYBRIDFL => {
            let global = read_params(r)?;
            let regionals = read_params_vec(r)?;
            let n = r.u64()? as usize;
            r.check_remaining(n, 8, "slack estimators")?;
            let slack = (0..n).map(|_| read_estimator(r)).collect::<Result<_, _>>()?;
            Ok(ProtocolState::HybridFl {
                global,
                regionals,
                slack,
            })
        }
        tag => Err(SnapshotError::Malformed(format!(
            "unknown protocol-state tag {tag}"
        ))),
    }
}

fn write_usize_vec(w: &mut Writer, xs: &[usize]) {
    w.u64(xs.len() as u64);
    for &x in xs {
        w.u64(x as u64);
    }
}

fn read_usize_vec(r: &mut Reader<'_>) -> Result<Vec<usize>, SnapshotError> {
    let n = r.u64()? as usize;
    r.check_remaining(n, 8, "count vector")?;
    (0..n).map(|_| r.u64().map(|v| v as usize)).collect()
}

fn write_f64_vec(w: &mut Writer, xs: &[f64]) {
    w.u64(xs.len() as u64);
    for &x in xs {
        w.f64(x);
    }
}

fn read_f64_vec(r: &mut Reader<'_>) -> Result<Vec<f64>, SnapshotError> {
    let n = r.u64()? as usize;
    r.check_remaining(n, 8, "f64 vector")?;
    (0..n).map(|_| r.f64()).collect()
}

pub(crate) fn write_round_trace(w: &mut Writer, row: &RoundTrace) {
    w.u64(row.t as u64);
    w.f64(row.round_len);
    w.f64(row.cum_time);
    w.f64(row.accuracy);
    w.f64(row.best_accuracy);
    w.f64(row.eval_loss);
    write_usize_vec(w, &row.selected);
    write_usize_vec(w, &row.alive);
    write_usize_vec(w, &row.submissions);
    write_f64_vec(w, &row.avail);
    w.f64(row.cum_energy_j);
    w.u64(row.bytes_moved);
    w.u8(row.deadline_hit as u8);
    w.u8(row.cloud_aggregated as u8);
    match row.slack {
        Some(ref states) => {
            w.u8(1);
            w.u64(states.len() as u64);
            for s in states {
                write_slack_state(w, s);
            }
        }
        None => w.u8(0),
    }
}

fn read_round_trace(r: &mut Reader<'_>) -> Result<RoundTrace, SnapshotError> {
    Ok(RoundTrace {
        t: r.u64()? as usize,
        round_len: r.f64()?,
        cum_time: r.f64()?,
        accuracy: r.f64()?,
        best_accuracy: r.f64()?,
        eval_loss: r.f64()?,
        selected: read_usize_vec(r)?,
        alive: read_usize_vec(r)?,
        submissions: read_usize_vec(r)?,
        avail: read_f64_vec(r)?,
        cum_energy_j: r.f64()?,
        bytes_moved: r.u64()?,
        deadline_hit: r.bool()?,
        cloud_aggregated: r.bool()?,
        slack: if r.bool()? {
            let n = r.u64()? as usize;
            r.check_remaining(n, 8 * 3, "slack trace states")?;
            Some((0..n).map(|_| read_slack_state(r)).collect::<Result<_, _>>()?)
        } else {
            None
        },
    })
}

fn write_driver(w: &mut Writer, d: &DriverState) {
    w.u64(d.rounds_done as u64);
    w.f64(d.cum_time);
    w.f64(d.cum_energy);
    w.f64(d.best_acc);
    w.f64(d.last_acc);
    w.f64(d.last_loss);
    w.u64(d.rounds.len() as u64);
    for row in &d.rounds {
        write_round_trace(w, row);
    }
}

fn read_driver(r: &mut Reader<'_>) -> Result<DriverState, SnapshotError> {
    let rounds_done = r.u64()? as usize;
    let cum_time = r.f64()?;
    let cum_energy = r.f64()?;
    let best_acc = r.f64()?;
    let last_acc = r.f64()?;
    let last_loss = r.f64()?;
    let n = r.u64()? as usize;
    r.check_remaining(n, 8, "round traces")?;
    let rounds = (0..n)
        .map(|_| read_round_trace(r))
        .collect::<Result<Vec<_>, _>>()?;
    if rounds.len() != rounds_done {
        return Err(SnapshotError::Malformed(format!(
            "driver claims {rounds_done} completed round(s) but carries {} trace row(s)",
            rounds.len()
        )));
    }
    Ok(DriverState {
        rounds_done,
        cum_time,
        cum_energy,
        best_acc,
        last_acc,
        last_loss,
        rounds,
    })
}

// ---------------------------------------------------------------------------
// Little-endian primitives.
// ---------------------------------------------------------------------------

/// Append-only little-endian byte sink.
#[derive(Default)]
pub(crate) struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub(crate) fn new() -> Writer {
        Writer::default()
    }

    pub(crate) fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Bulk arena write: one reserve, then a tight LE copy loop (the
    /// per-round checkpoint path serializes every model through this).
    pub(crate) fn f32s(&mut self, vs: &[f32]) {
        self.buf.reserve(vs.len() * 4);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    pub(crate) fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.f64(x);
            }
            None => self.u8(0),
        }
    }

    pub(crate) fn opt_u64(&mut self, v: Option<u64>) {
        match v {
            Some(x) => {
                self.u8(1);
                self.u64(x);
            }
            None => self.u8(0),
        }
    }

    pub(crate) fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

/// Bounds-checked little-endian cursor; every read returns a typed error
/// on exhaustion instead of panicking.
pub(crate) struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Reader<'a> {
        Reader { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        // `n` can be a corrupted u64 length prefix: compare against the
        // remaining span, never compute `pos + n`.
        if n > self.b.len() - self.pos {
            return Err(SnapshotError::Truncated {
                offset: self.pos,
                needed: n,
                len: self.b.len(),
            });
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Pre-flight a length-prefixed run: `count` elements of at least
    /// `elem_size` bytes each must still fit in the remaining input. Turns
    /// a corrupted huge length prefix into `Truncated` instead of an
    /// attempted multi-gigabyte allocation.
    pub(crate) fn check_remaining(
        &self,
        count: usize,
        elem_size: usize,
        _what: &str,
    ) -> Result<(), SnapshotError> {
        let needed = count.saturating_mul(elem_size);
        if needed > self.b.len() - self.pos {
            return Err(SnapshotError::Truncated {
                offset: self.pos,
                needed,
                len: self.b.len(),
            });
        }
        Ok(())
    }

    pub(crate) fn u8(&mut self) -> Result<u8, SnapshotError> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn bool(&mut self) -> Result<bool, SnapshotError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => Err(SnapshotError::Malformed(format!(
                "invalid bool byte {v:#04x}"
            ))),
        }
    }

    pub(crate) fn u32(&mut self) -> Result<u32, SnapshotError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Bulk arena read: one bounds check, then a chunked LE decode of
    /// `n` consecutive f32 values.
    pub(crate) fn f32s(&mut self, n: usize) -> Result<Vec<f32>, SnapshotError> {
        self.check_remaining(n, 4, "arena values")?;
        let bytes = self.take(n * 4)?;
        let mut out = Vec::with_capacity(n);
        for c in bytes.chunks_exact(4) {
            out.push(f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())));
        }
        Ok(out)
    }

    pub(crate) fn f64(&mut self) -> Result<f64, SnapshotError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn opt_f64(&mut self) -> Result<Option<f64>, SnapshotError> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }

    pub(crate) fn str(&mut self) -> Result<String, SnapshotError> {
        let n = self.u64()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|e| SnapshotError::Malformed(format!("invalid UTF-8 string: {e}")))
    }

    /// The payload must be fully consumed — leftover bytes mean the
    /// schema and the data disagree.
    pub(crate) fn finish(&self) -> Result<(), SnapshotError> {
        if self.pos != self.b.len() {
            return Err(SnapshotError::Malformed(format!(
                "{} unread byte(s) after the last field",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}
