//! Checkpoint/replay subsystem: versioned run snapshots and resumable
//! runs.
//!
//! The paper's premise is tolerating unreliable *end devices*; this module
//! extends the same discipline to the cloud/edge tier itself. A long
//! multi-round run no longer holds all of its state in process memory: at
//! any round boundary the driver can serialize a [`RunSnapshot`] — the
//! complete resumable state of the run — and a later process can load it,
//! verify it belongs to the same experiment, and continue to a
//! **byte-identical** [`crate::env::RunResult`] on either backend
//! (`tests/resume_determinism.rs` is the bar).
//!
//! # What a snapshot captures (and what it doesn't)
//!
//! Captured, because it is mutable run state:
//!
//! * the round index and the full per-round trace so far
//!   ([`crate::env::DriverState`]: virtual-time and energy sums, the
//!   best-accuracy watermark, the evaluation carry);
//! * the protocol state ([`crate::protocols::ProtocolState`]): global
//!   model, per-region regional models, and HybridFL's per-region slack
//!   estimators with their running LSE sums;
//! * the environment's round-stream RNG ([`crate::rng::RngState`],
//!   including the cached Box–Muller spare);
//! * the churn-process state ([`crate::churn::ChurnState`]: Markov
//!   on/off flags, battery charge levels), so a resumed run continues
//!   the exact reliability trajectory of a non-stationary world;
//! * the communication state ([`crate::comm::CommState`]: the per-client
//!   error-feedback residuals a `topk+ef` run carries between rounds),
//!   so resumed compressed runs stay byte-identical;
//! * the config fingerprint plus the full config JSON, so a resume
//!   against a diverging config is a **hard error naming the diverging
//!   fields** — never a silent hybrid run.
//!
//! Not captured, because it is deterministically rebuilt from the config:
//! the topology, the data partition, the device fleet, the timing/energy
//! models, and the engine. `World::build` derives all of them from
//! `cfg.seed` through fixed RNG stream splits, so re-running it on resume
//! reproduces the identical world — that is precisely what the config
//! fingerprint protects.
//!
//! # Codecs
//!
//! [`SnapshotCodec`] splits *what* is saved from *how* it is framed (the
//! codec/transport split of the RPC framing idiom). Two implementations
//! ship, both dependency-free:
//!
//! * [`BinaryCodec`] — the production format: a fixed 28-byte header
//!   (magic, format version, payload length, FNV-1a checksum) followed by
//!   a length-prefixed little-endian payload that dumps each
//!   `ModelParams` contiguous arena as an offset/shape table plus raw
//!   f32 LE bytes. See [`binary`] for the exact layout and the
//!   versioning policy.
//! * [`JsonCodec`] — a human-readable debug codec over [`crate::jsonx`];
//!   same information, greppable, ~8× larger. Values round-trip
//!   bit-exactly (shortest-roundtrip float formatting; u64 words as hex
//!   strings).
//!
//! Decoding never panics: truncated, corrupted or wrong-version byte
//! streams come back as typed [`SnapshotError`]s
//! (`tests/snapshot_roundtrip.rs` fuzzes this).
//!
//! [`load_snapshot`] sniffs the format from the leading bytes, so
//! `--resume` accepts either encoding.

pub mod binary;
pub mod json;

use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

use crate::churn::ChurnState;
use crate::comm::CommState;
use crate::env::{DriverState, FlEnvironment};
use crate::jsonx::Json;
use crate::protocols::{Protocol, ProtocolState};
use crate::rng::RngState;
use crate::Result;

pub use binary::BinaryCodec;
pub use json::JsonCodec;

/// On-disk format version understood by this build. Bumped whenever the
/// payload layout changes; old readers reject newer snapshots with
/// [`SnapshotError::UnsupportedVersion`] instead of misparsing them.
///
/// v2 (churn subsystem) added the churn-process state to the payload and
/// the per-round availability series to every trace row. v1 support was
/// retired rather than kept: the config schema gained the `churn` key in
/// the same change, so no v1 snapshot can pass the config-fingerprint
/// check against a config this build produces — a v1 decode path would
/// be dead code behind a guaranteed `ConfigMismatch`.
///
/// v3 (comm subsystem) added the communication state (per-client
/// error-feedback residuals) to the payload and `bytes_moved` to every
/// trace row. v2 support was retired by the same argument: the config
/// schema gained the `comm` key in the same change, so every v2 snapshot
/// is behind a guaranteed `ConfigMismatch` anyway.
pub const FORMAT_VERSION: u32 = 3;

/// Typed decode/validation errors. The codecs return these directly so
/// callers (and tests) can distinguish a truncated file from a checksum
/// mismatch from a config divergence; they convert into `anyhow::Error`
/// at the subsystem boundary.
#[derive(Debug)]
pub enum SnapshotError {
    /// The byte stream does not start with a known snapshot signature.
    BadMagic,
    /// The snapshot was written by an unknown (newer) format version.
    UnsupportedVersion { found: u32, supported: u32 },
    /// The byte stream ends before the declared content does.
    Truncated { offset: usize, needed: usize, len: usize },
    /// Header checksum does not match the payload bytes.
    ChecksumMismatch { expected: u64, actual: u64 },
    /// Structurally invalid content (bad tag, bad UTF-8, inconsistent
    /// lengths, missing JSON keys, ...).
    Malformed(String),
    /// The snapshot's config fingerprint does not match the resuming
    /// run's config.
    ConfigMismatch { diverging: Vec<String> },
    /// Filesystem failure while reading or writing a snapshot.
    Io(std::io::Error),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::BadMagic => {
                write!(f, "not a hybridfl snapshot (unrecognized signature)")
            }
            SnapshotError::UnsupportedVersion { found, supported } => write!(
                f,
                "snapshot format version {found} is not supported \
                 (this build reads up to version {supported})"
            ),
            SnapshotError::Truncated { offset, needed, len } => write!(
                f,
                "snapshot truncated: needed {needed} byte(s) at offset {offset} \
                 but only {len} byte(s) total"
            ),
            SnapshotError::ChecksumMismatch { expected, actual } => write!(
                f,
                "snapshot payload checksum mismatch \
                 (header says {expected:#018x}, payload hashes to {actual:#018x})"
            ),
            SnapshotError::Malformed(msg) => write!(f, "malformed snapshot: {msg}"),
            SnapshotError::ConfigMismatch { diverging } => {
                if diverging.is_empty() {
                    write!(
                        f,
                        "snapshot config fingerprint does not match this run's config"
                    )
                } else {
                    write!(
                        f,
                        "snapshot config does not match this run's config; \
                         diverging fields: {}",
                        diverging.join(", ")
                    )
                }
            }
            SnapshotError::Io(e) => write!(f, "snapshot I/O error: {e}"),
        }
    }
}

impl std::error::Error for SnapshotError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SnapshotError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SnapshotError {
    fn from(e: std::io::Error) -> SnapshotError {
        SnapshotError::Io(e)
    }
}

/// Everything needed to resume a run at a round boundary. Field-for-field
/// this is: *whose run* (backend + config fingerprint), *where in the
/// run* (driver state incl. the trace), and *what would have happened
/// next* (protocol state + RNG streams).
#[derive(Clone, Debug)]
pub struct RunSnapshot {
    /// Backend label (`sim` / `live`) the snapshot was captured on. A
    /// trace must not silently mix backends, so resume checks it.
    pub backend: String,
    /// `cfg.to_json().dump()` of the run's config — kept verbatim so a
    /// fingerprint mismatch can name the diverging fields.
    pub config_json: String,
    /// FNV-1a 64 of `config_json`.
    pub fingerprint: u64,
    /// The environment's round-stream RNG at the boundary.
    pub rng: RngState,
    /// The churn-process state at the boundary (Markov flags, battery
    /// levels; [`ChurnState::Stateless`] for stationary/scripted worlds).
    pub churn: ChurnState,
    /// The communication state at the boundary (per-client error-feedback
    /// residuals under `topk+ef`; [`CommState::Stateless`] otherwise).
    pub comm: CommState,
    /// The protocol's full mutable state at the boundary.
    pub protocol: ProtocolState,
    /// The driver's accumulators and per-round trace at the boundary.
    pub driver: DriverState,
}

impl RunSnapshot {
    /// Capture a snapshot at the current round boundary.
    pub fn capture(
        backend: &str,
        env: &dyn FlEnvironment,
        protocol: &dyn Protocol,
        driver: &DriverState,
    ) -> RunSnapshot {
        let config_json = env.cfg().to_json().dump();
        let state = env.capture_state();
        RunSnapshot {
            backend: backend.to_string(),
            fingerprint: fnv1a64(config_json.as_bytes()),
            config_json,
            rng: state.rng,
            churn: state.churn,
            comm: state.comm,
            protocol: protocol.snapshot_state(),
            driver: driver.clone(),
        }
    }

    /// Rounds completed when the snapshot was taken.
    pub fn round(&self) -> usize {
        self.driver.rounds_done
    }

    /// Verify this snapshot belongs to the given config. On divergence
    /// returns [`SnapshotError::ConfigMismatch`] naming the differing
    /// field paths.
    pub fn ensure_config_matches(
        &self,
        cfg: &crate::config::ExperimentConfig,
    ) -> std::result::Result<(), SnapshotError> {
        let current = cfg.to_json();
        let current_dump = current.dump();
        if current_dump == self.config_json {
            return Ok(());
        }
        let snap_cfg = Json::parse(&self.config_json)
            .map_err(|e| SnapshotError::Malformed(format!("embedded config: {e}")))?;
        Err(SnapshotError::ConfigMismatch {
            diverging: diff_json_paths(&snap_cfg, &current),
        })
    }

    /// Restore this snapshot into a freshly-built environment/protocol
    /// pair and hand back the driver state to continue from. Hard-errors
    /// on a backend, config-fingerprint or protocol mismatch.
    pub fn resume_into(
        self,
        backend: &str,
        env: &mut dyn FlEnvironment,
        protocol: &mut dyn Protocol,
    ) -> Result<DriverState> {
        anyhow::ensure!(
            self.backend == backend,
            "snapshot was captured on the '{}' backend but this run uses '{}'",
            self.backend,
            backend
        );
        self.ensure_config_matches(env.cfg())?;
        anyhow::ensure!(
            self.driver.rounds_done <= env.cfg().t_max,
            "snapshot is {} rounds in but t_max is {}",
            self.driver.rounds_done,
            env.cfg().t_max
        );
        env.restore_state(crate::env::EnvState {
            rng: self.rng,
            churn: self.churn,
            comm: self.comm,
        })?;
        protocol.restore_state(self.protocol)?;
        Ok(self.driver)
    }
}

/// The what/how split: a codec turns a [`RunSnapshot`] into bytes and
/// back without knowing where the bytes live (file today; a socket when
/// edge-state migration lands).
pub trait SnapshotCodec {
    /// Codec label for logs and reports.
    fn name(&self) -> &'static str;
    /// File extension snapshots written by this codec carry.
    fn extension(&self) -> &'static str;
    /// Serialize a snapshot (headers, checksums and all).
    fn encode(&self, snap: &RunSnapshot) -> Vec<u8>;
    /// Deserialize and validate. Never panics on hostile input.
    fn decode(&self, bytes: &[u8]) -> std::result::Result<RunSnapshot, SnapshotError>;
}

/// Which codec the `Scenario` checkpoint hook writes with.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodecKind {
    /// Versioned binary framing (production default).
    Binary,
    /// Human-readable JSON (debugging).
    Json,
}

impl CodecKind {
    pub fn codec(self) -> Box<dyn SnapshotCodec> {
        match self {
            CodecKind::Binary => Box::new(BinaryCodec),
            CodecKind::Json => Box::new(JsonCodec),
        }
    }
}

/// FNV-1a 64-bit — the checksum/fingerprint hash of the subsystem (fast,
/// dependency-free; integrity against corruption, not an adversary).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of a config — what ties a snapshot to its experiment.
pub fn config_fingerprint(cfg: &crate::config::ExperimentConfig) -> u64 {
    fnv1a64(cfg.to_json().dump().as_bytes())
}

/// Collect the JSON paths (e.g. `dropout.mean`) at which two values
/// differ — the substance of the `--resume` mismatch error message.
pub fn diff_json_paths(a: &Json, b: &Json) -> Vec<String> {
    let mut out = Vec::new();
    diff_walk(a, b, String::new(), &mut out);
    out
}

fn diff_walk(a: &Json, b: &Json, path: String, out: &mut Vec<String>) {
    match (a, b) {
        (Json::Obj(ma), Json::Obj(mb)) => {
            let keys: std::collections::BTreeSet<&String> =
                ma.keys().chain(mb.keys()).collect();
            for k in keys {
                let sub = if path.is_empty() {
                    k.to_string()
                } else {
                    format!("{path}.{k}")
                };
                match (ma.get(k.as_str()), mb.get(k.as_str())) {
                    (Some(va), Some(vb)) => diff_walk(va, vb, sub, out),
                    _ => out.push(sub),
                }
            }
        }
        (Json::Arr(va), Json::Arr(vb)) if va.len() == vb.len() => {
            for (i, (xa, xb)) in va.iter().zip(vb.iter()).enumerate() {
                diff_walk(xa, xb, format!("{path}[{i}]"), out);
            }
        }
        _ => {
            if a != b {
                out.push(if path.is_empty() { "<root>".into() } else { path });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// File I/O: atomic save, format-sniffing load.
// ---------------------------------------------------------------------------

/// Path of the checkpoint written after round `round` in `dir`.
pub fn snapshot_path(dir: &Path, round: usize, kind: CodecKind) -> PathBuf {
    dir.join(format!(
        "snapshot_round_{round:06}.{}",
        kind.codec().extension()
    ))
}

/// Serialize and write atomically (temp file + rename, so an interrupted
/// writer never leaves a half-snapshot under the final name). The temp
/// name carries the codec extension and the writer's pid, so concurrent
/// runs checkpointing the same round into one directory cannot stage
/// through the same file.
pub fn save_snapshot(path: &Path, kind: CodecKind, snap: &RunSnapshot) -> Result<()> {
    let codec = kind.codec();
    let bytes = codec.encode(snap);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(SnapshotError::Io)?;
    }
    let tmp = path.with_extension(format!("{}.{}.tmp", codec.extension(), std::process::id()));
    std::fs::write(&tmp, &bytes).map_err(SnapshotError::Io)?;
    std::fs::rename(&tmp, path).map_err(SnapshotError::Io)?;
    Ok(())
}

/// Write the round-`N` checkpoint into `dir` and return its path.
pub fn save_to_dir(dir: &Path, kind: CodecKind, snap: &RunSnapshot) -> Result<PathBuf> {
    let path = snapshot_path(dir, snap.round(), kind);
    save_snapshot(&path, kind, snap)?;
    Ok(path)
}

/// Decode a snapshot from bytes, sniffing the codec from the leading
/// bytes (binary magic vs. a JSON object).
pub fn decode_snapshot(bytes: &[u8]) -> std::result::Result<RunSnapshot, SnapshotError> {
    if bytes.starts_with(binary::MAGIC) {
        return BinaryCodec.decode(bytes);
    }
    if bytes
        .iter()
        .find(|b| !b.is_ascii_whitespace())
        .is_some_and(|&b| b == b'{')
    {
        return JsonCodec.decode(bytes);
    }
    Err(SnapshotError::BadMagic)
}

/// Read and decode a snapshot file (either codec).
pub fn load_snapshot(path: &Path) -> Result<RunSnapshot> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading snapshot {}: {e}", path.display()))?;
    decode_snapshot(&bytes)
        .map_err(|e| anyhow::anyhow!("decoding snapshot {}: {e}", path.display()))
}

// ---------------------------------------------------------------------------
// Shared helpers for the codec implementations and their tests.
// ---------------------------------------------------------------------------

/// A canonical, bit-exact byte encoding of a [`crate::env::RunResult`] —
/// the equality oracle of the deterministic-replay tests ("byte-identical
/// RunResult" means *these* bytes are identical).
pub fn run_result_bytes(r: &crate::env::RunResult) -> Vec<u8> {
    let mut w = binary::Writer::new();
    let s = &r.summary;
    w.str(&s.protocol);
    w.u64(s.rounds_run as u64);
    w.f64(s.best_accuracy);
    w.f64(s.avg_round_len);
    w.opt_u64(s.rounds_to_target.map(|v| v as u64));
    w.opt_f64(s.time_to_target);
    w.f64(s.mean_device_energy_wh);
    w.f64(s.total_time);
    w.f64(s.final_loss);
    w.u64(r.rounds.len() as u64);
    for row in &r.rounds {
        binary::write_round_trace(&mut w, row);
    }
    w.into_bytes()
}

/// `BTreeMap` view of a parsed JSON object (decode convenience).
pub(crate) fn as_obj<'a>(
    j: &'a Json,
    what: &str,
) -> std::result::Result<&'a BTreeMap<String, Json>, SnapshotError> {
    match j {
        Json::Obj(m) => Ok(m),
        _ => Err(SnapshotError::Malformed(format!("{what}: expected object"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_known_vectors() {
        // Published FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn diff_names_nested_and_missing_fields() {
        let a = Json::parse(r#"{"x": 1, "d": {"mean": 0.3, "std": 0.1}, "only_a": true}"#)
            .unwrap();
        let b = Json::parse(r#"{"x": 2, "d": {"mean": 0.6, "std": 0.1}}"#).unwrap();
        let diff = diff_json_paths(&a, &b);
        assert!(diff.contains(&"x".to_string()), "{diff:?}");
        assert!(diff.contains(&"d.mean".to_string()), "{diff:?}");
        assert!(diff.contains(&"only_a".to_string()), "{diff:?}");
        assert!(!diff.iter().any(|p| p == "d.std"), "{diff:?}");
    }

    #[test]
    fn config_fingerprint_is_stable_and_sensitive() {
        let cfg = crate::config::ExperimentConfig::fig2();
        let f1 = config_fingerprint(&cfg);
        let f2 = config_fingerprint(&cfg.clone());
        assert_eq!(f1, f2);
        let mut changed = cfg;
        changed.c_fraction = 0.31;
        assert_ne!(f1, config_fingerprint(&changed));
    }

    #[test]
    fn decode_sniffs_garbage_as_bad_magic() {
        assert!(matches!(
            decode_snapshot(b"definitely not a snapshot"),
            Err(SnapshotError::BadMagic)
        ));
        assert!(matches!(decode_snapshot(b""), Err(SnapshotError::BadMagic)));
    }
}
