//! Tiny benchmark harness (criterion is not in the offline vendor set).
//!
//! Used by the `benches/` targets (`harness = false`): warmup + timed
//! iterations with mean / stddev / min / p50 reporting, a `black_box` to
//! defeat const-folding, and the shared [`write_report`] emitter behind
//! the `BENCH_*.json` artifacts CI collects from every bench.

use std::time::{Duration, Instant};

use crate::jsonx::Json;

/// Prevent the optimizer from eliding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Timing statistics over `n` iterations.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub p50: Duration,
}

impl Stats {
    pub fn report(&self, name: &str) {
        println!(
            "{name:<44} {:>12} iters  mean {:>12?}  p50 {:>12?}  min {:>12?}  σ {:>10?}",
            self.iters, self.mean, self.p50, self.min, self.stddev
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    stats(&mut samples)
}

/// Run `f` repeatedly until `budget` elapses (at least once); report stats.
pub fn bench_for<F: FnMut()>(budget: Duration, mut f: F) -> Stats {
    let start = Instant::now();
    let mut samples = Vec::new();
    loop {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if start.elapsed() >= budget {
            break;
        }
    }
    stats(&mut samples)
}

fn stats(samples: &mut [Duration]) -> Stats {
    samples.sort();
    let n = samples.len().max(1);
    let total: Duration = samples.iter().sum();
    let mean = total / n as u32;
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| {
            let x = d.as_secs_f64() - mean_s;
            x * x
        })
        .sum::<f64>()
        / n as f64;
    Stats {
        iters: n,
        mean,
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: samples.first().copied().unwrap_or_default(),
        p50: samples[n / 2.min(n - 1)],
    }
}

/// Write a bench's machine-readable report to `BENCH_<stem>.json` in the
/// working directory — the artifact contract of the CI `bench · smoke`
/// job (its check list must name every stem benches pass here).
pub fn write_report(stem: &str, report: &Json) {
    let path = format!("BENCH_{stem}.json");
    std::fs::write(&path, report.pretty())
        .unwrap_or_else(|e| panic!("writing {path}: {e}"));
    println!("report -> {path}");
}

/// The process's peak resident set size (`VmHWM`) in bytes, read from
/// `/proc/self/status`. `None` off Linux or if the field is missing —
/// callers report it as best-effort telemetry and skip assertions when
/// absent. Note it is a process-lifetime high-water mark: it never
/// decreases, so memory-ceiling checks must run ascending scales.
pub fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Parse common bench CLI flags: `--full` (paper scale) and
/// `--quick` (minimal iterations for CI smoke).
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchArgs {
    pub full: bool,
    pub quick: bool,
}

impl BenchArgs {
    pub fn from_env() -> BenchArgs {
        let args: Vec<String> = std::env::args().collect();
        BenchArgs {
            full: args.iter().any(|a| a == "--full"),
            quick: args.iter().any(|a| a == "--quick"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut acc = 0u64;
        let s = bench(2, 10, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(s.iters, 10);
        assert!(s.min <= s.p50);
        assert!(s.p50 <= s.mean * 10);
    }

    #[test]
    fn bench_for_respects_budget_loosely() {
        let s = bench_for(Duration::from_millis(5), || {
            std::thread::sleep(Duration::from_micros(100));
        });
        assert!(s.iters >= 1);
    }
}
