//! Fate traces: ground-truth per-round client fates, recordable from any
//! run and replayable as a scenario.
//!
//! A [`FateTrace`] maps `(round, client)` to the client's fate — did it
//! drop out, and when did it complete (virtual seconds from round start).
//! The environment records one entry per *selected* client per round
//! (`--record-fates`); the JSON file it writes can be replayed verbatim
//! (`--replay-fates`, [`crate::churn::ChurnModel::Replay`]), hand-edited,
//! or written from scratch to script arbitrary availability patterns.
//!
//! Replay semantics: a selected client listed in the trace for that round
//! takes its recorded fate bit-for-bit; a selected client the trace does
//! not list is treated as unavailable (dropped). Selection itself is
//! untouched — it draws from the seeded RNG stream exactly as before —
//! so re-running the recorded experiment with its own trace is a fixed
//! point: the replayed run records the identical trace.
//!
//! # File format
//!
//! ```json
//! {
//!   "kind": "hybridfl-fate-trace",
//!   "version": 1,
//!   "rounds": [
//!     {"t": 1, "fates": [
//!       {"client": 0, "region": 0, "dropped": false, "completion": 41.25},
//!       {"client": 7, "region": 1, "dropped": true}
//!     ]}
//!   ]
//! }
//! ```
//!
//! Dropped entries carry no `completion` (it is +∞, which JSON cannot
//! express); `completion` is required for non-dropped entries. Floats
//! round-trip bit-exactly through the shortest-roundtrip formatting of
//! [`crate::jsonx`].

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::env::ClientFate;
use crate::jsonx::Json;

/// Trace-file `kind` discriminator.
const KIND: &str = "hybridfl-fate-trace";
/// Trace-file format version.
const VERSION: u64 = 1;

/// One client's recorded fate in one round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FateRecord {
    /// Region the client belonged to when the fate played out (kept for
    /// analysis; replay routes by the *current* topology).
    pub region: usize,
    pub dropped: bool,
    /// Completion time in virtual seconds from round start
    /// (`f64::INFINITY` when dropped).
    pub completion: f64,
}

/// Ground-truth per-round fates, keyed `(round, client)`. BTreeMaps keep
/// serialization deterministic (stable diffs, byte-stable fixed points).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FateTrace {
    rounds: BTreeMap<usize, BTreeMap<usize, FateRecord>>,
}

impl FateTrace {
    pub fn new() -> FateTrace {
        FateTrace::default()
    }

    /// Record every fate of one executed round (the environment calls
    /// this right after drawing — or replaying — the round's fates).
    pub fn record(&mut self, t: usize, fates: &[ClientFate]) {
        let round = self.rounds.entry(t).or_default();
        for f in fates {
            round.insert(
                f.client,
                FateRecord {
                    region: f.region,
                    dropped: f.dropped,
                    completion: f.completion,
                },
            );
        }
    }

    /// Insert a single hand-written entry.
    pub fn insert(&mut self, t: usize, client: usize, rec: FateRecord) {
        self.rounds.entry(t).or_default().insert(client, rec);
    }

    /// The recorded fate of `client` in round `t`, if any.
    pub fn get(&self, t: usize, client: usize) -> Option<&FateRecord> {
        self.rounds.get(&t).and_then(|r| r.get(&client))
    }

    /// Number of rounds with at least one recorded fate.
    pub fn n_rounds(&self) -> usize {
        self.rounds.len()
    }

    /// Total number of recorded (round, client) entries.
    pub fn n_entries(&self) -> usize {
        self.rounds.values().map(|r| r.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.rounds.is_empty()
    }

    // --- serialization ------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let rounds: Vec<Json> = self
            .rounds
            .iter()
            .map(|(&t, fates)| {
                let entries: Vec<Json> = fates
                    .iter()
                    .map(|(&client, rec)| {
                        let j = Json::obj()
                            .set("client", client)
                            .set("region", rec.region)
                            .set("dropped", rec.dropped);
                        if rec.dropped {
                            j
                        } else {
                            j.set("completion", rec.completion)
                        }
                    })
                    .collect();
                Json::obj().set("t", t).set("fates", Json::Arr(entries))
            })
            .collect();
        Json::obj()
            .set("kind", KIND)
            .set("version", VERSION)
            .set("rounds", Json::Arr(rounds))
    }

    pub fn from_json(j: &Json) -> Result<FateTrace> {
        match j.get("kind") {
            Some(Json::Str(k)) if k == KIND => {}
            _ => bail!("not a fate trace (missing kind '{KIND}')"),
        }
        let version = j.req("version")?.as_usize()? as u64;
        if version != VERSION {
            bail!("fate-trace version {version} is not supported (this build reads {VERSION})");
        }
        let mut trace = FateTrace::new();
        for round in j.req("rounds")?.as_arr()? {
            let t = round.req("t")?.as_usize()?;
            if t == 0 {
                bail!("fate-trace rounds are 1-based; round 0 is invalid");
            }
            for entry in round.req("fates")?.as_arr()? {
                let client = entry.req("client")?.as_usize()?;
                let region = entry.req("region")?.as_usize()?;
                let dropped = entry.req("dropped")?.as_bool()?;
                let completion = if dropped {
                    f64::INFINITY
                } else {
                    let c = entry
                        .req("completion")
                        .context("non-dropped fate needs a completion time")?
                        .as_f64()?;
                    if !(c.is_finite() && c >= 0.0) {
                        bail!("completion must be finite and >= 0, got {c}");
                    }
                    c
                };
                if trace
                    .rounds
                    .entry(t)
                    .or_default()
                    .insert(
                        client,
                        FateRecord {
                            region,
                            dropped,
                            completion,
                        },
                    )
                    .is_some()
                {
                    bail!("round {t} lists client {client} twice");
                }
            }
        }
        Ok(trace)
    }

    /// Write the trace as pretty JSON (atomically: temp file + rename).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, self.to_json().pretty())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<FateTrace> {
        Self::from_json(&Json::parse_file(path)?)
            .with_context(|| format!("loading fate trace {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fate(client: usize, region: usize, dropped: bool, completion: f64) -> ClientFate {
        ClientFate {
            client,
            region,
            dropped,
            completion,
        }
    }

    #[test]
    fn record_get_and_counts() {
        let mut tr = FateTrace::new();
        tr.record(
            1,
            &[fate(0, 0, false, 12.5), fate(3, 1, true, f64::INFINITY)],
        );
        tr.record(2, &[fate(0, 0, false, 9.0)]);
        assert_eq!(tr.n_rounds(), 2);
        assert_eq!(tr.n_entries(), 3);
        assert!(!tr.get(1, 0).unwrap().dropped);
        assert!(tr.get(1, 3).unwrap().dropped);
        assert!(tr.get(1, 3).unwrap().completion.is_infinite());
        assert!(tr.get(1, 7).is_none());
        assert!(tr.get(3, 0).is_none());
    }

    #[test]
    fn json_roundtrip_bit_exact() {
        let mut tr = FateTrace::new();
        tr.record(
            1,
            &[
                fate(0, 0, false, 41.25),
                fate(1, 0, false, 0.1 + 0.2), // non-representable decimal
                fate(9, 1, true, f64::INFINITY),
            ],
        );
        tr.record(7, &[fate(4, 1, false, 1e-12)]);
        let back = FateTrace::from_json(&Json::parse(&tr.to_json().dump()).unwrap()).unwrap();
        assert_eq!(tr, back);
        assert_eq!(
            back.get(1, 1).unwrap().completion.to_bits(),
            (0.1f64 + 0.2).to_bits()
        );
    }

    #[test]
    fn file_save_load_roundtrip() {
        let mut tr = FateTrace::new();
        tr.record(1, &[fate(2, 0, false, 5.0)]);
        let dir = std::env::temp_dir().join("hybridfl_fate_trace_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("trace.json");
        tr.save(&path).unwrap();
        assert_eq!(FateTrace::load(&path).unwrap(), tr);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn malformed_traces_are_rejected() {
        let bad = [
            r#"{"version": 1, "rounds": []}"#, // no kind
            r#"{"kind": "hybridfl-fate-trace", "version": 9, "rounds": []}"#,
            r#"{"kind": "hybridfl-fate-trace", "version": 1,
                "rounds": [{"t": 0, "fates": []}]}"#, // round 0
            r#"{"kind": "hybridfl-fate-trace", "version": 1,
                "rounds": [{"t": 1, "fates": [
                    {"client": 0, "region": 0, "dropped": false}]}]}"#, // no completion
            r#"{"kind": "hybridfl-fate-trace", "version": 1,
                "rounds": [{"t": 1, "fates": [
                    {"client": 0, "region": 0, "dropped": true},
                    {"client": 0, "region": 0, "dropped": true}]}]}"#, // duplicate
        ];
        for text in bad {
            let j = Json::parse(text).unwrap();
            assert!(FateTrace::from_json(&j).is_err(), "accepted: {text}");
        }
    }

    #[test]
    fn handwritten_trace_builds_via_insert() {
        let mut tr = FateTrace::new();
        for k in 0..5 {
            tr.insert(
                1,
                k,
                FateRecord {
                    region: 0,
                    dropped: k % 2 == 0,
                    completion: if k % 2 == 0 { f64::INFINITY } else { 30.0 },
                },
            );
        }
        assert_eq!(tr.n_entries(), 5);
        assert!(tr.get(1, 0).unwrap().dropped);
        assert!(!tr.get(1, 1).unwrap().dropped);
    }
}
