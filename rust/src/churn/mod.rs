//! Dynamic reliability: time-varying churn processes, scripted fault
//! events, and fate-trace replay.
//!
//! The paper's whole pitch is that the regional slack estimator adapts to
//! client reliability it cannot observe — but a *stationary* world (one
//! `dropout_p` per client, i.i.d. fates every round) only ever tests the
//! estimator against a fixed target. Real MEC fleets churn: diurnal
//! availability cycles, battery depletion, flash crowds and correlated
//! edge outages are the norm in mobile edge networks. This module makes
//! the simulated world non-stationary while keeping every draw
//! deterministic in the seed.
//!
//! # Architecture
//!
//! * [`ChurnModel`] — the *config-level* description of the world's
//!   dynamics. It lives in [`crate::config::ExperimentConfig::churn`],
//!   serializes with the config (so it participates in the snapshot
//!   fingerprint) and parses from a compact CLI spec
//!   ([`ChurnModel::parse_spec`], the `--churn` flag).
//! * [`WorldDynamics`] — the *runtime* process. Both
//!   [`crate::env::FlEnvironment`] backends run one dynamics step at each
//!   round boundary, **before** the round's fate draw: the step resets
//!   the *dirty* slice of the fleet to its pristine base rows (only the
//!   regions the previous step rewrote — [`Touched`]), then lets the
//!   model rewrite per-client reliability (and, for mobility events, the
//!   topology) as a deterministic function of its state, the round index
//!   and a dedicated RNG substream. Script-only models additionally skip
//!   the per-round event scan: an [`EventSchedule`] precomputes the round
//!   boundaries at which the touched-region set can change and caches the
//!   set between them, so a quiet round costs O(1) instead of O(n).
//!   Protocols never observe any of this — they still see only submission
//!   counts, exactly the paper's reliability-agnostic contract.
//! * [`ChurnState`] — the process's mutable state at a round boundary
//!   (Markov on/off flags, battery levels). Captured into a
//!   [`crate::snapshot::RunSnapshot`] so a resumed run continues the
//!   exact reliability trajectory of the uninterrupted one.
//! * [`FateTrace`] — ground-truth per-round fates recorded by the
//!   environment (`--record-fates`) and replayable as a scenario
//!   (`--replay-fates` / [`ChurnModel::Replay`]), including hand-written
//!   or externally derived traces. Replaying a recorded trace is a fixed
//!   point: the replayed run records the identical trace.
//!
//! # Determinism discipline
//!
//! The dynamics step draws from `round_rng.split(t).split(CHURN_STREAM)`
//! — a child stream of the round's RNG. Stream splitting never advances
//! the parent, so the selection and fate draws that follow are
//! bit-identical whether the step drew nothing ([`ChurnModel::Stationary`])
//! or ten thousand Bernoullis: a `Stationary` run is byte-identical to a
//! run of the pre-churn code, and adding churn never perturbs the parts
//! of the world it does not touch.

pub mod fate_trace;

pub use fate_trace::{FateRecord, FateTrace};

use anyhow::{bail, Context, Result};

use crate::devices::FleetState;
use crate::jsonx::Json;
use crate::rng::Rng;
use crate::topology::Topology;

/// Config-level description of the world's reliability dynamics. The
/// default ([`ChurnModel::Stationary`]) reproduces the historical
/// behavior: one static `dropout_p` per client, i.i.d. fates per round.
#[derive(Clone, Debug, PartialEq)]
pub enum ChurnModel {
    /// Frozen world — today's behavior, and the default.
    Stationary,
    /// Bursty availability: each client is an independent two-state
    /// Markov chain stepped once per round. An *up* client keeps its base
    /// `dropout_p`; a *down* client drops out with `down_dropout`
    /// (correlated multi-round outages, unlike i.i.d. fates).
    MarkovOnOff {
        /// P(up → down) per round.
        p_fail: f64,
        /// P(down → up) per round.
        p_recover: f64,
        /// Effective drop-out probability while down (≈ 1).
        down_dropout: f64,
        /// Optional per-region multiplier on both transition rates
        /// (empty = 1.0 everywhere; otherwise one entry per region).
        region_scale: Vec<f64>,
    },
    /// Sinusoidal drop-out modulation — the diurnal availability cycle:
    /// `dropout_k(t) = clamp(base_k + amplitude · sin(2π(t−1)/period + φ_r))`.
    Diurnal {
        /// Peak drop-out modulation added to the base probability.
        amplitude: f64,
        /// Cycle length in rounds.
        period: usize,
        /// Per-region phase offsets φ_r in radians (empty = evenly
        /// spaced over the cycle, so regions peak at different times).
        region_phase: Vec<f64>,
    },
    /// Monotone battery depletion with recharge: every client starts at a
    /// jittered charge level, loses `drain_per_round` per round, and once
    /// depleted drops out with `depleted_dropout` until a per-round
    /// recharge draw (`recharge_p`) restores it to full charge.
    BatteryDrain {
        drain_per_round: f64,
        recharge_p: f64,
        depleted_dropout: f64,
    },
    /// Scheduled, scripted events (region blackout over a round window,
    /// drop-out step changes, bandwidth degradation, client mobility
    /// between regions). Pure function of the round index — no state.
    FaultScript { events: Vec<FaultEvent> },
    /// Replay the ground-truth fates of a recorded [`FateTrace`] instead
    /// of drawing them: selected clients take their recorded
    /// dropped/completion — and recorded region attachment — verbatim; a
    /// selected client the trace does not list for that round is treated
    /// as unavailable (dropped). Traces recorded under migration events
    /// replay faithfully on the virtual clock only: the live fabric
    /// binds clients to their base edges, so a recorded region that
    /// disagrees with the static topology cannot be enacted there.
    Replay {
        /// Path to the trace JSON (written by `--record-fates` or by
        /// hand).
        path: String,
    },
    /// Layered composition: each layer rewrites the fleet in order, on
    /// top of what the previous layers produced (e.g. Markov burstiness
    /// plus one scripted regional blackout). One level deep; `Replay` is
    /// not composable (it bypasses the world entirely).
    Composed { layers: Vec<ChurnModel> },
}

impl Default for ChurnModel {
    fn default() -> ChurnModel {
        ChurnModel::Stationary
    }
}

/// One scripted fault event ([`ChurnModel::FaultScript`]). Round windows
/// are half-open `[from_round, until_round)` over 1-based round indices;
/// point events apply from `at_round` onward.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// Every client of `region` is unavailable during the window — a
    /// correlated edge outage.
    RegionBlackout {
        region: usize,
        from_round: usize,
        until_round: usize,
    },
    /// Permanent drop-out step change from `at_round` on: `delta` is
    /// added to the affected clients' base drop-out probability
    /// (`region: None` = the whole fleet). The dynamic Fig. 2 scenario.
    DropoutShift {
        region: Option<usize>,
        at_round: usize,
        delta: f64,
    },
    /// Wireless bandwidth of `region`'s clients is multiplied by
    /// `factor` (∈ (0, 1]) during the window — longer completions, more
    /// stragglers, same aliveness.
    BandwidthDegrade {
        region: usize,
        from_round: usize,
        until_round: usize,
        factor: f64,
    },
    /// Client mobility: from `at_round` on, `client` is attached to
    /// `to_region`'s edge. Supported on the virtual-clock backend only
    /// (the live fabric binds client threads to edge channels at spawn).
    Migrate {
        client: usize,
        at_round: usize,
        to_region: usize,
    },
}

fn prob(v: f64, what: &str) -> Result<()> {
    if !(0.0..=1.0).contains(&v) || !v.is_finite() {
        bail!("{what} must be a probability in [0, 1], got {v}");
    }
    Ok(())
}

impl FaultEvent {
    /// Validate against the experiment's region/client counts.
    fn validate(&self, n_regions: usize, n_clients: usize) -> Result<()> {
        let region_ok = |r: usize| -> Result<()> {
            if r >= n_regions {
                bail!("event names region {r} but the topology has {n_regions} regions");
            }
            Ok(())
        };
        match self {
            FaultEvent::RegionBlackout {
                region,
                from_round,
                until_round,
            } => {
                region_ok(*region)?;
                if from_round >= until_round {
                    bail!(
                        "blackout window [{from_round}, {until_round}) is empty \
                         (rounds are 1-based, until is exclusive)"
                    );
                }
            }
            FaultEvent::DropoutShift { region, delta, .. } => {
                if let Some(r) = region {
                    region_ok(*r)?;
                }
                if !delta.is_finite() || delta.abs() > 1.0 {
                    bail!("dropout shift delta must be finite and in [-1, 1], got {delta}");
                }
            }
            FaultEvent::BandwidthDegrade {
                region,
                from_round,
                until_round,
                factor,
            } => {
                region_ok(*region)?;
                if from_round >= until_round {
                    bail!(
                        "bandwidth window [{from_round}, {until_round}) is empty \
                         (rounds are 1-based, until is exclusive)"
                    );
                }
                if !(*factor > 0.0 && *factor <= 1.0) {
                    bail!("bandwidth factor must be in (0, 1], got {factor}");
                }
            }
            FaultEvent::Migrate {
                client, to_region, ..
            } => {
                region_ok(*to_region)?;
                if *client >= n_clients {
                    bail!("migration names client {client} but the fleet has {n_clients} clients");
                }
            }
        }
        Ok(())
    }

    /// First round at which the event has any effect: `from_round` for
    /// windowed events, `at_round` for point events. The ops control
    /// plane uses this to reject injections into rounds already run.
    pub fn start_round(&self) -> usize {
        match self {
            FaultEvent::RegionBlackout { from_round, .. }
            | FaultEvent::BandwidthDegrade { from_round, .. } => *from_round,
            FaultEvent::DropoutShift { at_round, .. } | FaultEvent::Migrate { at_round, .. } => {
                *at_round
            }
        }
    }

    /// JSON form, the same encoding the snapshot codec and the ops
    /// `inject` command use.
    pub fn to_json(&self) -> Json {
        match self {
            FaultEvent::RegionBlackout {
                region,
                from_round,
                until_round,
            } => Json::obj()
                .set("kind", "region_blackout")
                .set("region", *region)
                .set("from_round", *from_round)
                .set("until_round", *until_round),
            FaultEvent::DropoutShift {
                region,
                at_round,
                delta,
            } => Json::obj()
                .set("kind", "dropout_shift")
                .set(
                    "region",
                    region.map_or(Json::Null, |r| Json::Num(r as f64)),
                )
                .set("at_round", *at_round)
                .set("delta", *delta),
            FaultEvent::BandwidthDegrade {
                region,
                from_round,
                until_round,
                factor,
            } => Json::obj()
                .set("kind", "bandwidth_degrade")
                .set("region", *region)
                .set("from_round", *from_round)
                .set("until_round", *until_round)
                .set("factor", *factor),
            FaultEvent::Migrate {
                client,
                at_round,
                to_region,
            } => Json::obj()
                .set("kind", "migrate")
                .set("client", *client)
                .set("at_round", *at_round)
                .set("to_region", *to_region),
        }
    }

    /// Parse the [`FaultEvent::to_json`] encoding.
    pub fn from_json(j: &Json) -> Result<FaultEvent> {
        let kind = j.req("kind")?.as_str()?;
        Ok(match kind {
            "region_blackout" => FaultEvent::RegionBlackout {
                region: j.req("region")?.as_usize()?,
                from_round: j.req("from_round")?.as_usize()?,
                until_round: j.req("until_round")?.as_usize()?,
            },
            "dropout_shift" => FaultEvent::DropoutShift {
                region: match j.req("region")? {
                    Json::Null => None,
                    v => Some(v.as_usize()?),
                },
                at_round: j.req("at_round")?.as_usize()?,
                delta: j.req("delta")?.as_f64()?,
            },
            "bandwidth_degrade" => FaultEvent::BandwidthDegrade {
                region: j.req("region")?.as_usize()?,
                from_round: j.req("from_round")?.as_usize()?,
                until_round: j.req("until_round")?.as_usize()?,
                factor: j.req("factor")?.as_f64()?,
            },
            "migrate" => FaultEvent::Migrate {
                client: j.req("client")?.as_usize()?,
                at_round: j.req("at_round")?.as_usize()?,
                to_region: j.req("to_region")?.as_usize()?,
            },
            k => bail!("unknown fault event kind '{k}'"),
        })
    }
}

impl ChurnModel {
    /// Short kind label for logs and error messages.
    pub fn kind_str(&self) -> &'static str {
        match self {
            ChurnModel::Stationary => "stationary",
            ChurnModel::MarkovOnOff { .. } => "markov",
            ChurnModel::Diurnal { .. } => "diurnal",
            ChurnModel::BatteryDrain { .. } => "battery",
            ChurnModel::FaultScript { .. } => "script",
            ChurnModel::Replay { .. } => "replay",
            ChurnModel::Composed { .. } => "composed",
        }
    }

    /// Whether this model contains a [`FaultEvent::Migrate`] anywhere —
    /// the live backend rejects those (client threads are bound to their
    /// edge channels at spawn).
    pub fn has_migrations(&self) -> bool {
        match self {
            ChurnModel::FaultScript { events } => events
                .iter()
                .any(|e| matches!(e, FaultEvent::Migrate { .. })),
            ChurnModel::Composed { layers } => layers.iter().any(|l| l.has_migrations()),
            _ => false,
        }
    }

    /// Whether the dynamics step is a structural no-op (fates come from
    /// the base profiles or from a replayed trace).
    pub fn is_noop(&self) -> bool {
        match self {
            ChurnModel::Stationary | ChurnModel::Replay { .. } => true,
            ChurnModel::Composed { layers } => layers.iter().all(|l| l.is_noop()),
            _ => false,
        }
    }

    /// Validate against the experiment's region/client counts (called
    /// from [`crate::config::ExperimentConfig::validate`]).
    pub fn validate(&self, n_regions: usize, n_clients: usize) -> Result<()> {
        self.validate_inner(n_regions, n_clients, true)
    }

    fn validate_inner(&self, n_regions: usize, n_clients: usize, top: bool) -> Result<()> {
        match self {
            ChurnModel::Stationary => {}
            ChurnModel::MarkovOnOff {
                p_fail,
                p_recover,
                down_dropout,
                region_scale,
            } => {
                prob(*p_fail, "markov p_fail")?;
                prob(*p_recover, "markov p_recover")?;
                prob(*down_dropout, "markov down_dropout")?;
                if !region_scale.is_empty() && region_scale.len() != n_regions {
                    bail!(
                        "markov region_scale has {} entries but the topology has {} regions \
                         (leave it empty for 1.0 everywhere)",
                        region_scale.len(),
                        n_regions
                    );
                }
                for (r, &s) in region_scale.iter().enumerate() {
                    if !(s.is_finite() && s >= 0.0) {
                        bail!("markov region_scale[{r}] must be a finite non-negative factor, got {s}");
                    }
                }
            }
            ChurnModel::Diurnal {
                amplitude,
                period,
                region_phase,
            } => {
                prob(*amplitude, "diurnal amplitude")?;
                if *period == 0 {
                    bail!("diurnal period must be >= 1 round");
                }
                if !region_phase.is_empty() && region_phase.len() != n_regions {
                    bail!(
                        "diurnal region_phase has {} entries but the topology has {} regions \
                         (leave it empty for evenly spaced phases)",
                        region_phase.len(),
                        n_regions
                    );
                }
                for (r, &p) in region_phase.iter().enumerate() {
                    if !p.is_finite() {
                        bail!("diurnal region_phase[{r}] must be finite, got {p}");
                    }
                }
            }
            ChurnModel::BatteryDrain {
                drain_per_round,
                recharge_p,
                depleted_dropout,
            } => {
                if !(*drain_per_round > 0.0 && *drain_per_round <= 1.0) {
                    bail!("battery drain_per_round must be in (0, 1], got {drain_per_round}");
                }
                prob(*recharge_p, "battery recharge_p")?;
                prob(*depleted_dropout, "battery depleted_dropout")?;
            }
            ChurnModel::FaultScript { events } => {
                if events.is_empty() {
                    bail!("fault script has no events");
                }
                for e in events {
                    e.validate(n_regions, n_clients)?;
                }
            }
            ChurnModel::Replay { path } => {
                if path.is_empty() {
                    bail!("replay path is empty");
                }
                if !top {
                    bail!("replay cannot appear inside a composed churn model");
                }
            }
            ChurnModel::Composed { layers } => {
                if !top {
                    bail!("composed churn models nest at most one level deep");
                }
                if layers.is_empty() {
                    bail!("composed churn model has no layers");
                }
                for l in layers {
                    l.validate_inner(n_regions, n_clients, false)?;
                }
            }
        }
        Ok(())
    }

    // --- JSON (config serialization) ---------------------------------------

    pub fn to_json(&self) -> Json {
        match self {
            ChurnModel::Stationary => Json::obj().set("kind", "stationary"),
            ChurnModel::MarkovOnOff {
                p_fail,
                p_recover,
                down_dropout,
                region_scale,
            } => Json::obj()
                .set("kind", "markov_on_off")
                .set("p_fail", *p_fail)
                .set("p_recover", *p_recover)
                .set("down_dropout", *down_dropout)
                .set(
                    "region_scale",
                    Json::Arr(region_scale.iter().map(|&s| Json::Num(s)).collect()),
                ),
            ChurnModel::Diurnal {
                amplitude,
                period,
                region_phase,
            } => Json::obj()
                .set("kind", "diurnal")
                .set("amplitude", *amplitude)
                .set("period", *period)
                .set(
                    "region_phase",
                    Json::Arr(region_phase.iter().map(|&p| Json::Num(p)).collect()),
                ),
            ChurnModel::BatteryDrain {
                drain_per_round,
                recharge_p,
                depleted_dropout,
            } => Json::obj()
                .set("kind", "battery_drain")
                .set("drain_per_round", *drain_per_round)
                .set("recharge_p", *recharge_p)
                .set("depleted_dropout", *depleted_dropout),
            ChurnModel::FaultScript { events } => Json::obj()
                .set("kind", "fault_script")
                .set("events", Json::Arr(events.iter().map(|e| e.to_json()).collect())),
            ChurnModel::Replay { path } => Json::obj()
                .set("kind", "replay")
                .set("path", path.as_str()),
            ChurnModel::Composed { layers } => Json::obj()
                .set("kind", "composed")
                .set(
                    "layers",
                    Json::Arr(layers.iter().map(|l| l.to_json()).collect()),
                ),
        }
    }

    pub fn from_json(j: &Json) -> Result<ChurnModel> {
        let kind = j.req("kind")?.as_str()?;
        Ok(match kind {
            "stationary" => ChurnModel::Stationary,
            "markov_on_off" => ChurnModel::MarkovOnOff {
                p_fail: j.req("p_fail")?.as_f64()?,
                p_recover: j.req("p_recover")?.as_f64()?,
                down_dropout: j.req("down_dropout")?.as_f64()?,
                region_scale: j
                    .req("region_scale")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_f64())
                    .collect::<Result<_>>()?,
            },
            "diurnal" => ChurnModel::Diurnal {
                amplitude: j.req("amplitude")?.as_f64()?,
                period: j.req("period")?.as_usize()?,
                region_phase: j
                    .req("region_phase")?
                    .as_arr()?
                    .iter()
                    .map(|v| v.as_f64())
                    .collect::<Result<_>>()?,
            },
            "battery_drain" => ChurnModel::BatteryDrain {
                drain_per_round: j.req("drain_per_round")?.as_f64()?,
                recharge_p: j.req("recharge_p")?.as_f64()?,
                depleted_dropout: j.req("depleted_dropout")?.as_f64()?,
            },
            "fault_script" => ChurnModel::FaultScript {
                events: j
                    .req("events")?
                    .as_arr()?
                    .iter()
                    .map(FaultEvent::from_json)
                    .collect::<Result<_>>()?,
            },
            "replay" => ChurnModel::Replay {
                path: j.req("path")?.as_str()?.to_string(),
            },
            "composed" => ChurnModel::Composed {
                layers: j
                    .req("layers")?
                    .as_arr()?
                    .iter()
                    .map(ChurnModel::from_json)
                    .collect::<Result<_>>()?,
            },
            k => bail!("unknown churn kind '{k}'"),
        })
    }

    // --- CLI spec ----------------------------------------------------------

    /// Parse the compact `--churn` spec. Layers compose with `+`:
    ///
    /// ```text
    /// stationary
    /// markov[:p_fail=0.05,p_recover=0.25,down_dr=0.95]
    /// diurnal[:amplitude=0.25,period=48]
    /// battery[:drain=0.02,recharge=0.15,depleted_dr=0.99]
    /// script:events.json            # FaultScript events from a JSON file
    /// replay:trace.json             # == --replay-fates trace.json
    /// markov+script:events.json     # composition
    /// ```
    pub fn parse_spec(spec: &str) -> Result<ChurnModel> {
        let parts: Vec<&str> = spec.split('+').map(str::trim).collect();
        if parts.len() == 1 {
            return Self::parse_one(parts[0]);
        }
        let layers = parts
            .iter()
            .map(|p| Self::parse_one(p))
            .collect::<Result<Vec<_>>>()?;
        if layers.iter().any(|l| matches!(l, ChurnModel::Replay { .. })) {
            bail!("replay cannot be composed with other churn layers");
        }
        Ok(ChurnModel::Composed { layers })
    }

    fn parse_one(spec: &str) -> Result<ChurnModel> {
        let (kind, rest) = match spec.split_once(':') {
            Some((k, r)) => (k.trim(), Some(r.trim())),
            None => (spec.trim(), None),
        };
        let kv = |rest: Option<&str>| -> Result<Vec<(String, f64)>> {
            let Some(rest) = rest else {
                return Ok(Vec::new());
            };
            rest.split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|pair| {
                    let (k, v) = pair
                        .split_once('=')
                        .with_context(|| format!("churn option '{pair}' is not key=value"))?;
                    let v: f64 = v
                        .trim()
                        .parse()
                        .with_context(|| format!("churn option '{pair}': not a number"))?;
                    Ok((k.trim().to_string(), v))
                })
                .collect()
        };
        let take = |opts: &[(String, f64)], key: &str, default: f64| -> f64 {
            opts.iter()
                .rev()
                .find(|(k, _)| k == key)
                .map_or(default, |(_, v)| *v)
        };
        let known = |opts: &[(String, f64)], keys: &[&str]| -> Result<()> {
            for (k, _) in opts {
                if !keys.contains(&k.as_str()) {
                    bail!("unknown churn option '{k}' (valid: {})", keys.join(", "));
                }
            }
            Ok(())
        };
        Ok(match kind {
            "stationary" => ChurnModel::Stationary,
            "markov" => {
                let opts = kv(rest)?;
                known(&opts, &["p_fail", "p_recover", "down_dr"])?;
                ChurnModel::MarkovOnOff {
                    p_fail: take(&opts, "p_fail", 0.05),
                    p_recover: take(&opts, "p_recover", 0.25),
                    down_dropout: take(&opts, "down_dr", 0.95),
                    region_scale: Vec::new(),
                }
            }
            "diurnal" => {
                let opts = kv(rest)?;
                known(&opts, &["amplitude", "period"])?;
                let period = take(&opts, "period", 48.0);
                if period < 1.0 || period.fract() != 0.0 {
                    bail!("diurnal period must be a whole number of rounds >= 1, got {period}");
                }
                ChurnModel::Diurnal {
                    amplitude: take(&opts, "amplitude", 0.25),
                    period: period as usize,
                    region_phase: Vec::new(),
                }
            }
            "battery" => {
                let opts = kv(rest)?;
                known(&opts, &["drain", "recharge", "depleted_dr"])?;
                ChurnModel::BatteryDrain {
                    drain_per_round: take(&opts, "drain", 0.02),
                    recharge_p: take(&opts, "recharge", 0.15),
                    depleted_dropout: take(&opts, "depleted_dr", 0.99),
                }
            }
            "script" => {
                let path = rest.filter(|r| !r.is_empty()).with_context(|| {
                    "script churn needs a file: script:events.json".to_string()
                })?;
                let j = Json::parse_file(std::path::Path::new(path))?;
                let events_json = match &j {
                    Json::Arr(v) => v.as_slice(),
                    Json::Obj(_) => j.req("events")?.as_arr()?,
                    _ => bail!("{path}: expected an event array or {{\"events\": [...]}}"),
                };
                ChurnModel::FaultScript {
                    events: events_json
                        .iter()
                        .map(FaultEvent::from_json)
                        .collect::<Result<_>>()?,
                }
            }
            "replay" => {
                let path = rest.filter(|r| !r.is_empty()).with_context(|| {
                    "replay churn needs a file: replay:trace.json".to_string()
                })?;
                ChurnModel::Replay {
                    path: path.to_string(),
                }
            }
            k => bail!(
                "unknown churn kind '{k}' \
                 (stationary|markov|diurnal|battery|script:FILE|replay:FILE, compose with '+')"
            ),
        })
    }
}

/// A churn process's mutable state at a round boundary — what a
/// [`crate::snapshot::RunSnapshot`] captures so the resumed run continues
/// the exact reliability trajectory. Stateless models (stationary,
/// diurnal, fault scripts, replay) are pure functions of the round index
/// and carry [`ChurnState::Stateless`].
#[derive(Clone, Debug, PartialEq)]
pub enum ChurnState {
    Stateless,
    /// Per-client on/off flags of [`ChurnModel::MarkovOnOff`].
    Markov { up: Vec<bool> },
    /// Per-client charge levels of [`ChurnModel::BatteryDrain`].
    Battery { level: Vec<f64> },
    /// One state per layer of [`ChurnModel::Composed`].
    Composed { layers: Vec<ChurnState> },
}

/// Which slice of the fleet a dynamics step rewrote (or reset back to
/// base), in units of regions. Drives the O(dirty) base reset inside
/// [`WorldDynamics::step`] and the availability-cache refresh in the
/// environment — at million-client scale, a quiet script round must not
/// pay an O(n) fleet sweep.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Touched {
    /// No client's row differs from the pristine base.
    None,
    /// Only the named regions' clients were rewritten.
    Regions(Vec<usize>),
    /// Potentially every client (per-client stochastic layers, fleet-wide
    /// events, active migrations).
    All,
}

impl Touched {
    pub fn is_none(&self) -> bool {
        matches!(self, Touched::None)
    }

    /// Set union; region lists stay small (one entry per scripted event),
    /// so the quadratic dedup is fine.
    fn union(self, other: Touched) -> Touched {
        match (self, other) {
            (Touched::All, _) | (_, Touched::All) => Touched::All,
            (Touched::None, o) => o,
            (s, Touched::None) => s,
            (Touched::Regions(mut a), Touched::Regions(b)) => {
                for r in b {
                    if !a.contains(&r) {
                        a.push(r);
                    }
                }
                Touched::Regions(a)
            }
        }
    }
}

/// Result of one [`WorldDynamics::step`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepOutcome {
    /// The topology changed relative to the base — the caller refreshes
    /// region-data caches.
    pub topo_changed: bool,
    /// Regions whose per-client reliability may differ from *before* the
    /// step: the union of what this step rewrote and what it reset back
    /// to base. Both invalidate cached per-region availability.
    pub changed: Touched,
}

/// Round boundaries at which a scripted model's touched-region set can
/// change, precomputed from the event windows (`from_round`,
/// `until_round`, `at_round`). Between two consecutive boundaries the set
/// is constant, so [`WorldDynamics::step`] reuses a cached interval
/// instead of re-walking the script — the pending-event replacement for
/// the per-round full scan. Only built for models without per-round
/// stochastic layers (those touch every client every round regardless).
struct EventSchedule {
    /// Sorted, deduped rounds at which some event activates or expires.
    boundaries: Vec<usize>,
    /// `[lo, hi) → touched` interval from the last lookup.
    cached: Option<(usize, usize, Touched)>,
}

impl EventSchedule {
    fn new(model: &ChurnModel) -> EventSchedule {
        let mut boundaries = Vec::new();
        collect_boundaries(model, &mut boundaries);
        boundaries.sort_unstable();
        boundaries.dedup();
        EventSchedule {
            boundaries,
            cached: None,
        }
    }

    /// Touched set for round `t`: O(1) while `t` stays inside the cached
    /// interval, O(log B + events) when it crosses a boundary.
    fn touched_at(&mut self, model: &ChurnModel, t: usize) -> Touched {
        if let Some((lo, hi, touched)) = &self.cached {
            if *lo <= t && t < *hi {
                return touched.clone();
            }
        }
        let i = self.boundaries.partition_point(|&b| b <= t);
        let lo = if i == 0 { 0 } else { self.boundaries[i - 1] };
        let hi = self.boundaries.get(i).copied().unwrap_or(usize::MAX);
        let touched = script_touched(model, t);
        self.cached = Some((lo, hi, touched.clone()));
        touched
    }
}

fn collect_boundaries(model: &ChurnModel, out: &mut Vec<usize>) {
    match model {
        ChurnModel::FaultScript { events } => {
            for e in events {
                match e {
                    FaultEvent::RegionBlackout {
                        from_round,
                        until_round,
                        ..
                    }
                    | FaultEvent::BandwidthDegrade {
                        from_round,
                        until_round,
                        ..
                    } => {
                        out.push(*from_round);
                        out.push(*until_round);
                    }
                    FaultEvent::DropoutShift { at_round, .. }
                    | FaultEvent::Migrate { at_round, .. } => out.push(*at_round),
                }
            }
        }
        ChurnModel::Composed { layers } => {
            for l in layers {
                collect_boundaries(l, out);
            }
        }
        _ => {}
    }
}

/// Touched set of a script-only model at round `t` (pure function of the
/// event windows).
fn script_touched(model: &ChurnModel, t: usize) -> Touched {
    match model {
        ChurnModel::Stationary | ChurnModel::Replay { .. } => Touched::None,
        ChurnModel::FaultScript { events } => events
            .iter()
            .fold(Touched::None, |acc, e| acc.union(event_touched(e, t))),
        ChurnModel::Composed { layers } => layers
            .iter()
            .fold(Touched::None, |acc, l| acc.union(script_touched(l, t))),
        // Per-round stochastic layers never build a schedule.
        _ => Touched::All,
    }
}

fn event_touched(e: &FaultEvent, t: usize) -> Touched {
    match e {
        FaultEvent::RegionBlackout {
            region,
            from_round,
            until_round,
        }
        | FaultEvent::BandwidthDegrade {
            region,
            from_round,
            until_round,
            ..
        } => {
            if (*from_round..*until_round).contains(&t) {
                Touched::Regions(vec![*region])
            } else {
                Touched::None
            }
        }
        FaultEvent::DropoutShift {
            region, at_round, ..
        } => {
            if t >= *at_round {
                region.map_or(Touched::All, |r| Touched::Regions(vec![r]))
            } else {
                Touched::None
            }
        }
        FaultEvent::Migrate { at_round, .. } => {
            if t >= *at_round {
                Touched::All
            } else {
                Touched::None
            }
        }
    }
}

/// Whether any layer rewrites per-client state every round (Markov,
/// diurnal, battery) — those models touch the whole fleet regardless of
/// any schedule.
fn has_per_round_layers(model: &ChurnModel) -> bool {
    match model {
        ChurnModel::MarkovOnOff { .. }
        | ChurnModel::Diurnal { .. }
        | ChurnModel::BatteryDrain { .. } => true,
        ChurnModel::Composed { layers } => layers.iter().any(has_per_round_layers),
        _ => false,
    }
}

/// The runtime world dynamics: pristine base state plus the evolving
/// churn process. Both backends call [`WorldDynamics::step`] at each
/// round boundary, before the round's fate draw.
pub struct WorldDynamics {
    model: ChurnModel,
    base: FleetState,
    base_topo: Topology,
    state: ChurnState,
    /// Regions left dirty (≠ base) by the previous step, pending reset.
    stale: Touched,
    /// Boundary schedule for script-only models; `None` when the touched
    /// set is constant (`None` for no-op models, `All` for per-round
    /// stochastic layers and migrations).
    schedule: Option<EventSchedule>,
}

/// Initial state for one model layer. `init_rng` staggers battery levels
/// so fleets do not deplete in lockstep; Markov chains start all-up.
fn init_state(model: &ChurnModel, n: usize, init_rng: &mut Rng) -> ChurnState {
    match model {
        ChurnModel::MarkovOnOff { .. } => ChurnState::Markov { up: vec![true; n] },
        ChurnModel::BatteryDrain { .. } => ChurnState::Battery {
            level: (0..n).map(|_| 0.25 + 0.75 * init_rng.uniform()).collect(),
        },
        ChurnModel::Composed { layers } => ChurnState::Composed {
            layers: layers
                .iter()
                .map(|l| init_state(l, n, init_rng))
                .collect(),
        },
        _ => ChurnState::Stateless,
    }
}

fn state_matches(model: &ChurnModel, state: &ChurnState, n: usize) -> bool {
    match (model, state) {
        (ChurnModel::MarkovOnOff { .. }, ChurnState::Markov { up }) => up.len() == n,
        (ChurnModel::BatteryDrain { .. }, ChurnState::Battery { level }) => level.len() == n,
        (ChurnModel::Composed { layers }, ChurnState::Composed { layers: states }) => {
            layers.len() == states.len()
                && layers
                    .iter()
                    .zip(states.iter())
                    .all(|(m, s)| state_matches(m, s, n))
        }
        (
            ChurnModel::Stationary
            | ChurnModel::Diurnal { .. }
            | ChurnModel::FaultScript { .. }
            | ChurnModel::Replay { .. },
            ChurnState::Stateless,
        ) => true,
        _ => false,
    }
}

impl WorldDynamics {
    /// Build the dynamics from the sampled base world. `init_rng` is a
    /// dedicated stream from `World::build` (stream splitting never
    /// advances the parent, so stationary runs are unaffected).
    pub fn new(
        model: ChurnModel,
        fleet: &FleetState,
        topo: &Topology,
        init_rng: &mut Rng,
    ) -> WorldDynamics {
        let state = init_state(&model, fleet.len(), init_rng);
        let schedule = if model.is_noop() || model.has_migrations() || has_per_round_layers(&model)
        {
            None
        } else {
            Some(EventSchedule::new(&model))
        };
        WorldDynamics {
            model,
            base: fleet.clone(),
            base_topo: topo.clone(),
            state,
            stale: Touched::None,
            schedule,
        }
    }

    pub fn model(&self) -> &ChurnModel {
        &self.model
    }

    /// True when the step leaves the world untouched (stationary or
    /// replayed fates) — the caller can skip it entirely.
    pub fn is_noop(&self) -> bool {
        self.model.is_noop()
    }

    pub fn has_migrations(&self) -> bool {
        self.model.has_migrations()
    }

    /// Snapshot the process state (checkpoint path).
    pub fn state(&self) -> ChurnState {
        self.state.clone()
    }

    /// Restore a captured process state (resume path). Rejects a state of
    /// the wrong shape for this model.
    pub fn restore(&mut self, state: ChurnState) -> Result<()> {
        if !state_matches(&self.model, &state, self.base.len()) {
            bail!(
                "churn state does not fit the configured '{}' model \
                 ({} clients)",
                self.model.kind_str(),
                self.base.len()
            );
        }
        self.state = state;
        // The caller's fleet may be in any intermediate state; force the
        // next step to reset everything back to base first.
        self.stale = Touched::All;
        Ok(())
    }

    /// Splice a scripted fault into the *running* model (live injection
    /// from the ops control plane). The event lands in a
    /// [`ChurnModel::FaultScript`] layer exactly as if it had been
    /// configured up front: script layers draw no RNG and are inert
    /// outside their round windows, so — provided the event only touches
    /// rounds that have not run yet — the continued run is byte-identical
    /// to one that scripted the event from round 1.
    ///
    /// Stationary worlds become a bare script; a script gains an event;
    /// stochastic models are wrapped into a [`ChurnModel::Composed`] with
    /// the script as a new last layer (the existing layer state is
    /// rewrapped, preserving its trajectory). Replayed worlds reject
    /// injection: the recorded trace *is* the ground truth there.
    pub fn inject(&mut self, event: FaultEvent) -> Result<()> {
        event.validate(self.base_topo.n_regions(), self.base.len())?;
        match &mut self.model {
            ChurnModel::Replay { .. } => bail!(
                "cannot inject faults into a replayed world: fates come \
                 from the recorded trace, so the event would be ignored"
            ),
            ChurnModel::Stationary => {
                self.model = ChurnModel::FaultScript {
                    events: vec![event],
                };
            }
            ChurnModel::FaultScript { events } => events.push(event),
            ChurnModel::Composed { layers } => {
                if let Some(ChurnModel::FaultScript { events }) = layers.last_mut() {
                    events.push(event);
                } else {
                    layers.push(ChurnModel::FaultScript {
                        events: vec![event],
                    });
                    if let ChurnState::Composed { layers: states } = &mut self.state {
                        states.push(ChurnState::Stateless);
                    }
                }
            }
            _ => {
                let prev = std::mem::replace(&mut self.model, ChurnModel::Stationary);
                let prev_state = std::mem::replace(&mut self.state, ChurnState::Stateless);
                self.model = ChurnModel::Composed {
                    layers: vec![
                        prev,
                        ChurnModel::FaultScript {
                            events: vec![event],
                        },
                    ],
                };
                self.state = ChurnState::Composed {
                    layers: vec![prev_state, ChurnState::Stateless],
                };
            }
        }
        // Same schedule rule as `new`: the rewritten model may have gone
        // from no-op to scripted, or gained migration/per-round layers.
        self.schedule = if self.model.is_noop()
            || self.model.has_migrations()
            || has_per_round_layers(&self.model)
        {
            None
        } else {
            Some(EventSchedule::new(&self.model))
        };
        Ok(())
    }

    /// Evolve the world for round `t` (1-based): reset the *dirty* slice
    /// of the fleet to its pristine base rows, rebuild the topology under
    /// any active migrations, then let the model rewrite per-client
    /// reliability as a function of its state, `t` and `rng`. The
    /// returned [`StepOutcome`] names what changed so callers refresh
    /// only the affected caches.
    ///
    /// Deterministic and byte-identical to a full-fleet reset: the reset
    /// set always covers everything the previous step left different
    /// from base, and layer rewrites consume the identical RNG draws.
    /// Given the state at the round boundary and the round's churn
    /// substream, the rewritten world is identical whether the run is
    /// fresh or resumed.
    pub fn step(
        &mut self,
        t: usize,
        rng: &mut Rng,
        fleet: &mut FleetState,
        topo: &mut Topology,
    ) -> StepOutcome {
        let touched_now = match &mut self.schedule {
            Some(s) => s.touched_at(&self.model, t),
            None if self.model.is_noop() => Touched::None,
            None => Touched::All,
        };
        let changed = std::mem::replace(&mut self.stale, touched_now.clone()).union(touched_now);
        self.reset_dirty(fleet, &changed);
        let topo_changed = if self.has_migrations() {
            *topo = self.base_topo.clone();
            apply_migrations(&self.model, t, topo)
        } else {
            false
        };
        apply_layer(&self.model, &mut self.state, t, rng, fleet, topo);
        StepOutcome {
            topo_changed,
            changed,
        }
    }

    /// Copy pristine base rows back over the dirty slice. Region client
    /// ids from `Topology::build` are contiguous ascending ranges, so a
    /// regional reset is three `memcpy`s; a non-contiguous list (never
    /// produced today — migrations force the `All` path) degrades to
    /// per-client copies.
    fn reset_dirty(&self, fleet: &mut FleetState, dirty: &Touched) {
        match dirty {
            Touched::None => {}
            Touched::All => fleet.copy_all_from(&self.base),
            Touched::Regions(rs) => {
                for &r in rs {
                    let cs = &self.base_topo.regions[r];
                    if cs.is_empty() {
                        continue;
                    }
                    if cs.windows(2).all(|w| w[1] == w[0] + 1) {
                        fleet.copy_range_from(&self.base, cs[0], cs.len());
                    } else {
                        for &k in cs {
                            fleet.copy_client_from(&self.base, k);
                        }
                    }
                }
            }
        }
    }
}

/// Apply every `Migrate` event with `at_round <= t` to a fresh clone of
/// the base topology. Returns whether anything moved.
fn apply_migrations(model: &ChurnModel, t: usize, topo: &mut Topology) -> bool {
    let mut moved = false;
    let mut walk = |events: &[FaultEvent]| {
        for e in events {
            if let FaultEvent::Migrate {
                client,
                at_round,
                to_region,
            } = e
            {
                if t >= *at_round && topo.region_of[*client] != *to_region {
                    let from = topo.region_of[*client];
                    topo.regions[from].retain(|&k| k != *client);
                    topo.regions[*to_region].push(*client);
                    topo.region_of[*client] = *to_region;
                    moved = true;
                }
            }
        }
    };
    match model {
        ChurnModel::FaultScript { events } => walk(events),
        ChurnModel::Composed { layers } => {
            for l in layers {
                if let ChurnModel::FaultScript { events } = l {
                    walk(events);
                }
            }
        }
        _ => {}
    }
    moved
}

/// One model layer's rewrite of the (already base-reset) fleet. Layers of
/// a composed model run in order, each on top of the previous layer's
/// output; draws come sequentially from the shared churn substream, so
/// the draw sequence is a deterministic function of (state, t) — in
/// particular it does not depend on how much of the fleet the reset
/// touched.
fn apply_layer(
    model: &ChurnModel,
    state: &mut ChurnState,
    t: usize,
    rng: &mut Rng,
    fleet: &mut FleetState,
    topo: &Topology,
) {
    match (model, state) {
        (ChurnModel::Stationary | ChurnModel::Replay { .. }, _) => {}
        (
            ChurnModel::MarkovOnOff {
                p_fail,
                p_recover,
                down_dropout,
                region_scale,
            },
            ChurnState::Markov { up },
        ) => {
            for (k, flag) in up.iter_mut().enumerate() {
                let scale = region_scale
                    .get(topo.region_of[k])
                    .copied()
                    .unwrap_or(1.0);
                *flag = if *flag {
                    !rng.bernoulli((p_fail * scale).clamp(0.0, 1.0))
                } else {
                    rng.bernoulli((p_recover * scale).clamp(0.0, 1.0))
                };
                if !*flag {
                    fleet.dropout_p[k] = fleet.dropout_p[k].max(*down_dropout);
                }
            }
        }
        (
            ChurnModel::Diurnal {
                amplitude,
                period,
                region_phase,
            },
            _,
        ) => {
            let m = topo.n_regions();
            let omega = std::f64::consts::TAU / *period as f64;
            for (k, dp) in fleet.dropout_p.iter_mut().enumerate() {
                let r = topo.region_of[k];
                let phase = region_phase
                    .get(r)
                    .copied()
                    .unwrap_or(std::f64::consts::TAU * r as f64 / m as f64);
                let wave = amplitude * (omega * (t as f64 - 1.0) + phase).sin();
                *dp = (*dp + wave).clamp(0.0, 1.0);
            }
        }
        (
            ChurnModel::BatteryDrain {
                drain_per_round,
                recharge_p,
                depleted_dropout,
            },
            ChurnState::Battery { level },
        ) => {
            for (k, lvl) in level.iter_mut().enumerate() {
                if *lvl > 0.0 {
                    *lvl -= drain_per_round;
                }
                if *lvl <= 0.0 {
                    // Depleted this round; a recharge draw decides whether
                    // the client is back next round (draw count stays a
                    // deterministic function of the state).
                    fleet.dropout_p[k] = fleet.dropout_p[k].max(*depleted_dropout);
                    if rng.bernoulli(*recharge_p) {
                        *lvl = 1.0;
                    }
                }
            }
        }
        (ChurnModel::FaultScript { events }, _) => {
            for e in events {
                apply_profile_event(e, t, fleet, topo);
            }
        }
        (ChurnModel::Composed { layers }, ChurnState::Composed { layers: states }) => {
            for (l, s) in layers.iter().zip(states.iter_mut()) {
                apply_layer(l, s, t, rng, fleet, topo);
            }
        }
        // Shape mismatches are rejected at construction/restore time;
        // reaching this arm would be a logic error, but degrading to a
        // no-op round beats corrupting a run mid-flight.
        _ => debug_assert!(false, "churn model/state shape mismatch"),
    }
}

/// Profile-level effect of one scripted event at round `t` (migrations
/// are handled separately, against the topology).
fn apply_profile_event(e: &FaultEvent, t: usize, fleet: &mut FleetState, topo: &Topology) {
    match e {
        FaultEvent::RegionBlackout {
            region,
            from_round,
            until_round,
        } => {
            if (*from_round..*until_round).contains(&t) {
                for &k in &topo.regions[*region] {
                    fleet.dropout_p[k] = 1.0;
                }
            }
        }
        FaultEvent::DropoutShift {
            region,
            at_round,
            delta,
        } => {
            if t >= *at_round {
                match region {
                    Some(r) => {
                        for &k in &topo.regions[*r] {
                            fleet.dropout_p[k] = (fleet.dropout_p[k] + delta).clamp(0.0, 1.0);
                        }
                    }
                    None => {
                        for dp in fleet.dropout_p.iter_mut() {
                            *dp = (*dp + delta).clamp(0.0, 1.0);
                        }
                    }
                }
            }
        }
        FaultEvent::BandwidthDegrade {
            region,
            from_round,
            until_round,
            factor,
        } => {
            if (*from_round..*until_round).contains(&t) {
                for &k in &topo.regions[*region] {
                    fleet.bw_mhz[k] *= factor;
                }
            }
        }
        FaultEvent::Migrate { .. } => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn fixture() -> (FleetState, Topology) {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 12;
        cfg.n_edges = 3;
        let topo = Topology::build(&cfg, &mut Rng::new(1)).unwrap();
        let fleet = crate::devices::sample_fleet(&cfg, &topo, &mut Rng::new(2)).unwrap();
        (fleet, topo)
    }

    fn dynamics(model: ChurnModel) -> (WorldDynamics, FleetState, Topology) {
        let (fleet, topo) = fixture();
        let dyn_ = WorldDynamics::new(model, &fleet, &topo, &mut Rng::new(3));
        (dyn_, fleet, topo)
    }

    #[test]
    fn stationary_step_is_identity() {
        let (mut d, base, topo) = dynamics(ChurnModel::Stationary);
        let mut fleet = base.clone();
        let mut topo2 = topo.clone();
        for t in 1..=5 {
            let out = d.step(t, &mut Rng::new(t as u64), &mut fleet, &mut topo2);
            assert!(!out.topo_changed);
            assert_eq!(out.changed, Touched::None);
            assert_eq!(fleet, base);
        }
    }

    #[test]
    fn markov_produces_correlated_outages_and_is_deterministic() {
        let model = ChurnModel::MarkovOnOff {
            p_fail: 0.4,
            p_recover: 0.3,
            down_dropout: 0.97,
            region_scale: Vec::new(),
        };
        let run = |seed_offset: u64| -> Vec<Vec<f64>> {
            let (mut d, base, topo) = dynamics(model.clone());
            let mut fleet = base.clone();
            let mut topo2 = topo;
            (1..=20u64)
                .map(|t| {
                    let out = d.step(
                        t as usize,
                        &mut Rng::new(t + seed_offset),
                        &mut fleet,
                        &mut topo2,
                    );
                    assert_eq!(out.changed, Touched::All);
                    fleet.dropout_p.clone()
                })
                .collect()
        };
        let a = run(0);
        let b = run(0);
        assert_eq!(a, b, "same streams must evolve identically");
        // Some client must visit the down state within 20 rounds at
        // p_fail = 0.4.
        assert!(
            a.iter().flatten().any(|&dr| dr >= 0.97),
            "no outage in 20 rounds"
        );
    }

    #[test]
    fn markov_state_restore_continues_trajectory() {
        let model = ChurnModel::MarkovOnOff {
            p_fail: 0.3,
            p_recover: 0.3,
            down_dropout: 0.95,
            region_scale: Vec::new(),
        };
        let (mut d, base, topo) = dynamics(model.clone());
        let mut fleet = base.clone();
        let mut topo2 = topo.clone();
        for t in 1..=7 {
            d.step(t, &mut Rng::new(100 + t as u64), &mut fleet, &mut topo2);
        }
        let snap = d.state();

        let (mut resumed, _, _) = dynamics(model);
        resumed.restore(snap).unwrap();
        let mut f2 = base.clone();
        let mut t2 = topo;
        for t in 8..=20 {
            d.step(t, &mut Rng::new(100 + t as u64), &mut fleet, &mut topo2);
            resumed.step(t, &mut Rng::new(100 + t as u64), &mut f2, &mut t2);
            assert_eq!(fleet, f2, "round {t} diverged after restore");
        }
    }

    #[test]
    fn restore_rejects_wrong_shape() {
        let (mut d, ..) = dynamics(ChurnModel::MarkovOnOff {
            p_fail: 0.1,
            p_recover: 0.1,
            down_dropout: 0.9,
            region_scale: Vec::new(),
        });
        assert!(d.restore(ChurnState::Stateless).is_err());
        assert!(d.restore(ChurnState::Markov { up: vec![true; 3] }).is_err());
        assert!(d
            .restore(ChurnState::Markov {
                up: vec![true; 12]
            })
            .is_ok());
    }

    #[test]
    fn diurnal_modulation_cycles() {
        let model = ChurnModel::Diurnal {
            amplitude: 0.3,
            period: 8,
            region_phase: vec![0.0, 0.0, 0.0],
        };
        let (mut d, base, topo) = dynamics(model);
        let mut fleet = base.clone();
        let mut topo2 = topo;
        let mut series = Vec::new();
        for t in 1..=8 {
            d.step(t, &mut Rng::new(5), &mut fleet, &mut topo2);
            series.push(fleet.dropout_p[0]);
        }
        let max = series.iter().cloned().fold(f64::MIN, f64::max);
        let min = series.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max - min > 0.3, "no cycle visible: {series:?}");
        // Full period returns to the starting value.
        d.step(9, &mut Rng::new(5), &mut fleet, &mut topo2);
        assert!((fleet.dropout_p[0] - series[0]).abs() < 1e-12);
    }

    #[test]
    fn battery_depletes_and_recharges() {
        let model = ChurnModel::BatteryDrain {
            drain_per_round: 0.34,
            recharge_p: 0.5,
            depleted_dropout: 0.99,
        };
        let (mut d, base, topo) = dynamics(model);
        let mut fleet = base.clone();
        let mut topo2 = topo;
        let mut saw_depleted = false;
        let mut saw_recovered_after_depleted = false;
        let mut depleted_before = vec![false; fleet.len()];
        for t in 1..=30 {
            d.step(t, &mut Rng::new(40 + t as u64), &mut fleet, &mut topo2);
            for (k, &dp) in fleet.dropout_p.iter().enumerate() {
                let down = dp >= 0.99;
                if down {
                    saw_depleted = true;
                    depleted_before[k] = true;
                } else if depleted_before[k] {
                    saw_recovered_after_depleted = true;
                }
            }
        }
        assert!(saw_depleted, "no client ever depleted");
        assert!(saw_recovered_after_depleted, "no client ever recharged");
    }

    #[test]
    fn blackout_and_shift_and_bandwidth_apply_in_window() {
        let model = ChurnModel::FaultScript {
            events: vec![
                FaultEvent::RegionBlackout {
                    region: 0,
                    from_round: 3,
                    until_round: 5,
                },
                FaultEvent::DropoutShift {
                    region: Some(1),
                    at_round: 4,
                    delta: 0.2,
                },
                FaultEvent::BandwidthDegrade {
                    region: 2,
                    from_round: 2,
                    until_round: 4,
                    factor: 0.5,
                },
            ],
        };
        let (mut d, base, topo) = dynamics(model);
        let mut fleet = base.clone();
        let mut topo2 = topo.clone();
        let r0 = topo.regions[0][0];
        let r1 = topo.regions[1][0];
        let r2 = topo.regions[2][0];

        let out = d.step(2, &mut Rng::new(0), &mut fleet, &mut topo2);
        assert_eq!(out.changed, Touched::Regions(vec![2]));
        assert_eq!(fleet.dropout_p[r0], base.dropout_p[r0]);
        assert!((fleet.bw_mhz[r2] - base.bw_mhz[r2] * 0.5).abs() < 1e-12);

        let out = d.step(3, &mut Rng::new(0), &mut fleet, &mut topo2);
        assert_eq!(out.changed, Touched::Regions(vec![2, 0]));
        assert_eq!(fleet.dropout_p[r0], 1.0);
        assert_eq!(fleet.dropout_p[r1], base.dropout_p[r1]);

        let out = d.step(4, &mut Rng::new(0), &mut fleet, &mut topo2);
        // Region 2's bandwidth window closes this round (reset to base),
        // region 0's blackout continues, region 1's shift activates.
        assert_eq!(out.changed, Touched::Regions(vec![0, 2, 1]));
        assert_eq!(fleet.dropout_p[r0], 1.0);
        assert!((fleet.dropout_p[r1] - (base.dropout_p[r1] + 0.2)).abs() < 1e-12);
        assert_eq!(fleet.bw_mhz[r2], base.bw_mhz[r2]); // window closed

        let out = d.step(5, &mut Rng::new(0), &mut fleet, &mut topo2);
        // Region 0's blackout closes this round; region 1 stays shifted.
        assert_eq!(out.changed, Touched::Regions(vec![0, 1]));
        assert_eq!(fleet.dropout_p[r0], base.dropout_p[r0]); // window closed
        assert_eq!(fleet.bw_mhz[r2], base.bw_mhz[r2]); // window closed
        assert!((fleet.dropout_p[r1] - (base.dropout_p[r1] + 0.2)).abs() < 1e-12); // permanent

        let out = d.step(6, &mut Rng::new(0), &mut fleet, &mut topo2);
        assert_eq!(out.changed, Touched::Regions(vec![1]));
    }

    #[test]
    fn migration_moves_client_between_regions() {
        let (_, topo) = fixture();
        let client = topo.regions[0][0];
        let model = ChurnModel::FaultScript {
            events: vec![FaultEvent::Migrate {
                client,
                at_round: 3,
                to_region: 1,
            }],
        };
        let (mut d, base, _) = dynamics(model);
        let mut fleet = base;
        let mut topo2 = topo.clone();
        assert!(!d.step(2, &mut Rng::new(0), &mut fleet, &mut topo2).topo_changed);
        assert_eq!(topo2.region_of[client], 0);
        assert!(d.step(3, &mut Rng::new(0), &mut fleet, &mut topo2).topo_changed);
        assert_eq!(topo2.region_of[client], 1);
        assert!(!topo2.regions[0].contains(&client));
        assert!(topo2.regions[1].contains(&client));
        // Idempotent across later rounds.
        assert!(d.step(4, &mut Rng::new(0), &mut fleet, &mut topo2).topo_changed);
        assert_eq!(
            topo2.regions[1].iter().filter(|&&k| k == client).count(),
            1
        );
    }

    #[test]
    fn composed_layers_stack() {
        let model = ChurnModel::Composed {
            layers: vec![
                ChurnModel::MarkovOnOff {
                    p_fail: 0.0, // never fails — layer is a pass-through
                    p_recover: 1.0,
                    down_dropout: 0.9,
                    region_scale: Vec::new(),
                },
                ChurnModel::FaultScript {
                    events: vec![FaultEvent::RegionBlackout {
                        region: 0,
                        from_round: 1,
                        until_round: 2,
                    }],
                },
            ],
        };
        let (mut d, base, topo) = dynamics(model);
        let mut fleet = base.clone();
        let mut topo2 = topo.clone();
        d.step(1, &mut Rng::new(0), &mut fleet, &mut topo2);
        for &k in &topo.regions[0] {
            assert_eq!(fleet.dropout_p[k], 1.0);
        }
        for &k in &topo.regions[1] {
            assert_eq!(fleet.dropout_p[k], base.dropout_p[k]);
        }
    }

    #[test]
    fn lazy_reset_matches_full_reset_reference() {
        // The dirty-region reset plus boundary schedule must be
        // indistinguishable from the historical full-fleet reset.
        // Reference: copy the whole base every round, then apply the
        // layers with an identically-seeded state.
        let models = vec![
            ChurnModel::FaultScript {
                events: vec![
                    FaultEvent::RegionBlackout {
                        region: 0,
                        from_round: 2,
                        until_round: 6,
                    },
                    FaultEvent::BandwidthDegrade {
                        region: 0,
                        from_round: 4,
                        until_round: 8,
                        factor: 0.5,
                    },
                    FaultEvent::DropoutShift {
                        region: None,
                        at_round: 5,
                        delta: 0.1,
                    },
                    FaultEvent::DropoutShift {
                        region: Some(2),
                        at_round: 3,
                        delta: -0.05,
                    },
                ],
            },
            ChurnModel::Composed {
                layers: vec![
                    ChurnModel::MarkovOnOff {
                        p_fail: 0.3,
                        p_recover: 0.3,
                        down_dropout: 0.95,
                        region_scale: Vec::new(),
                    },
                    ChurnModel::FaultScript {
                        events: vec![FaultEvent::BandwidthDegrade {
                            region: 1,
                            from_round: 3,
                            until_round: 7,
                            factor: 0.25,
                        }],
                    },
                ],
            },
        ];
        for model in models {
            let (base, topo) = fixture();
            let mut d = WorldDynamics::new(model.clone(), &base, &topo, &mut Rng::new(3));
            let mut ref_state = init_state(&model, base.len(), &mut Rng::new(3));
            let mut fleet = base.clone();
            let mut ref_fleet = base.clone();
            let mut topo2 = topo.clone();
            for t in 1..=12 {
                d.step(t, &mut Rng::new(700 + t as u64), &mut fleet, &mut topo2);
                ref_fleet.copy_all_from(&base);
                apply_layer(
                    &model,
                    &mut ref_state,
                    t,
                    &mut Rng::new(700 + t as u64),
                    &mut ref_fleet,
                    &topo,
                );
                assert_eq!(
                    fleet,
                    ref_fleet,
                    "round {t} diverged from full-reset reference ({})",
                    model.kind_str()
                );
            }
        }
    }

    #[test]
    fn restore_forces_full_reset_on_next_step() {
        let model = ChurnModel::FaultScript {
            events: vec![FaultEvent::RegionBlackout {
                region: 0,
                from_round: 2,
                until_round: 3,
            }],
        };
        let (mut d, base, topo) = dynamics(model);
        d.restore(ChurnState::Stateless).unwrap();
        // Simulate a resumed fleet that drifted from base in a region the
        // schedule considers quiet at t=10; the post-restore step must
        // still reset it.
        let mut fleet = base.clone();
        fleet.dropout_p[5] = 0.123;
        let mut topo2 = topo;
        let out = d.step(10, &mut Rng::new(0), &mut fleet, &mut topo2);
        assert_eq!(out.changed, Touched::All);
        assert_eq!(fleet, base);
        // The following quiet round is back to zero work.
        let out = d.step(11, &mut Rng::new(0), &mut fleet, &mut topo2);
        assert_eq!(out.changed, Touched::None);
    }

    #[test]
    fn json_roundtrip_every_variant() {
        let models = vec![
            ChurnModel::Stationary,
            ChurnModel::MarkovOnOff {
                p_fail: 0.05,
                p_recover: 0.25,
                down_dropout: 0.95,
                region_scale: vec![1.0, 2.0],
            },
            ChurnModel::Diurnal {
                amplitude: 0.25,
                period: 48,
                region_phase: vec![0.0, 1.5],
            },
            ChurnModel::BatteryDrain {
                drain_per_round: 0.02,
                recharge_p: 0.15,
                depleted_dropout: 0.99,
            },
            ChurnModel::FaultScript {
                events: vec![
                    FaultEvent::RegionBlackout {
                        region: 0,
                        from_round: 10,
                        until_round: 20,
                    },
                    FaultEvent::DropoutShift {
                        region: None,
                        at_round: 5,
                        delta: -0.1,
                    },
                    FaultEvent::BandwidthDegrade {
                        region: 1,
                        from_round: 2,
                        until_round: 9,
                        factor: 0.25,
                    },
                    FaultEvent::Migrate {
                        client: 7,
                        at_round: 30,
                        to_region: 1,
                    },
                ],
            },
            ChurnModel::Replay {
                path: "trace.json".into(),
            },
            ChurnModel::Composed {
                layers: vec![
                    ChurnModel::Stationary,
                    ChurnModel::Diurnal {
                        amplitude: 0.1,
                        period: 10,
                        region_phase: vec![],
                    },
                ],
            },
        ];
        for m in models {
            let j = m.to_json();
            let back = ChurnModel::from_json(&Json::parse(&j.dump()).unwrap()).unwrap();
            assert_eq!(m, back, "roundtrip mismatch for {}", m.kind_str());
        }
    }

    #[test]
    fn spec_parsing_defaults_and_composition() {
        assert_eq!(
            ChurnModel::parse_spec("stationary").unwrap(),
            ChurnModel::Stationary
        );
        match ChurnModel::parse_spec("markov:p_fail=0.1").unwrap() {
            ChurnModel::MarkovOnOff {
                p_fail, p_recover, ..
            } => {
                assert!((p_fail - 0.1).abs() < 1e-12);
                assert!((p_recover - 0.25).abs() < 1e-12); // default
            }
            other => panic!("{other:?}"),
        }
        match ChurnModel::parse_spec("diurnal:amplitude=0.4,period=24").unwrap() {
            ChurnModel::Diurnal {
                amplitude, period, ..
            } => {
                assert!((amplitude - 0.4).abs() < 1e-12);
                assert_eq!(period, 24);
            }
            other => panic!("{other:?}"),
        }
        match ChurnModel::parse_spec("markov+battery:drain=0.1").unwrap() {
            ChurnModel::Composed { layers } => assert_eq!(layers.len(), 2),
            other => panic!("{other:?}"),
        }
        assert!(ChurnModel::parse_spec("bogus").is_err());
        assert!(ChurnModel::parse_spec("markov:bogus=1").is_err());
        assert!(ChurnModel::parse_spec("markov+replay:x.json").is_err());
        assert!(ChurnModel::parse_spec("script:").is_err());
    }

    #[test]
    fn validation_catches_bad_fields() {
        assert!(ChurnModel::MarkovOnOff {
            p_fail: 1.5,
            p_recover: 0.1,
            down_dropout: 0.9,
            region_scale: vec![],
        }
        .validate(2, 10)
        .is_err());
        assert!(ChurnModel::Diurnal {
            amplitude: 0.2,
            period: 0,
            region_phase: vec![],
        }
        .validate(2, 10)
        .is_err());
        assert!(ChurnModel::FaultScript {
            events: vec![FaultEvent::RegionBlackout {
                region: 5,
                from_round: 1,
                until_round: 2,
            }],
        }
        .validate(2, 10)
        .is_err());
        assert!(ChurnModel::FaultScript {
            events: vec![FaultEvent::Migrate {
                client: 99,
                at_round: 1,
                to_region: 0,
            }],
        }
        .validate(2, 10)
        .is_err());
        // Nested composition and nested replay are rejected.
        assert!(ChurnModel::Composed {
            layers: vec![ChurnModel::Composed {
                layers: vec![ChurnModel::Stationary],
            }],
        }
        .validate(2, 10)
        .is_err());
        assert!(ChurnModel::Composed {
            layers: vec![ChurnModel::Replay {
                path: "x.json".into(),
            }],
        }
        .validate(2, 10)
        .is_err());
    }
}
