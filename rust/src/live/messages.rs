//! Wire messages of the live runtime. In a deployment these would be RPCs
//! (edge↔cloud over Ethernet, client↔edge over the wireless link); here
//! they are mpsc payloads with exactly the information each party is
//! allowed to see — the privacy boundary is the message schema itself:
//! nothing in a [`Submission`] identifies client reliability, and no
//! protocol-level state (slack estimates, aggregation rules, quotas)
//! appears on the wire. Protocol logic lives entirely above the
//! [`crate::env::FlEnvironment`] trait; the fabric only moves jobs down,
//! folds models at the edge, and sends aggregates up.
//!
//! Transport economics after the streaming refactor: full model payloads
//! cross a channel in exactly two shapes — the round-start broadcast
//! (`Arc<ModelParams>`, one refcount bump per hop, no clone on fan-out)
//! and the client's own trained [`Submission`] (moved, never copied,
//! folded at the edge and dropped). The edge→cloud path carries only
//! model-free [`SubmissionNotice`]s during the round plus one
//! [`RegionalReport`] with the folded [`RegionAccumulator`] at round end
//! — per-round edge→cloud model traffic is O(regions), not O(selected).
//!
//! Submissions ship as **encoded frames**: each client runs the
//! configured [`crate::comm::UpdateCodec`] on its own thread and the
//! envelope carries the actual [`EncodedUpdate`] — a dense clone under
//! the default codec, a quantized or sparsified delta otherwise — which
//! the edge decodes straight into its accumulator
//! ([`crate::aggregation::RegionAccumulator::fold_encoded`]). What moves
//! over the channel is exactly what `bytes_moved` accounts.

use std::sync::Arc;

use crate::aggregation::RegionAccumulator;
use crate::comm::EncodedUpdate;
use crate::model::ModelParams;

/// One client's training job for a round. `dropped` and `completion` are
/// the simulated-world parameters the client *enacts* (drop silently /
/// finish after the scaled completion time) — they stand in for the real
/// device's autonomous behavior and are never observable to the protocol.
#[derive(Clone, Copy, Debug)]
pub struct RoundJob {
    pub client: usize,
    pub dropped: bool,
    /// Virtual completion time; `f64::INFINITY` when dropped.
    pub completion: f64,
}

/// Cloud → edge.
#[derive(Debug)]
pub enum CloudToEdge {
    /// Start round `t`: relay the start model and per-client jobs, and
    /// open a fresh regional accumulator for arrival-order folding.
    StartRound {
        t: usize,
        start: Arc<ModelParams>,
        jobs: Vec<RoundJob>,
    },
    /// The round is over (quota reached or deadline): stop straggling
    /// clients, close the accumulator and report it; late submissions
    /// will be discarded.
    EndRound { t: usize },
    /// Training is over; tear down.
    Shutdown,
}

/// Edge → client.
#[derive(Debug)]
pub enum EdgeToClient {
    /// Train locally from `start` and submit when done.
    Train {
        t: usize,
        start: Arc<ModelParams>,
        dropped: bool,
        completion: f64,
    },
    /// Round-end signal: abandon round `t` if still working on it.
    EndRound { t: usize },
    Shutdown,
}

/// Client → edge: a completed local update, framed by the configured
/// codec. The frame is *moved* into the envelope and decoded into the
/// edge's accumulator on receipt — it never travels further up nor gets
/// cloned. Under the dense default the payload is the full trained model
/// (legacy semantics); compressed codecs carry the encoded delta vs the
/// round-start model.
#[derive(Debug)]
pub struct Submission {
    pub t: usize,
    /// Opaque client id (routing only; carries no reliability info).
    pub client: usize,
    pub region: usize,
    /// Data volume |D_k| — carried by the update envelope (needed for
    /// weighted aggregation), not an identity.
    pub data_size: f64,
    /// Local training loss (diagnostic).
    pub loss: f64,
    pub frame: EncodedUpdate,
}

/// Edge → cloud, per folded submission: the model-free receipt the cloud
/// counts to decide *when* to broadcast the round-end signal. Accounting
/// (counts, cut time, energy) comes from the [`RegionalReport`]s instead;
/// the opaque `client`/`region` here are telemetry.
#[derive(Clone, Copy, Debug)]
pub struct SubmissionNotice {
    pub t: usize,
    pub client: usize,
    pub region: usize,
}

/// Edge → cloud, at round end: the region's folded aggregate — the only
/// model-bearing payload on the edge→cloud path, one per region per round.
/// The folded set is authoritative: the cloud derives the submission
/// counts, the quota decision and the round-cut time from these reports,
/// so what was aggregated and what is accounted can never diverge.
#[derive(Debug)]
pub struct RegionalReport {
    pub t: usize,
    pub region: usize,
    pub agg: RegionAccumulator,
    /// Opaque ids of the clients folded into `agg`, in arrival order
    /// (time accounting only — no model payload, no reliability info).
    pub clients: Vec<usize>,
}

/// Edge → cloud fan-in.
#[derive(Debug)]
pub enum EdgeToCloud {
    Notice(SubmissionNotice),
    Report(RegionalReport),
}
