//! Wire messages of the live runtime. In a deployment these would be RPCs
//! (edge↔cloud over Ethernet, client↔edge over the wireless link); here
//! they are mpsc payloads with exactly the information each party is
//! allowed to see — the privacy boundary is the message schema itself:
//! nothing in `Submission` or `EdgeReport` identifies client reliability,
//! and the cloud never learns which clients participated.

use crate::model::ModelParams;

/// Cloud → edge.
#[derive(Debug)]
pub enum CloudToEdge {
    /// Start round `t`: distribute the global model, select clients.
    StartRound { t: usize, global: ModelParams },
    /// Quota reached (or deadline): stop collecting, aggregate, reply.
    AggregationSignal { t: usize, quota_met: bool },
    /// Training is over; tear down.
    Shutdown,
}

/// Edge → cloud.
#[derive(Debug)]
pub enum EdgeToCloud {
    /// Live submission-count update ("keeps reporting update count").
    Progress { region: usize, t: usize, submissions: usize },
    /// Post-aggregation regional model + effective data coverage.
    Regional {
        region: usize,
        t: usize,
        model: ModelParams,
        edc: f64,
        submissions: usize,
    },
}

/// Edge → client.
#[derive(Debug)]
pub enum EdgeToClient {
    /// Train `epochs` local epochs from `model` and submit.
    Train { t: usize, model: ModelParams, epochs: usize, lr: f32 },
    Shutdown,
}

/// Client → edge.
#[derive(Debug)]
pub struct Submission {
    pub t: usize,
    /// Data volume |D_k| — carried by the model update envelope (needed
    /// for weighted aggregation), not an identity.
    pub data_size: f64,
    pub model: ModelParams,
}
