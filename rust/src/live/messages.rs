//! Wire messages of the live runtime. In a deployment these would be RPCs
//! (edge↔cloud over Ethernet, client↔edge over the wireless link); here
//! they are mpsc payloads with exactly the information each party is
//! allowed to see — the privacy boundary is the message schema itself:
//! nothing in a [`Submission`] identifies client reliability, and no
//! protocol-level state (slack estimates, aggregation rules, quotas)
//! appears on the wire. Protocol logic lives entirely above the
//! [`crate::env::FlEnvironment`] trait; the fabric only moves jobs down
//! and models up.

use std::sync::Arc;

use crate::model::ModelParams;

/// One client's training job for a round. `dropped` and `completion` are
/// the simulated-world parameters the client *enacts* (drop silently /
/// finish after the scaled completion time) — they stand in for the real
/// device's autonomous behavior and are never observable to the protocol.
#[derive(Clone, Copy, Debug)]
pub struct RoundJob {
    pub client: usize,
    pub dropped: bool,
    /// Virtual completion time; `f64::INFINITY` when dropped.
    pub completion: f64,
}

/// Cloud → edge.
#[derive(Debug)]
pub enum CloudToEdge {
    /// Start round `t`: relay the start model and per-client jobs.
    StartRound {
        t: usize,
        start: Arc<ModelParams>,
        jobs: Vec<RoundJob>,
    },
    /// The round is over (quota reached or deadline): stop straggling
    /// clients; late submissions will be discarded.
    EndRound { t: usize },
    /// Training is over; tear down.
    Shutdown,
}

/// Edge → client.
#[derive(Debug)]
pub enum EdgeToClient {
    /// Train locally from `start` and submit when done.
    Train {
        t: usize,
        start: Arc<ModelParams>,
        dropped: bool,
        completion: f64,
    },
    /// Round-end signal: abandon round `t` if still working on it.
    EndRound { t: usize },
    Shutdown,
}

/// Client → edge → cloud: a completed local update.
#[derive(Debug)]
pub struct Submission {
    pub t: usize,
    /// Opaque client id (routing only; carries no reliability info).
    pub client: usize,
    pub region: usize,
    /// Data volume |D_k| — carried by the update envelope (needed for
    /// weighted aggregation), not an identity.
    pub data_size: f64,
    /// Local training loss (diagnostic).
    pub loss: f64,
    pub model: ModelParams,
}
