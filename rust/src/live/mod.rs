//! Live threaded runtime (S9): the HybridFL coordination running as a
//! *real concurrent system* — one cloud leader thread, one thread per edge
//! node, one thread per client, communicating over mpsc channels.
//!
//! The DES in `sim::` is the experiment vehicle (deterministic, virtual
//! clock); this module is the deployment-shaped proof that the same
//! protocol state machines (slack estimation, quota trigger, cache rule,
//! EDC aggregation) compose under actual asynchrony: out-of-order
//! submissions, racing edges, a cloud that must arbitrate quota vs.
//! deadline in wall-clock time.
//!
//! Client compute uses the mock progress model (`runtime::mock` math)
//! because the PJRT client is not `Send` (Rc-based FFI handles) — the live
//! runtime demonstrates *coordination*, the PJRT path carries the real
//! numerics in the DES. Virtual durations (eqs. 31–34) are scaled to
//! wall-clock by `time_scale`.

pub mod cluster;
pub mod messages;

pub use cluster::{LiveCluster, LiveOpts, LiveRoundStats};
