//! Live threaded runtime (S9): the cloud/edge/client coordination as a
//! *real concurrent system* — one thread per edge node, one per client,
//! communicating over mpsc channels.
//!
//! Since the `FlEnvironment` redesign this module holds only the
//! **fabric**: spawn/teardown of the thread topology, message relay, the
//! edges' arrival-order streaming fold, and the cloud leader's
//! notice-counting loop ([`cluster::ClusterFabric`]). All protocol logic —
//! selection policy, slack estimation, quota configuration, the cache
//! rule, EDC aggregation — lives in `protocols/` and reaches this fabric
//! only through [`crate::env::LiveClusterEnv`], the live implementation
//! of [`crate::env::FlEnvironment`]. The same protocol code therefore
//! runs bit-for-bit on the deterministic simulator and,
//! coordination-wise, on this fabric.
//!
//! Model traffic is O(regions) per round on the edge→cloud link: clients
//! move (never copy) their trained model one hop to their edge, the edge
//! folds it into the region's accumulator immediately, and only the
//! folded aggregate travels up at round end. The round-start broadcast
//! shares one `Arc<ModelParams>` across all hops.
//!
//! Run it via [`crate::scenario::Scenario`]:
//!
//! ```no_run
//! use hybridfl::scenario::{Backend, Scenario};
//! let result = Scenario::task1()
//!     .mock()
//!     .rounds(10)
//!     .backend(Backend::Live)
//!     .run()
//!     .unwrap();
//! println!("live best accuracy: {:.3}", result.summary.best_accuracy);
//! ```

pub mod cluster;
pub mod messages;

pub use cluster::ClusterFabric;
