//! The live cluster fabric: edge workers + client actors as OS threads
//! over mpsc channels, driven round-by-round by the cloud leader (the
//! thread inside [`crate::env::LiveClusterEnv::run_round`]).
//!
//! This module is *pure transport and enactment*. It contains no protocol
//! logic: no selection policy, no slack estimation, no aggregation rules —
//! those live in `protocols/` above the [`crate::env::FlEnvironment`]
//! trait and run identically on the virtual-clock backend. What the
//! fabric provides is real concurrency: clients sleep their scaled
//! completion times, train on their own threads and frame their updates
//! with the configured [`crate::comm::UpdateCodec`]; edges decode each
//! arriving frame into their region's [`RegionAccumulator`] in true
//! arrival order (the mechanical Σ of eq. 17 — a transport-level fold,
//! not a protocol decision) and relay model-free notices up, and the
//! caller observes genuine out-of-order arrival, quota/deadline racing
//! and straggler stop-signals. Full models cross the edge→cloud link only
//! as one end-of-round [`RegionalReport`] per region.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::aggregation::RegionAccumulator;
use crate::comm::{CodecSpec, EncodeCtx, COMM_STREAM};
use crate::env::World;
use crate::live::messages::{
    CloudToEdge, EdgeToClient, EdgeToCloud, RegionalReport, RoundJob, Submission,
    SubmissionNotice,
};
use crate::model::ModelParams;
use crate::rng::Rng;
use crate::runtime::mock::MockEngine;
use crate::runtime::Engine;
use crate::Result;

/// How long the cloud waits for the end-of-round regional reports after
/// broadcasting `EndRound`. Edges answer immediately (the report is the
/// next message they produce), so this only guards against a crashed edge
/// thread turning into a hang.
const REPORT_TIMEOUT: Duration = Duration::from_secs(30);

/// Edge inbox fan-in: commands from the cloud and submissions from clients
/// arrive on one channel so the edge thread can block on a single recv.
enum EdgeInbox {
    Cmd(CloudToEdge),
    Sub(Submission),
}

/// A spawned cloud/edge/client thread fabric, reusable across rounds.
/// Tear-down is automatic on drop.
pub struct ClusterFabric {
    edge_txs: Vec<Sender<EdgeInbox>>,
    cloud_rx: Receiver<EdgeToCloud>,
    edge_handles: Vec<JoinHandle<()>>,
    client_handles: Vec<JoinHandle<()>>,
}

impl ClusterFabric {
    /// Spawn one edge thread per region and one client thread per device.
    pub(crate) fn spawn(world: &World, time_scale: f64) -> Result<ClusterFabric> {
        let m = world.topo.n_regions();
        let n = world.topo.n_clients();
        let region_data = world.region_data_sizes();

        let (cloud_tx, cloud_rx) = channel::<EdgeToCloud>();

        // Per-client command channels (senders held by the edges).
        let mut client_txs: Vec<Sender<EdgeToClient>> = Vec::with_capacity(n);
        let mut client_rxs: Vec<Receiver<EdgeToClient>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<EdgeToClient>();
            client_txs.push(tx);
            client_rxs.push(rx);
        }

        // Per-edge inboxes (cloud commands + client submissions fan in).
        let mut edge_txs: Vec<Sender<EdgeInbox>> = Vec::with_capacity(m);
        let mut edge_rxs: Vec<Receiver<EdgeInbox>> = Vec::with_capacity(m);
        for _ in 0..m {
            let (tx, rx) = channel::<EdgeInbox>();
            edge_txs.push(tx);
            edge_rxs.push(rx);
        }

        // Client actors.
        let mut client_handles = Vec::with_capacity(n);
        for (k, rx) in client_rxs.into_iter().enumerate() {
            let region = world.topo.region_of[k];
            let edge_tx = edge_txs[region].clone();
            let indices = world.data.partitions[k].clone();
            let engine = MockEngine::new(&world.cfg, Arc::clone(&world.data));
            let epochs = world.cfg.local_epochs;
            let lr = world.cfg.lr as f32;
            let spec = world.cfg.comm.codec.clone();
            let seed = world.cfg.seed;
            client_handles.push(std::thread::spawn(move || {
                client_loop(
                    rx, edge_tx, k, region, indices, engine, epochs, lr, time_scale, spec,
                    seed,
                );
            }));
        }

        // Edge relays (each owns its region's streaming accumulator).
        let mut edge_handles = Vec::with_capacity(m);
        for (r, rx) in edge_rxs.into_iter().enumerate() {
            let my_clients: HashMap<usize, Sender<EdgeToClient>> = world.topo.regions[r]
                .iter()
                .map(|&k| (k, client_txs[k].clone()))
                .collect();
            let cloud_tx = cloud_tx.clone();
            let d_r = region_data[r];
            edge_handles.push(std::thread::spawn(move || {
                edge_loop(rx, cloud_tx, my_clients, r, d_r);
            }));
        }
        drop(cloud_tx); // the cloud keeps only the receiver
        drop(client_txs); // clients are reachable through their edges only

        Ok(ClusterFabric {
            edge_txs,
            cloud_rx,
            edge_handles,
            client_handles,
        })
    }

    /// Drive one round: dispatch per-region job batches, count model-free
    /// submission notices until `target` of them arrived or `deadline`
    /// elapsed, broadcast the round-end signal, then collect every edge's
    /// folded [`RegionalReport`]. The reports (indexed by region) are the
    /// authoritative record of the round: the notices only decide *when*
    /// the cut is broadcast; what each edge folded before the signal
    /// reached it is what the round aggregated, counted and accounts.
    pub(crate) fn round(
        &mut self,
        t: usize,
        starts: &[Arc<ModelParams>],
        jobs: Vec<Vec<RoundJob>>,
        target: usize,
        deadline: Duration,
    ) -> Result<Vec<RegionalReport>> {
        for (r, js) in jobs.into_iter().enumerate() {
            self.edge_txs[r]
                .send(EdgeInbox::Cmd(CloudToEdge::StartRound {
                    t,
                    start: Arc::clone(&starts[r]),
                    jobs: js,
                }))
                .ok()
                .context("edge hung up")?;
        }

        let started = Instant::now();
        let mut noticed = 0usize;
        while noticed < target {
            let left = deadline.saturating_sub(started.elapsed());
            if left.is_zero() {
                break;
            }
            match self.cloud_rx.recv_timeout(left) {
                Ok(EdgeToCloud::Notice(n)) if n.t == t => noticed += 1,
                Ok(_) => {} // stale traffic from an earlier round
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all edges disconnected")
                }
            }
        }

        // Round-end signal: edges relay it to every client (stopping
        // stragglers — the quota trigger's energy saving), close their
        // accumulators and report them.
        for tx in &self.edge_txs {
            let _ = tx.send(EdgeInbox::Cmd(CloudToEdge::EndRound { t }));
        }

        let m = self.edge_txs.len();
        let mut reports: Vec<Option<RegionalReport>> = (0..m).map(|_| None).collect();
        let mut have = 0usize;
        let t0 = Instant::now();
        while have < m {
            let left = REPORT_TIMEOUT.saturating_sub(t0.elapsed());
            anyhow::ensure!(!left.is_zero(), "timed out waiting for edge reports");
            match self.cloud_rx.recv_timeout(left) {
                Ok(EdgeToCloud::Report(rep)) if rep.t == t => {
                    let r = rep.region;
                    if reports[r].is_none() {
                        have += 1;
                    }
                    reports[r] = Some(rep);
                }
                // Notices that lost the race against the cut (and any
                // other stale traffic) carry no information the reports
                // don't already hold; discard them.
                Ok(_) => {}
                Err(RecvTimeoutError::Timeout) => {
                    anyhow::bail!("timed out waiting for edge reports")
                }
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all edges disconnected")
                }
            }
        }
        Ok(reports
            .into_iter()
            .map(|r| r.expect("all regions reported"))
            .collect())
    }

    fn shutdown(&mut self) {
        for tx in &self.edge_txs {
            let _ = tx.send(EdgeInbox::Cmd(CloudToEdge::Shutdown));
        }
        for h in self.edge_handles.drain(..) {
            let _ = h.join();
        }
        for h in self.client_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ClusterFabric {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Edge worker: relay jobs to this region's clients and control signals
/// both ways; fold each in-time submission into the region's accumulator
/// the moment it arrives (sending a model-free notice up), and ship the
/// folded aggregate to the cloud at round end.
fn edge_loop(
    rx: Receiver<EdgeInbox>,
    cloud_tx: Sender<EdgeToCloud>,
    my_clients: HashMap<usize, Sender<EdgeToClient>>,
    region: usize,
    region_data: f64,
) {
    let mut cur_t = 0usize;
    let mut acc: Option<RegionAccumulator> = None;
    // The round's start model, kept for decoding delta frames (compressed
    // submissions fold as `start + decoded delta`).
    let mut cur_start: Option<Arc<ModelParams>> = None;
    let mut folded: Vec<usize> = Vec::new();
    loop {
        match rx.recv() {
            Ok(EdgeInbox::Cmd(CloudToEdge::StartRound { t, start, jobs })) => {
                cur_t = t;
                acc = Some(RegionAccumulator::new(region, region_data, &start));
                cur_start = Some(Arc::clone(&start));
                folded.clear();
                for job in jobs {
                    if let Some(tx) = my_clients.get(&job.client) {
                        let _ = tx.send(EdgeToClient::Train {
                            t,
                            start: Arc::clone(&start),
                            dropped: job.dropped,
                            completion: job.completion,
                        });
                    }
                }
            }
            Ok(EdgeInbox::Cmd(CloudToEdge::EndRound { t })) => {
                for tx in my_clients.values() {
                    let _ = tx.send(EdgeToClient::EndRound { t });
                }
                if t == cur_t {
                    if let Some(agg) = acc.take() {
                        let _ = cloud_tx.send(EdgeToCloud::Report(RegionalReport {
                            t,
                            region,
                            agg,
                            clients: std::mem::take(&mut folded),
                        }));
                    }
                }
            }
            Ok(EdgeInbox::Cmd(CloudToEdge::Shutdown)) | Err(_) => {
                for tx in my_clients.values() {
                    let _ = tx.send(EdgeToClient::Shutdown);
                }
                break;
            }
            Ok(EdgeInbox::Sub(s)) => {
                // Decode-and-fold in arrival order; the frame is dropped
                // here. The round-end signal closes the accumulator, so a
                // submission reaching the edge after it — or one from a
                // stale round — is discarded, never folded. A malformed
                // frame is logged and skipped (not counted, not folded):
                // the round simply proceeds without that client, exactly
                // as if it had dropped out.
                if s.t == cur_t {
                    if let (Some(a), Some(start)) = (acc.as_mut(), cur_start.as_ref()) {
                        match a.fold_encoded(start, &s.frame, s.data_size, s.loss) {
                            Ok(()) => {
                                folded.push(s.client);
                                let _ = cloud_tx.send(EdgeToCloud::Notice(SubmissionNotice {
                                    t: s.t,
                                    client: s.client,
                                    region: s.region,
                                }));
                            }
                            Err(e) => eprintln!(
                                "edge {region}: discarding malformed submission \
                                 from client {}: {e}",
                                s.client
                            ),
                        }
                    }
                }
            }
        }
    }
}

/// Client actor: on a training job, either drop silently, or sleep the
/// scaled completion time (interruptible by the round-end signal), train
/// locally on the mock engine, frame the update with the configured codec
/// and submit through the edge. The codec's randomness comes from the
/// client's own `seed → COMM_STREAM → client → round` stream, so encoding
/// is deterministic per (seed, client, round) regardless of thread
/// scheduling; the dense codec never draws from it.
#[allow(clippy::too_many_arguments)]
fn client_loop(
    rx: Receiver<EdgeToClient>,
    edge_tx: Sender<EdgeInbox>,
    client: usize,
    region: usize,
    indices: Vec<usize>,
    mut engine: MockEngine,
    epochs: usize,
    lr: f32,
    time_scale: f64,
    spec: CodecSpec,
    seed: u64,
) {
    let psize = indices.len() as f64;
    let codec = spec.codec();
    while let Ok(msg) = rx.recv() {
        match msg {
            EdgeToClient::Train {
                t,
                start,
                dropped,
                completion,
            } => {
                if dropped {
                    continue; // opted out: never responds
                }
                let wake = Instant::now() + Duration::from_secs_f64(completion * time_scale);
                let mut abandoned = false;
                loop {
                    let now = Instant::now();
                    if now >= wake {
                        break;
                    }
                    match rx.recv_timeout(wake - now) {
                        Ok(EdgeToClient::EndRound { t: et }) if et >= t => {
                            abandoned = true; // stopped by the round-end signal
                            break;
                        }
                        Ok(EdgeToClient::EndRound { .. }) => {}
                        Ok(EdgeToClient::Shutdown) => return,
                        // A new Train cannot arrive before our round's
                        // EndRound (the cloud broadcasts EndRound first,
                        // and per-channel order is FIFO); drop defensively.
                        Ok(EdgeToClient::Train { .. }) => {}
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => return,
                    }
                }
                if abandoned {
                    continue;
                }
                if let Ok(out) = engine.train_local(&start, &indices, epochs, lr) {
                    let loss = out.loss;
                    let mut crng = Rng::new(seed)
                        .split(COMM_STREAM)
                        .split(client as u64)
                        .split(t as u64);
                    let mut ctx = EncodeCtx {
                        rng: &mut crng,
                        residual: None, // +ef is sim-only; rejected upstream
                    };
                    let frame = if spec.is_dense() {
                        // Legacy semantics: the full trained model.
                        codec.encode(&out.params, &mut ctx)
                    } else {
                        // Compressed codecs frame the delta vs round start.
                        let mut delta = out.params;
                        delta.axpy(-1.0, &start);
                        codec.encode(&delta, &mut ctx)
                    };
                    let _ = edge_tx.send(EdgeInbox::Sub(Submission {
                        t,
                        client,
                        region,
                        data_size: psize,
                        loss,
                        frame,
                    }));
                }
            }
            EdgeToClient::EndRound { .. } => {}
            EdgeToClient::Shutdown => return,
        }
    }
}
