//! The live cluster: cloud leader + edge workers + client actors as OS
//! threads over mpsc channels, executing HybridFL in wall-clock time.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::aggregation;
use crate::config::ExperimentConfig;
use crate::devices::{self, ClientProfile};
use crate::live::messages::{CloudToEdge, EdgeToClient, EdgeToCloud, Submission};
use crate::model::ModelParams;
use crate::rng::Rng;
use crate::selection::{select_clients, SlackEstimator};
use crate::timing::TimingModel;
use crate::topology::Topology;
use crate::Result;

/// Knobs for a live run.
#[derive(Clone, Debug)]
pub struct LiveOpts {
    /// Number of federated rounds to drive.
    pub rounds: usize,
    /// Wall-clock seconds per virtual second (e.g. 1e-4 ⇒ a 90 s virtual
    /// deadline becomes 9 ms).
    pub time_scale: f64,
}

impl Default for LiveOpts {
    fn default() -> Self {
        LiveOpts { rounds: 10, time_scale: 1e-4 }
    }
}

/// Per-round observability from the cloud's vantage point.
#[derive(Clone, Debug)]
pub struct LiveRoundStats {
    pub t: usize,
    pub wall: Duration,
    pub submissions: Vec<usize>,
    pub quota_met: bool,
    /// Mock-progress scalar of the global model (monotone ⇒ training
    /// flowed through the full distributed path).
    pub global_progress: f64,
}

/// Everything needed to run a live cluster for one config.
pub struct LiveCluster {
    cfg: ExperimentConfig,
    topo: Topology,
    profiles: Vec<ClientProfile>,
    partition_sizes: Vec<usize>,
    tm: TimingModel,
}

/// Mock local training (see module docs): progress grows with epochs and
/// the client's share of data, exactly like `runtime::mock::MockEngine`.
fn mock_train(
    start: &ModelParams,
    epochs: usize,
    tau_ref: f64,
    data_frac: f64,
) -> ModelParams {
    let mut p = start.clone();
    let gain = (epochs as f64 / tau_ref) * data_frac;
    p.tensors[0][0] += gain as f32;
    p.tensors[0][1] += 0.01 * gain as f32;
    p
}

impl LiveCluster {
    pub fn new(cfg: ExperimentConfig) -> Result<LiveCluster> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        let topo = Topology::build(&cfg, &mut rng.split(1))?;
        // Partition sizes are simulated directly (no corpus needed for the
        // coordination path): Gaussian-ish around |D|/n.
        let mean = cfg.mean_partition();
        let mut prng = rng.split(2);
        let partition_sizes: Vec<usize> = (0..cfg.n_clients)
            .map(|_| prng.normal_clamped(mean, mean * 0.3, 5.0, mean * 3.0) as usize)
            .collect();
        let profiles = devices::sample_fleet(&cfg, &topo, &mut rng.split(3));
        let tm = TimingModel::new(&cfg);
        Ok(LiveCluster { cfg, topo, profiles, partition_sizes, tm })
    }

    /// Run the cluster: spawns 1 + m + n threads, drives `opts.rounds`
    /// rounds, tears everything down, returns per-round stats.
    pub fn run(&self, opts: &LiveOpts) -> Result<Vec<LiveRoundStats>> {
        let m = self.topo.n_regions();
        let scale = opts.time_scale;
        let tau = self.cfg.local_epochs;
        let lr = self.cfg.lr as f32;
        let mean_part = self.cfg.mean_partition();

        // Channel fabric.
        let (cloud_tx, cloud_rx) = channel::<EdgeToCloud>();
        let mut edge_txs: Vec<Sender<EdgeInbox>> = Vec::with_capacity(m);
        let mut edge_handles: Vec<JoinHandle<()>> = Vec::with_capacity(m);
        let mut client_handles: Vec<JoinHandle<()>> = Vec::new();
        let mut client_txs: Vec<Option<Sender<EdgeToClient>>> =
            (0..self.cfg.n_clients).map(|_| None).collect();

        // --- client command channels (senders shared with edges) ----------------
        let mut client_rxs: Vec<Receiver<EdgeToClient>> = Vec::with_capacity(self.cfg.n_clients);
        for k in 0..self.cfg.n_clients {
            let (tx, rx) = channel::<EdgeToClient>();
            client_txs[k] = Some(tx);
            client_rxs.push(rx);
        }

        // --- edge inboxes -----------------------------------------------------
        let mut edge_inbox_txs: Vec<Sender<EdgeInbox>> = Vec::with_capacity(m);
        let mut edge_inbox_rxs: Vec<Receiver<EdgeInbox>> = Vec::with_capacity(m);
        for _ in 0..m {
            let (tx, rx) = channel::<EdgeInbox>();
            edge_inbox_txs.push(tx);
            edge_inbox_rxs.push(rx);
        }

        // --- spawn clients ----------------------------------------------------
        for (k, rx) in client_rxs.into_iter().enumerate() {
            let profile = self.profiles[k];
            let psize = self.partition_sizes[k] as f64;
            let completion = self.tm.completion(&profile, psize);
            let region = self.topo.region_of[k];
            let edge_tx = edge_inbox_txs[region].clone();
            let seed = self.cfg.seed ^ (0xC11E57 + k as u64);
            let tau_ref = tau as f64;
            let data_frac = psize / mean_part.max(1.0);
            client_handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                while let Ok(msg) = rx.recv() {
                    match msg {
                        EdgeToClient::Train { t, model, epochs, lr: _ } => {
                            if rng.bernoulli(profile.dropout_p) {
                                continue; // dropped out: never responds
                            }
                            std::thread::sleep(Duration::from_secs_f64(
                                completion * scale,
                            ));
                            let trained = mock_train(&model, epochs, tau_ref, data_frac);
                            let _ = edge_tx.send(EdgeInbox::Sub(Submission {
                                t,
                                data_size: psize,
                                model: trained,
                            }));
                        }
                        EdgeToClient::Shutdown => break,
                    }
                }
            }));
        }

        // --- spawn edges --------------------------------------------------------
        for (r, rx) in edge_inbox_rxs.into_iter().enumerate() {
            let clients = self.topo.regions[r].clone();
            let my_client_txs: Vec<(usize, Sender<EdgeToClient>)> = clients
                .iter()
                .map(|&k| (k, client_txs[k].as_ref().unwrap().clone()))
                .collect();
            let cloud_tx = cloud_tx.clone();
            let region_data: f64 = clients
                .iter()
                .map(|&k| self.partition_sizes[k] as f64)
                .sum();
            let mut slack = SlackEstimator::new(
                clients.len(),
                self.cfg.c_fraction,
                self.cfg.theta_init,
            );
            let seed = self.cfg.seed ^ (0xED6E + r as u64);
            let tau_ = tau;
            let lr_ = lr;
            edge_txs.push(edge_inbox_txs[r].clone());
            edge_handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(seed);
                let mut regional: Option<ModelParams> = None;
                'rounds: loop {
                    // Await StartRound (ignore stale submissions).
                    let (t, global) = loop {
                        match rx.recv() {
                            Ok(EdgeInbox::Cmd(CloudToEdge::StartRound { t, global })) => {
                                break (t, global)
                            }
                            Ok(EdgeInbox::Cmd(CloudToEdge::Shutdown)) | Err(_) => {
                                break 'rounds
                            }
                            Ok(_) => continue, // stale submission / signal
                        }
                    };
                    if regional.is_none() {
                        regional = Some(global.clone());
                    }
                    // Step 1: slack-modulated selection; dispatch training.
                    let want = slack.selection_count();
                    let chosen = select_clients(
                        &(0..my_client_txs.len()).collect::<Vec<_>>(),
                        want,
                        &mut rng,
                    );
                    for &i in &chosen {
                        let _ = my_client_txs[i].1.send(EdgeToClient::Train {
                            t,
                            model: global.clone(),
                            epochs: tau_,
                            lr: lr_,
                        });
                    }
                    // Collect submissions until the aggregation signal.
                    let mut collected: Vec<Submission> = Vec::new();
                    let quota_met = loop {
                        match rx.recv() {
                            Ok(EdgeInbox::Sub(s)) if s.t == t => {
                                collected.push(s);
                                let _ = cloud_tx.send(EdgeToCloud::Progress {
                                    region: r,
                                    t,
                                    submissions: collected.len(),
                                });
                            }
                            Ok(EdgeInbox::Sub(_)) => {} // straggler from old round
                            Ok(EdgeInbox::Cmd(CloudToEdge::AggregationSignal {
                                t: st,
                                quota_met,
                            })) if st == t => break quota_met,
                            Ok(EdgeInbox::Cmd(CloudToEdge::Shutdown)) | Err(_) => {
                                break 'rounds
                            }
                            Ok(_) => {}
                        }
                    };
                    // Regional aggregation with the cache rule (eq. 17).
                    let refs: Vec<(&ModelParams, f64)> = collected
                        .iter()
                        .map(|s| (&s.model, s.data_size))
                        .collect();
                    let prev = regional.as_ref().unwrap();
                    let w_r = aggregation::regional_with_cache(&refs, region_data, prev);
                    let edc: f64 = collected.iter().map(|s| s.data_size).sum();
                    let n_sub = collected.len();
                    slack.observe(n_sub, quota_met);
                    regional = Some(w_r.clone());
                    let _ = cloud_tx.send(EdgeToCloud::Regional {
                        region: r,
                        t,
                        model: w_r,
                        edc,
                        submissions: n_sub,
                    });
                }
            }));
        }
        drop(cloud_tx); // cloud keeps only the receiver

        // --- cloud leader (this thread) -----------------------------------------
        let mut global = ModelParams::new(vec![vec![0.0, 0.0]], vec![vec![2]]);
        let quota = self.cfg.quota();
        let deadline = Duration::from_secs_f64(self.tm.t_lim * scale);
        let mut stats = Vec::with_capacity(opts.rounds);

        for t in 1..=opts.rounds {
            let started = Instant::now();
            for tx in &edge_txs {
                tx.send(EdgeInbox::Cmd(CloudToEdge::StartRound {
                    t,
                    global: global.clone(),
                }))
                .ok()
                .context("edge hung up")?;
            }
            // Monitor progress until quota or deadline.
            let mut counts = vec![0usize; m];
            let quota_met = loop {
                let left = deadline.saturating_sub(started.elapsed());
                if left.is_zero() {
                    break false;
                }
                match cloud_rx.recv_timeout(left) {
                    Ok(EdgeToCloud::Progress { region, t: pt, submissions })
                        if pt == t =>
                    {
                        counts[region] = submissions;
                        if counts.iter().sum::<usize>() >= quota {
                            break true;
                        }
                    }
                    Ok(_) => {}
                    Err(RecvTimeoutError::Timeout) => break false,
                    Err(RecvTimeoutError::Disconnected) => {
                        anyhow::bail!("all edges disconnected")
                    }
                }
            };
            // Signal aggregation; collect the m regional models.
            for tx in &edge_txs {
                let _ = tx.send(EdgeInbox::Cmd(CloudToEdge::AggregationSignal {
                    t,
                    quota_met,
                }));
            }
            let mut regionals: Vec<(ModelParams, f64)> = Vec::with_capacity(m);
            let mut submissions = vec![0usize; m];
            while regionals.len() < m {
                match cloud_rx.recv().context("edge hung up mid-aggregation")? {
                    EdgeToCloud::Regional { region, t: rt, model, edc, submissions: s }
                        if rt == t =>
                    {
                        submissions[region] = s;
                        regionals.push((model, edc));
                    }
                    _ => {}
                }
            }
            // Immediate EDC-weighted cloud aggregation (eq. 20).
            let refs: Vec<(&ModelParams, f64)> =
                regionals.iter().map(|(w, e)| (w, *e)).collect();
            if let Some(w) = aggregation::edc_cloud(&refs) {
                global = w;
            }
            stats.push(LiveRoundStats {
                t,
                wall: started.elapsed(),
                submissions,
                quota_met,
                global_progress: global.tensors[0][0] as f64,
            });
        }

        // --- teardown ------------------------------------------------------------
        for tx in &edge_txs {
            let _ = tx.send(EdgeInbox::Cmd(CloudToEdge::Shutdown));
        }
        for tx in client_txs.iter().flatten() {
            let _ = tx.send(EdgeToClient::Shutdown);
        }
        for h in edge_handles {
            let _ = h.join();
        }
        for h in client_handles {
            let _ = h.join();
        }
        Ok(stats)
    }
}

/// Edge inbox fan-in: commands from the cloud and submissions from clients
/// arrive on one channel so the edge thread can block on a single recv.
enum EdgeInbox {
    Cmd(CloudToEdge),
    Sub(Submission),
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dist;

    fn live_cfg(dropout: f64) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 20;
        cfg.n_edges = 2;
        cfg.dataset_size = 600;
        cfg.eval_size = 50;
        cfg.dropout = Dist::new(dropout, 0.02);
        cfg
    }

    #[test]
    fn live_cluster_runs_rounds_and_learns() {
        let cluster = LiveCluster::new(live_cfg(0.1)).unwrap();
        let stats = cluster
            .run(&LiveOpts { rounds: 6, time_scale: 2e-5 })
            .unwrap();
        assert_eq!(stats.len(), 6);
        // Reliable fleet: the quota should be met in most rounds.
        let met = stats.iter().filter(|s| s.quota_met).count();
        assert!(met >= 4, "quota met only {met}/6 rounds");
        // Global progress strictly increases when submissions flowed.
        assert!(stats.last().unwrap().global_progress > 0.0);
        for w in stats.windows(2) {
            assert!(w[1].global_progress >= w[0].global_progress);
        }
    }

    #[test]
    fn live_cluster_survives_heavy_dropout() {
        let cluster = LiveCluster::new(live_cfg(0.9)).unwrap();
        let stats = cluster
            .run(&LiveOpts { rounds: 4, time_scale: 2e-5 })
            .unwrap();
        assert_eq!(stats.len(), 4);
        // Rounds end (deadline) even when almost nobody responds, and the
        // system does not deadlock.
        assert!(stats.iter().any(|s| !s.quota_met));
    }

    #[test]
    fn quota_rounds_finish_before_deadline_wallclock() {
        let cluster = LiveCluster::new(live_cfg(0.0)).unwrap();
        let scale = 2e-5;
        let stats = cluster.run(&LiveOpts { rounds: 4, time_scale: scale }).unwrap();
        let deadline = Duration::from_secs_f64(cluster.tm.t_lim * scale);
        for s in &stats {
            if s.quota_met {
                assert!(
                    s.wall < deadline,
                    "round {} took {:?} >= deadline {:?}",
                    s.t,
                    s.wall,
                    deadline
                );
            }
        }
    }
}
