//! The live cluster fabric: edge workers + client actors as OS threads
//! over mpsc channels, driven round-by-round by the cloud leader (the
//! thread inside [`crate::env::LiveClusterEnv::run_round`]).
//!
//! This module is *pure transport and enactment*. It contains no protocol
//! logic: no selection policy, no slack estimation, no aggregation — those
//! live in `protocols/` above the [`crate::env::FlEnvironment`] trait and
//! run identically on the virtual-clock backend. What the fabric provides
//! is real concurrency: clients sleep their scaled completion times and
//! train on their own threads, edges relay jobs down and submissions up,
//! and the caller observes genuine out-of-order arrival, quota/deadline
//! racing and straggler stop-signals.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::env::World;
use crate::live::messages::{CloudToEdge, EdgeToClient, RoundJob, Submission};
use crate::model::ModelParams;
use crate::runtime::mock::MockEngine;
use crate::runtime::Engine;
use crate::Result;

/// Edge inbox fan-in: commands from the cloud and submissions from clients
/// arrive on one channel so the edge thread can block on a single recv.
enum EdgeInbox {
    Cmd(CloudToEdge),
    Sub(Submission),
}

/// A spawned cloud/edge/client thread fabric, reusable across rounds.
/// Tear-down is automatic on drop.
pub struct ClusterFabric {
    edge_txs: Vec<Sender<EdgeInbox>>,
    cloud_rx: Receiver<Submission>,
    edge_handles: Vec<JoinHandle<()>>,
    client_handles: Vec<JoinHandle<()>>,
}

impl ClusterFabric {
    /// Spawn one edge thread per region and one client thread per device.
    pub(crate) fn spawn(world: &World, time_scale: f64) -> Result<ClusterFabric> {
        let m = world.topo.n_regions();
        let n = world.topo.n_clients();

        let (cloud_tx, cloud_rx) = channel::<Submission>();

        // Per-client command channels (senders held by the edges).
        let mut client_txs: Vec<Sender<EdgeToClient>> = Vec::with_capacity(n);
        let mut client_rxs: Vec<Receiver<EdgeToClient>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel::<EdgeToClient>();
            client_txs.push(tx);
            client_rxs.push(rx);
        }

        // Per-edge inboxes (cloud commands + client submissions fan in).
        let mut edge_txs: Vec<Sender<EdgeInbox>> = Vec::with_capacity(m);
        let mut edge_rxs: Vec<Receiver<EdgeInbox>> = Vec::with_capacity(m);
        for _ in 0..m {
            let (tx, rx) = channel::<EdgeInbox>();
            edge_txs.push(tx);
            edge_rxs.push(rx);
        }

        // Client actors.
        let mut client_handles = Vec::with_capacity(n);
        for (k, rx) in client_rxs.into_iter().enumerate() {
            let region = world.topo.region_of[k];
            let edge_tx = edge_txs[region].clone();
            let indices = world.data.partitions[k].clone();
            let engine = MockEngine::new(&world.cfg, Arc::clone(&world.data));
            let epochs = world.cfg.local_epochs;
            let lr = world.cfg.lr as f32;
            client_handles.push(std::thread::spawn(move || {
                client_loop(rx, edge_tx, k, region, indices, engine, epochs, lr, time_scale);
            }));
        }

        // Edge relays.
        let mut edge_handles = Vec::with_capacity(m);
        for (r, rx) in edge_rxs.into_iter().enumerate() {
            let my_clients: HashMap<usize, Sender<EdgeToClient>> = world.topo.regions[r]
                .iter()
                .map(|&k| (k, client_txs[k].clone()))
                .collect();
            let cloud_tx = cloud_tx.clone();
            edge_handles.push(std::thread::spawn(move || {
                edge_loop(rx, cloud_tx, my_clients);
            }));
        }
        drop(cloud_tx); // the cloud keeps only the receiver
        drop(client_txs); // clients are reachable through their edges only

        Ok(ClusterFabric {
            edge_txs,
            cloud_rx,
            edge_handles,
            client_handles,
        })
    }

    /// Drive one round: dispatch per-region job batches, collect real
    /// submissions until `target` of them arrived or `deadline` elapsed,
    /// then broadcast the round-end signal. Returns the in-time
    /// submissions in arrival order.
    pub(crate) fn round(
        &mut self,
        t: usize,
        starts: &[Arc<ModelParams>],
        jobs: Vec<Vec<RoundJob>>,
        target: usize,
        deadline: Duration,
    ) -> Result<Vec<Submission>> {
        for (r, js) in jobs.into_iter().enumerate() {
            self.edge_txs[r]
                .send(EdgeInbox::Cmd(CloudToEdge::StartRound {
                    t,
                    start: Arc::clone(&starts[r]),
                    jobs: js,
                }))
                .ok()
                .context("edge hung up")?;
        }

        let started = Instant::now();
        let mut got: Vec<Submission> = Vec::new();
        while got.len() < target {
            let left = deadline.saturating_sub(started.elapsed());
            if left.is_zero() {
                break;
            }
            match self.cloud_rx.recv_timeout(left) {
                Ok(s) if s.t == t => got.push(s),
                Ok(_) => {} // straggler from an earlier round
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all edges disconnected")
                }
            }
        }

        // Round-end signal: edges relay it to every client, stopping
        // stragglers (the quota trigger's energy saving).
        for tx in &self.edge_txs {
            let _ = tx.send(EdgeInbox::Cmd(CloudToEdge::EndRound { t }));
        }
        Ok(got)
    }

    fn shutdown(&mut self) {
        for tx in &self.edge_txs {
            let _ = tx.send(EdgeInbox::Cmd(CloudToEdge::Shutdown));
        }
        for h in self.edge_handles.drain(..) {
            let _ = h.join();
        }
        for h in self.client_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ClusterFabric {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Edge worker: relay jobs to this region's clients, submissions to the
/// cloud, and control signals both ways.
fn edge_loop(
    rx: Receiver<EdgeInbox>,
    cloud_tx: Sender<Submission>,
    my_clients: HashMap<usize, Sender<EdgeToClient>>,
) {
    loop {
        match rx.recv() {
            Ok(EdgeInbox::Cmd(CloudToEdge::StartRound { t, start, jobs })) => {
                for job in jobs {
                    if let Some(tx) = my_clients.get(&job.client) {
                        let _ = tx.send(EdgeToClient::Train {
                            t,
                            start: Arc::clone(&start),
                            dropped: job.dropped,
                            completion: job.completion,
                        });
                    }
                }
            }
            Ok(EdgeInbox::Cmd(CloudToEdge::EndRound { t })) => {
                for tx in my_clients.values() {
                    let _ = tx.send(EdgeToClient::EndRound { t });
                }
            }
            Ok(EdgeInbox::Cmd(CloudToEdge::Shutdown)) | Err(_) => {
                for tx in my_clients.values() {
                    let _ = tx.send(EdgeToClient::Shutdown);
                }
                break;
            }
            Ok(EdgeInbox::Sub(s)) => {
                let _ = cloud_tx.send(s);
            }
        }
    }
}

/// Client actor: on a training job, either drop silently, or sleep the
/// scaled completion time (interruptible by the round-end signal), train
/// locally on the mock engine and submit through the edge.
#[allow(clippy::too_many_arguments)]
fn client_loop(
    rx: Receiver<EdgeToClient>,
    edge_tx: Sender<EdgeInbox>,
    client: usize,
    region: usize,
    indices: Vec<usize>,
    mut engine: MockEngine,
    epochs: usize,
    lr: f32,
    time_scale: f64,
) {
    let psize = indices.len() as f64;
    while let Ok(msg) = rx.recv() {
        match msg {
            EdgeToClient::Train {
                t,
                start,
                dropped,
                completion,
            } => {
                if dropped {
                    continue; // opted out: never responds
                }
                let wake = Instant::now() + Duration::from_secs_f64(completion * time_scale);
                let mut abandoned = false;
                loop {
                    let now = Instant::now();
                    if now >= wake {
                        break;
                    }
                    match rx.recv_timeout(wake - now) {
                        Ok(EdgeToClient::EndRound { t: et }) if et >= t => {
                            abandoned = true; // stopped by the round-end signal
                            break;
                        }
                        Ok(EdgeToClient::EndRound { .. }) => {}
                        Ok(EdgeToClient::Shutdown) => return,
                        // A new Train cannot arrive before our round's
                        // EndRound (the cloud broadcasts EndRound first,
                        // and per-channel order is FIFO); drop defensively.
                        Ok(EdgeToClient::Train { .. }) => {}
                        Err(RecvTimeoutError::Timeout) => break,
                        Err(RecvTimeoutError::Disconnected) => return,
                    }
                }
                if abandoned {
                    continue;
                }
                if let Ok(out) = engine.train_local(&start, &indices, epochs, lr) {
                    let _ = edge_tx.send(EdgeInbox::Sub(Submission {
                        t,
                        client,
                        region,
                        data_size: psize,
                        loss: out.loss,
                        model: out.params,
                    }));
                }
            }
            EdgeToClient::EndRound { .. } => {}
            EdgeToClient::Shutdown => return,
        }
    }
}
