//! MEC topology (S4): the cloud, `m` edge nodes, and `n` clients grouped
//! into regions. "We refer to the collection of clients connected to an
//! edge node as a region"; a client connects to exactly one edge node.

use anyhow::{bail, Result};

use crate::config::ExperimentConfig;
use crate::rng::Rng;

/// Static system topology. Region `r` corresponds to edge node `r`.
#[derive(Clone, Debug)]
pub struct Topology {
    /// `regions[r]` = client ids connected to edge node r.
    pub regions: Vec<Vec<usize>>,
    /// Inverse map: `region_of[k]` = the region of client k (the paper's
    /// r(k)).
    pub region_of: Vec<usize>,
    /// Per-region drop-out mean override (explicit `RegionSpec`s only).
    dropout_means: Vec<Option<f64>>,
}

impl Topology {
    /// Build from config: explicit `RegionSpec`s if present, otherwise
    /// region populations n_r ~ 𝓝(region_pop) normalized to n (each region
    /// keeps at least one client). Client ids are assigned contiguously per
    /// region, matching the paper's Task-2 client-index ↔ label congruence
    /// story (ids are just labels; data skew is index-based).
    pub fn build(cfg: &ExperimentConfig, rng: &mut Rng) -> Result<Topology> {
        let sizes: Vec<usize>;
        let mut dropout_means: Vec<Option<f64>> = Vec::new();
        if !cfg.regions.is_empty() {
            sizes = cfg.regions.iter().map(|r| r.n_clients).collect();
            dropout_means = cfg.regions.iter().map(|r| Some(r.dropout_mean)).collect();
        } else {
            if cfg.n_edges > cfg.n_clients {
                bail!(
                    "more edges ({}) than clients ({})",
                    cfg.n_edges,
                    cfg.n_clients
                );
            }
            // Sample raw populations and normalize to exactly n with >= 1.
            let raw: Vec<f64> = (0..cfg.n_edges)
                .map(|_| rng.normal(cfg.region_pop.mean, cfg.region_pop.std).max(1.0))
                .collect();
            let total: f64 = raw.iter().sum();
            let mut s: Vec<usize> = raw
                .iter()
                .map(|v| ((v / total) * cfg.n_clients as f64).floor().max(1.0) as usize)
                .collect();
            let mut assigned: usize = s.iter().sum();
            // Trim overshoot (possible via the >=1 floor) from the largest.
            while assigned > cfg.n_clients {
                let (i, _) = s.iter().enumerate().max_by_key(|(_, &v)| v).unwrap();
                if s[i] > 1 {
                    s[i] -= 1;
                    assigned -= 1;
                }
            }
            let mut i = 0;
            while assigned < cfg.n_clients {
                let len = s.len();
                s[i % len] += 1;
                assigned += 1;
                i += 1;
            }
            sizes = s;
            dropout_means.resize(cfg.n_edges, None);
        }

        let n: usize = sizes.iter().sum();
        let mut regions = Vec::with_capacity(sizes.len());
        let mut region_of = vec![0usize; n];
        let mut next = 0usize;
        for (r, &sz) in sizes.iter().enumerate() {
            let ids: Vec<usize> = (next..next + sz).collect();
            for &k in &ids {
                region_of[k] = r;
            }
            next += sz;
            regions.push(ids);
        }
        Ok(Topology {
            regions,
            region_of,
            dropout_means,
        })
    }

    /// m — number of edge nodes.
    pub fn n_regions(&self) -> usize {
        self.regions.len()
    }

    /// n — number of clients.
    pub fn n_clients(&self) -> usize {
        self.region_of.len()
    }

    /// n_r.
    pub fn region_size(&self, r: usize) -> usize {
        self.regions[r].len()
    }

    /// Explicit per-region drop-out mean, if configured (Fig. 2).
    pub fn dropout_mean_override(&self, r: usize) -> Option<f64> {
        self.dropout_means.get(r).copied().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RegionSpec;

    #[test]
    fn sampled_topology_partitions_clients() {
        let cfg = ExperimentConfig::task2_scaled();
        let topo = Topology::build(&cfg, &mut Rng::new(0)).unwrap();
        assert_eq!(topo.n_regions(), cfg.n_edges);
        assert_eq!(topo.n_clients(), cfg.n_clients);
        let total: usize = topo.regions.iter().map(|r| r.len()).sum();
        assert_eq!(total, cfg.n_clients);
        for r in 0..topo.n_regions() {
            assert!(topo.region_size(r) >= 1);
            for &k in &topo.regions[r] {
                assert_eq!(topo.region_of[k], r);
            }
        }
    }

    #[test]
    fn explicit_regions_honored() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 20;
        cfg.regions = vec![
            RegionSpec { n_clients: 11, dropout_mean: 0.57 },
            RegionSpec { n_clients: 9, dropout_mean: 0.43 },
        ];
        let topo = Topology::build(&cfg, &mut Rng::new(1)).unwrap();
        assert_eq!(topo.region_size(0), 11);
        assert_eq!(topo.region_size(1), 9);
        assert_eq!(topo.dropout_mean_override(0), Some(0.57));
        assert_eq!(topo.dropout_mean_override(1), Some(0.43));
    }

    #[test]
    fn rejects_more_edges_than_clients() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 2;
        cfg.n_edges = 5;
        cfg.dataset_size = 100;
        assert!(Topology::build(&cfg, &mut Rng::new(2)).is_err());
    }

    #[test]
    fn populations_vary_but_sum_exactly() {
        let mut cfg = ExperimentConfig::task2_paper();
        cfg.n_clients = 500;
        cfg.n_edges = 10;
        let topo = Topology::build(&cfg, &mut Rng::new(3)).unwrap();
        let sizes: Vec<usize> = (0..10).map(|r| topo.region_size(r)).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 500);
        assert!(sizes.iter().max().unwrap() > sizes.iter().min().unwrap());
    }
}
