//! Operations control plane — observe and steer a running experiment.
//!
//! A multi-hour, million-client, churning run must not be a black box
//! until it writes artifacts. This module turns the checkpoint + churn
//! subsystems into an operable system, in three pieces:
//!
//! * **[`RunObserver`]** — the typed round-boundary event stream. The
//!   driver ([`crate::env::run_resumable`]) emits [`RunEvent`]s on *both*
//!   backends: one [`RunEvent::RoundClosed`] per completed round, a
//!   [`RunEvent::CheckpointWritten`] per snapshot (scheduled or
//!   on-demand), a [`RunEvent::FaultInjected`] per live-injected churn
//!   event, and one final [`RunEvent::RunFinished`]. Observers see only
//!   protocol-visible aggregates (env contract point 8) — per-region
//!   counts, availability means, slack telemetry — never per-client
//!   ground truth.
//! * **[`OpsServer`]** — a Prometheus-text `/metrics` endpoint plus a
//!   line-oriented control socket, multiplexed on one std
//!   [`std::net::TcpListener`] (no new dependencies). Scrapes report the
//!   round index, per-region availability / selected proportion / slack
//!   θ̂, arena peak, peak RSS, cumulative `bytes_moved`, and
//!   quota/deadline counters.
//! * **[`RunControl`]** — what the driver services at every round
//!   boundary: fan out events to observers, write scheduled checkpoints
//!   ([`CheckpointPlan`]), and execute pending control commands
//!   (`pause`/`resume`, `checkpoint-now`, `inject`). Injected faults are
//!   spliced into the running churn model via
//!   [`crate::env::FlEnvironment::inject_fault`], so an injected blackout
//!   is indistinguishable from a scripted one.
//!
//! # Control protocol
//!
//! Connect to the ops address and send newline-terminated commands; each
//! gets one `ok …` or `err …` reply line (HTTP `GET` on the same port is
//! sniffed and served as a scrape):
//!
//! ```text
//! status                    → ok round=12 paused=false
//! pause                     → ok paused          (takes effect at the round boundary)
//! checkpoint-now [DIR]      → ok <path written>  (DIR defaults to the run's checkpoint dir)
//! inject {"kind":"region_blackout","region":1,"from_round":40,"until_round":50}
//!                           → ok injected
//! resume                    → ok resumed
//! quit                      → closes the connection
//! ```
//!
//! Replies are sent when the *driver* has executed the command, so a
//! client that has seen `ok` for `checkpoint-now` can rely on the file
//! being on disk. `pause` blocks the run at the next round boundary —
//! command servicing keeps working while paused, which is exactly what
//! makes `pause → checkpoint-now → resume` a consistent, byte-identical
//! maneuver (pinned by test against `snapshot::run_result_bytes`).

mod server;

pub use server::{OpsServer, RunInfo};

use std::path::{Path, PathBuf};

use crate::churn::FaultEvent;
use crate::env::{DriverState, FlEnvironment, RoundTrace, RunResult};
use crate::protocols::Protocol;
use crate::snapshot::{self, CodecKind, RunSnapshot};
use crate::Result;

pub(crate) use server::OpsDriver;

/// One typed round-boundary event. Borrowed views into driver-owned data
/// — observers read, the driver keeps ownership.
#[derive(Debug)]
pub enum RunEvent<'a> {
    /// A round completed; `trace` is its [`RoundTrace`] row, `driver`
    /// the full accumulator state (including every prior row), and
    /// `spans` the round's drained phase spans + per-region submission
    /// latencies ([`crate::trace`]) — virtual durations are
    /// protocol-visible, wall times profiling-only (env contract
    /// point 8).
    RoundClosed {
        trace: &'a RoundTrace,
        driver: &'a DriverState,
        spans: &'a crate::trace::RoundSpans,
    },
    /// A snapshot was written — by the schedule or by `checkpoint-now`.
    CheckpointWritten { round: usize, path: &'a Path },
    /// A fault event was live-injected into the world at round `round`
    /// (it takes effect at `event.start_round()`).
    FaultInjected { round: usize, event: &'a FaultEvent },
    /// The run is over; `result` is what the driver is about to return.
    RunFinished { result: &'a RunResult },
}

/// A consumer of the round-boundary event stream. Implemented by
/// [`crate::metrics::ReportSink`] (CSV / JSON report artifacts) and by the
/// ops endpoint's internal state; an error aborts the run.
pub trait RunObserver {
    fn observe(&mut self, ev: &RunEvent<'_>) -> Result<()>;
}

/// Scheduled checkpointing: write a snapshot to `dir` with codec `kind`
/// every `every` rounds (at rounds where `rounds_done % every == 0`).
#[derive(Clone, Debug)]
pub struct CheckpointPlan {
    pub dir: PathBuf,
    pub kind: CodecKind,
    pub every: usize,
}

/// Everything [`crate::env::run_resumable`] services at a round boundary:
/// observers, the checkpoint schedule, and the ops command queue. A plain
/// run uses `RunControl::new()` (no observers, no checkpoints, no ops) —
/// the boundary then costs one branch per concern.
pub struct RunControl<'a> {
    /// Backend label written into snapshots (`sim` / `live`).
    backend: String,
    observers: Vec<&'a mut dyn RunObserver>,
    checkpoints: Option<CheckpointPlan>,
    ops: Option<OpsDriver>,
}

impl Default for RunControl<'_> {
    fn default() -> Self {
        RunControl::new()
    }
}

impl<'a> RunControl<'a> {
    /// An inert control: no observers, no checkpoints, no ops endpoint.
    pub fn new() -> RunControl<'a> {
        RunControl {
            backend: "sim".to_string(),
            observers: Vec::new(),
            checkpoints: None,
            ops: None,
        }
    }

    /// Set the backend label snapshots are stamped with (`sim` is the
    /// default; [`crate::scenario::Scenario`] passes its own).
    pub fn backend(mut self, label: impl Into<String>) -> RunControl<'a> {
        self.backend = label.into();
        self
    }

    /// Attach an observer; events are fanned out in attachment order.
    pub fn observe_with(mut self, obs: &'a mut dyn RunObserver) -> RunControl<'a> {
        self.observers.push(obs);
        self
    }

    /// Attach a checkpoint schedule.
    pub fn checkpoints(mut self, plan: CheckpointPlan) -> RunControl<'a> {
        self.checkpoints = Some(plan);
        self
    }

    /// Attach a driver-side ops handle (from [`OpsServer::attach`]).
    pub fn ops(mut self, driver: OpsDriver) -> RunControl<'a> {
        self.ops = Some(driver);
        self
    }

    /// The driver's round boundary: write a scheduled checkpoint if one
    /// is due (span-bracketed, so it lands in this round's trace), drain
    /// the environment's span recorder, emit [`RunEvent::RoundClosed`]
    /// (and the checkpoint's event), then drain (and, while paused,
    /// block on) the ops command queue.
    pub(crate) fn round_closed(
        &mut self,
        env: &mut dyn FlEnvironment,
        protocol: &dyn Protocol,
        st: &DriverState,
    ) -> Result<()> {
        let trace = st
            .rounds
            .last()
            .expect("round_closed with an empty trace");
        let mut ckpt_path = None;
        if let Some(plan) = &self.checkpoints {
            if plan.every > 0 && st.rounds_done % plan.every == 0 {
                let sp = crate::trace::SpanStart::begin();
                let snap = RunSnapshot::capture(&self.backend, env, protocol, st);
                let path = snapshot::save_to_dir(&plan.dir, plan.kind, &snap)?;
                env.tracer()
                    .finish(sp, crate::trace::Phase::Checkpoint, None, 0.0);
                ckpt_path = Some(path);
            }
        }
        let spans = env.tracer().take_round();
        self.emit(&RunEvent::RoundClosed {
            trace,
            driver: st,
            spans: &spans,
        })?;
        if let Some(path) = ckpt_path {
            self.emit(&RunEvent::CheckpointWritten {
                round: st.rounds_done,
                path: &path,
            })?;
        }
        self.service_commands(env, protocol, st)
    }

    /// End of run: emit [`RunEvent::RunFinished`].
    pub(crate) fn run_finished(&mut self, result: &RunResult) -> Result<()> {
        self.emit(&RunEvent::RunFinished { result })
    }

    fn emit(&mut self, ev: &RunEvent<'_>) -> Result<()> {
        for obs in self.observers.iter_mut() {
            obs.observe(ev)?;
        }
        if let Some(ops) = self.ops.as_mut() {
            ops.observe(ev)?;
        }
        Ok(())
    }

    /// Execute every pending ops command. While paused this *blocks* on
    /// the queue — the run sits at the boundary, still answering
    /// `status` / `checkpoint-now` / `inject`, until `resume` arrives.
    fn service_commands(
        &mut self,
        env: &mut dyn FlEnvironment,
        protocol: &dyn Protocol,
        st: &DriverState,
    ) -> Result<()> {
        // Take the driver handle out so command handlers can borrow the
        // rest of `self` (checkpoint plan, observers) freely.
        let Some(mut ops) = self.ops.take() else {
            return Ok(());
        };
        let res = self.service_loop(&mut ops, env, protocol, st);
        self.ops = Some(ops);
        res
    }

    fn service_loop(
        &mut self,
        ops: &mut OpsDriver,
        env: &mut dyn FlEnvironment,
        protocol: &dyn Protocol,
        st: &DriverState,
    ) -> Result<()> {
        loop {
            let Some(req) = (if ops.paused() {
                ops.wait_next()
            } else {
                ops.try_next()
            }) else {
                return Ok(());
            };
            let reply = match req.cmd {
                server::Command::Status => {
                    format!("ok round={} paused={}", st.rounds_done, ops.paused())
                }
                server::Command::Pause => {
                    ops.set_paused(true);
                    "ok paused".to_string()
                }
                server::Command::Resume => {
                    ops.set_paused(false);
                    "ok resumed".to_string()
                }
                server::Command::CheckpointNow { dir } => {
                    match dir.or_else(|| self.checkpoints.as_ref().map(|p| p.dir.clone())) {
                        None => "err no checkpoint directory: this run has no schedule, \
                                 pass one explicitly (checkpoint-now DIR)"
                            .to_string(),
                        Some(dir) => {
                            let kind = self
                                .checkpoints
                                .as_ref()
                                .map_or(CodecKind::Binary, |p| p.kind);
                            // This boundary's spans are already drained;
                            // the span rides the next round's set.
                            let sp = crate::trace::SpanStart::begin();
                            let snap = RunSnapshot::capture(&self.backend, env, protocol, st);
                            match snapshot::save_to_dir(&dir, kind, &snap) {
                                Ok(path) => {
                                    env.tracer().finish(
                                        sp,
                                        crate::trace::Phase::Checkpoint,
                                        None,
                                        0.0,
                                    );
                                    let ev = RunEvent::CheckpointWritten {
                                        round: st.rounds_done,
                                        path: &path,
                                    };
                                    for obs in self.observers.iter_mut() {
                                        obs.observe(&ev)?;
                                    }
                                    ops.observe(&ev)?;
                                    format!("ok {}", path.display())
                                }
                                Err(e) => format!("err {e:#}"),
                            }
                        }
                    }
                }
                server::Command::Inject(event) => {
                    if event.start_round() <= st.rounds_done {
                        format!(
                            "err event starts at round {} but {} rounds have already run \
                             (injection must only touch future rounds)",
                            event.start_round(),
                            st.rounds_done
                        )
                    } else {
                        match env.inject_fault(event.clone()) {
                            Ok(()) => {
                                let ev = RunEvent::FaultInjected {
                                    round: st.rounds_done,
                                    event: &event,
                                };
                                for obs in self.observers.iter_mut() {
                                    obs.observe(&ev)?;
                                }
                                ops.observe(&ev)?;
                                "ok injected".to_string()
                            }
                            Err(e) => format!("err {e:#}"),
                        }
                    }
                }
            };
            req.respond(reply);
        }
    }
}
