//! The ops listener: Prometheus-text scrapes and the line-oriented
//! control protocol, multiplexed on one std [`TcpListener`].
//!
//! One acceptor thread takes connections and sniffs the first line: an
//! HTTP `GET` is answered as a scrape (rendered from the shared
//! [`MetricsState`], which [`OpsDriver::observe`] refreshes at every
//! round boundary), anything else enters control mode — one command per
//! line, one `ok …`/`err …` reply per command. Control commands travel to
//! the driver over an mpsc queue and are executed *by the run loop* at
//! round boundaries (see [`super::RunControl`]), so a reply certifies the
//! command's effect, not just its receipt.
//!
//! Everything here is std-only: `TcpListener`, `thread`, `mpsc`, `Mutex`
//! — no new dependencies (hard constraint of the repo).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use crate::churn::FaultEvent;
use crate::jsonx::Json;
use crate::ops::{RunEvent, RunObserver};
use crate::trace::{Histo, Phase};
use crate::Result;

/// What the run loop tells the endpoint about itself at attach time.
#[derive(Clone, Debug)]
pub struct RunInfo {
    /// Backend label (`sim` / `live`).
    pub backend: String,
    /// Protocol label (`hybridfl` / `fedavg` / `hierfavg`).
    pub protocol: String,
    /// Clients per region — the denominators of the selected-proportion
    /// gauges. Protocol-visible topology facts, not per-client state.
    pub region_sizes: Vec<usize>,
}

/// A parsed control command, queued for the driver.
#[derive(Clone, Debug)]
pub(crate) enum Command {
    Status,
    Pause,
    Resume,
    CheckpointNow { dir: Option<std::path::PathBuf> },
    Inject(FaultEvent),
}

/// One queued command plus its reply line channel.
pub(crate) struct OpsRequest {
    pub(crate) cmd: Command,
    reply: Sender<String>,
}

impl OpsRequest {
    /// Send the reply line back to the waiting control connection. A gone
    /// client is not an error — the command already took effect.
    pub(crate) fn respond(self, line: String) {
        let _ = self.reply.send(line);
    }
}

/// The scrape's source of truth — refreshed by [`OpsDriver::observe`] at
/// round boundaries, read by HTTP handler threads. Holds round-trace
/// aggregates only (env contract point 8).
#[derive(Default)]
struct MetricsState {
    attached: bool,
    backend: String,
    protocol: String,
    region_sizes: Vec<usize>,
    round: usize,
    accuracy: f64,
    best_accuracy: f64,
    avail: Vec<f64>,
    selected_proportion: Vec<f64>,
    slack_theta: Option<Vec<f64>>,
    bytes_moved_total: u64,
    quota_rounds_total: u64,
    deadline_rounds_total: u64,
    checkpoints_written_total: u64,
    faults_injected_total: u64,
    paused: bool,
    finished: bool,
    /// Whole-run virtual-clock round-length distribution.
    round_length: Histo,
    /// Per-region submission-latency distributions (virtual seconds from
    /// round start to each in-time model's arrival at its edge).
    submission_latency: Vec<Histo>,
    /// Per-phase virtual-duration distributions, indexed by
    /// [`Phase::index`] (protocol-visible durations).
    phase_virtual: Vec<Histo>,
    /// Per-phase host wall-time distributions (profiling-only — env
    /// contract point 8: these never feed back into the run).
    phase_wall: Vec<Histo>,
}

struct Shared {
    metrics: Mutex<MetricsState>,
    /// Cloned (under the lock) by each control connection handler.
    cmd_tx: Mutex<Sender<OpsRequest>>,
    shutdown: AtomicBool,
    /// When set, `/metrics` requires `?token=` and control sessions must
    /// open with `auth TOKEN`. Mandatory for non-loopback binds.
    token: Option<String>,
}

/// The ops endpoint. Bind it (explicitly or via
/// [`crate::scenario::Scenario::ops_listen`]), hand [`OpsServer::attach`]'s
/// driver handle to the run, and the listener serves scrapes and control
/// sessions until the server is dropped.
pub struct OpsServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    /// Taken by `attach`; commands queued before the run starts are
    /// serviced at its first round boundary.
    cmd_rx: Option<Receiver<OpsRequest>>,
}

impl OpsServer {
    /// Bind the listener and start accepting, with no access token.
    /// `addr` is anything `ToSocketAddrs` takes — use port 0 to let the
    /// OS pick (the bound address is [`OpsServer::local_addr`]). Refuses
    /// non-loopback addresses; use [`OpsServer::bind_with_token`] to
    /// expose the endpoint beyond the host.
    pub fn bind(addr: impl ToSocketAddrs) -> Result<OpsServer> {
        OpsServer::bind_with_token(addr, None)
    }

    /// Bind with an optional access token. When `token` is set, `/metrics`
    /// requires a matching `?token=` query parameter and control sessions
    /// must send `auth TOKEN` as their first line. A non-loopback bind
    /// without a token is refused outright: the control socket can pause
    /// runs and inject faults, so it never goes on the network bare.
    pub fn bind_with_token(
        addr: impl ToSocketAddrs,
        token: Option<String>,
    ) -> Result<OpsServer> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        if !addr.ip().is_loopback() && token.is_none() {
            anyhow::bail!(
                "refusing to serve the ops control plane on non-loopback address {addr} \
                 without a token: pass --ops-token TOKEN (or bind to 127.0.0.1)"
            );
        }
        let (cmd_tx, cmd_rx) = mpsc::channel();
        let shared = Arc::new(Shared {
            metrics: Mutex::new(MetricsState::default()),
            cmd_tx: Mutex::new(cmd_tx),
            shutdown: AtomicBool::new(false),
            token,
        });
        let acceptor = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("ops-acceptor".to_string())
                .spawn(move || accept_loop(listener, shared))?
        };
        Ok(OpsServer {
            shared,
            addr,
            acceptor: Some(acceptor),
            cmd_rx: Some(cmd_rx),
        })
    }

    /// The address the listener actually bound.
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Hand the run loop its side of the endpoint. Call once per server;
    /// the returned [`OpsDriver`] goes into
    /// [`super::RunControl::ops`].
    pub fn attach(&mut self, info: RunInfo) -> Result<OpsDriver> {
        let rx = self
            .cmd_rx
            .take()
            .ok_or_else(|| anyhow::anyhow!("ops server is already attached to a run"))?;
        {
            let mut m = self.shared.metrics.lock().unwrap();
            m.attached = true;
            m.backend = info.backend;
            m.protocol = info.protocol;
            m.region_sizes = info.region_sizes;
        }
        Ok(OpsDriver {
            shared: Arc::clone(&self.shared),
            rx,
            paused: false,
        })
    }
}

impl Drop for OpsServer {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Unblock `accept` with a throwaway connection to ourselves.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

/// The run-loop side of the endpoint: consumes queued commands
/// ([`super::RunControl`] executes them at round boundaries) and mirrors
/// the event stream into the scrape state.
pub(crate) struct OpsDriver {
    shared: Arc<Shared>,
    rx: Receiver<OpsRequest>,
    paused: bool,
}

impl OpsDriver {
    pub(crate) fn paused(&self) -> bool {
        self.paused
    }

    pub(crate) fn set_paused(&mut self, on: bool) {
        self.paused = on;
        self.shared.metrics.lock().unwrap().paused = on;
    }

    /// Non-blocking poll (the normal, unpaused boundary).
    pub(crate) fn try_next(&self) -> Option<OpsRequest> {
        match self.rx.try_recv() {
            Ok(req) => Some(req),
            Err(TryRecvError::Empty | TryRecvError::Disconnected) => None,
        }
    }

    /// Blocking wait (the paused boundary). `None` only if every sender
    /// is gone — impossible while the server lives, since [`Shared`]
    /// keeps one.
    pub(crate) fn wait_next(&self) -> Option<OpsRequest> {
        self.rx.recv().ok()
    }
}

impl RunObserver for OpsDriver {
    fn observe(&mut self, ev: &RunEvent<'_>) -> Result<()> {
        let mut m = self.shared.metrics.lock().unwrap();
        match ev {
            RunEvent::RoundClosed { trace, spans, .. } => {
                m.round = trace.t;
                m.accuracy = trace.accuracy;
                m.best_accuracy = trace.best_accuracy;
                m.avail = trace.avail.clone();
                m.selected_proportion = trace
                    .selected
                    .iter()
                    .zip(m.region_sizes.iter())
                    .map(|(&sel, &size)| {
                        if size == 0 {
                            0.0
                        } else {
                            sel as f64 / size as f64
                        }
                    })
                    .collect();
                m.slack_theta = trace
                    .slack
                    .as_ref()
                    .map(|ss| ss.iter().map(|s| s.theta).collect());
                m.bytes_moved_total += trace.bytes_moved;
                if trace.deadline_hit {
                    m.deadline_rounds_total += 1;
                } else {
                    m.quota_rounds_total += 1;
                }
                // Histograms: accumulated over the whole run from the
                // round's span set. Observer-side state only — never
                // snapshotted, never fingerprinted.
                m.round_length.record(trace.round_len);
                for (r, subs) in spans.submissions.iter().enumerate() {
                    if m.submission_latency.len() <= r {
                        m.submission_latency.resize_with(r + 1, Histo::new);
                    }
                    for &lat in subs {
                        m.submission_latency[r].record(lat);
                    }
                }
                if m.phase_virtual.is_empty() {
                    m.phase_virtual.resize_with(Phase::ALL.len(), Histo::new);
                    m.phase_wall.resize_with(Phase::ALL.len(), Histo::new);
                }
                for span in &spans.spans {
                    let i = span.phase.index();
                    m.phase_virtual[i].record(span.virtual_s);
                    m.phase_wall[i].record(span.wall_s);
                }
            }
            RunEvent::CheckpointWritten { .. } => m.checkpoints_written_total += 1,
            RunEvent::FaultInjected { .. } => m.faults_injected_total += 1,
            RunEvent::RunFinished { .. } => m.finished = true,
        }
        Ok(())
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let Ok(stream) = stream else { continue };
        let shared = Arc::clone(&shared);
        let _ = std::thread::Builder::new()
            .name("ops-conn".to_string())
            .spawn(move || {
                let _ = handle_connection(stream, shared);
            });
    }
}

fn handle_connection(stream: TcpStream, shared: Arc<Shared>) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut first = String::new();
    if reader.read_line(&mut first)? == 0 {
        return Ok(());
    }
    if let Some(request) = first.strip_prefix("GET ") {
        // HTTP mode: drain the header block, answer one scrape, close.
        let target = request.split_whitespace().next().unwrap_or("/");
        let (path, query) = match target.split_once('?') {
            Some((p, q)) => (p, q),
            None => (target, ""),
        };
        let mut line = String::new();
        loop {
            line.clear();
            if reader.read_line(&mut line)? == 0 || line == "\r\n" || line == "\n" {
                break;
            }
        }
        if let Some(tok) = &shared.token {
            let authed = query
                .split('&')
                .any(|kv| kv.strip_prefix("token=") == Some(tok.as_str()));
            if !authed {
                return http_respond(
                    &mut writer,
                    "401 Unauthorized",
                    "missing or wrong token: scrape /metrics?token=TOKEN\n",
                );
            }
        }
        return match path {
            "/metrics" => {
                let body = render_metrics(&shared.metrics.lock().unwrap());
                http_respond(&mut writer, "200 OK", &body)
            }
            _ => http_respond(&mut writer, "404 Not Found", "try /metrics\n"),
        };
    }

    // Control mode: one command per line until `quit` or EOF. With a
    // token configured, the session's first line must authenticate.
    let mut line = first;
    if let Some(tok) = &shared.token {
        let authed = match line.trim().split_once(char::is_whitespace) {
            Some(("auth", rest)) => rest.trim() == tok,
            _ => false,
        };
        if !authed {
            writer.write_all(b"err auth required: first line must be 'auth TOKEN'\n")?;
            writer.flush()?;
            return Ok(());
        }
        writer.write_all(b"ok authenticated\n")?;
        writer.flush()?;
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
    }
    loop {
        let reply = match parse_command(line.trim()) {
            ParsedLine::Empty => None,
            ParsedLine::Quit => return Ok(()),
            ParsedLine::Err(msg) => Some(format!("err {msg}")),
            ParsedLine::Cmd(cmd) => Some(dispatch(&shared, cmd)),
        };
        if let Some(reply) = reply {
            writer.write_all(reply.as_bytes())?;
            writer.write_all(b"\n")?;
            writer.flush()?;
        }
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(());
        }
    }
}

/// Queue a command for the driver and wait for its reply line.
fn dispatch(shared: &Shared, cmd: Command) -> String {
    let (reply_tx, reply_rx) = mpsc::channel();
    let sent = {
        let tx = shared.cmd_tx.lock().unwrap().clone();
        tx.send(OpsRequest {
            cmd,
            reply: reply_tx,
        })
    };
    if sent.is_err() {
        return "err no active run (driver detached)".to_string();
    }
    match reply_rx.recv() {
        Ok(line) => line,
        // The driver dropped the queue (run ended) with our command
        // still pending.
        Err(_) => "err run ended before the command was serviced".to_string(),
    }
}

enum ParsedLine {
    Empty,
    Quit,
    Cmd(Command),
    Err(String),
}

fn parse_command(line: &str) -> ParsedLine {
    if line.is_empty() {
        return ParsedLine::Empty;
    }
    let (verb, rest) = match line.split_once(char::is_whitespace) {
        Some((v, r)) => (v, r.trim()),
        None => (line, ""),
    };
    match verb {
        "quit" => ParsedLine::Quit,
        "status" => ParsedLine::Cmd(Command::Status),
        "pause" => ParsedLine::Cmd(Command::Pause),
        "resume" => ParsedLine::Cmd(Command::Resume),
        "checkpoint-now" => ParsedLine::Cmd(Command::CheckpointNow {
            dir: (!rest.is_empty()).then(|| std::path::PathBuf::from(rest)),
        }),
        "auth" => ParsedLine::Err(
            "unexpected auth: it is only accepted as a session's first line, and only \
             when the server was started with a token (--ops-token)"
                .to_string(),
        ),
        "inject" => match Json::parse(rest).and_then(|j| FaultEvent::from_json(&j)) {
            Ok(event) => ParsedLine::Cmd(Command::Inject(event)),
            Err(e) => ParsedLine::Err(format!("bad inject payload: {e:#}")),
        },
        other => ParsedLine::Err(format!(
            "unknown command '{other}' (commands: status, pause, resume, \
             checkpoint-now [DIR], inject JSON, quit)"
        )),
    }
}

fn http_respond(writer: &mut TcpStream, status: &str, body: &str) -> std::io::Result<()> {
    write!(
        writer,
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    writer.flush()
}

/// Render the Prometheus text exposition. Gauges come from the shared
/// round-boundary state; the arena peak and RSS are read live at scrape
/// time (they are process-level observables, not round aggregates).
fn render_metrics(m: &MetricsState) -> String {
    let mut out = String::with_capacity(1024);
    let mut gauge = |name: &str, help: &str, value: String| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {value}\n"
        ));
    };
    gauge(
        "hybridfl_round",
        "Rounds completed so far.",
        m.round.to_string(),
    );
    gauge(
        "hybridfl_paused",
        "1 while the run is paused at a round boundary.",
        u8::from(m.paused).to_string(),
    );
    gauge(
        "hybridfl_finished",
        "1 once the run has produced its final result.",
        u8::from(m.finished).to_string(),
    );
    gauge(
        "hybridfl_accuracy",
        "Global-model accuracy at the last evaluation.",
        m.accuracy.to_string(),
    );
    gauge(
        "hybridfl_best_accuracy",
        "Best global-model accuracy so far.",
        m.best_accuracy.to_string(),
    );
    gauge(
        "hybridfl_arena_models_peak",
        "Peak count of live model buffers in the params arena.",
        crate::model::arena_peak().to_string(),
    );
    if let Some(rss) = crate::benchkit::peak_rss_bytes() {
        gauge(
            "hybridfl_peak_rss_bytes",
            "Peak resident set size of this process (VmHWM).",
            rss.to_string(),
        );
    }

    let mut counter = |name: &str, help: &str, value: u64| {
        out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} counter\n{name} {value}\n"
        ));
    };
    counter(
        "hybridfl_bytes_moved_total",
        "Cumulative device-to-edge bytes moved (folded submissions x wire bytes).",
        m.bytes_moved_total,
    );
    counter(
        "hybridfl_quota_rounds_total",
        "Rounds whose cutoff policy was satisfied before the deadline.",
        m.quota_rounds_total,
    );
    counter(
        "hybridfl_deadline_rounds_total",
        "Rounds cut by the T_lim deadline instead of the cutoff policy.",
        m.deadline_rounds_total,
    );
    counter(
        "hybridfl_checkpoints_written_total",
        "Snapshots written (scheduled + checkpoint-now).",
        m.checkpoints_written_total,
    );
    counter(
        "hybridfl_faults_injected_total",
        "Churn fault events injected over the control interface.",
        m.faults_injected_total,
    );

    let mut region_gauge = |name: &str, help: &str, values: &[f64]| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
        for (r, v) in values.iter().enumerate() {
            out.push_str(&format!("{name}{{region=\"{r}\"}} {v}\n"));
        }
    };
    region_gauge(
        "hybridfl_region_availability",
        "Per-region mean availability after this round's churn step.",
        &m.avail,
    );
    region_gauge(
        "hybridfl_region_selected_proportion",
        "Selected clients this round as a fraction of the region's fleet.",
        &m.selected_proportion,
    );
    if let Some(theta) = &m.slack_theta {
        region_gauge(
            "hybridfl_region_slack_theta",
            "HybridFL slack estimate (theta-hat) per region.",
            theta,
        );
    }

    if m.attached {
        out.push_str(&format!(
            "# HELP hybridfl_run_info Static run labels.\n\
             # TYPE hybridfl_run_info gauge\n\
             hybridfl_run_info{{backend=\"{}\",protocol=\"{}\"}} 1\n",
            m.backend, m.protocol
        ));
    }

    // Histogram families, accumulated over the whole run from the span
    // stream (env contract point 8: virtual durations are
    // protocol-visible, wall time is profiling-only). Families appear
    // once the first round has closed.
    let histo_header = |out: &mut String, name: &str, help: &str| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
    };
    if !m.round_length.is_empty() {
        histo_header(
            &mut out,
            "hybridfl_round_length_seconds",
            "Virtual-clock round length distribution.",
        );
        m.round_length
            .render_into(&mut out, "hybridfl_round_length_seconds", "");
    }
    if m.submission_latency.iter().any(|h| !h.is_empty()) {
        histo_header(
            &mut out,
            "hybridfl_submission_latency_seconds",
            "Per-region in-time submission latency (virtual seconds from round start).",
        );
        for (r, h) in m.submission_latency.iter().enumerate() {
            if !h.is_empty() {
                h.render_into(
                    &mut out,
                    "hybridfl_submission_latency_seconds",
                    &format!("region=\"{r}\""),
                );
            }
        }
    }
    if m.phase_virtual.iter().any(|h| !h.is_empty()) {
        histo_header(
            &mut out,
            "hybridfl_phase_duration_seconds",
            "Per-phase virtual-clock duration (protocol-visible).",
        );
        for (p, h) in Phase::ALL.iter().zip(m.phase_virtual.iter()) {
            if !h.is_empty() {
                h.render_into(
                    &mut out,
                    "hybridfl_phase_duration_seconds",
                    &format!("phase=\"{}\"", p.as_str()),
                );
            }
        }
    }
    if m.phase_wall.iter().any(|h| !h.is_empty()) {
        histo_header(
            &mut out,
            "hybridfl_phase_wall_seconds",
            "Per-phase host wall time (profiling-only, non-deterministic).",
        );
        for (p, h) in Phase::ALL.iter().zip(m.phase_wall.iter()) {
            if !h.is_empty() {
                h.render_into(
                    &mut out,
                    "hybridfl_phase_wall_seconds",
                    &format!("phase=\"{}\"", p.as_str()),
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_commands() {
        assert!(matches!(parse_command(""), ParsedLine::Empty));
        assert!(matches!(parse_command("quit"), ParsedLine::Quit));
        assert!(matches!(
            parse_command("status"),
            ParsedLine::Cmd(Command::Status)
        ));
        assert!(matches!(
            parse_command("checkpoint-now"),
            ParsedLine::Cmd(Command::CheckpointNow { dir: None })
        ));
        match parse_command("checkpoint-now /tmp/ckpts") {
            ParsedLine::Cmd(Command::CheckpointNow { dir: Some(d) }) => {
                assert_eq!(d, std::path::PathBuf::from("/tmp/ckpts"));
            }
            _ => panic!("expected checkpoint-now with a dir"),
        }
        match parse_command(
            r#"inject {"kind":"region_blackout","region":1,"from_round":4,"until_round":9}"#,
        ) {
            ParsedLine::Cmd(Command::Inject(FaultEvent::RegionBlackout {
                region,
                from_round,
                until_round,
            })) => {
                assert_eq!((region, from_round, until_round), (1, 4, 9));
            }
            _ => panic!("expected a parsed blackout"),
        }
        assert!(matches!(parse_command("inject {"), ParsedLine::Err(_)));
        assert!(matches!(parse_command("frobnicate"), ParsedLine::Err(_)));
        // `auth` is consumed by the session handshake, never by the
        // command loop — mid-session it is a helpful error.
        assert!(matches!(parse_command("auth s3cret"), ParsedLine::Err(_)));
    }

    #[test]
    fn non_loopback_bind_requires_a_token() {
        let err = OpsServer::bind_with_token("0.0.0.0:0", None).unwrap_err();
        assert!(
            format!("{err:#}").contains("--ops-token"),
            "refusal should name the fix: {err:#}"
        );
        // Same address with a token is fine …
        let with_token =
            OpsServer::bind_with_token("0.0.0.0:0", Some("s3cret".to_string())).unwrap();
        drop(with_token);
        // … and loopback never needs one.
        let loopback = OpsServer::bind("127.0.0.1:0").unwrap();
        drop(loopback);
    }

    #[test]
    fn render_includes_required_gauges() {
        let mut m = MetricsState {
            attached: true,
            backend: "sim".into(),
            protocol: "hybridfl".into(),
            region_sizes: vec![10, 10],
            round: 7,
            avail: vec![0.75, 0.5],
            selected_proportion: vec![0.3, 0.2],
            slack_theta: Some(vec![1.5, 2.0]),
            bytes_moved_total: 4096,
            quota_rounds_total: 6,
            deadline_rounds_total: 1,
            ..MetricsState::default()
        };
        m.accuracy = 0.5;
        let text = render_metrics(&m);
        for needle in [
            "hybridfl_round 7\n",
            "hybridfl_region_availability{region=\"0\"} 0.75\n",
            "hybridfl_region_availability{region=\"1\"} 0.5\n",
            "hybridfl_region_selected_proportion{region=\"0\"} 0.3\n",
            "hybridfl_region_slack_theta{region=\"1\"} 2\n",
            "hybridfl_bytes_moved_total 4096\n",
            "hybridfl_quota_rounds_total 6\n",
            "hybridfl_deadline_rounds_total 1\n",
            "hybridfl_arena_models_peak ",
            "hybridfl_run_info{backend=\"sim\",protocol=\"hybridfl\"} 1\n",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // No rounds closed yet ⇒ no histogram families.
        assert!(!text.contains("histogram"), "{text}");
    }

    #[test]
    fn render_includes_histogram_families_once_rounds_closed() {
        let mut m = MetricsState::default();
        m.round_length.record(64.0);
        m.round_length.record(100.0);
        m.submission_latency.resize_with(2, Histo::new);
        m.submission_latency[1].record(2.0);
        m.phase_virtual.resize_with(Phase::ALL.len(), Histo::new);
        m.phase_wall.resize_with(Phase::ALL.len(), Histo::new);
        m.phase_virtual[Phase::TrainFold.index()].record(64.0);
        m.phase_wall[Phase::CloudAgg.index()].record(0.001);
        let text = render_metrics(&m);
        for needle in [
            "# TYPE hybridfl_round_length_seconds histogram\n",
            "hybridfl_round_length_seconds_bucket{le=\"64\"} 1\n",
            "hybridfl_round_length_seconds_bucket{le=\"128\"} 2\n",
            "hybridfl_round_length_seconds_bucket{le=\"+Inf\"} 2\n",
            "hybridfl_round_length_seconds_sum 164\n",
            "hybridfl_round_length_seconds_count 2\n",
            "# TYPE hybridfl_submission_latency_seconds histogram\n",
            "hybridfl_submission_latency_seconds_bucket{region=\"1\",le=\"2\"} 1\n",
            "hybridfl_submission_latency_seconds_count{region=\"1\"} 1\n",
            "# TYPE hybridfl_phase_duration_seconds histogram\n",
            "hybridfl_phase_duration_seconds_bucket{phase=\"train_fold\",le=\"64\"} 1\n",
            "hybridfl_phase_duration_seconds_sum{phase=\"train_fold\"} 64\n",
            "# TYPE hybridfl_phase_wall_seconds histogram\n",
            "hybridfl_phase_wall_seconds_count{phase=\"cloud_agg\"} 1\n",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Empty regions/phases are elided, not rendered as zero series.
        assert!(!text.contains("region=\"0\""), "{text}");
        assert!(!text.contains("phase=\"selection\""), "{text}");
    }
}
