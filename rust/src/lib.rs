//! # HybridFL — federated learning over reliability-agnostic clients in MEC
//!
//! Production-grade reproduction of *Wu, He, Lin, Mao — "Accelerating
//! Federated Learning over Reliability-Agnostic Clients in Mobile Edge
//! Computing Systems"* (IEEE TPDS 2020, DOI 10.1109/TPDS.2020.3040867).
//!
//! The crate is the **L3 coordinator** of a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels — fused dense
//!   matmul+bias+activation and row-wise softmax-NLL — the compute hot-spot.
//! * **L2** (`python/compile/model.py`): the paper's two on-device workloads
//!   (Aerofoil FCN, MNIST LeNet-5) as JAX train/eval graphs calling the L1
//!   kernels, AOT-lowered once to HLO text by `python/compile/aot.py`.
//! * **L3** (this crate): everything the paper's evaluation needs — the
//!   HybridFL protocol (regional slack factors, quota-triggered regional
//!   aggregation, EDC-weighted immediate cloud aggregation, model caching),
//!   the FedAvg/HierFAVG baselines, the MEC timing/energy/reliability
//!   simulator, a PJRT runtime that executes the AOT artifacts, a live
//!   threaded cloud/edge/client runtime, metrics and the experiment harness
//!   regenerating every table and figure of the paper.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! Python invocation, after which the `hybridfl` binary is self-contained.
//!
//! ## Quick tour
//!
//! Experiments are described by a [`scenario::Scenario`] — what to run —
//! and a [`scenario::Backend`] — where to run it. The same protocol
//! implementation executes on every backend; only the substrate changes.
//!
//! ```no_run
//! use hybridfl::config::ProtocolKind;
//! use hybridfl::scenario::{Backend, Scenario};
//!
//! // Scaled-down Task 1 (Aerofoil), HybridFL, 30% drop-out, on the
//! // deterministic virtual clock:
//! let result = Scenario::task1()
//!     .protocol(ProtocolKind::HybridFl)
//!     .dropout(0.3)
//!     .seed(42)
//!     .run()
//!     .unwrap();
//! println!("best accuracy: {:.3}", result.summary.best_accuracy);
//!
//! // The identical protocol on the live threaded cloud/edge/client
//! // cluster (mock numerics, real concurrency) — same RunResult shape:
//! let live = Scenario::task1()
//!     .protocol(ProtocolKind::HybridFl)
//!     .dropout(0.3)
//!     .seed(42)
//!     .rounds(10)
//!     .backend(Backend::Live)
//!     .run()
//!     .unwrap();
//! println!("live best accuracy: {:.3}", live.summary.best_accuracy);
//! ```
//!
//! The world need not stand still: the [`churn`] subsystem layers
//! time-varying reliability on top of the sampled fleet — Markov bursty
//! availability, diurnal drop-out cycles, battery depletion, and scripted
//! fault events (regional blackouts, drop-out step changes, bandwidth
//! degradation, client mobility), composable and deterministic in the
//! seed. Any run's ground-truth per-round fates can be exported as a JSON
//! [`churn::FateTrace`] and replayed as a scenario of its own (including
//! hand-written traces). Protocols observe none of this directly — only
//! submission counts — so the paper's reliability-agnosticism contract
//! survives a churning world, which is exactly what the dynamic Fig. 2
//! scenarios stress-test.
//!
//! ```no_run
//! use hybridfl::churn::{ChurnModel, FaultEvent};
//! use hybridfl::scenario::Scenario;
//!
//! // Bursty availability plus a scripted blackout of region 1 during
//! // rounds 40..60; record the ground truth for later replay.
//! let result = Scenario::task1()
//!     .mock()
//!     .churn(ChurnModel::Composed {
//!         layers: vec![
//!             ChurnModel::MarkovOnOff {
//!                 p_fail: 0.05,
//!                 p_recover: 0.25,
//!                 down_dropout: 0.95,
//!                 region_scale: vec![],
//!             },
//!             ChurnModel::FaultScript {
//!                 events: vec![FaultEvent::RegionBlackout {
//!                     region: 1,
//!                     from_round: 40,
//!                     until_round: 60,
//!                 }],
//!             },
//!         ],
//!     })
//!     .record_fates("fates.json")
//!     .run()?;
//! // Replaying the trace reproduces the run exactly (fixed point):
//! let replayed = Scenario::task1().mock().replay_fates("fates.json").run()?;
//! # let _ = (result, replayed);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! On the CLI this is `--churn markov:p_fail=0.1+script:events.json`,
//! `--record-fates trace.json` and `--replay-fates trace.json`.
//!
//! Client selection itself is pluggable — the [`selection`] zoo: the
//! paper's slack estimator (default, byte-identical to the pre-zoo
//! behavior), a FedCS-style deadline-aware ranker, a uniform-random
//! control, and a ground-truth oracle that lower-bounds the round length
//! (sim-only: the live backend rejects it loudly). `harness::matrix`
//! runs the full scenario × protocol × selector grid over adversarial
//! churn compositions.
//!
//! ```no_run
//! # use hybridfl::scenario::Scenario;
//! use hybridfl::selection::SelectorKind;
//!
//! // How close does the slack estimator get to cheating foresight?
//! let slack = Scenario::task1().mock().run()?;
//! let bound = Scenario::task1()
//!     .mock()
//!     .selector(SelectorKind::Oracle)
//!     .run()?;
//! println!(
//!     "slack {:.1}s vs oracle bound {:.1}s per round",
//!     slack.summary.avg_round_len,
//!     bound.summary.avg_round_len
//! );
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! On the CLI this is `--selector slack|fedcs|oracle|random`.
//!
//! Submissions need not be dense: the [`comm`] subsystem frames each
//! device→edge upload through an [`comm::UpdateCodec`] — stochastic f16
//! or i8 quantization, or top-k sparsification with per-client
//! error-feedback residuals — and the timing/energy models charge the
//! *encoded* frame's exact bytes, so compression directly shortens
//! rounds and cuts device energy. A relay axis hands the weakest
//! clients' frames to their region's fastest peer. Dense (the default)
//! is byte-identical to the pre-codec behavior.
//!
//! ```no_run
//! # use hybridfl::scenario::Scenario;
//! use hybridfl::comm::CommConfig;
//!
//! // Top-5% sparsification with error feedback, plus relaying the
//! // slowest quarter of each region through its fastest peer:
//! let compressed = Scenario::task1()
//!     .mock()
//!     .comm(CommConfig::parse_spec("topk:0.05+ef")?)
//!     .relay(0.25)
//!     .run()?;
//! let dense = Scenario::task1().mock().run()?;
//! println!(
//!     "round {:.1}s vs dense {:.1}s, bytes/round {} vs {}",
//!     compressed.summary.avg_round_len,
//!     dense.summary.avg_round_len,
//!     compressed.rounds[0].bytes_moved,
//!     dense.rounds[0].bytes_moved,
//! );
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! On the CLI this is `--comm topk:0.05+ef+relay:0.25` (or `f16`, `i8`,
//! `dense`); every round's `bytes_moved` lands in the CSV and the
//! `comm_tradeoff` bench sweeps codec × protocol into `BENCH_comm.json`.
//!
//! Long runs survive coordinator interruption: give the scenario a
//! checkpoint directory and every round boundary writes a versioned
//! binary [`snapshot::RunSnapshot`] (round index, global/regional models,
//! RNG streams, slack-estimator state, churn-process state, metric
//! accumulators, config fingerprint); a later process resumes it to a
//! **byte-identical**
//! [`env::RunResult`] on either backend. Resuming against a different
//! config is a hard error naming the diverging fields. On the CLI this is
//! `--checkpoint-dir DIR [--checkpoint-every N]` and `--resume FILE`.
//!
//! ```no_run
//! # use hybridfl::scenario::Scenario;
//! let partial = Scenario::task1().mock().checkpoint_dir("ckpts").run()?;
//! // ...process dies; a new one picks up at round 250:
//! let resumed = Scenario::task1()
//!     .mock()
//!     .resume_from("ckpts/snapshot_round_000250.hflsnap")
//!     .run()?;
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! And a *running* experiment is not a black box: the [`ops`] control
//! plane serves a Prometheus-text `/metrics` scrape (round index,
//! per-region availability / selected proportion / slack θ̂, arena peak,
//! peak RSS, `bytes_moved`, quota/deadline counters) and a line-oriented
//! control socket (`pause` / `resume` / `checkpoint-now` / live
//! `inject`) on one std TCP listener. Under the hood both are
//! [`ops::RunObserver`]s on the driver's typed round-boundary event
//! stream — the same stream [`metrics::ReportSink`] turns into CSV/JSON
//! artifacts — and observers see only protocol-visible aggregates, so
//! reliability-agnosticism holds on the wire (env contract point 8).
//!
//! Distributions, not just gauges: every round phase (selection, churn
//! step, fate draw, train+fold, regional/cloud aggregation, checkpoint)
//! is bracketed by a [`trace`] span on both backends, and the scrape
//! exposes Prometheus **histograms** — round length, per-region
//! submission latency, per-phase duration (virtual-clock seconds,
//! protocol-visible) and per-phase wall time (profiling-only, never
//! fingerprinted) — built on the no-deps log₂-bucket [`trace::Histo`].
//! `--trace-out FILE` / [`trace::TraceWriter`] additionally emits a
//! Chrome trace-event JSON (one complete event per span, pid = region)
//! loadable in Perfetto for flamegraph-style round profiling. None of
//! it perturbs the run: a traced, ops-attached run stays byte-identical
//! to a plain one.
//!
//! ```no_run
//! # use hybridfl::scenario::Scenario;
//! // Serve /metrics and the control socket on port 9184 while running:
//! let result = Scenario::task1()
//!     .mock()
//!     .checkpoint_dir("ckpts")
//!     .ops_listen("127.0.0.1:9184")
//!     .run()?;
//! // Meanwhile:   curl -s http://127.0.0.1:9184/metrics
//! //              printf 'pause\n' | nc 127.0.0.1 9184   (etc.)
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! On the CLI this is `--ops-listen 127.0.0.1:9184`; see the README's
//! "Operating a run" section for scrape and control transcripts.
//!
//! The layering underneath, for code that needs more control:
//!
//! * [`env`] — the [`env::FlEnvironment`] backend trait and its two
//!   implementations ([`env::VirtualClockEnv`], [`env::LiveClusterEnv`]),
//!   plus the generic [`env::run_to_completion`] driver.
//! * [`protocols`] — FedAvg / HierFAVG / HybridFL, each written once
//!   against the trait.
//! * [`harness`] — the paper's tables and figures; the Table III/IV sweep
//!   runs its independent grid cells on scoped worker threads.
//! * [`snapshot`] — the checkpoint/replay subsystem: the
//!   [`snapshot::SnapshotCodec`] trait with binary and JSON codecs, and
//!   the resume plumbing ([`env::run_resumable`]) the scenario wraps.
//!
//! ## The data plane, in one paragraph
//!
//! [`model::ModelParams`] stores every tensor in one contiguous f32 arena
//! (offset table per tensor, `Arc`-shared with copy-on-write, so a
//! broadcast clone is two refcount bumps), and the aggregation hot loops
//! are chunked flat-slice kernels over that arena. Rounds **stream**:
//! both backends fold each in-time submission into its region's
//! [`aggregation::RegionAccumulator`] the moment it arrives — at the edge
//! threads on the live cluster, in completion-time order on the virtual
//! clock — so peak resident model state per round is O(regions), not
//! O(selected clients), and a 10⁵-client round costs the same model
//! memory as a 10²-client one (see `tests/large_fleet.rs` and
//! `benches/params_hotpath.rs`). Encoded submissions keep that
//! guarantee: a compressed frame decodes **into** the accumulator
//! ([`aggregation::RegionAccumulator::fold_encoded`]) without ever
//! materializing an intermediate dense model.
//!
//! The *fleet* side scales the same way: device parameters live in a
//! struct-of-arrays [`devices::FleetState`] (flat `f64` arrays indexed by
//! client id) rather than a `Vec` of profile structs, per-round fate and
//! selection draws touch only the **selected** clients (sparse
//! Fisher–Yates in [`rng::Rng::sample_indices`], byte-identical to the
//! dense shuffle), churn resets rewrite only the regions the round's
//! events touched ([`churn::Touched`]), and the virtual clock fans the
//! per-region train→fold work across scoped worker threads when the
//! engine permits — so a round's cost tracks O(selected + regions), and
//! a **million-client** fleet completes rounds in seconds within a flat
//! memory ceiling (see `tests/scale_identity.rs` for the byte-identity
//! pins and `benches/scale_fleet.rs` for the 100k/500k/1M ladder).

pub mod aggregation;
pub mod benchkit;
pub mod churn;
pub mod cli;
pub mod comm;
pub mod config;
pub mod data;
pub mod devices;
pub mod energy;
pub mod env;
pub mod harness;
pub mod jsonx;
pub mod live;
pub mod metrics;
pub mod model;
pub mod ops;
pub mod protocols;
pub mod rng;
pub mod runtime;
pub mod scenario;
pub mod selection;
pub mod sim;
pub mod snapshot;
pub mod timing;
pub mod topology;
pub mod trace;

/// Crate-wide result alias (anyhow-based; the coordinator is an application
/// stack, not a library with typed error recovery).
pub type Result<T> = anyhow::Result<T>;
