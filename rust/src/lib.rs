//! # HybridFL — federated learning over reliability-agnostic clients in MEC
//!
//! Production-grade reproduction of *Wu, He, Lin, Mao — "Accelerating
//! Federated Learning over Reliability-Agnostic Clients in Mobile Edge
//! Computing Systems"* (IEEE TPDS 2020, DOI 10.1109/TPDS.2020.3040867).
//!
//! The crate is the **L3 coordinator** of a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas kernels — fused dense
//!   matmul+bias+activation and row-wise softmax-NLL — the compute hot-spot.
//! * **L2** (`python/compile/model.py`): the paper's two on-device workloads
//!   (Aerofoil FCN, MNIST LeNet-5) as JAX train/eval graphs calling the L1
//!   kernels, AOT-lowered once to HLO text by `python/compile/aot.py`.
//! * **L3** (this crate): everything the paper's evaluation needs — the
//!   HybridFL protocol (regional slack factors, quota-triggered regional
//!   aggregation, EDC-weighted immediate cloud aggregation, model caching),
//!   the FedAvg/HierFAVG baselines, the MEC timing/energy/reliability
//!   simulator, a PJRT runtime that executes the AOT artifacts, a live
//!   threaded cloud/edge/client runtime, metrics and the experiment harness
//!   regenerating every table and figure of the paper.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! Python invocation, after which the `hybridfl` binary is self-contained.
//!
//! ## Quick tour
//!
//! ```no_run
//! use hybridfl::config::ExperimentConfig;
//! use hybridfl::sim::FlRun;
//!
//! // Scaled-down Task 1 (Aerofoil) preset, HybridFL protocol.
//! let mut cfg = ExperimentConfig::task1_scaled();
//! cfg.protocol = hybridfl::config::ProtocolKind::HybridFl;
//! let result = FlRun::new(cfg).unwrap().run().unwrap();
//! println!("best accuracy: {:.3}", result.summary.best_accuracy);
//! ```

pub mod aggregation;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod data;
pub mod devices;
pub mod energy;
pub mod harness;
pub mod jsonx;
pub mod live;
pub mod metrics;
pub mod model;
pub mod protocols;
pub mod rng;
pub mod runtime;
pub mod selection;
pub mod sim;
pub mod timing;
pub mod topology;

/// Crate-wide result alias (anyhow-based; the coordinator is an application
/// stack, not a library with typed error recovery).
pub type Result<T> = anyhow::Result<T>;
