//! Padded fixed-shape batch construction.
//!
//! The AOT train/eval graphs are static-shaped: capacity-P inputs plus a
//! {0,1} mask (see DESIGN.md §Key-design-decisions). This module turns a
//! client's partition (index list into the training corpus) or an eval
//! chunk into `(x, y, mask)` buffers of exactly the bucket capacity.

use crate::data::Dataset;

/// A padded training/eval batch matching one artifact bucket.
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub mask: Vec<f32>,
    /// The bucket capacity P (rows in x/y/mask).
    pub capacity: usize,
    /// Number of real (unmasked) samples.
    pub n_real: usize,
}

/// Build a padded batch for `indices` of `data` at `capacity`. If the
/// partition exceeds the capacity the first `capacity` samples are used
/// (the bucket picker only lets this happen when the partition exceeds the
/// largest compiled bucket).
pub fn build(data: &Dataset, indices: &[usize], capacity: usize) -> Batch {
    let f = data.feat_len();
    let n_real = indices.len().min(capacity);
    let mut x = vec![0.0f32; capacity * f];
    let mut y = vec![0.0f32; capacity];
    let mut mask = vec![0.0f32; capacity];
    for (row, &i) in indices.iter().take(n_real).enumerate() {
        x[row * f..(row + 1) * f].copy_from_slice(data.row(i));
        y[row] = data.y[i];
        mask[row] = 1.0;
    }
    Batch {
        x,
        y,
        mask,
        capacity,
        n_real,
    }
}

/// Iterate a dataset in padded chunks of `capacity` (evaluation path).
pub fn chunks(data: &Dataset, capacity: usize) -> impl Iterator<Item = Batch> + '_ {
    let n = data.n;
    (0..n.div_ceil(capacity)).map(move |c| {
        let lo = c * capacity;
        let hi = ((c + 1) * capacity).min(n);
        let indices: Vec<usize> = (lo..hi).collect();
        build(data, &indices, capacity)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(n: usize) -> Dataset {
        Dataset {
            x: (0..n * 2).map(|v| v as f32).collect(),
            y: (0..n).map(|v| v as f32 * 10.0).collect(),
            feature_dims: vec![2],
            n,
        }
    }

    #[test]
    fn pads_and_masks() {
        let d = data(3);
        let b = build(&d, &[2, 0], 4);
        assert_eq!(b.capacity, 4);
        assert_eq!(b.n_real, 2);
        assert_eq!(b.mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(&b.x[0..2], &[4.0, 5.0]); // sample 2
        assert_eq!(&b.x[2..4], &[0.0, 1.0]); // sample 0
        assert_eq!(&b.x[4..], &[0.0, 0.0, 0.0, 0.0]); // padding zeroed
        assert_eq!(b.y[0], 20.0);
        assert_eq!(b.y[2], 0.0);
    }

    #[test]
    fn truncates_oversized_partitions() {
        let d = data(10);
        let idx: Vec<usize> = (0..10).collect();
        let b = build(&d, &idx, 4);
        assert_eq!(b.n_real, 4);
        assert_eq!(b.mask.iter().sum::<f32>(), 4.0);
    }

    #[test]
    fn chunks_cover_dataset() {
        let d = data(10);
        let cs: Vec<Batch> = chunks(&d, 4).collect();
        assert_eq!(cs.len(), 3);
        assert_eq!(cs[0].n_real, 4);
        assert_eq!(cs[1].n_real, 4);
        assert_eq!(cs[2].n_real, 2);
        let total: f32 = cs.iter().map(|b| b.mask.iter().sum::<f32>()).sum();
        assert_eq!(total, 10.0);
        // Last chunk's first row is sample 8.
        assert_eq!(cs[2].y[0], 80.0);
    }
}
