//! Compute runtime (S10): executes local training and global evaluation.
//!
//! Two implementations of the [`Engine`] trait:
//!
//! * [`pjrt::PjrtEngine`] — the real thing: loads the AOT HLO-text
//!   artifacts, compiles them once on the PJRT CPU client, and executes
//!   train/eval calls from the coordinator hot path. Python is never
//!   involved.
//! * [`mock::MockEngine`] — an analytic learning-curve proxy for
//!   protocol-dynamics experiments (Fig. 2), property tests and fast smoke
//!   runs. Same trait, no artifacts required.

pub mod batch;
pub mod mock;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use crate::config::{EngineKind, ExperimentConfig};
use crate::data::FederatedData;
use crate::model::ModelParams;
use crate::Result;

/// Global-model evaluation result on the held-out set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    /// Task loss (MSE for Aerofoil, mean NLL for MNIST).
    pub loss: f64,
    /// Task accuracy: classification accuracy for MNIST; the bounded
    /// regression score `1 − MAE/MAD` for Aerofoil (paper reports Aerofoil
    /// "accuracy" on the same ~0.73 scale).
    pub accuracy: f64,
    /// Number of evaluated samples.
    pub n: f64,
}

/// Outcome of one client's local training.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub params: ModelParams,
    /// Training loss before the final epoch's step (the paper logs local
    /// loss for diagnostics only).
    pub loss: f64,
}

/// The compute interface the coordinator drives. One engine instance per
/// run; implementations may cache compiled executables and device buffers.
pub trait Engine {
    /// Initial global model w(0).
    fn init_params(&self) -> ModelParams;

    /// Run `epochs` full-batch GD epochs for one client, starting from
    /// `start`, on the samples `indices` of the training corpus.
    fn train_local(
        &mut self,
        start: &ModelParams,
        indices: &[usize],
        epochs: usize,
        lr: f32,
    ) -> Result<TrainOutcome>;

    /// Evaluate a model on the held-out test set.
    fn evaluate(&mut self, params: &ModelParams) -> Result<EvalResult>;

    /// Engine label for logs/reports.
    fn name(&self) -> &'static str;
}

/// True when the real-training PJRT path can actually run: the crate was
/// built with the `pjrt` feature *and* the AOT artifacts are on disk.
/// Examples, benches and the e2e tests use this to decide between real
/// training and the mock fallback / skip.
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt") && std::path::Path::new("artifacts/manifest.json").exists()
}

/// Construct the engine selected by the config. The federated data is
/// shared with the engine so batches can be built on demand.
///
/// The PJRT path requires the `pjrt` cargo feature (vendored `xla`
/// bindings); without it, selecting `EngineKind::Pjrt` is a runtime error
/// so the rest of the stack builds against the minimal offline dependency
/// set.
pub fn build_engine(
    cfg: &ExperimentConfig,
    data: std::sync::Arc<FederatedData>,
) -> Result<Box<dyn Engine>> {
    match cfg.engine {
        EngineKind::Pjrt => build_pjrt_engine(cfg, data),
        EngineKind::Mock => Ok(Box::new(mock::MockEngine::new(cfg, data))),
    }
}

#[cfg(feature = "pjrt")]
fn build_pjrt_engine(
    cfg: &ExperimentConfig,
    data: std::sync::Arc<FederatedData>,
) -> Result<Box<dyn Engine>> {
    Ok(Box::new(pjrt::PjrtEngine::new(cfg, data)?))
}

#[cfg(not(feature = "pjrt"))]
fn build_pjrt_engine(
    _cfg: &ExperimentConfig,
    _data: std::sync::Arc<FederatedData>,
) -> Result<Box<dyn Engine>> {
    anyhow::bail!(
        "engine 'pjrt' requires building with `--features pjrt` (vendored xla \
         bindings); use engine=mock, or rebuild with the feature enabled"
    )
}
