//! Compute runtime (S10): executes local training and global evaluation.
//!
//! Two implementations of the [`Engine`] trait:
//!
//! * [`pjrt::PjrtEngine`] — the real thing: loads the AOT HLO-text
//!   artifacts, compiles them once on the PJRT CPU client, and executes
//!   train/eval calls from the coordinator hot path. Python is never
//!   involved.
//! * [`mock::MockEngine`] — an analytic learning-curve proxy for
//!   protocol-dynamics experiments (Fig. 2), property tests and fast smoke
//!   runs. Same trait, no artifacts required.

pub mod batch;
pub mod mock;
pub mod pjrt;

use crate::config::{EngineKind, ExperimentConfig};
use crate::data::FederatedData;
use crate::model::ModelParams;
use crate::Result;

/// Global-model evaluation result on the held-out set.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    /// Task loss (MSE for Aerofoil, mean NLL for MNIST).
    pub loss: f64,
    /// Task accuracy: classification accuracy for MNIST; the bounded
    /// regression score `1 − MAE/MAD` for Aerofoil (paper reports Aerofoil
    /// "accuracy" on the same ~0.73 scale).
    pub accuracy: f64,
    /// Number of evaluated samples.
    pub n: f64,
}

/// Outcome of one client's local training.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    pub params: ModelParams,
    /// Training loss before the final epoch's step (the paper logs local
    /// loss for diagnostics only).
    pub loss: f64,
}

/// The compute interface the coordinator drives. One engine instance per
/// run; implementations may cache compiled executables and device buffers.
pub trait Engine {
    /// Initial global model w(0).
    fn init_params(&self) -> ModelParams;

    /// Run `epochs` full-batch GD epochs for one client, starting from
    /// `start`, on the samples `indices` of the training corpus.
    fn train_local(
        &mut self,
        start: &ModelParams,
        indices: &[usize],
        epochs: usize,
        lr: f32,
    ) -> Result<TrainOutcome>;

    /// Evaluate a model on the held-out test set.
    fn evaluate(&mut self, params: &ModelParams) -> Result<EvalResult>;

    /// Engine label for logs/reports.
    fn name(&self) -> &'static str;
}

/// Construct the engine selected by the config. The federated data is
/// shared with the engine so batches can be built on demand.
pub fn build_engine(
    cfg: &ExperimentConfig,
    data: std::sync::Arc<FederatedData>,
) -> Result<Box<dyn Engine>> {
    match cfg.engine {
        EngineKind::Pjrt => Ok(Box::new(pjrt::PjrtEngine::new(cfg, data)?)),
        EngineKind::Mock => Ok(Box::new(mock::MockEngine::new(cfg, data))),
    }
}
