//! Analytic mock engine — protocol dynamics without real training.
//!
//! Used by the Fig. 2 experiment (which studies only the slack-factor /
//! selection dynamics), by property tests that need thousands of rounds,
//! and by smoke runs. The "model" is a 2-scalar parameter vector:
//!
//! * `progress` — accumulated effective training (epochs × data fraction).
//!   Local training adds to it; aggregation (weighted averaging of
//!   [`ModelParams`]) mixes it exactly the way real weights mix, so the
//!   caching/EDC/selection logic is exercised unchanged.
//! * `noise` — a stand-in weight that drifts, giving `l2_distance` a
//!   nonzero value for diagnostics.
//!
//! Accuracy follows a saturating curve `acc_max · (1 − exp(−progress/k))`,
//! qualitatively matching an FL loss curve (fast early gains, plateau).

use std::sync::Arc;

use crate::config::{ExperimentConfig, TaskKind};
use crate::data::FederatedData;
use crate::model::ModelParams;
use crate::runtime::{Engine, EvalResult, TrainOutcome};
use crate::Result;

pub struct MockEngine {
    data: Arc<FederatedData>,
    mean_partition: f64,
    /// Accuracy plateau (task-flavored: ≈0.73 regression score for
    /// Aerofoil, ≈0.97 classification accuracy for MNIST).
    acc_max: f64,
    /// Progress scale of the saturating curve.
    k: f64,
    tau_ref: f64,
}

impl MockEngine {
    pub fn new(cfg: &ExperimentConfig, data: Arc<FederatedData>) -> MockEngine {
        MockEngine {
            data,
            mean_partition: cfg.mean_partition(),
            acc_max: match cfg.task {
                TaskKind::Aerofoil => 0.73,
                TaskKind::Mnist => 0.97,
            },
            k: 25.0,
            tau_ref: cfg.local_epochs as f64,
        }
    }

    fn accuracy(&self, progress: f64) -> f64 {
        self.acc_max * (1.0 - (-progress.max(0.0) / self.k).exp())
    }
}

impl Engine for MockEngine {
    fn init_params(&self) -> ModelParams {
        ModelParams::new(vec![vec![0.0, 0.0]], vec![vec![2]])
    }

    fn train_local(
        &mut self,
        start: &ModelParams,
        indices: &[usize],
        epochs: usize,
        lr: f32,
    ) -> Result<TrainOutcome> {
        let mut params = start.clone();
        // Effective work: epochs weighted by how much data the client holds
        // relative to the fleet average (a big-partition client moves the
        // model more, mirroring FedAvg weighting intuition).
        let data_frac = indices.len() as f64 / self.mean_partition.max(1.0);
        let gain = (epochs as f64 / self.tau_ref) * data_frac * (lr as f64 / lr.max(1e-9) as f64);
        let v = params.values_mut();
        v[0] += gain as f32;
        v[1] += 0.01 * gain as f32;
        let progress = v[0] as f64;
        let loss = 1.0 / (1.0 + progress); // monotone-decreasing proxy
        Ok(TrainOutcome { params, loss })
    }

    fn evaluate(&mut self, params: &ModelParams) -> Result<EvalResult> {
        let progress = params.values()[0] as f64;
        let acc = self.accuracy(progress);
        Ok(EvalResult {
            loss: 1.0 / (1.0 + progress),
            accuracy: acc,
            n: self.data.test.n as f64,
        })
    }

    fn name(&self) -> &'static str {
        "mock"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn engine() -> MockEngine {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.dataset_size = 200;
        cfg.eval_size = 50;
        cfg.n_clients = 4;
        let data = Arc::new(crate::data::build(&cfg, &mut Rng::new(1)));
        MockEngine::new(&cfg, data)
    }

    #[test]
    fn training_increases_accuracy_monotonically() {
        let mut eng = engine();
        let mut w = eng.init_params();
        let mut prev = eng.evaluate(&w).unwrap().accuracy;
        for _ in 0..10 {
            w = eng.train_local(&w, &(0..100).collect::<Vec<_>>(), 5, 1e-3).unwrap().params;
            let acc = eng.evaluate(&w).unwrap().accuracy;
            assert!(acc > prev);
            prev = acc;
        }
    }

    #[test]
    fn accuracy_saturates_below_max() {
        let mut eng = engine();
        let mut w = eng.init_params();
        w.values_mut()[0] = 1e6;
        let r = eng.evaluate(&w).unwrap();
        assert!(r.accuracy <= 0.73 + 1e-9);
        assert!(r.accuracy > 0.72);
    }

    #[test]
    fn aggregation_mixes_progress_like_weights() {
        let mut eng = engine();
        let w0 = eng.init_params();
        let idx: Vec<usize> = (0..100).collect();
        let fast = eng.train_local(&w0, &idx, 10, 1e-3).unwrap().params;
        let avg =
            crate::model::weighted_average(&[(&w0, 0.5), (&fast, 0.5)]).unwrap();
        let p = avg.values()[0];
        assert!(p > 0.0 && p < fast.values()[0]);
    }

    #[test]
    fn bigger_partitions_move_faster() {
        let mut eng = engine();
        let w0 = eng.init_params();
        let small = eng.train_local(&w0, &[0, 1], 5, 1e-3).unwrap().params;
        let big = eng.train_local(&w0, &(0..100).collect::<Vec<_>>(), 5, 1e-3).unwrap().params;
        assert!(big.values()[0] > small.values()[0]);
    }
}
