//! PJRT execution engine: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once per bucket on the PJRT CPU
//! client, and serves `train_local` / `evaluate` calls from the
//! coordinator hot path.
//!
//! Call discipline: the τ-epoch GD loop is baked into the train artifact
//! (`lax.fori_loop` with a runtime `epochs` scalar), so one client-round
//! costs exactly **one** PJRT execution — no host↔device round-trips
//! between local epochs. Outputs come back as a single tuple literal
//! (PJRT here does not untuple), decomposed on the host.
//!
//! Evaluation reuses pre-built test-set chunk literals (the test set is
//! static) and one executable; only the parameters change between calls.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context};
use xla::{ElementType, FromRawBytes, Literal, PjRtClient, PjRtLoadedExecutable};

use crate::config::{ExperimentConfig, TaskKind};
use crate::data::FederatedData;
use crate::model::{ModelParams, TaskManifest};
use crate::runtime::batch::{self, Batch};
use crate::runtime::{Engine, EvalResult, TrainOutcome};
use crate::Result;

pub struct PjrtEngine {
    #[allow(dead_code)]
    client: PjRtClient,
    manifest: TaskManifest,
    /// capacity -> compiled train executable.
    train_execs: HashMap<usize, PjRtLoadedExecutable>,
    eval_exec: PjRtLoadedExecutable,
    init: ModelParams,
    data: Arc<FederatedData>,
    task: TaskKind,
    /// MAD normalizer for the Aerofoil regression accuracy score.
    test_mad: f64,
    /// Pre-built (x, y, mask) literals per eval chunk.
    eval_chunk_lits: Vec<[Literal; 3]>,
    /// Scratch: number of PJRT executions served (perf telemetry).
    pub executions: u64,
}

impl PjrtEngine {
    pub fn new(cfg: &ExperimentConfig, data: Arc<FederatedData>) -> Result<PjrtEngine> {
        let art_dir = Path::new(&cfg.artifacts_dir);
        let manifest = TaskManifest::load(art_dir, cfg.task)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;

        let mut train_execs = HashMap::new();
        for (cap, path) in &manifest.train_buckets {
            train_execs.insert(*cap, compile(&client, path)?);
        }
        let (eval_capacity, eval_path) = manifest.eval_bucket();
        let eval_exec = compile(&client, eval_path)?;

        let init = load_init(&manifest)?;
        let test_mad = data.test.y_mad();
        let eval_chunk_lits = batch::chunks(&data.test, eval_capacity)
            .map(|c| batch_literals(&c, &manifest.x_dims))
            .collect::<Result<Vec<_>>>()?;

        Ok(PjrtEngine {
            client,
            manifest,
            train_execs,
            eval_exec,
            init,
            data,
            task: cfg.task,
            test_mad,
            eval_chunk_lits,
            executions: 0,
        })
    }

    fn params_to_literals(&self, params: &ModelParams) -> Result<Vec<Literal>> {
        params
            .tensors()
            .zip(params.shapes().iter())
            .map(|(t, s)| literal_f32(t, s))
            .collect()
    }
}

/// Build an f32 literal of the given logical shape from a host slice.
fn literal_f32(values: &[f32], shape: &[usize]) -> Result<Literal> {
    debug_assert_eq!(values.len(), shape.iter().product::<usize>());
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(values.as_ptr() as *const u8, values.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32,
        shape,
        bytes,
    )?)
}

/// (x, y, mask) literals for one padded batch.
fn batch_literals(b: &Batch, x_dims: &[usize]) -> Result<[Literal; 3]> {
    let mut x_shape = vec![b.capacity];
    x_shape.extend_from_slice(x_dims);
    Ok([
        literal_f32(&b.x, &x_shape)?,
        literal_f32(&b.y, &[b.capacity])?,
        literal_f32(&b.mask, &[b.capacity])?,
    ])
}

fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().context("non-utf8 artifact path")?,
    )
    .with_context(|| format!("parsing HLO text {}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .with_context(|| format!("compiling {}", path.display()))
}

/// Read the initial parameters npz (written by aot.py as p000, p001, ...).
fn load_init(manifest: &TaskManifest) -> Result<ModelParams> {
    let mut entries = Literal::read_npz(&manifest.init_npz, &())
        .with_context(|| format!("reading {}", manifest.init_npz.display()))?;
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    if entries.len() != manifest.params.len() {
        bail!(
            "init npz has {} tensors, manifest expects {}",
            entries.len(),
            manifest.params.len()
        );
    }
    let mut tensors = Vec::with_capacity(entries.len());
    let mut shapes = Vec::with_capacity(entries.len());
    for ((_, lit), spec) in entries.iter().zip(manifest.params.iter()) {
        let v = lit.to_vec::<f32>()?;
        if v.len() != spec.shape.iter().product::<usize>() {
            bail!("init tensor '{}' has wrong size", spec.name);
        }
        tensors.push(v);
        shapes.push(spec.shape.clone());
    }
    Ok(ModelParams::new(tensors, shapes))
}

impl Engine for PjrtEngine {
    fn init_params(&self) -> ModelParams {
        self.init.clone()
    }

    fn train_local(
        &mut self,
        start: &ModelParams,
        indices: &[usize],
        epochs: usize,
        lr: f32,
    ) -> Result<TrainOutcome> {
        let n_params = start.n_tensors();
        let (cap, _) = self.manifest.pick_train_bucket(indices.len());
        let exec = self
            .train_execs
            .get(&cap)
            .with_context(|| format!("no train bucket of capacity {cap}"))?;

        let b = batch::build(&self.data.train, indices, cap);
        let [x, y, mask] = batch_literals(&b, &self.manifest.x_dims)?;
        let lr_lit = Literal::scalar(lr);
        let epochs_lit = Literal::scalar(epochs.max(1) as i32);
        let param_lits = self.params_to_literals(start)?;

        let mut args: Vec<&Literal> = Vec::with_capacity(n_params + 5);
        args.extend(param_lits.iter());
        args.push(&x);
        args.push(&y);
        args.push(&mask);
        args.push(&lr_lit);
        args.push(&epochs_lit);

        let mut out = exec.execute::<&Literal>(&args)?;
        self.executions += 1;
        let result = out
            .swap_remove(0)
            .swap_remove(0)
            .to_literal_sync()?;
        let mut parts = result.to_tuple()?;
        if parts.len() != n_params + 1 {
            bail!(
                "train step returned {} outputs, expected {}",
                parts.len(),
                n_params + 1
            );
        }
        let loss = parts.pop().unwrap().to_vec::<f32>()?[0] as f64;
        let mut tensors = Vec::with_capacity(n_params);
        for p in &parts {
            tensors.push(p.to_vec::<f32>()?);
        }
        Ok(TrainOutcome {
            params: ModelParams::new(tensors, start.shapes().to_vec()),
            loss,
        })
    }

    fn evaluate(&mut self, params: &ModelParams) -> Result<EvalResult> {
        let param_lits = self.params_to_literals(params)?;
        let (mut s0, mut s1, mut s2) = (0.0f64, 0.0f64, 0.0f64);
        for chunk in &self.eval_chunk_lits {
            let mut args: Vec<&Literal> = Vec::with_capacity(param_lits.len() + 3);
            args.extend(param_lits.iter());
            args.extend(chunk.iter());
            let mut out = self.eval_exec.execute::<&Literal>(&args)?;
            self.executions += 1;
            let result = out
                .swap_remove(0)
                .swap_remove(0)
                .to_literal_sync()?;
            let parts = result.to_tuple()?;
            if parts.len() != 3 {
                bail!("eval returned {} outputs, expected 3", parts.len());
            }
            s0 += parts[0].to_vec::<f32>()?[0] as f64;
            s1 += parts[1].to_vec::<f32>()?[0] as f64;
            s2 += parts[2].to_vec::<f32>()?[0] as f64;
        }
        let n = s2.max(1.0);
        Ok(match self.task {
            // (sq_err_sum, abs_err_sum, count)
            TaskKind::Aerofoil => EvalResult {
                loss: s0 / n,
                accuracy: (1.0 - (s1 / n) / self.test_mad.max(1e-9)).max(0.0),
                n,
            },
            // (nll_sum, correct, count)
            TaskKind::Mnist => EvalResult {
                loss: s0 / n,
                accuracy: s1 / n,
                n,
            },
        })
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    fn engine(task: TaskKind) -> (ExperimentConfig, PjrtEngine) {
        let mut cfg = match task {
            TaskKind::Aerofoil => ExperimentConfig::task1_scaled(),
            TaskKind::Mnist => ExperimentConfig::task2_scaled(),
        };
        cfg.dataset_size = 200;
        cfg.eval_size = 100;
        cfg.n_clients = 4;
        cfg.n_edges = 2;
        let mut rng = crate::rng::Rng::new(cfg.seed);
        let data = Arc::new(crate::data::build(&cfg, &mut rng));
        let eng = PjrtEngine::new(&cfg, data).unwrap();
        (cfg, eng)
    }

    #[test]
    fn aerofoil_train_reduces_eval_loss() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let (_, mut eng) = engine(TaskKind::Aerofoil);
        let w0 = eng.init_params();
        let before = eng.evaluate(&w0).unwrap();
        let idx: Vec<usize> = (0..150).collect();
        let mut w = w0.clone();
        for _ in 0..20 {
            let out = eng.train_local(&w, &idx, 5, 0.05).unwrap();
            w = out.params;
        }
        let after = eng.evaluate(&w).unwrap();
        assert!(w.is_finite());
        assert!(
            after.loss < before.loss * 0.9,
            "loss {} -> {}",
            before.loss,
            after.loss
        );
        assert!(after.accuracy > before.accuracy);
    }

    #[test]
    fn mnist_train_improves_accuracy() {
        if !have_artifacts() {
            return;
        }
        let (_, mut eng) = engine(TaskKind::Mnist);
        let w0 = eng.init_params();
        let before = eng.evaluate(&w0).unwrap();
        let idx: Vec<usize> = (0..64).collect();
        let mut w = w0;
        for _ in 0..10 {
            let out = eng.train_local(&w, &idx, 5, 0.05).unwrap();
            w = out.params;
        }
        let after = eng.evaluate(&w).unwrap();
        assert!(
            after.accuracy > before.accuracy + 0.2,
            "acc {} -> {}",
            before.accuracy,
            after.accuracy
        );
    }

    /// The fori_loop inside the artifact must equal repeated single-epoch
    /// calls (the Python tests pin single-epoch vs eager; this pins the
    /// multi-epoch loop against composition).
    #[test]
    fn epochs_loop_matches_repeated_single_epochs() {
        if !have_artifacts() {
            return;
        }
        let (_, mut eng) = engine(TaskKind::Aerofoil);
        let w0 = eng.init_params();
        let idx: Vec<usize> = (0..40).collect();
        let five = eng.train_local(&w0, &idx, 5, 0.02).unwrap().params;
        let mut w = w0;
        for _ in 0..5 {
            w = eng.train_local(&w, &idx, 1, 0.02).unwrap().params;
        }
        let dist = five.l2_distance(&w);
        assert!(dist < 1e-4, "fori_loop vs composed single epochs: {dist}");
    }

    #[test]
    fn zero_lr_train_is_identity() {
        if !have_artifacts() {
            return;
        }
        let (_, mut eng) = engine(TaskKind::Aerofoil);
        let w0 = eng.init_params();
        let out = eng.train_local(&w0, &[0, 1, 2, 3], 3, 0.0).unwrap();
        assert!(out.params.l2_distance(&w0) < 1e-6);
    }

    #[test]
    fn eval_counts_match_test_set() {
        if !have_artifacts() {
            return;
        }
        let (cfg, mut eng) = engine(TaskKind::Mnist);
        let r = eng.evaluate(&eng.init_params()).unwrap();
        assert_eq!(r.n as usize, cfg.eval_size);
        assert!(r.accuracy >= 0.0 && r.accuracy <= 1.0);
    }

    #[test]
    fn bucket_switch_small_vs_large_partition() {
        if !have_artifacts() {
            return;
        }
        let (_, mut eng) = engine(TaskKind::Aerofoil);
        let w0 = eng.init_params();
        let small = eng.train_local(&w0, &(0..10).collect::<Vec<_>>(), 1, 0.01).unwrap();
        let large = eng.train_local(&w0, &(0..150).collect::<Vec<_>>(), 1, 0.01).unwrap();
        assert!(small.params.is_finite() && large.params.is_finite());
    }

    #[test]
    fn one_execution_per_client_round() {
        if !have_artifacts() {
            return;
        }
        let (_, mut eng) = engine(TaskKind::Aerofoil);
        let w0 = eng.init_params();
        let before = eng.executions;
        eng.train_local(&w0, &[0, 1, 2], 5, 0.01).unwrap();
        assert_eq!(eng.executions, before + 1);
    }
}
