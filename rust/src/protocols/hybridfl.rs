//! HybridFL — the paper's protocol (§III).
//!
//! Round anatomy (Fig. 1's eight steps, collapsed to the four that matter
//! computationally):
//!
//! 1. **Regional client selection** (§III.A): each edge r selects
//!    `C_r(t)·n_r` clients where `C_r(t) = C/θ̂_r` and θ̂_r is the
//!    LSE-estimated regional slack factor over observable submission
//!    counts only ([`crate::selection::SlackEstimator`], held behind the
//!    configured [`crate::selection::SelectionStrategy`]).
//! 2. **Local training**: survivors train τ GD epochs from the global
//!    model w(t−1) (the environment fans this out — inline on the virtual
//!    clock, on client threads in the live cluster).
//! 3. **Quota-triggered regional aggregation** (§III.B): the round ends
//!    the moment C·n models have arrived *globally* (or at T_lim) —
//!    [`CutoffPolicy::Quota`] — then each region aggregates with the
//!    model-cache rule (eq. 17) so stale clients contribute the previous
//!    regional model.
//! 4. **Immediate EDC-weighted cloud aggregation** (eqs. 18–20): regional
//!    models are combined the same round, weighted by effective data
//!    coverage.

use crate::config::{CacheMode, ExperimentConfig, ProtocolKind};
use crate::env::{CutoffPolicy, FlEnvironment, Selection, Starts};
use crate::model::ModelParams;
use crate::protocols::{check_regions, mean_loss, wrong_kind, Protocol, ProtocolState, RoundRecord};
use crate::selection::{build_strategy, SelectionStrategy};
use crate::selection::slack::SlackState;
use crate::Result;

pub struct HybridFl {
    global: ModelParams,
    /// w^r(t−1) — previous regional models (the cache substrate, eq. 17).
    regionals: Vec<ModelParams>,
    /// The configured count head (edge-resident state in a real
    /// deployment; here cloud-side protocol state driven purely by
    /// observable submission counts). The default [`SlackStrategy`] is
    /// the paper's per-region estimators, bit for bit.
    ///
    /// [`SlackStrategy`]: crate::selection::SlackStrategy
    strategy: Box<dyn SelectionStrategy>,
    cache_mode: CacheMode,
}

impl HybridFl {
    pub fn new(cfg: &ExperimentConfig, region_sizes: &[usize], init: ModelParams) -> HybridFl {
        HybridFl {
            regionals: vec![init.clone(); region_sizes.len()],
            global: init,
            strategy: build_strategy(cfg, region_sizes),
            cache_mode: cfg.cache_mode,
        }
    }
}

impl Protocol for HybridFl {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::HybridFl
    }

    fn run_round(&mut self, t: usize, env: &mut dyn FlEnvironment) -> Result<RoundRecord> {
        let m = env.n_regions();

        // --- step 1: strategy-modulated regional selection (the slack
        // estimators under the default selector) --------------------------------
        let counts: Vec<usize> = self.strategy.counts(t);

        // --- steps 2–3: fan out training; the round ends when C·n models
        // arrived globally (or at T_lim).
        let quota = env.cfg().quota();
        let out = env.run_round(
            t,
            Selection::PerRegion(counts),
            Starts::Global(&self.global),
            CutoffPolicy::Quota(quota),
        )?;
        let quota_met = !out.deadline_hit;

        // --- regional aggregation: eq. 17 cache rule, or the fresh-only
        // ablation (see CacheMode docs). The environment already streamed
        // each in-time model into its region's accumulator (the Σ term of
        // eq. 17); only the cache/rescale finisher runs here.
        let mut regional_models: Vec<(ModelParams, f64)> = Vec::with_capacity(m);
        for agg in &out.regional {
            let sp = crate::trace::SpanStart::begin();
            let r = agg.region();
            let edc_r = agg.edc();
            let w_r = match self.cache_mode {
                CacheMode::Regional => agg.finish_cached(&self.regionals[r])?,
                CacheMode::Fresh => agg
                    .fedavg()
                    .unwrap_or_else(|| self.regionals[r].clone()),
            };
            regional_models.push((w_r, edc_r));
            env.tracer()
                .finish(sp, crate::trace::Phase::RegionalAgg, Some(r), 0.0);
        }

        // --- immediate EDC-weighted cloud aggregation (eqs. 18–20) -------------
        // Its virtual cost is the edge↔cloud exchange charged below.
        let sp = crate::trace::SpanStart::begin();
        let refs: Vec<(&ModelParams, f64)> = regional_models
            .iter()
            .map(|(w, edc)| (w, *edc))
            .collect();
        if let Some(w) = crate::aggregation::edc_cloud(&refs) {
            self.global = w;
        }
        let rtt = env.t_c2e2c();
        env.tracer()
            .finish(sp, crate::trace::Phase::CloudAgg, None, rtt);
        // The regional cache advances regardless (w^r(t) is defined by
        // eq. 17 whether or not the cloud used it).
        for (r, (w_r, _)) in regional_models.into_iter().enumerate() {
            self.regionals[r] = w_r;
        }

        // --- strategy update from the observable submission counts ------------
        debug_assert_eq!(out.submissions.len(), m);
        self.strategy.observe(&out.submissions, quota_met);
        let mean_local_loss = mean_loss(&out);

        Ok(RoundRecord {
            t,
            // Three-layer: edge↔cloud exchange happens every round.
            round_len: out.round_len + env.t_c2e2c(),
            selected: out.selected,
            alive: out.alive,
            submissions: out.submissions,
            avail: out.avail,
            energy_j: out.energy_j,
            bytes_moved: out.bytes_moved,
            deadline_hit: out.deadline_hit,
            cloud_aggregated: true,
            mean_local_loss,
        })
    }

    fn global_model(&self) -> &ModelParams {
        &self.global
    }

    fn slack_states(&self) -> Option<Vec<SlackState>> {
        self.strategy.slack_states()
    }

    fn snapshot_state(&self) -> ProtocolState {
        ProtocolState::HybridFl {
            global: self.global.clone(),
            regionals: self.regionals.clone(),
            slack: self.strategy.snapshot(),
        }
    }

    fn restore_state(&mut self, state: ProtocolState) -> Result<()> {
        match state {
            ProtocolState::HybridFl {
                global,
                regionals,
                slack,
            } => {
                check_regions(ProtocolKind::HybridFl, self.regionals.len(), regionals.len())?;
                self.strategy.restore(slack)?;
                self.global = global;
                self.regionals = regionals;
                Ok(())
            }
            other => Err(wrong_kind(ProtocolKind::HybridFl, &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{FlEnvironment as _, VirtualClockEnv};
    use crate::sim::test_support::mock_cfg;

    fn run_rounds(
        dropout: f64,
        n: usize,
        m: usize,
        rounds: usize,
        seed: u64,
    ) -> (HybridFl, Vec<RoundRecord>) {
        let mut cfg = mock_cfg(dropout, n, m);
        cfg.seed = seed;
        let mut env = VirtualClockEnv::new(cfg.clone()).unwrap();
        let sizes: Vec<usize> = (0..m).map(|r| env.region_size(r)).collect();
        let mut proto = HybridFl::new(&cfg, &sizes, env.init_model());
        let mut recs = Vec::new();
        for t in 1..=rounds {
            recs.push(proto.run_round(t, &mut env).unwrap());
        }
        (proto, recs)
    }

    #[test]
    fn quota_ends_round_before_deadline_when_reliable() {
        let (_, recs) = run_rounds(0.0, 20, 2, 5, 1);
        for rec in &recs {
            assert!(!rec.deadline_hit);
            let subs: usize = rec.submissions.iter().sum();
            assert_eq!(subs, 6); // quota = 0.3 * 20
        }
    }

    /// The §III.A claim: slack modulation drives the per-region alive count
    /// toward C·n_r despite heavy unreliability.
    #[test]
    fn slack_modulation_compensates_dropout() {
        let (proto, recs) = run_rounds(0.5, 40, 2, 120, 2);
        // After convergence, mean |X_r|/n_r should be near C = 0.3 and
        // selections should exceed quota to compensate the 50% drop rate.
        let tail = &recs[60..];
        let mean_alive_frac: f64 = tail
            .iter()
            .map(|r| r.alive.iter().sum::<usize>() as f64 / 40.0)
            .sum::<f64>()
            / tail.len() as f64;
        assert!(
            (mean_alive_frac - 0.3).abs() < 0.12,
            "alive fraction {mean_alive_frac} should hover near C=0.3"
        );
        // θ̂ must have moved off its 0.5 init toward ~P(1 - dr) territory.
        let states = proto.slack_states().unwrap();
        for s in states {
            assert!(s.theta < 0.75, "theta should reflect unreliability: {s:?}");
        }
    }

    #[test]
    fn unreachable_quota_degrades_to_deadline() {
        // C = 0.3 but 95% drop-out: alive ≈ 5% of selections, far below
        // quota even with C_r at its 1.0 clamp ⇒ rounds run to T_lim (the
        // paper's "interesting result" at E[dr]=0.6, C=0.5).
        let (_, recs) = run_rounds(0.95, 20, 2, 30, 3);
        let deadline_rounds = recs.iter().filter(|r| r.deadline_hit).count();
        assert!(deadline_rounds > 25, "{deadline_rounds}");
    }

    #[test]
    fn global_model_advances_every_round() {
        let (proto, recs) = run_rounds(0.2, 20, 2, 10, 4);
        assert!(recs.iter().all(|r| r.cloud_aggregated));
        assert!(proto.global_model().values()[0] > 0.0);
    }

    #[test]
    fn slack_states_exposed_for_fig2() {
        let (proto, _) = run_rounds(0.3, 20, 2, 5, 5);
        let states = proto.slack_states().unwrap();
        assert_eq!(states.len(), 2);
        for s in states {
            assert!(s.theta > 0.0 && s.theta <= 1.0);
            assert!(s.c_r >= 0.3 - 1e-12);
        }
    }
}
