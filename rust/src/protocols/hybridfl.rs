//! HybridFL — the paper's protocol (§III).
//!
//! Round anatomy (Fig. 1's eight steps, collapsed to the four that matter
//! computationally):
//!
//! 1. **Regional client selection** (§III.A): each edge r selects
//!    `C_r(t)·n_r` clients where `C_r(t) = C/θ̂_r` and θ̂_r is the
//!    LSE-estimated regional slack factor over observable submission
//!    counts only ([`SlackEstimator`]).
//! 2. **Local training**: survivors train τ GD epochs from the global
//!    model w(t−1).
//! 3. **Quota-triggered regional aggregation** (§III.B): the cloud ends
//!    the round the moment C·n models have arrived *globally* (or at
//!    T_lim), then each edge aggregates with the model-cache rule
//!    (eq. 17) so stale clients contribute the previous regional model.
//! 4. **Immediate EDC-weighted cloud aggregation** (eqs. 18–20): regional
//!    models are combined the same round, weighted by effective data
//!    coverage.

use crate::config::{CacheMode, ExperimentConfig, ProtocolKind};
use crate::model::ModelParams;
use crate::protocols::{Protocol, RoundCtx, RoundRecord};
use crate::selection::slack::{SlackEstimator, SlackState};
use crate::selection::select_clients;
use crate::topology::Topology;
use crate::Result;

pub struct HybridFl {
    global: ModelParams,
    /// w^r(t−1) — previous regional models (the cache substrate, eq. 17).
    regionals: Vec<ModelParams>,
    /// One slack estimator per region (edge-resident state in the real
    /// deployment; see `live::edge`).
    slack: Vec<SlackEstimator>,
    /// |D^r| per region.
    region_data: Vec<f64>,
    cache_mode: CacheMode,
}

impl HybridFl {
    pub fn new(cfg: &ExperimentConfig, topo: &Topology, init: ModelParams) -> HybridFl {
        let slack = (0..topo.n_regions())
            .map(|r| {
                SlackEstimator::new(topo.region_size(r), cfg.c_fraction, cfg.theta_init)
            })
            .collect();
        HybridFl {
            regionals: vec![init.clone(); topo.n_regions()],
            global: init,
            slack,
            region_data: Vec::new(),
            cache_mode: cfg.cache_mode,
        }
    }

    fn ensure_region_data(&mut self, ctx: &RoundCtx) {
        if self.region_data.is_empty() {
            self.region_data = ctx
                .topo
                .regions
                .iter()
                .map(|cs| ctx.data.region_data_size(cs) as f64)
                .collect();
        }
    }
}

impl Protocol for HybridFl {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::HybridFl
    }

    fn run_round(&mut self, t: usize, ctx: &mut RoundCtx) -> Result<RoundRecord> {
        self.ensure_region_data(ctx);
        let m = ctx.topo.n_regions();

        // --- step 1: slack-modulated regional selection ------------------------
        let mut selected: Vec<usize> = Vec::new();
        for r in 0..m {
            let want = self.slack[r].selection_count();
            selected.extend(select_clients(&ctx.topo.regions[r], want, ctx.rng));
        }
        let sel_by_region = ctx.region_counts(&selected);

        // --- simulate fates ----------------------------------------------------
        let fates = ctx.simulate(&selected);
        let alive = ctx.count_alive(&fates);

        // --- quota trigger: the round ends when C·n models arrived globally ----
        let quota = ctx.cfg.quota();
        let mut completions: Vec<f64> = fates
            .iter()
            .filter(|f| !f.dropped)
            .map(|f| f.completion)
            .collect();
        completions.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (cutoff, quota_met) = if completions.len() >= quota
            && completions[quota - 1] <= ctx.tm.t_lim
        {
            (completions[quota - 1], true)
        } else {
            (ctx.tm.t_lim, false)
        };
        // The aggregation signal stops straggling clients at the cutoff —
        // the quota trigger's energy saving (see RoundCtx::charge_energy).
        ctx.charge_energy(&fates, |_| cutoff);

        // --- train the in-time survivors from the global model -----------------
        // S_r(t): alive with completion ≤ cutoff.
        let submissions = ctx.count_by_region(&fates, |f| {
            !f.dropped && f.completion <= cutoff
        });
        let mut loss_sum = 0.0;
        let mut n_trained = 0usize;
        let mut regional_models: Vec<(ModelParams, f64)> = Vec::with_capacity(m);
        for r in 0..m {
            let members: Vec<_> = fates
                .iter()
                .filter(|f| f.region == r && !f.dropped && f.completion <= cutoff)
                .collect();
            let mut models: Vec<(ModelParams, f64)> = Vec::with_capacity(members.len());
            let mut edc_r = 0.0f64;
            for f in &members {
                let (w, loss) = ctx.train(&self.global, f.client)?;
                loss_sum += loss;
                n_trained += 1;
                let d = ctx.data.partitions[f.client].len() as f64;
                edc_r += d;
                models.push((w, d));
            }
            // Regional aggregation: eq. 17 cache rule, or the fresh-only
            // ablation (see CacheMode docs).
            let refs: Vec<(&ModelParams, f64)> =
                models.iter().map(|(w, d)| (w, *d)).collect();
            let w_r = match self.cache_mode {
                CacheMode::Regional => crate::aggregation::regional_with_cache(
                    &refs,
                    self.region_data[r],
                    &self.regionals[r],
                ),
                CacheMode::Fresh => crate::aggregation::fedavg(&refs)
                    .unwrap_or_else(|| self.regionals[r].clone()),
            };
            regional_models.push((w_r, edc_r));
        }

        // --- immediate EDC-weighted cloud aggregation (eqs. 18–20) -------------
        let refs: Vec<(&ModelParams, f64)> = regional_models
            .iter()
            .map(|(w, edc)| (w, *edc))
            .collect();
        if let Some(w) = crate::aggregation::edc_cloud(&refs) {
            self.global = w;
        }
        // The regional cache advances regardless (w^r(t) is defined by
        // eq. 17 whether or not the cloud used it).
        for (r, (w_r, _)) in regional_models.into_iter().enumerate() {
            self.regionals[r] = w_r;
        }

        // --- slack update from the observable submission counts ---------------
        for r in 0..m {
            self.slack[r].observe(submissions[r], quota_met);
        }

        Ok(RoundRecord {
            t,
            // Three-layer: edge↔cloud exchange happens every round.
            round_len: cutoff + ctx.tm.t_c2e2c,
            selected: sel_by_region,
            alive,
            submissions,
            energy_j: ctx.energy_j(),
            deadline_hit: !quota_met,
            cloud_aggregated: true,
            mean_local_loss: if n_trained == 0 {
                f64::NAN
            } else {
                loss_sum / n_trained as f64
            },
        })
    }

    fn global_model(&self) -> &ModelParams {
        &self.global
    }

    fn slack_states(&self) -> Option<Vec<SlackState>> {
        Some(
            self.slack
                .iter()
                .map(|s| {
                    s.last_state().unwrap_or(SlackState {
                        theta: s.theta(),
                        c_r: s.c_r(),
                        q_r: 0.0,
                        submissions: 0,
                    })
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::test_support::mock_ctx_parts;

    fn run_rounds(
        dropout: f64,
        n: usize,
        m: usize,
        rounds: usize,
        seed: u64,
    ) -> (HybridFl, Vec<RoundRecord>) {
        let (cfg, topo, data, tm, em, mut engine, profiles) =
            mock_ctx_parts(dropout, n, m);
        let mut rng = crate::rng::Rng::new(seed);
        let mut proto = HybridFl::new(&cfg, &topo, engine.init_params());
        let mut recs = Vec::new();
        for t in 1..=rounds {
            let mut ctx = RoundCtx::new(
                &cfg, &topo, &data, &tm, &em, engine.as_mut(), &mut rng, &profiles,
            );
            recs.push(proto.run_round(t, &mut ctx).unwrap());
        }
        (proto, recs)
    }

    #[test]
    fn quota_ends_round_before_deadline_when_reliable() {
        let (_, recs) = run_rounds(0.0, 20, 2, 5, 1);
        for rec in &recs {
            assert!(!rec.deadline_hit);
            let subs: usize = rec.submissions.iter().sum();
            assert_eq!(subs, 6); // quota = 0.3 * 20
        }
    }

    /// The §III.A claim: slack modulation drives the per-region alive count
    /// toward C·n_r despite heavy unreliability.
    #[test]
    fn slack_modulation_compensates_dropout() {
        let (proto, recs) = run_rounds(0.5, 40, 2, 120, 2);
        // After convergence, mean |X_r|/n_r should be near C = 0.3 and
        // selections should exceed quota to compensate the 50% drop rate.
        let tail = &recs[60..];
        let mean_alive_frac: f64 = tail
            .iter()
            .map(|r| r.alive.iter().sum::<usize>() as f64 / 40.0)
            .sum::<f64>()
            / tail.len() as f64;
        assert!(
            (mean_alive_frac - 0.3).abs() < 0.12,
            "alive fraction {mean_alive_frac} should hover near C=0.3"
        );
        // θ̂ must have moved off its 0.5 init toward ~P(1 - dr) territory.
        let states = proto.slack_states().unwrap();
        for s in states {
            assert!(s.theta < 0.75, "theta should reflect unreliability: {s:?}");
        }
    }

    #[test]
    fn unreachable_quota_degrades_to_deadline() {
        // C = 0.3 but 95% drop-out: alive ≈ 5% of selections, far below
        // quota even with C_r at its 1.0 clamp ⇒ rounds run to T_lim (the
        // paper's "interesting result" at E[dr]=0.6, C=0.5).
        let (_, recs) = run_rounds(0.95, 20, 2, 30, 3);
        let deadline_rounds = recs.iter().filter(|r| r.deadline_hit).count();
        assert!(deadline_rounds > 25, "{deadline_rounds}");
    }

    #[test]
    fn global_model_advances_every_round() {
        let (proto, recs) = run_rounds(0.2, 20, 2, 10, 4);
        assert!(recs.iter().all(|r| r.cloud_aggregated));
        assert!(proto.global_model().tensors[0][0] > 0.0);
    }

    #[test]
    fn slack_states_exposed_for_fig2() {
        let (proto, _) = run_rounds(0.3, 20, 2, 5, 5);
        let states = proto.slack_states().unwrap();
        assert_eq!(states.len(), 2);
        for s in states {
            assert!(s.theta > 0.0 && s.theta <= 1.0);
            assert!(s.c_r >= 0.3 - 1e-12);
        }
    }
}
