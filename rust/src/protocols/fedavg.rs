//! FedAvg baseline (McMahan et al.) — two-layer client/cloud FL.
//!
//! Per round: the cloud selects C·n clients uniformly from the whole
//! fleet, waits for **all** of them (a dropped client never responds, so
//! any drop-out stalls the round until the response limit T_lim), then
//! weight-averages the models that did arrive. There is no edge layer, so
//! no cloud↔edge time is charged (eq. 32 applies only to 3-layer
//! protocols).

use crate::config::ProtocolKind;
use crate::model::ModelParams;
use crate::protocols::{count_from_fraction, Protocol, RoundCtx, RoundRecord};
use crate::selection::select_clients;
use crate::Result;

pub struct FedAvg {
    global: ModelParams,
}

impl FedAvg {
    pub fn new(init: ModelParams) -> FedAvg {
        FedAvg { global: init }
    }
}

impl Protocol for FedAvg {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::FedAvg
    }

    fn run_round(&mut self, t: usize, ctx: &mut RoundCtx) -> Result<RoundRecord> {
        // --- selection: C·n clients uniformly over the fleet -----------------
        let n = ctx.topo.n_clients();
        let want = count_from_fraction(ctx.cfg.c_fraction, n);
        let all: Vec<usize> = (0..n).collect();
        let selected = select_clients(&all, want, ctx.rng);
        let sel_by_region = ctx.region_counts(&selected);

        // --- simulate fates ---------------------------------------------------
        let fates = ctx.simulate(&selected);
        let alive = ctx.count_alive(&fates);

        // Round ends when every selected client responded, or at T_lim
        // (dropped clients have completion = ∞, so one drop ⇒ T_lim).
        let max_completion = fates
            .iter()
            .map(|f| f.completion)
            .fold(0.0f64, f64::max);
        let cutoff = max_completion.min(ctx.tm.t_lim);
        let deadline_hit = max_completion > ctx.tm.t_lim;
        ctx.charge_energy(&fates, |_| cutoff);

        // --- aggregate what arrived in time ----------------------------------
        let arrived: Vec<_> = fates
            .iter()
            .filter(|f| !f.dropped && f.completion <= cutoff)
            .collect();
        let submissions = ctx.count_by_region(&fates, |f| {
            !f.dropped && f.completion <= cutoff
        });

        let mut models: Vec<(ModelParams, f64)> = Vec::with_capacity(arrived.len());
        let mut loss_sum = 0.0;
        for f in &arrived {
            let (m, loss) = ctx.train(&self.global, f.client)?;
            loss_sum += loss;
            models.push((m, ctx.data.partitions[f.client].len() as f64));
        }
        let refs: Vec<(&ModelParams, f64)> =
            models.iter().map(|(m, d)| (m, *d)).collect();
        if let Some(w) = crate::aggregation::fedavg(&refs) {
            self.global = w;
        }

        Ok(RoundRecord {
            t,
            // Two-layer: no edge RTT term.
            round_len: cutoff,
            selected: sel_by_region,
            alive,
            submissions,
            energy_j: ctx.energy_j(),
            deadline_hit,
            cloud_aggregated: true,
            mean_local_loss: if arrived.is_empty() {
                f64::NAN
            } else {
                loss_sum / arrived.len() as f64
            },
        })
    }

    fn global_model(&self) -> &ModelParams {
        &self.global
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::test_support::mock_ctx_parts;

    #[test]
    fn aggregates_only_survivors_and_waits_tlim_on_dropout() {
        let (cfg, topo, data, tm, em, mut engine, profiles) =
            mock_ctx_parts(0.9 /*dropout*/, 12, 3);
        let mut rng = crate::rng::Rng::new(5);
        let mut proto = FedAvg::new(engine.init_params());
        let mut ctx = RoundCtx::new(
            &cfg, &topo, &data, &tm, &em, engine.as_mut(), &mut rng, &profiles,
        );
        let rec = proto.run_round(1, &mut ctx).unwrap();
        // With 90% drop-out a selected set almost surely loses someone ⇒
        // the round runs to the deadline.
        assert!(rec.deadline_hit);
        assert!((rec.round_len - tm.t_lim).abs() < 1e-9);
        assert!(rec.energy_j > 0.0);
    }

    #[test]
    fn reliable_fleet_finishes_before_deadline() {
        let (cfg, topo, data, tm, em, mut engine, profiles) =
            mock_ctx_parts(0.0, 12, 3);
        let mut rng = crate::rng::Rng::new(6);
        let mut proto = FedAvg::new(engine.init_params());
        let mut ctx = RoundCtx::new(
            &cfg, &topo, &data, &tm, &em, engine.as_mut(), &mut rng, &profiles,
        );
        let rec = proto.run_round(1, &mut ctx).unwrap();
        assert!(!rec.deadline_hit);
        assert!(rec.round_len < tm.t_lim);
        let total_sel: usize = rec.selected.iter().sum();
        let total_sub: usize = rec.submissions.iter().sum();
        assert_eq!(total_sel, total_sub); // nobody dropped
        // Model moved (training happened).
        assert!(proto.global_model().tensors[0][0] > 0.0);
    }
}
