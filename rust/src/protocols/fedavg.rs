//! FedAvg baseline (McMahan et al.) — two-layer client/cloud FL.
//!
//! Per round: the cloud selects C·n clients uniformly from the whole
//! fleet, waits for **all** of them (a dropped client never responds, so
//! any drop-out stalls the round until the response limit T_lim), then
//! weight-averages the models that did arrive. There is no edge layer, so
//! no cloud↔edge time is charged (eq. 32 applies only to 3-layer
//! protocols).

use crate::config::ProtocolKind;
use crate::env::{CutoffPolicy, FlEnvironment, Selection, Starts};
use crate::model::ModelParams;
use crate::protocols::{
    count_from_fraction, mean_loss, wrong_kind, Protocol, ProtocolState, RoundRecord,
};
use crate::Result;

pub struct FedAvg {
    global: ModelParams,
}

impl FedAvg {
    pub fn new(init: ModelParams) -> FedAvg {
        FedAvg { global: init }
    }
}

impl Protocol for FedAvg {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::FedAvg
    }

    fn run_round(&mut self, t: usize, env: &mut dyn FlEnvironment) -> Result<RoundRecord> {
        // --- selection: C·n clients uniformly over the fleet; wait for all.
        let want = count_from_fraction(env.cfg().c_fraction, env.n_clients());
        let out = env.run_round(
            t,
            Selection::Uniform(want),
            Starts::Global(&self.global),
            CutoffPolicy::AllSelected,
        )?;

        // --- aggregate what arrived in time ----------------------------------
        // The environment folded each in-time model into per-region
        // partial sums as it arrived; recombining them with |D^r|/EDC
        // weights is exactly global FedAvg (no edge layer in the math).
        // Two-layer protocol: the cloud recombination charges no edge
        // RTT, so the span's virtual duration is zero.
        let sp = crate::trace::SpanStart::begin();
        if let Some(w) = crate::aggregation::fedavg_from_regions(&out.regional) {
            self.global = w;
        }
        env.tracer()
            .finish(sp, crate::trace::Phase::CloudAgg, None, 0.0);
        let mean_local_loss = mean_loss(&out);

        Ok(RoundRecord {
            t,
            // Two-layer: no edge RTT term.
            round_len: out.round_len,
            selected: out.selected,
            alive: out.alive,
            submissions: out.submissions,
            avail: out.avail,
            energy_j: out.energy_j,
            bytes_moved: out.bytes_moved,
            deadline_hit: out.deadline_hit,
            cloud_aggregated: true,
            mean_local_loss,
        })
    }

    fn global_model(&self) -> &ModelParams {
        &self.global
    }

    fn snapshot_state(&self) -> ProtocolState {
        ProtocolState::FedAvg {
            global: self.global.clone(),
        }
    }

    fn restore_state(&mut self, state: ProtocolState) -> Result<()> {
        match state {
            ProtocolState::FedAvg { global } => {
                self.global = global;
                Ok(())
            }
            other => Err(wrong_kind(ProtocolKind::FedAvg, &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::FlEnvironment as _;
    use crate::sim::test_support::mock_env;

    #[test]
    fn aggregates_only_survivors_and_waits_tlim_on_dropout() {
        let mut env = mock_env(0.9 /*dropout*/, 12, 3);
        let t_lim = env.timing().t_lim;
        let mut proto = FedAvg::new(env.init_model());
        let rec = proto.run_round(1, &mut env).unwrap();
        // With 90% drop-out a selected set almost surely loses someone ⇒
        // the round runs to the deadline.
        assert!(rec.deadline_hit);
        assert!((rec.round_len - t_lim).abs() < 1e-9);
        assert!(rec.energy_j > 0.0);
    }

    #[test]
    fn reliable_fleet_finishes_before_deadline() {
        let mut env = mock_env(0.0, 12, 3);
        let t_lim = env.timing().t_lim;
        let mut proto = FedAvg::new(env.init_model());
        let rec = proto.run_round(1, &mut env).unwrap();
        assert!(!rec.deadline_hit);
        assert!(rec.round_len < t_lim);
        let total_sel: usize = rec.selected.iter().sum();
        let total_sub: usize = rec.submissions.iter().sum();
        assert_eq!(total_sel, total_sub); // nobody dropped
        // Model moved (training happened).
        assert!(proto.global_model().values()[0] > 0.0);
    }
}
