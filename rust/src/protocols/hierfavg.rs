//! HierFAVG baseline (Liu et al.) — three-layer hierarchical FL.
//!
//! Per round, each edge selects C·n_r of its clients and waits for **all**
//! of them (drop-outs stall the region until T_lim, exactly the coupling
//! problem the paper criticizes). Edges aggregate every round; the cloud
//! aggregates the regional models every κ₂ rounds (paper sets κ₂ = 10,
//! "shown to be an optimal setting in their work") and redistributes the
//! global model to the edges. Clients always train from their region's
//! current model.

use crate::config::{ExperimentConfig, ProtocolKind};
use crate::env::{CutoffPolicy, FlEnvironment, Selection, Starts};
use crate::model::ModelParams;
use crate::protocols::{
    check_regions, count_from_fraction, mean_loss, wrong_kind, Protocol, ProtocolState,
    RoundRecord,
};
use crate::Result;

pub struct HierFavg {
    /// Last cloud-aggregated model — what the cloud evaluates/deploys.
    global: ModelParams,
    /// Per-region models (updated every round by edge aggregation).
    regionals: Vec<ModelParams>,
    /// |D^r| per region (constant aggregation weights — the paper notes
    /// HierFAVG uses constant weights, unlike HybridFL's EDC).
    region_data: Vec<f64>,
    kappa2: usize,
}

impl HierFavg {
    pub fn new(cfg: &ExperimentConfig, n_regions: usize, init: ModelParams) -> HierFavg {
        HierFavg {
            regionals: vec![init.clone(); n_regions],
            global: init,
            region_data: Vec::new(), // filled lazily on first round
            kappa2: cfg.hier_kappa2,
        }
    }
}

impl Protocol for HierFavg {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::HierFavg
    }

    fn run_round(&mut self, t: usize, env: &mut dyn FlEnvironment) -> Result<RoundRecord> {
        let m = env.n_regions();
        if self.region_data.is_empty() {
            self.region_data = (0..m).map(|r| env.region_data_size(r)).collect();
        }

        // --- per-region selection; every edge waits for all its clients ------
        let counts: Vec<usize> = (0..m)
            .map(|r| count_from_fraction(env.cfg().c_fraction, env.region_size(r)))
            .collect();
        let out = env.run_round(
            t,
            Selection::PerRegion(counts),
            Starts::PerRegion(&self.regionals),
            CutoffPolicy::AllPerRegion,
        )?;

        // --- edge aggregation from the streamed per-region folds -------------
        // Each accumulator already holds its region's weighted partial
        // sum; `fedavg()` rescales it to the plain weighted average. An
        // empty region returns None and keeps its previous model.
        for agg in &out.regional {
            let sp = crate::trace::SpanStart::begin();
            let r = agg.region();
            if let Some(w) = agg.fedavg() {
                self.regionals[r] = w;
            }
            env.tracer()
                .finish(sp, crate::trace::Phase::RegionalAgg, Some(r), 0.0);
        }

        // --- cloud aggregation every κ₂ rounds --------------------------------
        // The cloud-agg span exists only on cloud rounds, charging the
        // edge RTT added to `round_len` below.
        let cloud_round = t % self.kappa2 == 0;
        if cloud_round {
            let sp = crate::trace::SpanStart::begin();
            let refs: Vec<(&ModelParams, f64)> = self
                .regionals
                .iter()
                .zip(self.region_data.iter())
                .map(|(w, d)| (w, *d))
                .collect();
            if let Some(w) = crate::aggregation::fedavg(&refs) {
                self.global = w;
            }
            // Redistribute the global model to the edges.
            for r in 0..m {
                self.regionals[r] = self.global.clone();
            }
            let rtt = env.t_c2e2c();
            env.tracer()
                .finish(sp, crate::trace::Phase::CloudAgg, None, rtt);
        }
        let mean_local_loss = mean_loss(&out);

        Ok(RoundRecord {
            t,
            // Edge RTT charged on cloud rounds only (model up+down between
            // cloud and edges); client comm is inside the completions.
            round_len: out.round_len + if cloud_round { env.t_c2e2c() } else { 0.0 },
            selected: out.selected,
            alive: out.alive,
            submissions: out.submissions,
            avail: out.avail,
            energy_j: out.energy_j,
            bytes_moved: out.bytes_moved,
            deadline_hit: out.deadline_hit,
            cloud_aggregated: cloud_round,
            mean_local_loss,
        })
    }

    fn global_model(&self) -> &ModelParams {
        &self.global
    }

    fn snapshot_state(&self) -> ProtocolState {
        ProtocolState::HierFavg {
            global: self.global.clone(),
            regionals: self.regionals.clone(),
            region_data: self.region_data.clone(),
        }
    }

    fn restore_state(&mut self, state: ProtocolState) -> Result<()> {
        match state {
            ProtocolState::HierFavg {
                global,
                regionals,
                region_data,
            } => {
                check_regions(ProtocolKind::HierFavg, self.regionals.len(), regionals.len())?;
                // region_data is legitimately empty only pre-round-1; any
                // other length would silently truncate the cloud zip.
                if !region_data.is_empty() {
                    check_regions(
                        ProtocolKind::HierFavg,
                        self.regionals.len(),
                        region_data.len(),
                    )?;
                }
                self.global = global;
                self.regionals = regionals;
                self.region_data = region_data;
                Ok(())
            }
            other => Err(wrong_kind(ProtocolKind::HierFavg, &other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::{FlEnvironment as _, VirtualClockEnv};
    use crate::sim::test_support::{mock_cfg, mock_env};

    #[test]
    fn cloud_aggregates_only_every_kappa2_rounds() {
        let mut cfg = mock_cfg(0.0, 12, 3);
        cfg.hier_kappa2 = 3;
        let mut env = VirtualClockEnv::new(cfg.clone()).unwrap();
        let mut proto = HierFavg::new(&cfg, 3, env.init_model());
        let mut cloud_rounds = Vec::new();
        for t in 1..=6 {
            let rec = proto.run_round(t, &mut env).unwrap();
            if rec.cloud_aggregated {
                cloud_rounds.push(t);
            }
        }
        assert_eq!(cloud_rounds, vec![3, 6]);
    }

    #[test]
    fn global_frozen_between_cloud_rounds_but_regionals_move() {
        let mut cfg = mock_cfg(0.0, 12, 3);
        cfg.hier_kappa2 = 10;
        let mut env = VirtualClockEnv::new(cfg.clone()).unwrap();
        let mut proto = HierFavg::new(&cfg, 3, env.init_model());
        let g0 = proto.global_model().clone();
        for t in 1..=3 {
            proto.run_round(t, &mut env).unwrap();
        }
        // Global untouched before round 10 …
        assert!(proto.global_model().l2_distance(&g0) < 1e-9);
        // … while regionals have accumulated training progress.
        assert!(proto.regionals.iter().any(|r| r.l2_distance(&g0) > 1e-6));
    }

    #[test]
    fn dropouts_stall_regions_to_deadline() {
        let mut env = mock_env(0.95, 12, 3);
        let t_lim = env.timing().t_lim;
        let cfg = env.cfg().clone();
        let mut proto = HierFavg::new(&cfg, 3, env.init_model());
        let rec = proto.run_round(1, &mut env).unwrap();
        assert!(rec.deadline_hit);
        assert!(rec.round_len >= t_lim);
    }
}
