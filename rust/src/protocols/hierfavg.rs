//! HierFAVG baseline (Liu et al.) — three-layer hierarchical FL.
//!
//! Per round, each edge selects C·n_r of its clients and waits for **all**
//! of them (drop-outs stall the region until T_lim, exactly the coupling
//! problem the paper criticizes). Edges aggregate every round; the cloud
//! aggregates the regional models every κ₂ rounds (paper sets κ₂ = 10,
//! "shown to be an optimal setting in their work") and redistributes the
//! global model to the edges. Clients always train from their region's
//! current model.

use crate::config::{ExperimentConfig, ProtocolKind};
use crate::model::ModelParams;
use crate::protocols::{count_from_fraction, Protocol, RoundCtx, RoundRecord};
use crate::selection::select_clients;
use crate::topology::Topology;
use crate::Result;

pub struct HierFavg {
    /// Last cloud-aggregated model — what the cloud evaluates/deploys.
    global: ModelParams,
    /// Per-region models (updated every round by edge aggregation).
    regionals: Vec<ModelParams>,
    /// |D^r| per region (constant aggregation weights — the paper notes
    /// HierFAVG uses constant weights, unlike HybridFL's EDC).
    region_data: Vec<f64>,
    kappa2: usize,
}

impl HierFavg {
    pub fn new(cfg: &ExperimentConfig, topo: &Topology, init: ModelParams) -> HierFavg {
        HierFavg {
            regionals: vec![init.clone(); topo.n_regions()],
            global: init,
            region_data: Vec::new(), // filled lazily on first round
            kappa2: cfg.hier_kappa2,
        }
    }

    fn ensure_region_data(&mut self, ctx: &RoundCtx) {
        if self.region_data.is_empty() {
            self.region_data = ctx
                .topo
                .regions
                .iter()
                .map(|cs| ctx.data.region_data_size(cs) as f64)
                .collect();
        }
    }
}

impl Protocol for HierFavg {
    fn kind(&self) -> ProtocolKind {
        ProtocolKind::HierFavg
    }

    fn run_round(&mut self, t: usize, ctx: &mut RoundCtx) -> Result<RoundRecord> {
        self.ensure_region_data(ctx);
        let m = ctx.topo.n_regions();

        // --- per-region selection --------------------------------------------
        let mut selected: Vec<usize> = Vec::new();
        for r in 0..m {
            let region = &ctx.topo.regions[r];
            let want = count_from_fraction(ctx.cfg.c_fraction, region.len());
            selected.extend(select_clients(region, want, ctx.rng));
        }
        let sel_by_region = ctx.region_counts(&selected);

        // --- fates; every edge waits for all its selected clients -------------
        let fates = ctx.simulate(&selected);
        let alive = ctx.count_alive(&fates);

        // Synchronous global round: ends when the slowest region is done.
        let mut cutoff_r = vec![0.0f64; m];
        for f in &fates {
            cutoff_r[f.region] = cutoff_r[f.region].max(f.completion);
        }
        for c in cutoff_r.iter_mut() {
            *c = c.min(ctx.tm.t_lim);
        }
        let core = cutoff_r.iter().copied().fold(0.0f64, f64::max);
        let deadline_hit = fates.iter().any(|f| f.completion > ctx.tm.t_lim);
        {
            let cr = cutoff_r.clone();
            ctx.charge_energy(&fates, move |r| cr[r]);
        }

        // --- train survivors from their regional model; edge aggregation ------
        let submissions = ctx.count_by_region(&fates, |f| {
            !f.dropped && f.completion <= cutoff_r[f.region]
        });
        let mut loss_sum = 0.0;
        let mut n_trained = 0usize;
        for r in 0..m {
            let members: Vec<_> = fates
                .iter()
                .filter(|f| {
                    f.region == r && !f.dropped && f.completion <= cutoff_r[r]
                })
                .collect();
            if members.is_empty() {
                continue; // region keeps its previous model
            }
            let start = self.regionals[r].clone();
            let mut models: Vec<(ModelParams, f64)> = Vec::with_capacity(members.len());
            for f in members {
                let (w, loss) = ctx.train(&start, f.client)?;
                loss_sum += loss;
                n_trained += 1;
                models.push((w, ctx.data.partitions[f.client].len() as f64));
            }
            let refs: Vec<(&ModelParams, f64)> =
                models.iter().map(|(w, d)| (w, *d)).collect();
            if let Some(w) = crate::aggregation::fedavg(&refs) {
                self.regionals[r] = w;
            }
        }

        // --- cloud aggregation every κ₂ rounds --------------------------------
        let cloud_round = t % self.kappa2 == 0;
        if cloud_round {
            let refs: Vec<(&ModelParams, f64)> = self
                .regionals
                .iter()
                .zip(self.region_data.iter())
                .map(|(w, d)| (w, *d))
                .collect();
            if let Some(w) = crate::aggregation::fedavg(&refs) {
                self.global = w;
            }
            // Redistribute the global model to the edges.
            for r in 0..m {
                self.regionals[r] = self.global.clone();
            }
        }

        Ok(RoundRecord {
            t,
            // Edge RTT charged on cloud rounds only (model up+down between
            // cloud and edges); client comm is inside the completions.
            round_len: core + if cloud_round { ctx.tm.t_c2e2c } else { 0.0 },
            selected: sel_by_region,
            alive,
            submissions,
            energy_j: ctx.energy_j(),
            deadline_hit,
            cloud_aggregated: cloud_round,
            mean_local_loss: if n_trained == 0 {
                f64::NAN
            } else {
                loss_sum / n_trained as f64
            },
        })
    }

    fn global_model(&self) -> &ModelParams {
        &self.global
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::test_support::mock_ctx_parts;

    #[test]
    fn cloud_aggregates_only_every_kappa2_rounds() {
        let (mut cfg, topo, data, tm, em, mut engine, profiles) =
            mock_ctx_parts(0.0, 12, 3);
        cfg.hier_kappa2 = 3;
        let mut rng = crate::rng::Rng::new(1);
        let mut proto = HierFavg::new(&cfg, &topo, engine.init_params());
        let mut cloud_rounds = Vec::new();
        for t in 1..=6 {
            let mut ctx = RoundCtx::new(
                &cfg, &topo, &data, &tm, &em, engine.as_mut(), &mut rng, &profiles,
            );
            let rec = proto.run_round(t, &mut ctx).unwrap();
            if rec.cloud_aggregated {
                cloud_rounds.push(t);
            }
        }
        assert_eq!(cloud_rounds, vec![3, 6]);
    }

    #[test]
    fn global_frozen_between_cloud_rounds_but_regionals_move() {
        let (mut cfg, topo, data, tm, em, mut engine, profiles) =
            mock_ctx_parts(0.0, 12, 3);
        cfg.hier_kappa2 = 10;
        let mut rng = crate::rng::Rng::new(2);
        let mut proto = HierFavg::new(&cfg, &topo, engine.init_params());
        let g0 = proto.global_model().clone();
        for t in 1..=3 {
            let mut ctx = RoundCtx::new(
                &cfg, &topo, &data, &tm, &em, engine.as_mut(), &mut rng, &profiles,
            );
            proto.run_round(t, &mut ctx).unwrap();
        }
        // Global untouched before round 10 …
        assert!(proto.global_model().l2_distance(&g0) < 1e-9);
        // … while regionals have accumulated training progress.
        assert!(proto.regionals.iter().any(|r| r.l2_distance(&g0) > 1e-6));
    }

    #[test]
    fn dropouts_stall_regions_to_deadline() {
        let (cfg, topo, data, tm, em, mut engine, profiles) =
            mock_ctx_parts(0.95, 12, 3);
        let mut rng = crate::rng::Rng::new(3);
        let mut proto = HierFavg::new(&cfg, &topo, engine.init_params());
        let mut ctx = RoundCtx::new(
            &cfg, &topo, &data, &tm, &em, engine.as_mut(), &mut rng, &profiles,
        );
        let rec = proto.run_round(1, &mut ctx).unwrap();
        assert!(rec.deadline_hit);
        assert!(rec.round_len >= tm.t_lim);
    }
}
