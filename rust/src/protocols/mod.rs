//! FL control protocols (S1–S3): the paper's HybridFL and the two
//! baselines it is evaluated against.
//!
//! Each protocol is written **once** against the
//! [`crate::env::FlEnvironment`] backend trait and runs unchanged on both
//! the virtual-clock simulator and the live threaded cluster. A round from
//! the protocol's side is three moves:
//!
//! 1. decide a [`crate::env::Selection`] (how many clients per region) and
//!    which model each region trains from ([`crate::env::Starts`]);
//! 2. hand the environment a [`crate::env::CutoffPolicy`] and receive a
//!    [`crate::env::RoundOutcome`] — who submitted (counts per region) and
//!    the *streamed* per-region aggregates: the environment folded every
//!    in-time model into a [`crate::aggregation::RegionAccumulator`] as
//!    it arrived, so no per-submission model buffer ever exists;
//! 3. finish aggregation from that state (the eq. 17 cache term, eq. 20's
//!    EDC weighting, or plain FedAvg recombination) and update protocol
//!    state (slack estimators, regional caches, the global model).
//!
//! Protocols receive only observables — submission counts and folded
//! aggregates — never device profiles or fates, mirroring the paper's
//! reliability-agnostic constraint. The returned [`RoundRecord`] carries
//! everything the metrics layer and the experiment harness need.

pub mod fedavg;
pub mod hierfavg;
pub mod hybridfl;

pub use fedavg::FedAvg;
pub use hierfavg::HierFavg;
pub use hybridfl::HybridFl;

use crate::config::{ExperimentConfig, ProtocolKind};
use crate::env::{FlEnvironment, RoundOutcome};
use crate::model::ModelParams;
use crate::selection::slack::{SlackEstimatorState, SlackState};
use crate::Result;

/// What a protocol reports after running one round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub t: usize,
    /// T_round (eq. 31), seconds of simulated time.
    pub round_len: f64,
    /// |U_r(t)| — clients selected, per region.
    pub selected: Vec<usize>,
    /// |X_r(t)| — selected clients that did not drop out, per region
    /// (environment ground truth; protocols never act on this, it is
    /// recorded by the backend for the metrics layer).
    pub alive: Vec<usize>,
    /// |S_r(t)| — models collected in time, per region.
    pub submissions: Vec<usize>,
    /// Per-region ground-truth availability after this round's
    /// world-dynamics step (environment truth for the metrics layer —
    /// protocols relay it, never act on it).
    pub avail: Vec<f64>,
    /// Total device energy spent this round (Joules).
    pub energy_j: f64,
    /// Device→edge bytes shipped this round — folded submissions times the
    /// configured codec's per-update wire size (backend ground truth,
    /// identical on both backends).
    pub bytes_moved: u64,
    /// Whether the quota / all-responses condition was met before T_lim.
    pub deadline_hit: bool,
    /// Whether this round updated the cloud's global model.
    pub cloud_aggregated: bool,
    /// Mean local training loss across this round's aggregated models
    /// (diagnostic).
    pub mean_local_loss: f64,
}

/// A protocol's complete mutable state at a round boundary — everything a
/// resumed run needs to continue exactly where the interrupted run
/// stopped. Captured by [`Protocol::snapshot_state`], serialized by the
/// `snapshot` codecs, and restored with [`Protocol::restore_state`].
#[derive(Clone, Debug)]
pub enum ProtocolState {
    FedAvg {
        global: ModelParams,
    },
    HierFavg {
        global: ModelParams,
        regionals: Vec<ModelParams>,
        /// |D^r| per region (filled lazily on round 1; part of the state
        /// so a resumed run never re-derives it mid-stream).
        region_data: Vec<f64>,
    },
    HybridFl {
        global: ModelParams,
        regionals: Vec<ModelParams>,
        slack: Vec<SlackEstimatorState>,
    },
}

impl ProtocolState {
    /// Which protocol this state belongs to (mismatch diagnostics).
    pub fn kind(&self) -> ProtocolKind {
        match self {
            ProtocolState::FedAvg { .. } => ProtocolKind::FedAvg,
            ProtocolState::HierFavg { .. } => ProtocolKind::HierFavg,
            ProtocolState::HybridFl { .. } => ProtocolKind::HybridFl,
        }
    }
}

/// The protocol interface the run loop drives.
pub trait Protocol {
    fn kind(&self) -> ProtocolKind;

    /// Execute round `t` (1-based) end to end against the backend:
    /// selection, training fan-out, collection, aggregation.
    fn run_round(&mut self, t: usize, env: &mut dyn FlEnvironment) -> Result<RoundRecord>;

    /// The model the cloud would currently deploy / evaluate.
    fn global_model(&self) -> &ModelParams;

    /// HybridFL's per-region slack telemetry (None for the baselines).
    fn slack_states(&self) -> Option<Vec<SlackState>> {
        None
    }

    /// Capture the full protocol state for a checkpoint (round boundary).
    fn snapshot_state(&self) -> ProtocolState;

    /// Restore state captured by [`Self::snapshot_state`] (resume path).
    /// Errors on a protocol-kind or region-count mismatch instead of
    /// silently running a hybrid of two configurations.
    fn restore_state(&mut self, state: ProtocolState) -> Result<()>;
}

/// Shared restore guard: the snapshot's region count must match the
/// protocol's current topology.
pub(crate) fn check_regions(kind: ProtocolKind, have: usize, got: usize) -> Result<()> {
    anyhow::ensure!(
        have == got,
        "{} snapshot holds {got} regional entries but the run's topology has {have} regions",
        kind.as_str()
    );
    Ok(())
}

/// Shared restore guard: the snapshot must belong to the same protocol.
pub(crate) fn wrong_kind(expected: ProtocolKind, state: &ProtocolState) -> anyhow::Error {
    anyhow::anyhow!(
        "snapshot holds {} protocol state but the run uses {}",
        state.kind().as_str(),
        expected.as_str()
    )
}

/// Instantiate the configured protocol for a topology with the given
/// per-region populations.
pub fn build_protocol(
    cfg: &ExperimentConfig,
    region_sizes: &[usize],
    init: ModelParams,
) -> Box<dyn Protocol> {
    match cfg.protocol {
        ProtocolKind::FedAvg => Box::new(FedAvg::new(init)),
        ProtocolKind::HierFavg => Box::new(HierFavg::new(cfg, region_sizes.len(), init)),
        ProtocolKind::HybridFl => Box::new(HybridFl::new(cfg, region_sizes, init)),
    }
}

/// Instantiate the protocol an environment's config asks for.
pub fn protocol_for(env: &dyn FlEnvironment) -> Box<dyn Protocol> {
    let sizes: Vec<usize> = (0..env.n_regions()).map(|r| env.region_size(r)).collect();
    build_protocol(env.cfg(), &sizes, env.init_model())
}

/// Shared helper: round a fractional client count to a concrete selection
/// size in [1, n].
pub(crate) fn count_from_fraction(fraction: f64, n: usize) -> usize {
    ((fraction * n as f64).round() as usize).clamp(1, n)
}

/// Mean local loss across the folded submissions (NaN when nothing
/// arrived) — recovered from the accumulators' running loss sums.
pub(crate) fn mean_loss(outcome: &RoundOutcome) -> f64 {
    let n: usize = outcome.regional.iter().map(|r| r.count()).sum();
    if n == 0 {
        f64::NAN
    } else {
        outcome.regional.iter().map(|r| r.loss_sum()).sum::<f64>() / n as f64
    }
}
