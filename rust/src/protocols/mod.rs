//! FL control protocols (S1–S3): the paper's HybridFL and the two
//! baselines it is evaluated against.
//!
//! Protocols orchestrate a federated round through a [`RoundCtx`], which
//! exposes exactly two capabilities:
//!
//! * `simulate(selected)` — the MEC simulator decides each selected
//!   client's fate (drop-out draw + completion time). Protocols receive
//!   [`ClientFate`]s — *who finished when* — never the underlying device
//!   profiles, mirroring the paper's reliability-agnostic constraint.
//! * `train(start, client)` — run the client's local GD epochs on the
//!   compute engine and get the updated model.
//!
//! The returned [`RoundRecord`] carries everything the metrics layer and
//! the experiment harness need (round length, per-region submission and
//! aliveness counts, energy).

pub mod fedavg;
pub mod hierfavg;
pub mod hybridfl;

pub use fedavg::FedAvg;
pub use hierfavg::HierFavg;
pub use hybridfl::HybridFl;

use crate::config::{ExperimentConfig, ProtocolKind};
use crate::data::FederatedData;
use crate::devices::ClientProfile;
use crate::energy::EnergyModel;
use crate::model::ModelParams;
use crate::rng::Rng;
use crate::runtime::Engine;
use crate::selection::slack::SlackState;
use crate::timing::TimingModel;
use crate::topology::Topology;
use crate::Result;

/// A selected client's simulated fate in one round.
#[derive(Clone, Copy, Debug)]
pub struct ClientFate {
    pub client: usize,
    pub region: usize,
    /// True if the client dropped/opted out this round (never responds).
    pub dropped: bool,
    /// Completion time from round start (comm + training) when not
    /// dropped; `f64::INFINITY` when dropped.
    pub completion: f64,
}

/// What a protocol reports after running one round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub t: usize,
    /// T_round (eq. 31), seconds of simulated time.
    pub round_len: f64,
    /// |U_r(t)| — clients selected, per region.
    pub selected: Vec<usize>,
    /// |X_r(t)| — selected clients that did not drop out, per region
    /// (simulator ground truth; protocols never see this, it is recorded
    /// by the context during `simulate`).
    pub alive: Vec<usize>,
    /// |S_r(t)| — models collected in time, per region.
    pub submissions: Vec<usize>,
    /// Total device energy spent this round (Joules).
    pub energy_j: f64,
    /// Whether the quota / all-responses condition was met before T_lim.
    pub deadline_hit: bool,
    /// Whether this round updated the cloud's global model.
    pub cloud_aggregated: bool,
    /// Mean local training loss across this round's aggregated models
    /// (diagnostic).
    pub mean_local_loss: f64,
}

/// Shared services for one round. Constructed fresh each round by the
/// run loop in `sim::FlRun`.
pub struct RoundCtx<'a> {
    pub cfg: &'a ExperimentConfig,
    pub topo: &'a Topology,
    pub data: &'a FederatedData,
    pub tm: &'a TimingModel,
    pub em: &'a EnergyModel,
    pub engine: &'a mut dyn Engine,
    pub rng: &'a mut Rng,
    /// Device ground truth — private to the simulator; protocols only
    /// access it through `simulate()`.
    profiles: &'a [ClientProfile],
    /// Energy accumulated by `simulate()` for this round.
    energy_j: f64,
}

impl<'a> RoundCtx<'a> {
    pub fn new(
        cfg: &'a ExperimentConfig,
        topo: &'a Topology,
        data: &'a FederatedData,
        tm: &'a TimingModel,
        em: &'a EnergyModel,
        engine: &'a mut dyn Engine,
        rng: &'a mut Rng,
        profiles: &'a [ClientProfile],
    ) -> RoundCtx<'a> {
        RoundCtx {
            cfg,
            topo,
            data,
            tm,
            em,
            engine,
            rng,
            profiles,
            energy_j: 0.0,
        }
    }

    /// Simulate the fates of the selected clients: independent drop-out
    /// draw per client (dr_k) and completion time from the timing model.
    /// Energy is charged separately once the protocol has determined the
    /// round cutoff — see [`Self::charge_energy`].
    pub fn simulate(&mut self, selected: &[usize]) -> Vec<ClientFate> {
        selected
            .iter()
            .map(|&k| {
                let p = &self.profiles[k];
                let dropped = self.rng.bernoulli(p.dropout_p);
                let psize = self.data.partitions[k].len() as f64;
                let completion = if dropped {
                    f64::INFINITY
                } else {
                    self.tm.completion(p, psize)
                };
                ClientFate {
                    client: k,
                    region: self.topo.region_of[k],
                    dropped,
                    completion,
                }
            })
            .collect()
    }

    /// Charge device energy for a round that ended at `cutoff(region)`:
    ///
    /// * dropped clients burn half their training energy (abort mid-epoch,
    ///   no upload);
    /// * clients finishing before the cutoff burn the full eq. 35;
    /// * stragglers are *stopped by the round-end signal* (the edge stops
    ///   waiting and tells them to abandon the round), burning only the
    ///   `cutoff/completion` fraction — this is precisely where the
    ///   quota-triggered protocols save device energy relative to the
    ///   deadline-bound baselines.
    pub fn charge_energy(
        &mut self,
        fates: &[ClientFate],
        cutoff: impl Fn(usize) -> f64,
    ) {
        for f in fates {
            let p = &self.profiles[f.client];
            let psize = self.data.partitions[f.client].len() as f64;
            let spend = if f.dropped {
                self.em.aborted_round(p, self.tm, psize).total_j()
            } else {
                let full = self.em.full_round(p, self.tm, psize).total_j();
                let cut = cutoff(f.region);
                if f.completion <= cut {
                    full
                } else {
                    full * (cut / f.completion).clamp(0.0, 1.0)
                }
            };
            self.energy_j += spend;
        }
    }

    /// Local training for one client from the given starting model.
    pub fn train(&mut self, start: &ModelParams, client: usize) -> Result<(ModelParams, f64)> {
        let out = self.engine.train_local(
            start,
            &self.data.partitions[client],
            self.cfg.local_epochs,
            self.cfg.lr as f32,
        )?;
        Ok((out.params, out.loss))
    }

    /// Energy spent so far this round (Joules).
    pub fn energy_j(&self) -> f64 {
        self.energy_j
    }

    /// Per-region |X_r| from a fate list (ground-truth bookkeeping for the
    /// record; computed by the context, not by protocol logic).
    pub fn count_alive(&self, fates: &[ClientFate]) -> Vec<usize> {
        let mut alive = vec![0usize; self.topo.n_regions()];
        for f in fates {
            if !f.dropped {
                alive[f.region] += 1;
            }
        }
        alive
    }

    /// Per-region histogram of a client list (e.g. |U_r| from a selection).
    pub fn region_counts(&self, clients: &[usize]) -> Vec<usize> {
        let mut out = vec![0usize; self.topo.n_regions()];
        for &k in clients {
            out[self.topo.region_of[k]] += 1;
        }
        out
    }

    /// Per-region count of fates matching a predicate.
    pub fn count_by_region(
        &self,
        fates: &[ClientFate],
        pred: impl Fn(&ClientFate) -> bool,
    ) -> Vec<usize> {
        let mut out = vec![0usize; self.topo.n_regions()];
        for f in fates {
            if pred(f) {
                out[f.region] += 1;
            }
        }
        out
    }
}

/// The protocol interface the run loop drives.
pub trait Protocol {
    fn kind(&self) -> ProtocolKind;

    /// Execute round `t` (1-based) end to end: selection, simulated
    /// client fates, local training of the useful survivors, aggregation.
    fn run_round(&mut self, t: usize, ctx: &mut RoundCtx) -> Result<RoundRecord>;

    /// The model the cloud would currently deploy / evaluate.
    fn global_model(&self) -> &ModelParams;

    /// HybridFL's per-region slack telemetry (None for the baselines).
    fn slack_states(&self) -> Option<Vec<SlackState>> {
        None
    }
}

/// Instantiate the configured protocol.
pub fn build_protocol(
    cfg: &ExperimentConfig,
    topo: &Topology,
    init: ModelParams,
) -> Box<dyn Protocol> {
    match cfg.protocol {
        ProtocolKind::FedAvg => Box::new(FedAvg::new(init)),
        ProtocolKind::HierFavg => Box::new(HierFavg::new(cfg, topo, init)),
        ProtocolKind::HybridFl => Box::new(HybridFl::new(cfg, topo, init)),
    }
}

/// Shared helper: round a fractional client count to a concrete selection
/// size in [1, n].
pub(crate) fn count_from_fraction(fraction: f64, n: usize) -> usize {
    ((fraction * n as f64).round() as usize).clamp(1, n)
}
