//! Minimal CLI argument parser (clap is not in the offline vendor set).
//!
//! Grammar: `hybridfl <command> [positional...] [--key value|--key=value]
//! [--switch]`. The option vocabulary is closed: a `--key` that is neither
//! a known value option nor a known switch is an error — previously an
//! unknown `--key value` silently became a switch plus a stray positional,
//! which made typos like `--portocol hybridfl` vanish into thin air.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, Vec<String>>,
    switches: Vec<String>,
}

/// Option keys that take a value; `--key value` and `--key=value` both work.
const VALUE_KEYS: &[&str] = &[
    "set", "preset", "config", "out", "seed", "protocol", "rounds", "c", "e-dr",
    "scale", "target", "backend", "checkpoint-dir", "checkpoint-every", "resume",
    "churn", "record-fates", "replay-fates", "selector", "comm", "ops-listen",
    "ops-token", "trace-out",
];

/// Boolean switches (no value).
const SWITCH_KEYS: &[&str] = &["full", "quick", "mock", "serial"];

impl Args {
    pub fn parse(raw: impl Iterator<Item = String>) -> Result<Args> {
        let mut args = Args::default();
        let mut raw = raw.peekable();
        while let Some(tok) = raw.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    if !VALUE_KEYS.contains(&k) {
                        bail!(
                            "unknown option '--{k}' (value options: {}; switches: {})",
                            VALUE_KEYS.join(", "),
                            SWITCH_KEYS.join(", ")
                        );
                    }
                    args.options.entry(k.to_string()).or_default().push(v.to_string());
                } else if VALUE_KEYS.contains(&stripped) {
                    match raw.next() {
                        Some(v) => args
                            .options
                            .entry(stripped.to_string())
                            .or_default()
                            .push(v),
                        None => bail!("--{stripped} expects a value"),
                    }
                } else if SWITCH_KEYS.contains(&stripped) {
                    args.switches.push(stripped.to_string());
                } else {
                    // Unknown key. If the next token looks like a value it
                    // would previously have been swallowed as a stray
                    // positional — refuse loudly instead.
                    match raw.peek() {
                        Some(v) if !v.starts_with("--") => bail!(
                            "unknown option '--{stripped}' (followed by '{v}'); \
                             value options: {}; switches: {}",
                            VALUE_KEYS.join(", "),
                            SWITCH_KEYS.join(", ")
                        ),
                        _ => bail!(
                            "unknown option '--{stripped}'; value options: {}; switches: {}",
                            VALUE_KEYS.join(", "),
                            SWITCH_KEYS.join(", ")
                        ),
                    }
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// First positional (the subcommand).
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    /// Last value for `--key` (repeatable keys: see `all`).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).and_then(|v| v.last()).map(|s| s.as_str())
    }

    /// All values given for a repeatable option (e.g. `--set k=v`).
    pub fn all(&self, key: &str) -> Vec<String> {
        self.options.get(key).cloned().unwrap_or_default()
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(x) => Ok(Some(x)),
                Err(e) => bail!("--{key} {v}: {e}"),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    fn parse_err(toks: &[&str]) -> String {
        Args::parse(toks.iter().map(|s| s.to_string()))
            .unwrap_err()
            .to_string()
    }

    #[test]
    fn parses_commands_options_switches() {
        let a = parse(&[
            "table3", "--set", "c=0.5", "--set=e_dr=0.6", "--full", "--out", "x.csv",
        ]);
        assert_eq!(a.command(), Some("table3"));
        assert_eq!(a.all("set"), vec!["c=0.5", "e_dr=0.6"]);
        assert!(a.has("full"));
        assert_eq!(a.get("out"), Some("x.csv"));
        assert!(!a.has("quick"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(["--preset".to_string()].into_iter()).is_err());
    }

    #[test]
    fn get_parsed_types() {
        let a = parse(&["run", "--rounds", "42"]);
        assert_eq!(a.get_parsed::<usize>("rounds").unwrap(), Some(42));
        let bad = parse(&["run", "--rounds", "xyz"]);
        assert!(bad.get_parsed::<usize>("rounds").is_err());
    }

    #[test]
    fn unknown_key_with_value_errors_helpfully() {
        // Previously: '--portocol' became a switch and 'hybridfl' a stray
        // positional. Now it errors, naming both the key and the value it
        // would have swallowed.
        let msg = parse_err(&["run", "--portocol", "hybridfl"]);
        assert!(msg.contains("--portocol"), "{msg}");
        assert!(msg.contains("hybridfl"), "{msg}");
    }

    #[test]
    fn unknown_switch_errors() {
        let msg = parse_err(&["run", "--bogus"]);
        assert!(msg.contains("--bogus"), "{msg}");
        let msg = parse_err(&["run", "--bogus=1"]);
        assert!(msg.contains("--bogus"), "{msg}");
    }

    #[test]
    fn backend_is_a_value_key() {
        let a = parse(&["run", "--backend", "live"]);
        assert_eq!(a.get("backend"), Some("live"));
    }

    #[test]
    fn churn_and_fate_flags_are_value_keys() {
        let a = parse(&[
            "run",
            "--churn",
            "markov:p_fail=0.1",
            "--record-fates",
            "trace.json",
        ]);
        assert_eq!(a.get("churn"), Some("markov:p_fail=0.1"));
        assert_eq!(a.get("record-fates"), Some("trace.json"));
        let b = parse(&["run", "--replay-fates", "trace.json"]);
        assert_eq!(b.get("replay-fates"), Some("trace.json"));
    }

    #[test]
    fn selector_is_a_value_key() {
        let a = parse(&["run", "--selector", "fedcs"]);
        assert_eq!(a.get("selector"), Some("fedcs"));
    }

    #[test]
    fn comm_is_a_value_key() {
        let a = parse(&["run", "--comm", "topk:0.05+ef"]);
        assert_eq!(a.get("comm"), Some("topk:0.05+ef"));
    }

    #[test]
    fn ops_listen_is_a_value_key() {
        let a = parse(&["run", "--ops-listen", "127.0.0.1:9184"]);
        assert_eq!(a.get("ops-listen"), Some("127.0.0.1:9184"));
    }

    #[test]
    fn ops_token_and_trace_out_are_value_keys() {
        let a = parse(&[
            "run",
            "--ops-token",
            "s3cret",
            "--trace-out",
            "spans.json",
        ]);
        assert_eq!(a.get("ops-token"), Some("s3cret"));
        assert_eq!(a.get("trace-out"), Some("spans.json"));
    }

    #[test]
    fn checkpoint_and_resume_are_value_keys() {
        let a = parse(&[
            "run",
            "--checkpoint-dir",
            "ckpts",
            "--checkpoint-every",
            "5",
            "--resume",
            "ckpts/snapshot_round_000010.hflsnap",
        ]);
        assert_eq!(a.get("checkpoint-dir"), Some("ckpts"));
        assert_eq!(a.get_parsed::<usize>("checkpoint-every").unwrap(), Some(5));
        assert_eq!(
            a.get("resume"),
            Some("ckpts/snapshot_round_000010.hflsnap")
        );
    }
}
