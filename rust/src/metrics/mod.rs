//! Metrics output (S17): CSV trace emission, fixed-width table rendering,
//! and JSON report building for the experiment harness.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Context;

use crate::jsonx::Json;
use crate::sim::{RoundTrace, RunResult, RunSummary};
use crate::Result;

/// Render per-round traces as CSV (one row per round; slack columns appear
/// when present — HybridFL runs; `avail_rN` is the per-region ground-truth
/// availability after the round's world-dynamics step, the series churn
/// analyses plot against the protocol's observables).
pub fn traces_to_csv(rounds: &[RoundTrace]) -> String {
    let mut out = String::new();
    let n_regions = rounds.first().map_or(0, |r| r.submissions.len());
    let has_slack = rounds.first().is_some_and(|r| r.slack.is_some());
    out.push_str("t,round_len,cum_time,accuracy,best_accuracy,eval_loss,cum_energy_wh,bytes_moved,deadline_hit,cloud_aggregated");
    for r in 0..n_regions {
        let _ = write!(out, ",selected_r{r},alive_r{r},submissions_r{r},avail_r{r}");
        if has_slack {
            let _ = write!(out, ",theta_r{r},c_r{r},q_r{r}");
        }
    }
    out.push('\n');
    for row in rounds {
        let _ = write!(
            out,
            "{},{:.4},{:.4},{:.6},{:.6},{:.6},{:.6},{},{},{}",
            row.t,
            row.round_len,
            row.cum_time,
            row.accuracy,
            row.best_accuracy,
            row.eval_loss,
            row.cum_energy_j / 3600.0,
            row.bytes_moved,
            row.deadline_hit as u8,
            row.cloud_aggregated as u8,
        );
        for r in 0..n_regions {
            let _ = write!(
                out,
                ",{},{},{},{:.5}",
                row.selected.get(r).copied().unwrap_or(0),
                row.alive.get(r).copied().unwrap_or(0),
                row.submissions.get(r).copied().unwrap_or(0),
                row.avail.get(r).copied().unwrap_or(0.0),
            );
            if has_slack {
                if let Some(s) = row.slack.as_ref().and_then(|v| v.get(r)) {
                    let _ = write!(out, ",{:.5},{:.5},{:.5}", s.theta, s.c_r, s.q_r);
                } else {
                    out.push_str(",,,");
                }
            }
        }
        out.push('\n');
    }
    out
}

pub fn write_csv(path: &Path, rounds: &[RoundTrace]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, traces_to_csv(rounds))
        .with_context(|| format!("writing {}", path.display()))
}

/// Summary → JSON (machine-readable reports under `reports/`).
pub fn summary_to_json(s: &RunSummary) -> Json {
    Json::obj()
        .set("protocol", s.protocol.as_str())
        .set("rounds_run", s.rounds_run)
        .set("best_accuracy", s.best_accuracy)
        .set("avg_round_len", s.avg_round_len)
        .set(
            "rounds_to_target",
            s.rounds_to_target.map_or(Json::Null, |v| Json::Num(v as f64)),
        )
        .set(
            "time_to_target",
            s.time_to_target.map_or(Json::Null, Json::Num),
        )
        .set("mean_device_energy_wh", s.mean_device_energy_wh)
        .set("total_time", s.total_time)
        .set("final_loss", s.final_loss)
}

pub fn result_to_json(r: &RunResult) -> Json {
    summary_to_json(&r.summary)
}

/// Fixed-width table renderer for terminal output — the harness prints
/// paper-style rows with it.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let sep_len: usize = widths.iter().sum::<usize>() + 3 * ncol + 1;
        let fmt_row = |cells: &[String], out: &mut String| {
            out.push('|');
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, " {:width$} |", c, width = widths[i]);
            }
            out.push('\n');
        };
        out.push_str(&"-".repeat(sep_len));
        out.push('\n');
        fmt_row(&self.headers, &mut out);
        out.push_str(&"-".repeat(sep_len));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out.push_str(&"-".repeat(sep_len));
        out.push('\n');
        out
    }
}

/// Format an `Option<f64>` table cell ("-" when the target was not hit).
pub fn opt_cell(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) => format!("{x:.prec$}"),
        None => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, ExperimentConfig, ProtocolKind};
    use crate::sim::FlRun;

    fn tiny_result() -> RunResult {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.engine = EngineKind::Mock;
        cfg.protocol = ProtocolKind::HybridFl;
        cfg.t_max = 5;
        cfg.n_clients = 10;
        cfg.n_edges = 2;
        cfg.dataset_size = 200;
        cfg.eval_size = 50;
        FlRun::new(cfg).unwrap().run().unwrap()
    }

    #[test]
    fn csv_has_header_and_rows() {
        let r = tiny_result();
        let csv = traces_to_csv(&r.rounds);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 6); // header + 5 rounds
        assert!(lines[0].starts_with("t,round_len"));
        assert!(lines[0].contains("theta_r0")); // HybridFL slack columns
        assert!(lines[0].contains("avail_r0")); // ground-truth availability
        assert!(lines[0].contains("bytes_moved")); // comm accounting
        // Every row has the same number of fields as the header.
        let n = lines[0].split(',').count();
        for l in &lines[1..] {
            assert_eq!(l.split(',').count(), n, "row: {l}");
        }
    }

    #[test]
    fn summary_json_roundtrips() {
        let r = tiny_result();
        let j = summary_to_json(&r.summary);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(
            parsed.get("protocol").unwrap().as_str().unwrap(),
            "hybridfl"
        );
        assert!(parsed.get("best_accuracy").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["protocol", "acc"]);
        t.row(vec!["fedavg".into(), "0.93".into()]);
        t.row(vec!["hybridfl-long-name".into(), "0.96".into()]);
        let s = t.render();
        assert!(s.contains("hybridfl-long-name"));
        // All body lines equal length.
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn opt_cell_formats() {
        assert_eq!(opt_cell(Some(1.23456), 2), "1.23");
        assert_eq!(opt_cell(None, 2), "-");
    }
}
