//! Metrics output (S17): CSV trace emission, fixed-width table rendering,
//! and JSON report building for the experiment harness.
//!
//! Since the ops control plane landed, the preferred way to produce run
//! artifacts is event-driven: [`ReportSink`] implements
//! [`crate::ops::RunObserver`] and builds its CSV/JSON from the same
//! round-boundary stream the live `/metrics` endpoint consumes. The free
//! functions remain for post-hoc conversion of an existing
//! [`RunResult`]; the schema-blind variants ([`traces_to_csv`],
//! [`write_csv`]) are deprecated because they guess the column layout
//! from the first trace row.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::config::{ExperimentConfig, ProtocolKind};
use crate::jsonx::Json;
use crate::ops::{RunEvent, RunObserver};
use crate::sim::{RoundTrace, RunResult, RunSummary};
use crate::Result;

/// The CSV column layout: how many per-region column groups, and whether
/// the slack telemetry columns (`theta_rN,c_rN,q_rN`) are present. Derived
/// from the *config*, never from trace rows — a resumed or segmented
/// trace can therefore never emit a header that disagrees with later
/// rows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsvSchema {
    pub n_regions: usize,
    pub has_slack: bool,
}

impl CsvSchema {
    /// The schema of any run under `cfg`: one column group per edge
    /// region; slack columns exactly when the protocol reports slack
    /// telemetry (HybridFL).
    pub fn from_config(cfg: &ExperimentConfig) -> CsvSchema {
        CsvSchema {
            n_regions: cfg.n_edges,
            has_slack: cfg.protocol == ProtocolKind::HybridFl,
        }
    }
}

/// Render per-round traces as CSV under an explicit [`CsvSchema`] (one
/// row per round; `avail_rN` is the per-region ground-truth availability
/// after the round's world-dynamics step, the series churn analyses plot
/// against the protocol's observables).
pub fn traces_to_csv_with(schema: &CsvSchema, rounds: &[RoundTrace]) -> String {
    let mut out = String::new();
    let CsvSchema {
        n_regions,
        has_slack,
    } = *schema;
    out.push_str("t,round_len,cum_time,accuracy,best_accuracy,eval_loss,cum_energy_wh,bytes_moved,deadline_hit,cloud_aggregated");
    for r in 0..n_regions {
        let _ = write!(out, ",selected_r{r},alive_r{r},submissions_r{r},avail_r{r}");
        if has_slack {
            let _ = write!(out, ",theta_r{r},c_r{r},q_r{r}");
        }
    }
    out.push('\n');
    for row in rounds {
        let _ = write!(
            out,
            "{},{:.4},{:.4},{:.6},{:.6},{:.6},{:.6},{},{},{}",
            row.t,
            row.round_len,
            row.cum_time,
            row.accuracy,
            row.best_accuracy,
            row.eval_loss,
            row.cum_energy_j / 3600.0,
            row.bytes_moved,
            row.deadline_hit as u8,
            row.cloud_aggregated as u8,
        );
        for r in 0..n_regions {
            let _ = write!(
                out,
                ",{},{},{},{:.5}",
                row.selected.get(r).copied().unwrap_or(0),
                row.alive.get(r).copied().unwrap_or(0),
                row.submissions.get(r).copied().unwrap_or(0),
                row.avail.get(r).copied().unwrap_or(0.0),
            );
            if has_slack {
                if let Some(s) = row.slack.as_ref().and_then(|v| v.get(r)) {
                    let _ = write!(out, ",{:.5},{:.5},{:.5}", s.theta, s.c_r, s.q_r);
                } else {
                    out.push_str(",,,");
                }
            }
        }
        out.push('\n');
    }
    out
}

/// [`traces_to_csv_with`] straight to a file (parent dirs created).
pub fn write_csv_with(path: &Path, schema: &CsvSchema, rounds: &[RoundTrace]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, traces_to_csv_with(schema, rounds))
        .with_context(|| format!("writing {}", path.display()))
}

/// Guess a [`CsvSchema`] from the first trace row — the legacy behavior
/// the deprecated entry points preserve.
fn schema_from_first_row(rounds: &[RoundTrace]) -> CsvSchema {
    CsvSchema {
        n_regions: rounds.first().map_or(0, |r| r.submissions.len()),
        has_slack: rounds.first().is_some_and(|r| r.slack.is_some()),
    }
}

#[deprecated(
    since = "0.9.0",
    note = "derives the column schema from the first trace row; use \
            `traces_to_csv_with(&CsvSchema::from_config(cfg), rounds)` or a \
            `ReportSink` observer"
)]
pub fn traces_to_csv(rounds: &[RoundTrace]) -> String {
    traces_to_csv_with(&schema_from_first_row(rounds), rounds)
}

#[deprecated(
    since = "0.9.0",
    note = "derives the column schema from the first trace row; use \
            `write_csv_with(path, &CsvSchema::from_config(cfg), rounds)` or a \
            `ReportSink` observer"
)]
pub fn write_csv(path: &Path, rounds: &[RoundTrace]) -> Result<()> {
    write_csv_with(path, &schema_from_first_row(rounds), rounds)
}

/// Event-driven artifact writer: a [`RunObserver`] that renders the run's
/// CSV trace (and, optionally, the JSON summary report) from the same
/// round-boundary stream the ops endpoint consumes. The CSV body is
/// appended row by row as rounds close — restored rows from a resumed
/// run are caught up on the first event — and files are flushed once, on
/// [`RunEvent::RunFinished`].
pub struct ReportSink {
    schema: CsvSchema,
    csv_path: Option<PathBuf>,
    report_path: Option<PathBuf>,
    /// CSV accumulated so far (header + every row seen).
    csv: String,
    rows_seen: usize,
}

impl ReportSink {
    /// A sink for runs under `cfg`; attach paths with [`ReportSink::csv`]
    /// / [`ReportSink::json_report`].
    pub fn new(cfg: &ExperimentConfig) -> ReportSink {
        ReportSink {
            schema: CsvSchema::from_config(cfg),
            csv_path: None,
            report_path: None,
            csv: String::new(),
            rows_seen: 0,
        }
    }

    /// Write the per-round CSV trace here at run end.
    pub fn csv(mut self, path: impl Into<PathBuf>) -> ReportSink {
        self.csv_path = Some(path.into());
        self
    }

    /// Write the JSON summary report here at run end.
    pub fn json_report(mut self, path: impl Into<PathBuf>) -> ReportSink {
        self.report_path = Some(path.into());
        self
    }

    /// The rendered CSV so far (header + closed rounds) — what the file
    /// will contain, exposed for tests and custom writers.
    pub fn csv_text(&self) -> &str {
        &self.csv
    }

    fn append_rows(&mut self, rounds: &[RoundTrace]) {
        if self.csv.is_empty() {
            self.csv = traces_to_csv_with(&self.schema, &[]);
        }
        for row in rounds.iter().skip(self.rows_seen) {
            let body = traces_to_csv_with(&self.schema, std::slice::from_ref(row));
            // Strip the header line the single-row render repeats.
            if let Some(nl) = body.find('\n') {
                self.csv.push_str(&body[nl + 1..]);
            }
        }
        self.rows_seen = rounds.len();
    }
}

impl RunObserver for ReportSink {
    fn observe(&mut self, ev: &RunEvent<'_>) -> Result<()> {
        match ev {
            RunEvent::RoundClosed { driver, .. } => self.append_rows(&driver.rounds),
            RunEvent::RunFinished { result } => {
                self.append_rows(&result.rounds);
                if let Some(path) = &self.csv_path {
                    if let Some(dir) = path.parent() {
                        std::fs::create_dir_all(dir)?;
                    }
                    std::fs::write(path, &self.csv)
                        .with_context(|| format!("writing {}", path.display()))?;
                }
                if let Some(path) = &self.report_path {
                    if let Some(dir) = path.parent() {
                        std::fs::create_dir_all(dir)?;
                    }
                    std::fs::write(path, result_to_json(result).dump())
                        .with_context(|| format!("writing {}", path.display()))?;
                }
            }
            RunEvent::CheckpointWritten { .. } | RunEvent::FaultInjected { .. } => {}
        }
        Ok(())
    }
}

/// Summary → JSON (machine-readable reports under `reports/`).
pub fn summary_to_json(s: &RunSummary) -> Json {
    Json::obj()
        .set("protocol", s.protocol.as_str())
        .set("rounds_run", s.rounds_run)
        .set("best_accuracy", s.best_accuracy)
        .set("avg_round_len", s.avg_round_len)
        .set(
            "rounds_to_target",
            s.rounds_to_target.map_or(Json::Null, |v| Json::Num(v as f64)),
        )
        .set(
            "time_to_target",
            s.time_to_target.map_or(Json::Null, Json::Num),
        )
        .set("mean_device_energy_wh", s.mean_device_energy_wh)
        .set("total_time", s.total_time)
        .set("final_loss", s.final_loss)
}

pub fn result_to_json(r: &RunResult) -> Json {
    summary_to_json(&r.summary)
}

/// Fixed-width table renderer for terminal output — the harness prints
/// paper-style rows with it.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let sep_len: usize = widths.iter().sum::<usize>() + 3 * ncol + 1;
        let fmt_row = |cells: &[String], out: &mut String| {
            out.push('|');
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, " {:width$} |", c, width = widths[i]);
            }
            out.push('\n');
        };
        out.push_str(&"-".repeat(sep_len));
        out.push('\n');
        fmt_row(&self.headers, &mut out);
        out.push_str(&"-".repeat(sep_len));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out.push_str(&"-".repeat(sep_len));
        out.push('\n');
        out
    }
}

/// Format an `Option<f64>` table cell ("-" when the target was not hit).
pub fn opt_cell(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) => format!("{x:.prec$}"),
        None => "-".to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, ExperimentConfig, ProtocolKind};
    use crate::sim::FlRun;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.engine = EngineKind::Mock;
        cfg.protocol = ProtocolKind::HybridFl;
        cfg.t_max = 5;
        cfg.n_clients = 10;
        cfg.n_edges = 2;
        cfg.dataset_size = 200;
        cfg.eval_size = 50;
        cfg
    }

    fn tiny_result() -> RunResult {
        FlRun::new(tiny_cfg()).unwrap().run().unwrap()
    }

    #[test]
    fn csv_has_header_and_rows() {
        let r = tiny_result();
        let csv = traces_to_csv_with(&CsvSchema::from_config(&tiny_cfg()), &r.rounds);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 6); // header + 5 rounds
        assert!(lines[0].starts_with("t,round_len"));
        assert!(lines[0].contains("theta_r0")); // HybridFL slack columns
        assert!(lines[0].contains("avail_r0")); // ground-truth availability
        assert!(lines[0].contains("bytes_moved")); // comm accounting
        // Every row has the same number of fields as the header.
        let n = lines[0].split(',').count();
        for l in &lines[1..] {
            assert_eq!(l.split(',').count(), n, "row: {l}");
        }
    }

    /// The config-derived schema matches what the legacy first-row guess
    /// produced on a complete trace — and, unlike it, stays correct on an
    /// empty or truncated segment.
    #[test]
    #[allow(deprecated)]
    fn legacy_wrappers_match_config_schema() {
        let cfg = tiny_cfg();
        let r = tiny_result();
        let schema = CsvSchema::from_config(&cfg);
        assert_eq!(
            schema,
            CsvSchema {
                n_regions: 2,
                has_slack: true
            }
        );
        assert_eq!(
            traces_to_csv(&r.rounds),
            traces_to_csv_with(&schema, &r.rounds)
        );
        // The legacy guess degrades on an empty trace (headerless
        // region columns); the config-derived header never does.
        assert!(!traces_to_csv(&[]).contains("avail_r0"));
        assert!(traces_to_csv_with(&schema, &[]).contains("avail_r0"));
    }

    /// `ReportSink` consuming the event stream produces exactly the CSV
    /// the post-hoc renderer produces from the final result.
    #[test]
    fn report_sink_matches_post_hoc_csv() {
        use crate::env::DriverState;

        let cfg = tiny_cfg();
        let r = tiny_result();
        let mut sink = ReportSink::new(&cfg);
        let mut driver = DriverState::fresh();
        for row in &r.rounds {
            driver.rounds.push(row.clone());
            driver.rounds_done = row.t;
            let spans = crate::trace::RoundSpans::empty(row.t);
            sink.observe(&RunEvent::RoundClosed {
                trace: driver.rounds.last().unwrap(),
                driver: &driver,
                spans: &spans,
            })
            .unwrap();
        }
        sink.observe(&RunEvent::RunFinished { result: &r }).unwrap();
        assert_eq!(
            sink.csv_text(),
            traces_to_csv_with(&CsvSchema::from_config(&cfg), &r.rounds)
        );
    }

    #[test]
    fn summary_json_roundtrips() {
        let r = tiny_result();
        let j = summary_to_json(&r.summary);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(
            parsed.get("protocol").unwrap().as_str().unwrap(),
            "hybridfl"
        );
        assert!(parsed.get("best_accuracy").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["protocol", "acc"]);
        t.row(vec!["fedavg".into(), "0.93".into()]);
        t.row(vec!["hybridfl-long-name".into(), "0.96".into()]);
        let s = t.render();
        assert!(s.contains("hybridfl-long-name"));
        // All body lines equal length.
        let lens: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    fn opt_cell_formats() {
        assert_eq!(opt_cell(Some(1.23456), 2), "1.23");
        assert_eq!(opt_cell(None, 2), "-");
    }
}
