//! Minimal JSON codec (parser + writer).
//!
//! The offline vendor set has no `serde` facade crate, so the coordinator
//! carries its own small JSON implementation. It covers the full JSON
//! grammar (objects, arrays, strings with escapes, numbers, booleans, null)
//! and is used for three things: reading `artifacts/manifest.json` written
//! by the AOT pipeline, loading/saving experiment configs, and emitting
//! machine-readable metric reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value. Object keys are kept in a BTreeMap so serialization
/// is deterministic (stable diffs in committed reports).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics if `self` is not an object (programmer
    /// error in report-building code).
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors --------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest parsing wants loud
    /// failures, not silent defaults.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    // ---- parsing ----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos).context("JSON parse error")?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing characters at byte {pos}");
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ---- writing ----------------------------------------------------------

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        // JSON has no NaN/Inf; null is the conventional stand-in.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- recursive-descent parser ---------------------------------------------

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        bail!("unexpected end of input");
    };
    match c {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, val: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(val)
    } else {
        bail!("invalid literal at byte {pos}")
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    let n: f64 = s
        .parse()
        .map_err(|_| anyhow!("invalid number '{s}' at byte {start}"))?;
    Ok(Json::Num(n))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            bail!("unterminated string");
        };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else {
                    bail!("unterminated escape");
                };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| anyhow!("bad \\u escape '{hex}'"))?;
                        *pos += 4;
                        // Surrogate pairs: decode the low half if present.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if b.get(*pos) == Some(&b'\\') && b.get(*pos + 1) == Some(&b'u') {
                                let hex2 = std::str::from_utf8(&b[*pos + 2..*pos + 6])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                *pos += 6;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| anyhow!("bad surrogate"))?
                            } else {
                                bail!("lone high surrogate");
                            }
                        } else {
                            char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => bail!("unknown escape '\\{}'", e as char),
                }
            }
            c => {
                // Re-assemble multi-byte UTF-8 (input is a &str, so valid).
                if c < 0x80 {
                    out.push(c as char);
                } else {
                    let len = utf8_len(c);
                    let slice = &b[*pos - 1..*pos - 1 + len];
                    out.push_str(std::str::from_utf8(slice)?);
                    *pos += len - 1;
                }
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => {
                *pos += 1;
            }
            Some(&b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') {
            bail!("expected object key at byte {pos}");
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            bail!("expected ':' at byte {pos}");
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => {
                *pos += 1;
            }
            Some(&b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

// ---- Into conversions for ergonomic report building ------------------------

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basics() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64().unwrap(), -2500.0);
        let re = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn parses_manifest_like_structure() {
        let src = r#"{"format":1,"tasks":{"mnist":{"params":[{"name":"w","shape":[25,6]}],
            "train_buckets":{"64":"mnist_train_p64.hlo.txt"}}}}"#;
        let v = Json::parse(src).unwrap();
        let buckets = v
            .req("tasks").unwrap()
            .req("mnist").unwrap()
            .req("train_buckets").unwrap();
        assert_eq!(
            buckets.get("64").unwrap().as_str().unwrap(),
            "mnist_train_p64.hlo.txt"
        );
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{'a':1}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn req_reports_key() {
        let v = Json::parse("{}").unwrap();
        let err = v.req("missing_thing").unwrap_err().to_string();
        assert!(err.contains("missing_thing"));
    }

    #[test]
    fn integer_formatting_stays_integral() {
        let v = Json::Num(42.0);
        assert_eq!(v.dump(), "42");
        let v = Json::Num(0.5);
        assert_eq!(v.dump(), "0.5");
    }

    #[test]
    fn builder_and_from_impls() {
        let j = Json::obj()
            .set("n", 3usize)
            .set("ok", true)
            .set("name", "run")
            .set("xs", vec![1.0, 2.0]);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("xs").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
    }
}
