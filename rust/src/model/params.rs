//! Flat model parameter store (S11) — contiguous-arena edition.
//!
//! All FL aggregation math — FedAvg weighted averaging (eq. 17), EDC
//! weighting (eq. 20), model caching — operates on [`ModelParams`]. Since
//! the data-plane refactor the store is a **single contiguous `Vec<f32>`
//! arena** with an offset table per tensor:
//!
//! ```text
//!   data:    [ t0 .......... | t1 .... | t2 ........... ]   one allocation
//!   offsets: [ 0, len(t0), len(t0)+len(t1), n_values ]      n_tensors + 1
//!   shapes:  [ [..], [..], [..] ]                           logical dims
//! ```
//!
//! Tensor `i` is the slice `data[offsets[i]..offsets[i+1]]`; logical
//! shapes are kept alongside for artifact I/O and sanity checks. The hot
//! kernels (`axpy`, `scale`, `l2_distance`) are chunked flat-slice loops
//! over the whole arena — one stream, no per-tensor pointer chasing — so
//! they auto-vectorize.
//!
//! Storage is behind an `Arc` with copy-on-write semantics: `clone()` is
//! two reference-count bumps (what the live backend's broadcast fan-out
//! relies on), and the arena is copied only when a shared instance is
//! first mutated. The arena/layout split means `zeros_like` and clones
//! share one layout allocation per model architecture.
//!
//! The module also counts live arenas (allocations, not `ModelParams`
//! handles) through [`arena_count`] / [`arena_peak`] — the instrumentation
//! the large-fleet smoke test and `params_hotpath` bench use to prove the
//! streaming round keeps O(regions) models resident.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Chunk width of the flat kernels. Eight f32 lanes = one AVX2 register;
/// the compiler unrolls/vectorizes the fixed-size inner loop.
const LANES: usize = 8;

static ACTIVE_ARENAS: AtomicUsize = AtomicUsize::new(0);
static PEAK_ARENAS: AtomicUsize = AtomicUsize::new(0);

/// Number of parameter arenas currently allocated process-wide (cheap
/// `ModelParams` clones share one arena and count once).
pub fn arena_count() -> usize {
    ACTIVE_ARENAS.load(Ordering::Relaxed)
}

/// High-water mark of [`arena_count`] since process start or the last
/// [`reset_arena_peak`].
pub fn arena_peak() -> usize {
    PEAK_ARENAS.load(Ordering::Relaxed)
}

/// Reset the high-water mark to the current live count.
pub fn reset_arena_peak() {
    PEAK_ARENAS.store(ACTIVE_ARENAS.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// The contiguous value storage, instrumented for live/peak accounting.
#[derive(Debug)]
struct Arena(Vec<f32>);

impl Arena {
    fn new(values: Vec<f32>) -> Arena {
        let now = ACTIVE_ARENAS.fetch_add(1, Ordering::Relaxed) + 1;
        PEAK_ARENAS.fetch_max(now, Ordering::Relaxed);
        Arena(values)
    }
}

impl Clone for Arena {
    fn clone(&self) -> Arena {
        // A deep copy is a new allocation — count it.
        Arena::new(self.0.clone())
    }
}

impl Drop for Arena {
    fn drop(&mut self) {
        ACTIVE_ARENAS.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Offset table + logical shapes, shared by every clone and `zeros_like`
/// of a model architecture.
#[derive(Clone, Debug, PartialEq)]
struct Layout {
    /// `offsets[i]..offsets[i+1]` is tensor `i`; `len == n_tensors + 1`.
    offsets: Vec<usize>,
    shapes: Vec<Vec<usize>>,
}

/// An ordered set of named f32 tensors backed by one contiguous arena.
#[derive(Clone, Debug)]
pub struct ModelParams {
    data: Arc<Arena>,
    layout: Arc<Layout>,
}

impl PartialEq for ModelParams {
    fn eq(&self, other: &ModelParams) -> bool {
        self.layout.shapes == other.layout.shapes && self.data.0 == other.data.0
    }
}

impl ModelParams {
    /// Build from per-tensor payloads (artifact order), flattening into
    /// one arena.
    pub fn new(tensors: Vec<Vec<f32>>, shapes: Vec<Vec<usize>>) -> ModelParams {
        debug_assert_eq!(tensors.len(), shapes.len());
        let total: usize = tensors.iter().map(|t| t.len()).sum();
        let mut offsets = Vec::with_capacity(tensors.len() + 1);
        let mut data = Vec::with_capacity(total);
        offsets.push(0);
        for (t, s) in tensors.iter().zip(shapes.iter()) {
            debug_assert_eq!(t.len(), s.iter().product::<usize>());
            data.extend_from_slice(t);
            offsets.push(data.len());
        }
        ModelParams {
            data: Arc::new(Arena::new(data)),
            layout: Arc::new(Layout { offsets, shapes }),
        }
    }

    /// Build directly from a flat arena (`data.len()` must equal the total
    /// of the shape products).
    pub fn from_flat(data: Vec<f32>, shapes: Vec<Vec<usize>>) -> ModelParams {
        let mut offsets = Vec::with_capacity(shapes.len() + 1);
        offsets.push(0);
        let mut total = 0usize;
        for s in &shapes {
            total += s.iter().product::<usize>();
            offsets.push(total);
        }
        assert_eq!(data.len(), total, "flat arena does not match shapes");
        ModelParams {
            data: Arc::new(Arena::new(data)),
            layout: Arc::new(Layout { offsets, shapes }),
        }
    }

    /// All-zero parameters with the same structure (shares the layout
    /// allocation; new arena).
    pub fn zeros_like(&self) -> ModelParams {
        ModelParams {
            data: Arc::new(Arena::new(vec![0.0; self.n_values()])),
            layout: Arc::clone(&self.layout),
        }
    }

    pub fn n_tensors(&self) -> usize {
        self.layout.shapes.len()
    }

    /// Total scalar count (O(1): arena length).
    pub fn n_values(&self) -> usize {
        self.data.0.len()
    }

    /// Logical shapes, artifact order.
    pub fn shapes(&self) -> &[Vec<usize>] {
        &self.layout.shapes
    }

    /// Tensor `i` as a slice view into the arena.
    pub fn tensor(&self, i: usize) -> &[f32] {
        &self.data.0[self.layout.offsets[i]..self.layout.offsets[i + 1]]
    }

    /// Mutable view of tensor `i` (copy-on-write if the arena is shared).
    pub fn tensor_mut(&mut self, i: usize) -> &mut [f32] {
        let (lo, hi) = (self.layout.offsets[i], self.layout.offsets[i + 1]);
        &mut Arc::make_mut(&mut self.data).0[lo..hi]
    }

    /// Slice views of all tensors, artifact order.
    pub fn tensors(&self) -> impl Iterator<Item = &[f32]> + '_ {
        self.layout
            .offsets
            .windows(2)
            .map(move |w| &self.data.0[w[0]..w[1]])
    }

    /// The whole arena as one flat slice.
    pub fn values(&self) -> &[f32] {
        &self.data.0
    }

    /// Mutable flat arena (copy-on-write if shared).
    pub fn values_mut(&mut self) -> &mut [f32] {
        &mut Arc::make_mut(&mut self.data).0
    }

    /// True when both handles share one arena allocation (cheap-clone /
    /// COW diagnostics).
    pub fn shares_arena(&self, other: &ModelParams) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// `self += a * x` — the aggregation hot loop, one chunked pass over
    /// the flat arena.
    pub fn axpy(&mut self, a: f32, x: &ModelParams) {
        debug_assert_eq!(self.layout.offsets, x.layout.offsets);
        let dst = self.values_mut();
        let src = x.values();
        assert_eq!(dst.len(), src.len(), "axpy over mismatched arenas");
        let mut d = dst.chunks_exact_mut(LANES);
        let mut s = src.chunks_exact(LANES);
        for (dc, sc) in (&mut d).zip(&mut s) {
            for j in 0..LANES {
                dc[j] += a * sc[j];
            }
        }
        for (dv, sv) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *dv += a * *sv;
        }
    }

    /// `self *= a`.
    pub fn scale(&mut self, a: f32) {
        for v in self.values_mut() {
            *v *= a;
        }
    }

    /// L2 distance to another parameter set (diagnostics, tests,
    /// convergence probes).
    pub fn l2_distance(&self, other: &ModelParams) -> f64 {
        let mut acc = 0.0f64;
        for (&x, &y) in self.values().iter().zip(other.values().iter()) {
            let d = (x - y) as f64;
            acc += d * d;
        }
        acc.sqrt()
    }

    /// Max |value| across the arena (NaN/blow-up guard in tests).
    pub fn max_abs(&self) -> f32 {
        self.values().iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.values().iter().all(|v| v.is_finite())
    }
}

/// Weighted average of models: `Σ w_i · m_i / Σ w_i`. Returns `None` when
/// the inputs are empty or all weights are ~0 (callers then keep the
/// previous model — the "round produced nothing" case).
pub fn weighted_average(models: &[(&ModelParams, f64)]) -> Option<ModelParams> {
    let total: f64 = models.iter().map(|(_, w)| *w).sum();
    if models.is_empty() || total <= f64::EPSILON {
        return None;
    }
    let mut out = models[0].0.zeros_like();
    for (m, w) in models {
        out.axpy((*w / total) as f32, m);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(vals: &[f32]) -> ModelParams {
        ModelParams::new(vec![vals.to_vec()], vec![vec![vals.len()]])
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = p(&[1.0, 2.0]);
        let b = p(&[10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.tensor(0), &[6.0, 12.0][..]);
        a.scale(2.0);
        assert_eq!(a.tensor(0), &[12.0, 24.0][..]);
    }

    /// The chunked kernel must agree with the scalar definition across the
    /// remainder boundary (lengths not divisible by the lane width).
    #[test]
    fn axpy_handles_remainder_lengths() {
        for n in [1usize, 7, 8, 9, 16, 19] {
            let xs: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let mut a = p(&vec![1.0; n]);
            let b = p(&xs);
            a.axpy(2.0, &b);
            for (i, &v) in a.tensor(0).iter().enumerate() {
                assert_eq!(v, 1.0 + 2.0 * i as f32, "n={n} i={i}");
            }
        }
    }

    #[test]
    fn weighted_average_normalizes() {
        let a = p(&[0.0, 0.0]);
        let b = p(&[4.0, 8.0]);
        let avg = weighted_average(&[(&a, 1.0), (&b, 3.0)]).unwrap();
        assert_eq!(avg.tensor(0), &[3.0, 6.0][..]);
    }

    #[test]
    fn weighted_average_empty_or_zero_is_none() {
        assert!(weighted_average(&[]).is_none());
        let a = p(&[1.0]);
        assert!(weighted_average(&[(&a, 0.0)]).is_none());
    }

    #[test]
    fn weighted_average_identity_for_single_model() {
        let a = p(&[1.5, -2.5, 3.0]);
        let avg = weighted_average(&[(&a, 0.123)]).unwrap();
        for (x, y) in avg.tensor(0).iter().zip(a.tensor(0).iter()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn l2_distance_and_max_abs() {
        let a = p(&[0.0, 3.0]);
        let b = p(&[4.0, 3.0]);
        assert!((a.l2_distance(&b) - 4.0).abs() < 1e-9);
        assert_eq!(b.max_abs(), 4.0);
        assert!(a.is_finite());
        let bad = p(&[f32::NAN]);
        assert!(!bad.is_finite());
    }

    #[test]
    fn zeros_like_preserves_structure() {
        let a = ModelParams::new(
            vec![vec![1.0; 6], vec![2.0; 3]],
            vec![vec![2, 3], vec![3]],
        );
        let z = a.zeros_like();
        assert_eq!(z.n_tensors(), 2);
        assert_eq!(z.n_values(), 9);
        assert!(z.values().iter().all(|&v| v == 0.0));
        assert_eq!(z.shapes(), a.shapes());
    }

    #[test]
    fn arena_is_contiguous_with_offset_views() {
        let a = ModelParams::new(
            vec![vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![7.0, 8.0, 9.0]],
            vec![vec![2, 3], vec![3]],
        );
        // The flat arena is the tensors concatenated in artifact order …
        assert_eq!(
            a.values(),
            &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0][..]
        );
        // … and per-tensor views are windows into it.
        assert_eq!(a.tensor(0), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0][..]);
        assert_eq!(a.tensor(1), &[7.0, 8.0, 9.0][..]);
        assert_eq!(a.tensors().count(), 2);
    }

    #[test]
    fn from_flat_matches_new() {
        let shapes = vec![vec![2, 2], vec![3]];
        let a = ModelParams::from_flat(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0], shapes.clone());
        let b = ModelParams::new(
            vec![vec![1.0, 2.0, 3.0, 4.0], vec![5.0, 6.0, 7.0]],
            shapes,
        );
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "flat arena does not match shapes")]
    fn from_flat_rejects_size_mismatch() {
        ModelParams::from_flat(vec![0.0; 5], vec![vec![2, 2]]);
    }

    /// Broadcast economics: clone is an Arc bump (shared arena); mutation
    /// copies on write, leaving the original untouched.
    #[test]
    fn clone_is_shared_until_mutated() {
        let a = p(&[1.0, 2.0, 3.0]);
        let mut b = a.clone();
        assert!(a.shares_arena(&b));
        b.values_mut()[0] = 9.0;
        assert!(!a.shares_arena(&b));
        assert_eq!(a.tensor(0), &[1.0, 2.0, 3.0][..]);
        assert_eq!(b.tensor(0), &[9.0, 2.0, 3.0][..]);
    }

    /// Arena accounting counts allocations, not handles. Other tests run
    /// concurrently and move the global counters too, so the assertions
    /// use a large batch with generous slack instead of exact equality.
    #[test]
    fn arena_accounting_tracks_allocations_not_handles() {
        const N: usize = 4096;
        const SLACK: usize = 512;
        let a = p(&[1.0; 16]);
        let before = arena_count();
        let deep: Vec<ModelParams> = (0..N).map(|_| a.zeros_like()).collect();
        let shared: Vec<ModelParams> = (0..N).map(|_| a.clone()).collect();
        let held = arena_count();
        // N new arenas from zeros_like; the N cheap clones add none.
        assert!(held + SLACK >= before + N, "held={held} before={before}");
        assert!(held <= before + N + SLACK, "held={held} before={before}");
        assert!(arena_peak() >= held);
        drop(deep);
        drop(shared);
        let after = arena_count();
        assert!(after <= held - N + SLACK, "after={after} held={held}");
    }
}
