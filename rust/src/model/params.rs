//! Flat model parameter store (S11).
//!
//! All FL aggregation math — FedAvg weighted averaging (eq. 17), EDC
//! weighting (eq. 20), model caching — operates on [`ModelParams`]: an
//! ordered list of f32 tensors matching the AOT artifact's parameter
//! order. The hot loop is `axpy` (scaled accumulate), which the
//! aggregators call once per contributing model.

/// An ordered set of named f32 tensors.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelParams {
    /// Tensor payloads, artifact order.
    pub tensors: Vec<Vec<f32>>,
    /// Logical shapes (same order). Kept for literal construction and
    /// sanity checks; `tensors[i].len() == shapes[i].iter().product()`.
    pub shapes: Vec<Vec<usize>>,
}

impl ModelParams {
    pub fn new(tensors: Vec<Vec<f32>>, shapes: Vec<Vec<usize>>) -> ModelParams {
        debug_assert_eq!(tensors.len(), shapes.len());
        for (t, s) in tensors.iter().zip(shapes.iter()) {
            debug_assert_eq!(t.len(), s.iter().product::<usize>());
        }
        ModelParams { tensors, shapes }
    }

    /// All-zero parameters with the same structure.
    pub fn zeros_like(&self) -> ModelParams {
        ModelParams {
            tensors: self.tensors.iter().map(|t| vec![0.0; t.len()]).collect(),
            shapes: self.shapes.clone(),
        }
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    /// Total scalar count.
    pub fn n_values(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// `self += a * x` — the aggregation hot loop.
    pub fn axpy(&mut self, a: f32, x: &ModelParams) {
        debug_assert_eq!(self.n_tensors(), x.n_tensors());
        for (dst, src) in self.tensors.iter_mut().zip(x.tensors.iter()) {
            debug_assert_eq!(dst.len(), src.len());
            for (d, &s) in dst.iter_mut().zip(src.iter()) {
                *d += a * s;
            }
        }
    }

    /// `self *= a`.
    pub fn scale(&mut self, a: f32) {
        for t in self.tensors.iter_mut() {
            for v in t.iter_mut() {
                *v *= a;
            }
        }
    }

    /// L2 distance to another parameter set (diagnostics, tests,
    /// convergence probes).
    pub fn l2_distance(&self, other: &ModelParams) -> f64 {
        let mut acc = 0.0f64;
        for (a, b) in self.tensors.iter().zip(other.tensors.iter()) {
            for (&x, &y) in a.iter().zip(b.iter()) {
                let d = (x - y) as f64;
                acc += d * d;
            }
        }
        acc.sqrt()
    }

    /// Max |value| across all tensors (NaN/blow-up guard in tests).
    pub fn max_abs(&self) -> f32 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    pub fn is_finite(&self) -> bool {
        self.tensors.iter().all(|t| t.iter().all(|v| v.is_finite()))
    }
}

/// Weighted average of models: `Σ w_i · m_i / Σ w_i`. Returns `None` when
/// the inputs are empty or all weights are ~0 (callers then keep the
/// previous model — the "round produced nothing" case).
pub fn weighted_average(models: &[(&ModelParams, f64)]) -> Option<ModelParams> {
    let total: f64 = models.iter().map(|(_, w)| *w).sum();
    if models.is_empty() || total <= f64::EPSILON {
        return None;
    }
    let mut out = models[0].0.zeros_like();
    for (m, w) in models {
        out.axpy((*w / total) as f32, m);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(vals: &[f32]) -> ModelParams {
        ModelParams::new(vec![vals.to_vec()], vec![vec![vals.len()]])
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = p(&[1.0, 2.0]);
        let b = p(&[10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.tensors[0], vec![6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.tensors[0], vec![12.0, 24.0]);
    }

    #[test]
    fn weighted_average_normalizes() {
        let a = p(&[0.0, 0.0]);
        let b = p(&[4.0, 8.0]);
        let avg = weighted_average(&[(&a, 1.0), (&b, 3.0)]).unwrap();
        assert_eq!(avg.tensors[0], vec![3.0, 6.0]);
    }

    #[test]
    fn weighted_average_empty_or_zero_is_none() {
        assert!(weighted_average(&[]).is_none());
        let a = p(&[1.0]);
        assert!(weighted_average(&[(&a, 0.0)]).is_none());
    }

    #[test]
    fn weighted_average_identity_for_single_model() {
        let a = p(&[1.5, -2.5, 3.0]);
        let avg = weighted_average(&[(&a, 0.123)]).unwrap();
        for (x, y) in avg.tensors[0].iter().zip(a.tensors[0].iter()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn l2_distance_and_max_abs() {
        let a = p(&[0.0, 3.0]);
        let b = p(&[4.0, 3.0]);
        assert!((a.l2_distance(&b) - 4.0).abs() < 1e-9);
        assert_eq!(b.max_abs(), 4.0);
        assert!(a.is_finite());
        let bad = p(&[f32::NAN]);
        assert!(!bad.is_finite());
    }

    #[test]
    fn zeros_like_preserves_structure() {
        let a = ModelParams::new(
            vec![vec![1.0; 6], vec![2.0; 3]],
            vec![vec![2, 3], vec![3]],
        );
        let z = a.zeros_like();
        assert_eq!(z.n_tensors(), 2);
        assert_eq!(z.n_values(), 9);
        assert!(z.tensors.iter().flatten().all(|&v| v == 0.0));
        assert_eq!(z.shapes, a.shapes);
    }
}
