//! `artifacts/manifest.json` loader — the contract between the AOT
//! pipeline (`python/compile/aot.py`) and the Rust runtime.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::TaskKind;
use crate::jsonx::Json;

/// One parameter tensor's name and shape, artifact order.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Everything the runtime needs to load one task's executables.
#[derive(Clone, Debug)]
pub struct TaskManifest {
    pub task: TaskKind,
    pub params: Vec<ParamSpec>,
    /// Input feature dims (e.g. [5] or [1, 28, 28]).
    pub x_dims: Vec<usize>,
    /// Names of the three eval-sum outputs (documentation / sanity).
    pub eval_outputs: Vec<String>,
    /// (capacity, path) ascending by capacity.
    pub train_buckets: Vec<(usize, PathBuf)>,
    /// (capacity, path) ascending by capacity.
    pub eval_buckets: Vec<(usize, PathBuf)>,
    pub init_npz: PathBuf,
}

impl TaskManifest {
    /// Load the manifest for `task` from `artifacts_dir`.
    pub fn load(artifacts_dir: &Path, task: TaskKind) -> Result<TaskManifest> {
        let manifest_path = artifacts_dir.join("manifest.json");
        let root = Json::parse_file(&manifest_path).with_context(|| {
            format!(
                "loading {} — run `make artifacts` first",
                manifest_path.display()
            )
        })?;
        let entry = root
            .req("tasks")?
            .req(task.as_str())
            .with_context(|| format!("task '{}' not in manifest", task.as_str()))?;

        let params = entry
            .req("params")?
            .as_arr()?
            .iter()
            .map(|p| {
                Ok(ParamSpec {
                    name: p.req("name")?.as_str()?.to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()?
                        .iter()
                        .map(|d| d.as_usize())
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let x_dims = entry
            .req("x_dims")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;

        let eval_outputs = entry
            .req("eval_outputs")?
            .as_arr()?
            .iter()
            .map(|s| Ok(s.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?;

        let buckets = |key: &str| -> Result<Vec<(usize, PathBuf)>> {
            let mut out: Vec<(usize, PathBuf)> = entry
                .req(key)?
                .as_obj()?
                .iter()
                .map(|(cap, path)| {
                    let cap: usize = cap
                        .parse()
                        .with_context(|| format!("bad bucket capacity '{cap}'"))?;
                    Ok((cap, artifacts_dir.join(path.as_str()?)))
                })
                .collect::<Result<Vec<_>>>()?;
            out.sort_by_key(|(c, _)| *c);
            if out.is_empty() {
                bail!("no {key} in manifest for {}", task.as_str());
            }
            Ok(out)
        };

        let tm = TaskManifest {
            task,
            params,
            x_dims,
            eval_outputs,
            train_buckets: buckets("train_buckets")?,
            eval_buckets: buckets("eval_buckets")?,
            init_npz: artifacts_dir.join(entry.req("init_npz")?.as_str()?),
        };
        for (_, p) in tm.train_buckets.iter().chain(tm.eval_buckets.iter()) {
            if !p.exists() {
                bail!("artifact missing: {} — run `make artifacts`", p.display());
            }
        }
        Ok(tm)
    }

    /// Flattened per-sample feature length.
    pub fn feat_len(&self) -> usize {
        self.x_dims.iter().product()
    }

    /// Smallest train bucket with capacity ≥ `n`, or the largest bucket if
    /// `n` exceeds all capacities (the batch builder then truncates — see
    /// DESIGN.md on fixed-shape padding).
    pub fn pick_train_bucket(&self, n: usize) -> (usize, &Path) {
        for (cap, path) in &self.train_buckets {
            if *cap >= n {
                return (*cap, path);
            }
        }
        let (cap, path) = self.train_buckets.last().unwrap();
        (*cap, path)
    }

    pub fn eval_bucket(&self) -> (usize, &Path) {
        let (cap, path) = self.eval_buckets.last().unwrap();
        (*cap, path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // Tests run from the crate root; artifacts are built by `make`.
        PathBuf::from("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_both_tasks() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts");
            return;
        }
        for task in [TaskKind::Aerofoil, TaskKind::Mnist] {
            let m = TaskManifest::load(&artifacts_dir(), task).unwrap();
            assert!(!m.params.is_empty());
            assert!(!m.train_buckets.is_empty());
            assert_eq!(m.eval_outputs.len(), 3);
            assert!(m.init_npz.exists());
        }
    }

    #[test]
    fn mnist_shapes_match_lenet() {
        if !have_artifacts() {
            return;
        }
        let m = TaskManifest::load(&artifacts_dir(), TaskKind::Mnist).unwrap();
        assert_eq!(m.x_dims, vec![1, 28, 28]);
        assert_eq!(m.params.len(), 10);
        assert_eq!(m.params[0].shape, vec![25, 6]); // conv1 im2col weights
        let total: usize = m
            .params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum();
        assert_eq!(total, 44_426);
    }

    #[test]
    fn bucket_selection_policy() {
        if !have_artifacts() {
            return;
        }
        let m = TaskManifest::load(&artifacts_dir(), TaskKind::Mnist).unwrap();
        let (small, _) = m.pick_train_bucket(10);
        assert_eq!(small, 64);
        let (big, _) = m.pick_train_bucket(100);
        assert_eq!(big, 256);
        // Oversized partitions fall back to the largest bucket.
        let (cap, _) = m.pick_train_bucket(10_000);
        assert_eq!(cap, 256);
    }

    #[test]
    fn missing_task_errors() {
        if !have_artifacts() {
            return;
        }
        let err = TaskManifest::load(&PathBuf::from("/nonexistent"), TaskKind::Mnist);
        assert!(err.is_err());
    }
}
