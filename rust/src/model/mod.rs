//! Model parameter store and artifact manifest (S11).

pub mod manifest;
pub mod params;

pub use manifest::{ParamSpec, TaskManifest};
pub use params::{
    arena_count, arena_peak, reset_arena_peak, weighted_average, ModelParams,
};
