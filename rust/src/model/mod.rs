//! Model parameter store and artifact manifest (S11).

pub mod manifest;
pub mod params;

pub use manifest::{ParamSpec, TaskManifest};
pub use params::{weighted_average, ModelParams};
