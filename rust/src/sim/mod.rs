//! The FL run engine (S8): assembles topology, fleet, data, timing,
//! energy, compute engine and protocol, then drives rounds on a virtual
//! clock, recording everything the experiment harness needs.

mod run;
pub mod test_support;

pub use run::{FlRun, RoundTrace, RunResult, RunSummary};
