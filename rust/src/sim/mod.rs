//! The virtual-clock FL run engine (S8), now a convenience layer over the
//! [`crate::env::VirtualClockEnv`] backend: assembles topology, fleet,
//! data, timing, energy, compute engine and protocol, then drives rounds
//! on the virtual clock, recording everything the experiment harness
//! needs. The trace/summary types are re-exported from `crate::env`, where
//! they are shared by every backend.

mod run;
pub mod test_support;

pub use crate::env::{RoundTrace, RunResult, RunSummary};
pub use run::FlRun;
