//! Shared fixtures for protocol unit tests: a fully-assembled
//! [`VirtualClockEnv`] over the mock engine, the canonical two-region
//! fleet the churn/selection suites drive, and the reduced-scale PJRT
//! configs for the end-to-end tests. Exposed as a public module so
//! integration tests and benches can reuse it, but not part of the
//! stable API surface.

use crate::churn::ChurnModel;
use crate::config::{Dist, EngineKind, ExperimentConfig, ProtocolKind, RegionSpec, TaskKind};
use crate::env::VirtualClockEnv;

/// A small mock-engine config with a uniform drop-out probability across
/// the fleet (fixed world seed 99 unless the caller overrides `seed`).
pub fn mock_cfg(dropout: f64, n_clients: usize, n_edges: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.n_clients = n_clients;
    cfg.n_edges = n_edges;
    cfg.dataset_size = (n_clients * 30).max(200);
    cfg.eval_size = 50;
    cfg.dropout = Dist::new(dropout, 0.0);
    cfg.seed = 99;
    cfg.validate().expect("fixture config must validate");
    cfg
}

/// Build a ready-to-drive virtual-clock environment over [`mock_cfg`].
pub fn mock_env(dropout: f64, n_clients: usize, n_edges: usize) -> VirtualClockEnv {
    VirtualClockEnv::new(mock_cfg(dropout, n_clients, n_edges))
        .expect("fixture environment must build")
}

/// Two explicit 20-client regions on the mock engine with *heterogeneous*
/// per-region drop-out means — the regional imbalance the slack estimator
/// exists for. 20-round HybridFL run, fixed seed 13; callers override
/// `t_max`/`seed`/`protocol` as needed.
pub fn hetero_two_region_cfg(dropout_mean_0: f64, dropout_mean_1: f64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.protocol = ProtocolKind::HybridFl;
    cfg.n_clients = 40;
    cfg.n_edges = 2;
    cfg.regions = vec![
        RegionSpec { n_clients: 20, dropout_mean: dropout_mean_0 },
        RegionSpec { n_clients: 20, dropout_mean: dropout_mean_1 },
    ];
    cfg.dropout = Dist::new((dropout_mean_0 + dropout_mean_1) / 2.0, 0.02);
    cfg.c_fraction = 0.3;
    cfg.dataset_size = 800;
    cfg.eval_size = 50;
    cfg.t_max = 20;
    cfg.seed = 13;
    cfg
}

/// [`hetero_two_region_cfg`] with both regions at the same mean — the
/// fleet the churn-dynamics suite has pinned byte-identity against.
pub fn two_region_cfg(dropout_mean: f64) -> ExperimentConfig {
    hetero_two_region_cfg(dropout_mean, dropout_mean)
}

/// The canonical bursty-availability churn spec: clients fail into a
/// near-dead state (drop-out 0.97) and recover, uniformly across regions.
pub fn markov_churn() -> ChurnModel {
    ChurnModel::MarkovOnOff {
        p_fail: 0.25,
        p_recover: 0.35,
        down_dropout: 0.97,
        region_scale: Vec::new(),
    }
}

/// A scratch path under the OS temp dir, namespaced per suite so
/// concurrent test binaries never collide.
pub fn tmp_path(suite: &str, name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("hybridfl_{suite}"));
    let _ = std::fs::create_dir_all(&dir);
    dir.join(name)
}

/// Reduced-scale real-training config for the end-to-end suite: the
/// task's `*_scaled` preset trimmed to `t_max` rounds.
pub fn e2e_cfg(task: TaskKind, t_max: usize) -> ExperimentConfig {
    let mut cfg = match task {
        TaskKind::Aerofoil => ExperimentConfig::task1_scaled(),
        TaskKind::Mnist => ExperimentConfig::task2_scaled(),
    };
    cfg.t_max = t_max;
    cfg
}
