//! Shared fixtures for protocol unit tests: a fully-assembled
//! [`VirtualClockEnv`] over the mock engine. Exposed as a public module so
//! integration tests and benches can reuse it, but not part of the stable
//! API surface.

use crate::config::{Dist, EngineKind, ExperimentConfig};
use crate::env::VirtualClockEnv;

/// A small mock-engine config with a uniform drop-out probability across
/// the fleet (fixed world seed 99 unless the caller overrides `seed`).
pub fn mock_cfg(dropout: f64, n_clients: usize, n_edges: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.n_clients = n_clients;
    cfg.n_edges = n_edges;
    cfg.dataset_size = (n_clients * 30).max(200);
    cfg.eval_size = 50;
    cfg.dropout = Dist::new(dropout, 0.0);
    cfg.seed = 99;
    cfg.validate().expect("fixture config must validate");
    cfg
}

/// Build a ready-to-drive virtual-clock environment over [`mock_cfg`].
pub fn mock_env(dropout: f64, n_clients: usize, n_edges: usize) -> VirtualClockEnv {
    VirtualClockEnv::new(mock_cfg(dropout, n_clients, n_edges))
        .expect("fixture environment must build")
}
