//! Shared fixtures for protocol unit tests: a fully-assembled set of round
//! context ingredients over the mock engine. Exposed as a public module so
//! integration tests and benches can reuse it, but not part of the stable
//! API surface.

use std::sync::Arc;

use crate::config::{Dist, EngineKind, ExperimentConfig};
use crate::data::FederatedData;
use crate::devices::{self, ClientProfile};
use crate::energy::EnergyModel;
use crate::rng::Rng;
use crate::runtime::{build_engine, Engine};
use crate::timing::TimingModel;
use crate::topology::Topology;

/// Build every ingredient a `RoundCtx` needs, with a uniform drop-out
/// probability across the fleet and the mock engine.
#[allow(clippy::type_complexity)]
pub fn mock_ctx_parts(
    dropout: f64,
    n_clients: usize,
    n_edges: usize,
) -> (
    ExperimentConfig,
    Topology,
    Arc<FederatedData>,
    TimingModel,
    EnergyModel,
    Box<dyn Engine>,
    Vec<ClientProfile>,
) {
    let mut cfg = ExperimentConfig::task1_scaled();
    cfg.engine = EngineKind::Mock;
    cfg.n_clients = n_clients;
    cfg.n_edges = n_edges;
    cfg.dataset_size = (n_clients * 30).max(200);
    cfg.eval_size = 50;
    cfg.dropout = Dist::new(dropout, 0.0);
    cfg.validate().expect("fixture config must validate");

    let mut rng = Rng::new(99);
    let topo = Topology::build(&cfg, &mut rng.split(1)).unwrap();
    let data = Arc::new(crate::data::build(&cfg, &mut rng.split(2)));
    let profiles = devices::sample_fleet(&cfg, &topo, &mut rng.split(3));
    let tm = TimingModel::new(&cfg);
    let em = EnergyModel::new(&cfg);
    let engine = build_engine(&cfg, Arc::clone(&data)).unwrap();
    (cfg, topo, data, tm, em, engine, profiles)
}
