//! `FlRun` — one complete federated-learning experiment on a virtual clock.

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::data::FederatedData;
use crate::devices::{self, ClientProfile};
use crate::energy::EnergyModel;
use crate::protocols::{build_protocol, Protocol, RoundCtx};
use crate::rng::Rng;
use crate::runtime::{build_engine, Engine};
use crate::selection::slack::SlackState;
use crate::timing::TimingModel;
use crate::topology::Topology;
use crate::Result;

/// Per-round trace row — one per executed round. This is the substrate for
/// every figure: accuracy traces (Figs. 4/6), slack traces (Fig. 2), energy
/// accumulation (Figs. 5/7).
#[derive(Clone, Debug)]
pub struct RoundTrace {
    pub t: usize,
    pub round_len: f64,
    /// Virtual time at the end of this round.
    pub cum_time: f64,
    /// Global-model accuracy after this round (evaluated every
    /// `eval_every` rounds; in between, carries the last measured value).
    pub accuracy: f64,
    /// Best accuracy seen so far ("the cloud always keeps the best global
    /// model").
    pub best_accuracy: f64,
    pub eval_loss: f64,
    pub selected: Vec<usize>,
    pub alive: Vec<usize>,
    pub submissions: Vec<usize>,
    /// Cumulative device energy, Joules, across the fleet.
    pub cum_energy_j: f64,
    pub deadline_hit: bool,
    pub cloud_aggregated: bool,
    /// HybridFL slack telemetry (θ̂_r, C_r, q_r per region).
    pub slack: Option<Vec<SlackState>>,
}

/// End-of-run aggregates — the numbers the paper's tables report.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub protocol: String,
    pub rounds_run: usize,
    /// Best global-model accuracy over the run ("Best Accuracy").
    pub best_accuracy: f64,
    /// Mean T_round ("Round length (sec)").
    pub avg_round_len: f64,
    /// Rounds needed to reach `target_accuracy` ("Rounds needed"), if hit.
    pub rounds_to_target: Option<usize>,
    /// Virtual time to reach the target ("Total time (sec)"), if hit.
    pub time_to_target: Option<f64>,
    /// Mean per-device energy in Wh over the whole run (Figs. 5/7).
    pub mean_device_energy_wh: f64,
    /// Total virtual time of the run.
    pub total_time: f64,
    pub final_loss: f64,
}

#[derive(Clone, Debug)]
pub struct RunResult {
    pub summary: RunSummary,
    pub rounds: Vec<RoundTrace>,
}

/// A fully-assembled experiment, ready to run.
pub struct FlRun {
    pub cfg: ExperimentConfig,
    pub topo: Topology,
    pub data: Arc<FederatedData>,
    pub profiles: Vec<ClientProfile>,
    pub tm: TimingModel,
    pub em: EnergyModel,
    engine: Box<dyn Engine>,
    protocol: Box<dyn Protocol>,
    rng: Rng,
}

impl FlRun {
    /// Build everything from a config (deterministic in `cfg.seed`).
    pub fn new(cfg: ExperimentConfig) -> Result<FlRun> {
        cfg.validate()?;
        let mut rng = Rng::new(cfg.seed);
        let topo = Topology::build(&cfg, &mut rng.split(1))?;
        let data = Arc::new(crate::data::build(&cfg, &mut rng.split(2)));
        let profiles = devices::sample_fleet(&cfg, &topo, &mut rng.split(3));
        let tm = TimingModel::new(&cfg);
        let em = EnergyModel::new(&cfg);
        let engine = build_engine(&cfg, Arc::clone(&data))?;
        let protocol = build_protocol(&cfg, &topo, engine.init_params());
        Ok(FlRun {
            cfg,
            topo,
            data,
            profiles,
            tm,
            em,
            engine,
            protocol,
            rng: rng.split(4),
        })
    }

    /// Run to `t_max` rounds or until `target_accuracy` is reached.
    pub fn run(mut self) -> Result<RunResult> {
        let mut rounds: Vec<RoundTrace> = Vec::with_capacity(self.cfg.t_max);
        let mut cum_time = 0.0f64;
        let mut cum_energy = 0.0f64;
        let mut best_acc = f64::MIN;
        let mut last_acc = 0.0f64;
        let mut last_loss = f64::NAN;
        let mut rounds_to_target = None;
        let mut time_to_target = None;

        for t in 1..=self.cfg.t_max {
            let mut round_rng = self.rng.split(t as u64);
            let rec = {
                let mut ctx = RoundCtx::new(
                    &self.cfg,
                    &self.topo,
                    &self.data,
                    &self.tm,
                    &self.em,
                    self.engine.as_mut(),
                    &mut round_rng,
                    &self.profiles,
                );
                self.protocol.run_round(t, &mut ctx)?
            };
            cum_time += rec.round_len;
            cum_energy += rec.energy_j;

            if t % self.cfg.eval_every == 0 || t == self.cfg.t_max {
                let ev = self.engine.evaluate(self.protocol.global_model())?;
                last_acc = ev.accuracy;
                last_loss = ev.loss;
            }
            best_acc = best_acc.max(last_acc);

            rounds.push(RoundTrace {
                t,
                round_len: rec.round_len,
                cum_time,
                accuracy: last_acc,
                best_accuracy: best_acc,
                eval_loss: last_loss,
                selected: rec.selected,
                alive: rec.alive,
                submissions: rec.submissions,
                cum_energy_j: cum_energy,
                deadline_hit: rec.deadline_hit,
                cloud_aggregated: rec.cloud_aggregated,
                slack: self.protocol.slack_states(),
            });

            if let Some(target) = self.cfg.target_accuracy {
                if best_acc >= target && rounds_to_target.is_none() {
                    rounds_to_target = Some(t);
                    time_to_target = Some(cum_time);
                    break; // "Stop @Acc" mode
                }
            }
        }

        let n_rounds = rounds.len().max(1);
        let summary = RunSummary {
            protocol: self.cfg.protocol.as_str().to_string(),
            rounds_run: rounds.len(),
            best_accuracy: best_acc.max(0.0),
            avg_round_len: cum_time / n_rounds as f64,
            rounds_to_target,
            time_to_target,
            mean_device_energy_wh: cum_energy / 3600.0 / self.cfg.n_clients as f64,
            total_time: cum_time,
            final_loss: last_loss,
        };
        Ok(RunResult { summary, rounds })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, ProtocolKind};

    fn mock_cfg(protocol: ProtocolKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.engine = EngineKind::Mock;
        cfg.protocol = protocol;
        cfg.t_max = 30;
        cfg.n_clients = 20;
        cfg.n_edges = 2;
        cfg.dataset_size = 400;
        cfg.eval_size = 50;
        cfg
    }

    #[test]
    fn full_run_all_protocols_mock() {
        for p in ProtocolKind::ALL {
            let result = FlRun::new(mock_cfg(p)).unwrap().run().unwrap();
            assert_eq!(result.rounds.len(), 30, "{p:?}");
            assert!(result.summary.best_accuracy > 0.0, "{p:?}");
            assert!(result.summary.avg_round_len > 0.0, "{p:?}");
            assert!(result.summary.mean_device_energy_wh > 0.0, "{p:?}");
            // Virtual time is monotone.
            for w in result.rounds.windows(2) {
                assert!(w[1].cum_time > w[0].cum_time);
                assert!(w[1].best_accuracy >= w[0].best_accuracy);
            }
        }
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let a = FlRun::new(mock_cfg(ProtocolKind::HybridFl)).unwrap().run().unwrap();
        let b = FlRun::new(mock_cfg(ProtocolKind::HybridFl)).unwrap().run().unwrap();
        assert_eq!(a.summary.best_accuracy, b.summary.best_accuracy);
        assert_eq!(a.summary.avg_round_len, b.summary.avg_round_len);
        for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
            assert_eq!(ra.submissions, rb.submissions);
            assert_eq!(ra.round_len, rb.round_len);
        }
        let mut cfg = mock_cfg(ProtocolKind::HybridFl);
        cfg.seed = 777;
        let c = FlRun::new(cfg).unwrap().run().unwrap();
        assert_ne!(a.summary.avg_round_len, c.summary.avg_round_len);
    }

    #[test]
    fn target_accuracy_stops_early() {
        let mut cfg = mock_cfg(ProtocolKind::HybridFl);
        cfg.t_max = 500;
        cfg.target_accuracy = Some(0.3);
        let result = FlRun::new(cfg).unwrap().run().unwrap();
        assert!(result.summary.rounds_to_target.is_some());
        assert!(result.summary.rounds_run < 500);
        assert!(result.summary.time_to_target.unwrap() > 0.0);
    }

    #[test]
    fn hybridfl_traces_include_slack() {
        let result = FlRun::new(mock_cfg(ProtocolKind::HybridFl)).unwrap().run().unwrap();
        assert!(result.rounds[0].slack.is_some());
        assert_eq!(result.rounds[0].slack.as_ref().unwrap().len(), 2);
        let fed = FlRun::new(mock_cfg(ProtocolKind::FedAvg)).unwrap().run().unwrap();
        assert!(fed.rounds[0].slack.is_none());
    }

    /// The paper's headline shape at moderate drop-out: HybridFL's average
    /// round is shorter than FedAvg's and HierFAVG's under identical seeds.
    #[test]
    fn hybridfl_rounds_shorter_under_dropout() {
        let mut lens = std::collections::HashMap::new();
        for p in ProtocolKind::ALL {
            let mut cfg = mock_cfg(p);
            cfg.dropout.mean = 0.3;
            cfg.t_max = 40;
            let r = FlRun::new(cfg).unwrap().run().unwrap();
            lens.insert(p.as_str(), r.summary.avg_round_len);
        }
        let hybrid = lens["hybridfl"];
        assert!(
            hybrid < lens["fedavg"] && hybrid < lens["hierfavg"] * 1.05,
            "round lengths: {lens:?}"
        );
    }
}
