//! `FlRun` — one complete federated-learning experiment on the virtual
//! clock.
//!
//! Since the `FlEnvironment` redesign this is a thin convenience wrapper:
//! it builds a [`VirtualClockEnv`], instantiates the configured protocol
//! and drives [`run_to_completion`]. New code should prefer the
//! [`crate::scenario::Scenario`] builder, which offers the same run over
//! either backend; `FlRun` stays for the harness and the existing tests.

use crate::config::ExperimentConfig;
use crate::env::{run_to_completion, FlEnvironment as _, RunResult, VirtualClockEnv};
use crate::protocols::{protocol_for, Protocol};
use crate::timing::TimingModel;
use crate::Result;

/// A fully-assembled virtual-clock experiment, ready to run.
pub struct FlRun {
    pub cfg: ExperimentConfig,
    /// The timing model in effect (exposed for bound checks in tests).
    pub tm: TimingModel,
    env: VirtualClockEnv,
    protocol: Box<dyn Protocol>,
}

impl FlRun {
    /// Build everything from a config (deterministic in `cfg.seed`).
    pub fn new(cfg: ExperimentConfig) -> Result<FlRun> {
        let env = VirtualClockEnv::new(cfg)?;
        let cfg = env.cfg().clone();
        let tm = env.timing().clone();
        let protocol = protocol_for(&env);
        Ok(FlRun {
            cfg,
            tm,
            env,
            protocol,
        })
    }

    /// Run to `t_max` rounds or until `target_accuracy` is reached.
    pub fn run(mut self) -> Result<RunResult> {
        run_to_completion(&mut self.env, self.protocol.as_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, ProtocolKind};

    fn mock_cfg(protocol: ProtocolKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.engine = EngineKind::Mock;
        cfg.protocol = protocol;
        cfg.t_max = 30;
        cfg.n_clients = 20;
        cfg.n_edges = 2;
        cfg.dataset_size = 400;
        cfg.eval_size = 50;
        cfg
    }

    #[test]
    fn full_run_all_protocols_mock() {
        for p in ProtocolKind::ALL {
            let result = FlRun::new(mock_cfg(p)).unwrap().run().unwrap();
            assert_eq!(result.rounds.len(), 30, "{p:?}");
            assert!(result.summary.best_accuracy > 0.0, "{p:?}");
            assert!(result.summary.avg_round_len > 0.0, "{p:?}");
            assert!(result.summary.mean_device_energy_wh > 0.0, "{p:?}");
            // Virtual time is monotone.
            for w in result.rounds.windows(2) {
                assert!(w[1].cum_time > w[0].cum_time);
                assert!(w[1].best_accuracy >= w[0].best_accuracy);
            }
        }
    }

    #[test]
    fn runs_are_deterministic_per_seed() {
        let a = FlRun::new(mock_cfg(ProtocolKind::HybridFl)).unwrap().run().unwrap();
        let b = FlRun::new(mock_cfg(ProtocolKind::HybridFl)).unwrap().run().unwrap();
        assert_eq!(a.summary.best_accuracy, b.summary.best_accuracy);
        assert_eq!(a.summary.avg_round_len, b.summary.avg_round_len);
        for (ra, rb) in a.rounds.iter().zip(b.rounds.iter()) {
            assert_eq!(ra.submissions, rb.submissions);
            assert_eq!(ra.round_len, rb.round_len);
        }
        let mut cfg = mock_cfg(ProtocolKind::HybridFl);
        cfg.seed = 777;
        let c = FlRun::new(cfg).unwrap().run().unwrap();
        assert_ne!(a.summary.avg_round_len, c.summary.avg_round_len);
    }

    #[test]
    fn target_accuracy_stops_early() {
        let mut cfg = mock_cfg(ProtocolKind::HybridFl);
        cfg.t_max = 500;
        cfg.target_accuracy = Some(0.3);
        let result = FlRun::new(cfg).unwrap().run().unwrap();
        assert!(result.summary.rounds_to_target.is_some());
        assert!(result.summary.rounds_run < 500);
        assert!(result.summary.time_to_target.unwrap() > 0.0);
    }

    #[test]
    fn hybridfl_traces_include_slack() {
        let result = FlRun::new(mock_cfg(ProtocolKind::HybridFl)).unwrap().run().unwrap();
        assert!(result.rounds[0].slack.is_some());
        assert_eq!(result.rounds[0].slack.as_ref().unwrap().len(), 2);
        let fed = FlRun::new(mock_cfg(ProtocolKind::FedAvg)).unwrap().run().unwrap();
        assert!(fed.rounds[0].slack.is_none());
    }

    /// The paper's headline shape at moderate drop-out: HybridFL's average
    /// round is shorter than FedAvg's and HierFAVG's under identical seeds.
    #[test]
    fn hybridfl_rounds_shorter_under_dropout() {
        let mut lens = std::collections::HashMap::new();
        for p in ProtocolKind::ALL {
            let mut cfg = mock_cfg(p);
            cfg.dropout.mean = 0.3;
            cfg.t_max = 40;
            let r = FlRun::new(cfg).unwrap().run().unwrap();
            lens.insert(p.as_str(), r.summary.avg_round_len);
        }
        let hybrid = lens["hybridfl"];
        assert!(
            hybrid < lens["fedavg"] && hybrid < lens["hierfavg"] * 1.05,
            "round lengths: {lens:?}"
        );
    }
}
