//! Communication-efficient submission paths: update codecs, error
//! feedback, and relay-assisted upload.
//!
//! The paper's round length is dominated by device→edge model transfer
//! (`timing::TimingModel::t_comm`), yet every submission in the seed
//! reproduction was a dense f32 dump of the arena. This module adds the
//! missing lever: an [`UpdateCodec`] trait with four implementations,
//! each reporting its **exact bytes on the wire** so the timing model can
//! convert a codec choice into shorter simulated uploads and the energy
//! model into lower device spend.
//!
//! * [`DenseCodec`] — the legacy path. A dense submission carries the
//!   client's **full trained model** (not a delta) and is byte-identical
//!   to the pre-codec behavior: the timing/energy formulas branch to the
//!   original expressions and no codec RNG stream is ever drawn.
//! * [`F16Codec`] — stochastic rounding of the **model delta** (trained
//!   model minus the round's start model) to IEEE-754 half precision:
//!   2 bytes/value, relative error ≤ 2⁻¹⁰ in the normal range.
//! * [`I8Codec`] — symmetric linear 8-bit quantization of the delta with
//!   stochastic rounding: 1 byte/value + one f32 scale, absolute error
//!   ≤ `max_abs/127` per value.
//! * [`TopKCodec`] — magnitude top-k sparsification of the delta
//!   (8 bytes per kept value), optionally with per-client
//!   **error-feedback residuals** (`+ef`): the mass not sent this round
//!   is carried into the next round's delta, so nothing is ever silently
//!   dropped — `sent + residual ≡ delta` exactly.
//!
//! Quantized/sparsified payloads are deltas because averaging truncated
//! *models* would destroy the 95 % of mass top-k drops; averaging
//! truncated *updates* only delays it (and `+ef` repays it). The edge
//! already holds the round's start model, so a delta-coded frame folds
//! into [`crate::aggregation::RegionAccumulator`] as
//! `acc += α·start + α·decode(frame)` without ever materializing an
//! intermediate dense model per submission — the O(regions) arena-peak
//! guarantee survives compression on both backends.
//!
//! On top of the codecs sits the **relay** axis ("Relay-Assisted
//! Cooperative Federated Learning", arXiv 2107.09518): the weakest
//! quantile of each region's surviving selected clients hands its
//! encoded frame to the fastest surviving peer over a device-to-device
//! hop, and the relay uploads a combined frame — cutting the
//! straggler-driven tail of the round. The transform is a deterministic
//! post-pass over the drawn fates (`env::draw_fates`), shared verbatim
//! by both backends and recorded into fate traces, so replay remains a
//! fixed point.
//!
//! Everything is configured through [`CommConfig`] (`ExperimentConfig.
//! comm`, `--comm` / `--set comm=` on the CLI, `Scenario::comm` /
//! `Scenario::relay` in code) with a small spec DSL:
//!
//! ```text
//! dense | f16 | i8 | topk:0.05 | topk:0.05+ef | i8+relay:0.25 | relay:0.25
//! ```
//!
//! Determinism: stochastic rounding draws from a dedicated child stream
//! ([`COMM_STREAM`]) of the round RNG, split per client, and the stream
//! is derived only when the codec actually needs it — a `dense` run
//! never perturbs the legacy RNG draws.

use crate::jsonx::Json;
use crate::model::ModelParams;
use crate::rng::Rng;
use crate::Result;

/// RNG stream label for the codec layer's stochastic rounding, split off
/// the round stream (`rng.split(COMM_STREAM).split(client)`), sibling of
/// the churn and oracle streams. Never derived for `dense`.
pub const COMM_STREAM: u64 = 0xC0_DE_CC;

// ---------------------------------------------------------------------------
// Configuration.
// ---------------------------------------------------------------------------

/// Which update codec encodes device→edge submissions.
#[derive(Clone, Debug, PartialEq)]
pub enum CodecSpec {
    /// Legacy dense f32 submission of the full trained model (default).
    Dense,
    /// Stochastic rounding of the model delta to f16.
    F16,
    /// Stochastic symmetric 8-bit quantization of the model delta.
    I8,
    /// Magnitude top-k sparsification of the model delta; `error_feedback`
    /// carries the unsent mass into the next round (sim-only state).
    TopK { fraction: f64, error_feedback: bool },
}

impl CodecSpec {
    pub fn is_dense(&self) -> bool {
        matches!(self, CodecSpec::Dense)
    }

    pub fn has_error_feedback(&self) -> bool {
        matches!(
            self,
            CodecSpec::TopK {
                error_feedback: true,
                ..
            }
        )
    }

    pub fn name(&self) -> &'static str {
        match self {
            CodecSpec::Dense => "dense",
            CodecSpec::F16 => "f16",
            CodecSpec::I8 => "i8",
            CodecSpec::TopK { .. } => "topk",
        }
    }

    /// Build the codec implementation for this spec.
    pub fn codec(&self) -> Box<dyn UpdateCodec> {
        match *self {
            CodecSpec::Dense => Box::new(DenseCodec),
            CodecSpec::F16 => Box::new(F16Codec),
            CodecSpec::I8 => Box::new(I8Codec),
            CodecSpec::TopK {
                fraction,
                error_feedback,
            } => Box::new(TopKCodec {
                fraction,
                error_feedback,
            }),
        }
    }

    /// Exact device→edge bytes on the wire for one encoded update of an
    /// `n_values`-parameter model — a pure function of the config, so
    /// upload times are computable before any training runs.
    pub fn wire_bytes(&self, n_values: usize) -> u64 {
        match *self {
            CodecSpec::Dense => 4 * n_values as u64,
            CodecSpec::F16 => 2 * n_values as u64,
            // Per-value i8 plus the shared f32 scale.
            CodecSpec::I8 => n_values as u64 + 4,
            // (u32 index, f32 value) per kept entry.
            CodecSpec::TopK { fraction, .. } => 8 * top_k_count(fraction, n_values) as u64,
        }
    }
}

/// Kept-entry count for top-k over `n` values: at least one entry as
/// long as the model is non-empty, never more than `n`.
pub fn top_k_count(fraction: f64, n: usize) -> usize {
    if n == 0 {
        return 0;
    }
    (((n as f64) * fraction).ceil() as usize).clamp(1, n)
}

/// The `comm` axis of an experiment: codec choice plus the optional
/// relay quantile. The default (`dense`, no relay) is byte-identical to
/// the pre-codec behavior on both backends.
#[derive(Clone, Debug, PartialEq)]
pub struct CommConfig {
    pub codec: CodecSpec,
    /// `Some(q)`: per region, the slowest `⌊q·survivors⌋` selected
    /// clients hand their encoded frame to the fastest survivor, which
    /// uploads a combined frame.
    pub relay: Option<f64>,
}

impl Default for CommConfig {
    fn default() -> CommConfig {
        CommConfig {
            codec: CodecSpec::Dense,
            relay: None,
        }
    }
}

impl CommConfig {
    /// True when every code path must take the legacy (pre-codec) route.
    pub fn is_legacy(&self) -> bool {
        self.codec.is_dense() && self.relay.is_none()
    }

    /// Parse the spec DSL: a codec (`dense|f16|i8|topk:K`), optionally
    /// `+ef` (top-k only) and/or `+relay:Q`, in any order; a bare
    /// `relay:Q` keeps the dense codec.
    pub fn parse_spec(spec: &str) -> Result<CommConfig> {
        let mut codec: Option<CodecSpec> = None;
        let mut ef = false;
        let mut relay = None;
        let set_codec = |slot: &mut Option<CodecSpec>, c: CodecSpec| -> Result<()> {
            anyhow::ensure!(
                slot.is_none(),
                "comm spec '{spec}' names more than one codec"
            );
            *slot = Some(c);
            Ok(())
        };
        for part in spec.split('+') {
            let part = part.trim();
            match part {
                "dense" => set_codec(&mut codec, CodecSpec::Dense)?,
                "f16" => set_codec(&mut codec, CodecSpec::F16)?,
                "i8" => set_codec(&mut codec, CodecSpec::I8)?,
                "ef" => ef = true,
                _ => {
                    if let Some(v) = part.strip_prefix("topk:") {
                        let fraction: f64 = v
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad top-k fraction '{v}'"))?;
                        set_codec(
                            &mut codec,
                            CodecSpec::TopK {
                                fraction,
                                error_feedback: false,
                            },
                        )?;
                    } else if let Some(v) = part.strip_prefix("relay:") {
                        let q: f64 = v
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad relay quantile '{v}'"))?;
                        relay = Some(q);
                    } else {
                        anyhow::bail!(
                            "unknown comm spec part '{part}' \
                             (dense | f16 | i8 | topk:K [+ef] | relay:Q)"
                        );
                    }
                }
            }
        }
        let mut codec = codec.unwrap_or(CodecSpec::Dense);
        if ef {
            match &mut codec {
                CodecSpec::TopK { error_feedback, .. } => *error_feedback = true,
                other => anyhow::bail!(
                    "'+ef' (error feedback) applies to topk only, not '{}'",
                    other.name()
                ),
            }
        }
        let cfg = CommConfig { codec, relay };
        cfg.validate()?;
        Ok(cfg)
    }

    /// The canonical spec string (inverse of [`Self::parse_spec`]).
    pub fn spec(&self) -> String {
        let mut s = match self.codec {
            CodecSpec::Dense => "dense".to_string(),
            CodecSpec::F16 => "f16".to_string(),
            CodecSpec::I8 => "i8".to_string(),
            CodecSpec::TopK {
                fraction,
                error_feedback,
            } => {
                let mut s = format!("topk:{fraction}");
                if error_feedback {
                    s.push_str("+ef");
                }
                s
            }
        };
        if let Some(q) = self.relay {
            s.push_str(&format!("+relay:{q}"));
        }
        s
    }

    pub fn validate(&self) -> Result<()> {
        if let CodecSpec::TopK { fraction, .. } = self.codec {
            anyhow::ensure!(
                fraction > 0.0 && fraction <= 1.0,
                "comm: top-k fraction must be in (0, 1], got {fraction}"
            );
        }
        if let Some(q) = self.relay {
            anyhow::ensure!(
                q > 0.0 && q < 1.0,
                "comm: relay quantile must be in (0, 1), got {q}"
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj().set("codec", self.codec.name()).set(
            "relay",
            match self.relay {
                Some(q) => Json::Num(q),
                None => Json::Null,
            },
        );
        if let CodecSpec::TopK {
            fraction,
            error_feedback,
        } = self.codec
        {
            j = j.set("fraction", fraction).set("ef", error_feedback);
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<CommConfig> {
        let codec = match j.req("codec")?.as_str()? {
            "dense" => CodecSpec::Dense,
            "f16" => CodecSpec::F16,
            "i8" => CodecSpec::I8,
            "topk" => CodecSpec::TopK {
                fraction: j.req("fraction")?.as_f64()?,
                error_feedback: j.req("ef")?.as_bool()?,
            },
            other => anyhow::bail!("unknown comm codec '{other}'"),
        };
        let relay = match j.req("relay")? {
            Json::Null => None,
            v => Some(v.as_f64()?),
        };
        let cfg = CommConfig { codec, relay };
        cfg.validate()?;
        Ok(cfg)
    }
}

// ---------------------------------------------------------------------------
// Encoded frames.
// ---------------------------------------------------------------------------

/// The encoded body of one device→edge submission. `Dense` carries the
/// full trained model (two refcount bumps to clone); every other variant
/// carries the encoded **delta** from the round's start model.
#[derive(Clone, Debug)]
pub enum Payload {
    Dense(ModelParams),
    F16(Vec<u16>),
    I8 { scale: f32, values: Vec<i8> },
    Sparse { indices: Vec<u32>, values: Vec<f32> },
}

/// One encoded update frame plus its exact size on the wire.
#[derive(Clone, Debug)]
pub struct EncodedUpdate {
    pub payload: Payload,
    pub wire_bytes: u64,
}

/// Per-encode context: the client's stochastic-rounding stream and, for
/// `topk+ef`, its mutable residual vector (device-side state, outside
/// the coordinator's arena accounting).
pub struct EncodeCtx<'a> {
    pub rng: &'a mut Rng,
    pub residual: Option<&'a mut Vec<f32>>,
}

/// An update codec: frames a model (or model delta) for the wire and
/// reports the frame's exact byte count.
pub trait UpdateCodec {
    fn name(&self) -> &'static str;
    /// Exact bytes on the wire for one update of an `n_values` model.
    fn wire_bytes(&self, n_values: usize) -> u64;
    /// Encode `update` — the full model for [`DenseCodec`], the delta
    /// from the round's start model for every other codec. Total over
    /// any input: non-finite values saturate or map to zero per codec
    /// (documented on each implementation), never a panic.
    fn encode(&self, update: &ModelParams, ctx: &mut EncodeCtx<'_>) -> EncodedUpdate;
}

/// Legacy dense f32 submission (full model, zero-copy).
pub struct DenseCodec;

impl UpdateCodec for DenseCodec {
    fn name(&self) -> &'static str {
        "dense"
    }

    fn wire_bytes(&self, n_values: usize) -> u64 {
        4 * n_values as u64
    }

    fn encode(&self, update: &ModelParams, _ctx: &mut EncodeCtx<'_>) -> EncodedUpdate {
        EncodedUpdate {
            wire_bytes: self.wire_bytes(update.n_values()),
            payload: Payload::Dense(update.clone()),
        }
    }
}

/// Stochastic rounding to f16. Non-finite values pass through (`NaN`
/// stays `NaN`, infinities stay infinite); magnitudes beyond the f16
/// range saturate to ±65504 rather than overflowing to infinity.
pub struct F16Codec;

impl UpdateCodec for F16Codec {
    fn name(&self) -> &'static str {
        "f16"
    }

    fn wire_bytes(&self, n_values: usize) -> u64 {
        2 * n_values as u64
    }

    fn encode(&self, update: &ModelParams, ctx: &mut EncodeCtx<'_>) -> EncodedUpdate {
        let values = update
            .values()
            .iter()
            .map(|&v| f16_stochastic(v, ctx.rng))
            .collect();
        EncodedUpdate {
            wire_bytes: self.wire_bytes(update.n_values()),
            payload: Payload::F16(values),
        }
    }
}

/// Symmetric linear 8-bit quantization with stochastic rounding:
/// `scale = max_abs/127`, values rounded to `q·scale`. Non-finite values
/// are excluded from the scale and quantize to zero.
pub struct I8Codec;

impl UpdateCodec for I8Codec {
    fn name(&self) -> &'static str {
        "i8"
    }

    fn wire_bytes(&self, n_values: usize) -> u64 {
        n_values as u64 + 4
    }

    fn encode(&self, update: &ModelParams, ctx: &mut EncodeCtx<'_>) -> EncodedUpdate {
        let src = update.values();
        let max_abs = src
            .iter()
            .filter(|v| v.is_finite())
            .fold(0.0f32, |m, &v| m.max(v.abs()));
        let scale = max_abs / 127.0;
        let values = if scale > 0.0 {
            src.iter()
                .map(|&v| {
                    if !v.is_finite() {
                        return 0;
                    }
                    let q = (v / scale) as f64;
                    let lo = q.floor();
                    let up = ctx.rng.uniform() < q - lo;
                    ((lo as i32 + up as i32).clamp(-127, 127)) as i8
                })
                .collect()
        } else {
            vec![0i8; src.len()]
        };
        EncodedUpdate {
            wire_bytes: self.wire_bytes(src.len()),
            payload: Payload::I8 { scale, values },
        }
    }
}

/// Magnitude top-k sparsification with optional error feedback. The
/// ranked signal is `delta + residual`; the kept entries are sent as
/// exact f32 copies, and with `+ef` the residual becomes exactly what
/// was not sent, so `sent + residual ≡ delta + residual_in` bit for
/// bit. Non-finite values rank as zero magnitude and are never sent
/// (their residual is reset to zero).
pub struct TopKCodec {
    pub fraction: f64,
    pub error_feedback: bool,
}

impl UpdateCodec for TopKCodec {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn wire_bytes(&self, n_values: usize) -> u64 {
        8 * top_k_count(self.fraction, n_values) as u64
    }

    fn encode(&self, update: &ModelParams, ctx: &mut EncodeCtx<'_>) -> EncodedUpdate {
        let src = update.values();
        let n = src.len();
        // The ranked signal: this round's delta plus the carried residual.
        let mut work: Vec<f32> = src.to_vec();
        if let Some(residual) = ctx.residual.as_deref() {
            debug_assert_eq!(residual.len(), n, "residual length mismatch");
            for (w, &r) in work.iter_mut().zip(residual.iter()) {
                *w += r;
            }
        }
        let k = top_k_count(self.fraction, n);
        let magnitude = |v: f32| if v.is_finite() { v.abs() } else { 0.0 };
        let mut order: Vec<u32> = (0..n as u32).collect();
        if k < n {
            order.select_nth_unstable_by(k, |&a, &b| {
                magnitude(work[b as usize])
                    .partial_cmp(&magnitude(work[a as usize]))
                    .expect("magnitudes are finite")
                    .then(a.cmp(&b))
            });
            order.truncate(k);
        }
        // Index order: deterministic regardless of the partial-select
        // permutation, and cache-friendly to apply at the edge.
        order.sort_unstable();
        let mut indices = Vec::with_capacity(k);
        let mut values = Vec::with_capacity(k);
        for &i in &order {
            let v = work[i as usize];
            indices.push(i);
            values.push(if v.is_finite() { v } else { 0.0 });
        }
        if self.error_feedback {
            if let Some(residual) = ctx.residual.as_deref_mut() {
                // residual := ranked signal minus what was sent; exact.
                residual.copy_from_slice(&work);
                for r in residual.iter_mut() {
                    if !r.is_finite() {
                        *r = 0.0;
                    }
                }
                for &i in &indices {
                    residual[i as usize] = 0.0;
                }
            }
        }
        EncodedUpdate {
            wire_bytes: self.wire_bytes(n),
            payload: Payload::Sparse { indices, values },
        }
    }
}

// ---------------------------------------------------------------------------
// Stochastic f16 rounding primitives.
// ---------------------------------------------------------------------------

/// f32 → f16 bits, truncating toward zero (the lower bracket of the
/// stochastic round). Saturates past the f16 range; preserves NaN/Inf.
pub fn f16_truncate_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // Inf or NaN (canonical quiet NaN keeps one payload bit set).
        return sign | if mant == 0 { 0x7C00 } else { 0x7E00 };
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        // Beyond the f16 range: saturate to the largest finite value.
        return sign | 0x7BFF;
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflows to signed zero
        }
        // Subnormal: implicit leading 1, shifted into the 10-bit field.
        let m = (mant | 0x80_0000) >> (13 + 1 - e);
        return sign | m as u16;
    }
    sign | ((e as u16) << 10) | (mant >> 13) as u16
}

/// f16 bits → f32 (exact).
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = ((bits & 0x8000) as u32) << 16;
    let exp = ((bits >> 10) & 0x1F) as u32;
    let mant = (bits & 0x3FF) as u32;
    if exp == 0 {
        // Signed zero or subnormal: value = ±mant · 2⁻²⁴.
        return sign_factor(bits) * (mant as f32) * 2.0f32.powi(-24);
    }
    let out = if exp == 31 {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(out)
}

fn sign_factor(bits: u16) -> f32 {
    if bits & 0x8000 != 0 {
        -1.0
    } else {
        1.0
    }
}

/// Stochastically round `x` to f16: pick the bracketing representable
/// below (toward zero) or above with probability proportional to the
/// distance, so the rounding is unbiased. Non-finite inputs pass through
/// without drawing from the RNG.
pub fn f16_stochastic(x: f32, rng: &mut Rng) -> u16 {
    if !x.is_finite() {
        return f16_truncate_bits(x);
    }
    let lo_bits = f16_truncate_bits(x);
    let lo = f16_to_f32(lo_bits);
    if lo == x || (lo_bits & 0x7FFF) >= 0x7BFF {
        // Exactly representable, or saturated at the range edge.
        return lo_bits;
    }
    // IEEE ordering: +1 on the magnitude bits is the next representable
    // away from zero, across exponent boundaries included.
    let hi_bits = lo_bits + 1;
    let hi = f16_to_f32(hi_bits);
    let frac = f64::from((x - lo).abs()) / f64::from((hi - lo).abs());
    if rng.uniform() < frac {
        hi_bits
    } else {
        lo_bits
    }
}

// ---------------------------------------------------------------------------
// Error-feedback residual state (snapshot payload).
// ---------------------------------------------------------------------------

/// The codec layer's only cross-round mutable state: per-client
/// error-feedback residuals for `topk+ef`. Held as raw `Arc<Vec<f32>>`
/// device-side state (never `ModelParams` — 50k residual arenas would
/// demolish the O(regions) arena-peak guarantee) and carried in
/// [`crate::snapshot::RunSnapshot`] so resumed runs stay byte-identical.
/// The `Arc` makes a snapshot a reference share, not a deep copy:
/// checkpointing a 50k-client `+ef` run bumps 50k refcounts instead of
/// doubling residual memory, and the environment copy-on-writes
/// (`Arc::make_mut`) only the residuals the next round actually updates.
#[derive(Clone, Debug, PartialEq)]
pub enum CommState {
    /// No residuals in flight (every codec except `topk+ef`).
    Stateless,
    /// `(client, residual)` pairs, sorted by client id.
    Residuals {
        clients: Vec<(usize, std::sync::Arc<Vec<f32>>)>,
    },
}

impl CommState {
    pub fn is_stateless(&self) -> bool {
        matches!(self, CommState::Stateless)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn model(values: Vec<f32>) -> ModelParams {
        let n = values.len();
        ModelParams::from_flat(values, vec![vec![n]])
    }

    fn decode_dense(frame: &EncodedUpdate, n: usize) -> Vec<f32> {
        match &frame.payload {
            Payload::Dense(m) => m.values().to_vec(),
            Payload::F16(v) => v.iter().map(|&b| f16_to_f32(b)).collect(),
            Payload::I8 { scale, values } => {
                values.iter().map(|&q| q as f32 * scale).collect()
            }
            Payload::Sparse { indices, values } => {
                let mut out = vec![0.0f32; n];
                for (&i, &v) in indices.iter().zip(values.iter()) {
                    out[i as usize] = v;
                }
                out
            }
        }
    }

    #[test]
    fn spec_dsl_roundtrips() {
        for spec in ["dense", "f16", "i8", "topk:0.05", "topk:0.05+ef", "i8+relay:0.25"] {
            let cfg = CommConfig::parse_spec(spec).unwrap();
            assert_eq!(cfg.spec(), spec, "spec {spec}");
            let back = CommConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back, cfg, "json roundtrip of {spec}");
        }
        // Bare relay keeps the dense codec.
        let cfg = CommConfig::parse_spec("relay:0.25").unwrap();
        assert_eq!(cfg.codec, CodecSpec::Dense);
        assert_eq!(cfg.relay, Some(0.25));
        assert!(!cfg.is_legacy());
        assert!(CommConfig::default().is_legacy());
    }

    #[test]
    fn spec_dsl_rejects_nonsense() {
        assert!(CommConfig::parse_spec("f16+ef").is_err()); // ef needs topk
        assert!(CommConfig::parse_spec("f16+i8").is_err()); // two codecs
        assert!(CommConfig::parse_spec("topk:0").is_err()); // fraction range
        assert!(CommConfig::parse_spec("topk:1.5").is_err());
        assert!(CommConfig::parse_spec("relay:1.0").is_err()); // quantile range
        assert!(CommConfig::parse_spec("gzip").is_err());
    }

    #[test]
    fn wire_bytes_formulas() {
        let n = 1000;
        assert_eq!(CodecSpec::Dense.wire_bytes(n), 4000);
        assert_eq!(CodecSpec::F16.wire_bytes(n), 2000);
        assert_eq!(CodecSpec::I8.wire_bytes(n), 1004);
        let topk = CodecSpec::TopK {
            fraction: 0.05,
            error_feedback: true,
        };
        assert_eq!(topk.wire_bytes(n), 8 * 50);
        // ≥4× below dense at k=5% — the bench's headline ratio.
        assert!(4 * topk.wire_bytes(n) <= CodecSpec::Dense.wire_bytes(n));
        // Tiny models still send at least one entry.
        assert_eq!(top_k_count(0.05, 3), 1);
        assert_eq!(top_k_count(0.05, 0), 0);
    }

    #[test]
    fn frame_reports_the_config_byte_count() {
        let mut rng = Rng::new(7);
        let update = model((0..100).map(|i| (i as f32) * 0.01 - 0.3).collect());
        for spec in [
            CodecSpec::Dense,
            CodecSpec::F16,
            CodecSpec::I8,
            CodecSpec::TopK {
                fraction: 0.05,
                error_feedback: false,
            },
        ] {
            let frame = spec.codec().encode(
                &update,
                &mut EncodeCtx {
                    rng: &mut rng,
                    residual: None,
                },
            );
            assert_eq!(frame.wire_bytes, spec.wire_bytes(100), "{}", spec.name());
        }
    }

    #[test]
    fn f16_roundtrip_error_is_bounded() {
        let mut rng = Rng::new(42);
        for i in 0..5000 {
            let x = ((rng.uniform() - 0.5) * 200.0) as f32;
            if x.abs() < 1e-3 {
                continue;
            }
            let dec = f16_to_f32(f16_stochastic(x, &mut rng));
            let rel = ((dec - x) / x).abs();
            assert!(rel <= 1.0 / 1024.0 + 1e-9, "iter {i}: x={x} dec={dec} rel={rel}");
        }
        // Exact values survive untouched.
        for x in [0.0f32, 1.0, -2.5, 0.5, 65504.0] {
            assert_eq!(f16_to_f32(f16_stochastic(x, &mut rng)), x);
        }
        // Saturation instead of overflow.
        assert_eq!(f16_to_f32(f16_stochastic(1e6, &mut rng)), 65504.0);
        assert_eq!(f16_to_f32(f16_stochastic(-1e6, &mut rng)), -65504.0);
    }

    #[test]
    fn f16_preserves_specials() {
        let mut rng = Rng::new(1);
        assert!(f16_to_f32(f16_stochastic(f32::NAN, &mut rng)).is_nan());
        assert_eq!(f16_to_f32(f16_stochastic(f32::INFINITY, &mut rng)), f32::INFINITY);
        assert_eq!(
            f16_to_f32(f16_stochastic(f32::NEG_INFINITY, &mut rng)),
            f32::NEG_INFINITY
        );
    }

    #[test]
    fn i8_roundtrip_error_is_bounded() {
        let mut rng = Rng::new(9);
        let src: Vec<f32> = (0..512).map(|_| ((rng.uniform() - 0.5) * 4.0) as f32).collect();
        let max_abs = src.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let frame = I8Codec.encode(
            &model(src.clone()),
            &mut EncodeCtx {
                rng: &mut rng,
                residual: None,
            },
        );
        let dec = decode_dense(&frame, src.len());
        let bound = max_abs / 127.0 + 1e-6;
        for (d, s) in dec.iter().zip(src.iter()) {
            assert!((d - s).abs() <= bound, "|{d} - {s}| > {bound}");
        }
    }

    #[test]
    fn i8_handles_zero_and_nonfinite_payloads() {
        let mut rng = Rng::new(3);
        let frame = I8Codec.encode(
            &model(vec![0.0; 16]),
            &mut EncodeCtx {
                rng: &mut rng,
                residual: None,
            },
        );
        assert!(decode_dense(&frame, 16).iter().all(|&v| v == 0.0));
        // NaN/Inf neither poison the scale nor the decoded values.
        let frame = I8Codec.encode(
            &model(vec![f32::NAN, f32::INFINITY, 1.0, -0.5]),
            &mut EncodeCtx {
                rng: &mut rng,
                residual: None,
            },
        );
        let dec = decode_dense(&frame, 4);
        assert_eq!(dec[0], 0.0);
        assert_eq!(dec[1], 0.0);
        assert!((dec[2] - 1.0).abs() <= 1.0 / 127.0 + 1e-6);
    }

    #[test]
    fn topk_ef_conserves_mass_exactly() {
        let mut rng = Rng::new(11);
        let delta: Vec<f32> = (0..256).map(|_| ((rng.uniform() - 0.5) * 2.0) as f32).collect();
        let mut residual = vec![0.0f32; 256];
        // Seed the residual with prior-round leftovers.
        for (i, r) in residual.iter_mut().enumerate() {
            *r = (i as f32) * 1e-3;
        }
        let expect: Vec<f32> = delta
            .iter()
            .zip(residual.iter())
            .map(|(d, r)| d + r)
            .collect();
        let codec = TopKCodec {
            fraction: 0.05,
            error_feedback: true,
        };
        let frame = codec.encode(
            &model(delta),
            &mut EncodeCtx {
                rng: &mut rng,
                residual: Some(&mut residual),
            },
        );
        let sent = decode_dense(&frame, 256);
        for i in 0..256 {
            // sent + residual ≡ delta + residual_in, exactly (f32 copies).
            assert!(
                (sent[i] + residual[i] - expect[i]).abs() <= 1e-6,
                "index {i}: {} + {} != {}",
                sent[i],
                residual[i],
                expect[i]
            );
        }
        // The kept entries are exact copies with zeroed residual.
        let Payload::Sparse { indices, .. } = &frame.payload else {
            panic!("topk frames are sparse");
        };
        assert_eq!(indices.len(), top_k_count(0.05, 256));
        for &i in indices {
            assert_eq!(residual[i as usize], 0.0);
        }
    }

    #[test]
    fn topk_keeps_the_largest_magnitudes_and_ignores_nonfinite() {
        let mut rng = Rng::new(5);
        let mut delta = vec![0.01f32; 100];
        delta[7] = 5.0;
        delta[42] = -9.0;
        delta[13] = f32::NAN; // ranks as zero, never sent
        let codec = TopKCodec {
            fraction: 0.02,
            error_feedback: false,
        };
        let frame = codec.encode(
            &model(delta),
            &mut EncodeCtx {
                rng: &mut rng,
                residual: None,
            },
        );
        let Payload::Sparse { indices, values } = &frame.payload else {
            panic!("topk frames are sparse");
        };
        assert_eq!(indices, &[7, 42]);
        assert_eq!(values, &[5.0, -9.0]);
    }

    #[test]
    fn empty_model_encodes_to_empty_frames() {
        let mut rng = Rng::new(2);
        let empty = ModelParams::from_flat(Vec::new(), vec![vec![0]]);
        for spec in [
            CodecSpec::F16,
            CodecSpec::I8,
            CodecSpec::TopK {
                fraction: 0.5,
                error_feedback: false,
            },
        ] {
            let frame = spec.codec().encode(
                &empty,
                &mut EncodeCtx {
                    rng: &mut rng,
                    residual: None,
                },
            );
            assert_eq!(frame.wire_bytes, spec.wire_bytes(0), "{}", spec.name());
            assert!(decode_dense(&frame, 0).is_empty());
        }
    }

    #[test]
    fn dense_runs_never_touch_the_comm_stream() {
        // The dense codec draws nothing: encoding with two different RNGs
        // yields identical frames, and the RNG state is untouched.
        let update = model(vec![1.0, -2.0, 3.5]);
        let mut a = Rng::new(1);
        let before = a.state();
        let f = DenseCodec.encode(
            &update,
            &mut EncodeCtx {
                rng: &mut a,
                residual: None,
            },
        );
        assert_eq!(a.state(), before);
        match f.payload {
            Payload::Dense(m) => assert!(m.shares_arena(&update)),
            _ => panic!("dense payload"),
        }
    }
}
