//! Ablation studies over HybridFL's design choices (DESIGN.md §3):
//!
//! * **cache rule** — literal eq. 17 (regional EMA) vs fresh-only;
//! * **θ_init** — sensitivity of the slack loop to its initialization;
//! * **κ₂** — HierFAVG's cloud-aggregation interval (the paper takes 10
//!   from Liu et al.; this sweep shows what that choice costs);
//! * **quota vs deadline** — HybridFL with the quota trigger disabled
//!   (T_lim-bound rounds), isolating the round-shortening mechanism.
//!
//! All runs share seeds and the mock engine by default (dynamics-only,
//! seconds); pass a PJRT-engined config for real-training ablations.

use crate::config::{CacheMode, EngineKind, ExperimentConfig, ProtocolKind};
use crate::metrics::Table;
use crate::sim::FlRun;
use crate::Result;

/// One ablation row: a labelled config variant and its outcome.
#[derive(Clone, Debug)]
pub struct AblationRow {
    pub label: String,
    pub best_accuracy: f64,
    pub avg_round_len: f64,
    pub mean_energy_wh: f64,
    /// Mean |X(t)|/n over the last half of the run (selection-target
    /// tracking quality).
    pub participation: f64,
}

fn run_variant(label: &str, cfg: ExperimentConfig) -> Result<AblationRow> {
    let n = cfg.n_clients as f64;
    let result = FlRun::new(cfg)?.run()?;
    let half = result.rounds.len() / 2;
    let tail = &result.rounds[half..];
    let participation = tail
        .iter()
        .map(|r| r.alive.iter().sum::<usize>() as f64 / n)
        .sum::<f64>()
        / tail.len().max(1) as f64;
    Ok(AblationRow {
        label: label.to_string(),
        best_accuracy: result.summary.best_accuracy,
        avg_round_len: result.summary.avg_round_len,
        mean_energy_wh: result.summary.mean_device_energy_wh,
        participation,
    })
}

/// Baseline config for ablations: mid-grid Task-1 conditions.
pub fn base_config(mock: bool) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::task1_scaled();
    if mock {
        cfg.engine = EngineKind::Mock;
    }
    cfg.protocol = ProtocolKind::HybridFl;
    cfg.dropout.mean = 0.4;
    cfg.c_fraction = 0.3;
    cfg.t_max = 200;
    cfg
}

/// Run every ablation family; returns (family name, rows).
pub fn run_all(mock: bool) -> Result<Vec<(String, Vec<AblationRow>)>> {
    let mut out = Vec::new();

    // 1. Cache rule.
    let mut rows = Vec::new();
    for (label, mode) in [("fresh (default)", CacheMode::Fresh), ("eq.17 literal", CacheMode::Regional)] {
        let mut cfg = base_config(mock);
        cfg.cache_mode = mode;
        rows.push(run_variant(label, cfg)?);
    }
    out.push(("cache rule".to_string(), rows));

    // 2. theta_init sensitivity.
    let mut rows = Vec::new();
    for init in [0.1, 0.3, 0.5, 0.8, 1.0] {
        let mut cfg = base_config(mock);
        cfg.theta_init = init;
        rows.push(run_variant(&format!("theta_init={init}"), cfg)?);
    }
    out.push(("theta_init".to_string(), rows));

    // 3. HierFAVG kappa_2.
    let mut rows = Vec::new();
    for k in [1usize, 5, 10, 20] {
        let mut cfg = base_config(mock);
        cfg.protocol = ProtocolKind::HierFavg;
        cfg.hier_kappa2 = k;
        rows.push(run_variant(&format!("kappa2={k}"), cfg)?);
    }
    out.push(("hierfavg kappa2".to_string(), rows));

    // 4. Quota trigger off: C_r fixed at C (theta pinned via init=1.0 and
    //    a quota nobody can trigger early is emulated by C=1 selection —
    //    instead we compare against FedAvg-style full-wait via HierFAVG
    //    kappa2=1, plus HybridFL with theta frozen at 1 (no slack).
    let mut rows = Vec::new();
    {
        let cfg = base_config(mock);
        rows.push(run_variant("hybridfl (slack on)", cfg)?);
        let mut cfg = base_config(mock);
        cfg.theta_init = 1.0; // C_r starts at C; slack may still adapt
        rows.push(run_variant("hybridfl theta_init=1", cfg)?);
        let mut cfg = base_config(mock);
        cfg.protocol = ProtocolKind::FedAvg;
        rows.push(run_variant("fedavg (no quota, no slack)", cfg)?);
    }
    out.push(("slack/quota contribution".to_string(), rows));

    Ok(out)
}

/// Render one family as a fixed-width table.
pub fn render(family: &str, rows: &[AblationRow]) -> String {
    let mut table = Table::new(&["variant", "best acc", "round len (s)", "Wh/device", "|X|/n"]);
    for r in rows {
        table.row(vec![
            r.label.clone(),
            format!("{:.3}", r.best_accuracy),
            format!("{:.2}", r.avg_round_len),
            format!("{:.4}", r.mean_energy_wh),
            format!("{:.3}", r.participation),
        ]);
    }
    format!("ablation: {family}\n{}", table.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_run_and_separate_variants() {
        let families = run_all(true).unwrap();
        assert_eq!(families.len(), 4);
        for (name, rows) in &families {
            assert!(rows.len() >= 2, "{name}");
            let rendered = render(name, rows);
            assert!(rendered.contains(name));
        }
        // kappa2=1 must aggregate at the cloud more often than kappa2=20 →
        // different outcomes under identical seeds.
        let kappa = &families[2].1;
        assert!(
            (kappa[0].best_accuracy - kappa[3].best_accuracy).abs() > 1e-9
                || (kappa[0].avg_round_len - kappa[3].avg_round_len).abs() > 1e-9
        );
    }

    #[test]
    fn theta_init_converges_to_similar_equilibrium() {
        // The slack loop should wash out its initialization: participation
        // in the second half of the run lands near C for any theta_init.
        let families = run_all(true).unwrap();
        let theta_rows = &families[1].1;
        for row in theta_rows {
            assert!(
                (row.participation - 0.3).abs() < 0.15,
                "{}: participation {}",
                row.label,
                row.participation
            );
        }
    }
}
