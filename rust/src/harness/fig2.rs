//! Fig. 2 — the paper's §III.A validation experiment.
//!
//! 20 clients in two regions (11 / 9) with no-abort means 0.43 / 0.57
//! (σ = 0.15), C = 0.3, 100 rounds of HybridFL, protocol dynamics only
//! (mock engine). Regenerates the four trace rows: θ_r(t), C_r(t),
//! q_r(t), |X_r(t)|/n_r — and checks the headline behaviour: θ̂ converges
//! and the per-region participation settles near C.

use std::path::Path;

use crate::config::ExperimentConfig;
use crate::metrics;
use crate::sim::{FlRun, RunResult};
use crate::Result;

/// Converged statistics reported alongside the traces.
#[derive(Clone, Debug)]
pub struct Fig2Stats {
    /// Mean θ̂ per region over the last quarter of the run.
    pub theta_converged: Vec<f64>,
    /// Mean C_r per region over the last quarter.
    pub c_r_converged: Vec<f64>,
    /// Mean |X_r|/n_r per region over the last quarter.
    pub alive_frac_converged: Vec<f64>,
    /// The configured target C.
    pub c: f64,
}

pub fn run_fig2(out_dir: &Path, seed: u64) -> Result<(RunResult, Fig2Stats)> {
    let mut cfg = ExperimentConfig::fig2();
    cfg.seed = seed;
    let region_sizes: Vec<usize> = cfg.regions.iter().map(|r| r.n_clients).collect();
    let c = cfg.c_fraction;
    let schema = metrics::CsvSchema::from_config(&cfg);
    let result = FlRun::new(cfg)?.run()?;

    // Converged means over the last quarter of rounds.
    let tail_start = result.rounds.len() * 3 / 4;
    let tail = &result.rounds[tail_start..];
    let m = region_sizes.len();
    let mut theta = vec![0.0; m];
    let mut c_r = vec![0.0; m];
    let mut alive = vec![0.0; m];
    for row in tail {
        let slack = row.slack.as_ref().expect("HybridFL run must expose slack");
        for r in 0..m {
            theta[r] += slack[r].theta;
            c_r[r] += slack[r].c_r;
            alive[r] += row.alive[r] as f64 / region_sizes[r] as f64;
        }
    }
    let k = tail.len().max(1) as f64;
    for r in 0..m {
        theta[r] /= k;
        c_r[r] /= k;
        alive[r] /= k;
    }

    std::fs::create_dir_all(out_dir)?;
    metrics::write_csv_with(&out_dir.join("fig2_traces.csv"), &schema, &result.rounds)?;

    let stats = Fig2Stats {
        theta_converged: theta,
        c_r_converged: c_r,
        alive_frac_converged: alive,
        c,
    };
    Ok((result, stats))
}

/// Human-readable report printed by the CLI and the bench.
pub fn render_stats(stats: &Fig2Stats) -> String {
    let mut out = String::new();
    out.push_str("Fig. 2 — regional slack factor traces (converged means, last quarter)\n");
    out.push_str(&format!(
        "{:<10} {:>10} {:>10} {:>14}   (paper: theta -> 0.46 / 0.63; |X_r|/n_r -> C)\n",
        "region", "theta", "C_r", "|X_r|/n_r"
    ));
    for r in 0..stats.theta_converged.len() {
        out.push_str(&format!(
            "region {:<3} {:>10.3} {:>10.3} {:>14.3}\n",
            r + 1,
            stats.theta_converged[r],
            stats.c_r_converged[r],
            stats.alive_frac_converged[r],
        ));
    }
    out.push_str(&format!("target C = {:.2}\n", stats.c));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 2 headline: the probabilistic estimation converges
    /// and participation |X_r|/n_r is held near C in both regions despite
    /// very different (agnostic) reliabilities.
    #[test]
    fn fig2_reproduces_paper_shape() {
        let dir = std::env::temp_dir().join("hybridfl_fig2_test");
        let (result, stats) = run_fig2(&dir, 42).unwrap();
        assert_eq!(result.rounds.len(), 100);

        // Region 1 (E[P]=0.43) is less reliable than region 2 (E[P]=0.57):
        // its theta must settle lower and its C_r higher.
        assert!(
            stats.theta_converged[0] < stats.theta_converged[1],
            "theta ordering: {:?}",
            stats.theta_converged
        );
        assert!(stats.c_r_converged[0] > stats.c_r_converged[1]);

        // Participation held near C = 0.3 in both regions.
        for (r, &frac) in stats.alive_frac_converged.iter().enumerate() {
            assert!(
                (frac - 0.3).abs() < 0.15,
                "region {r} alive frac {frac} should be near C=0.3"
            );
        }

        // Theta moved off its 0.5 init and into a plausible band around
        // the true no-abort probabilities (0.43 / 0.57).
        assert!((0.25..=0.62).contains(&stats.theta_converged[0]));
        assert!((0.40..=0.80).contains(&stats.theta_converged[1]));

        // The CSV landed with slack columns.
        let csv = std::fs::read_to_string(dir.join("fig2_traces.csv")).unwrap();
        assert!(csv.lines().next().unwrap().contains("theta_r1"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
