//! The adversarial evaluation matrix: scenario × protocol × selector.
//!
//! The slack estimator's published comparisons run under churn it was
//! implicitly tuned for. This harness pits every protocol and every
//! selection strategy (see [`crate::selection`]) against adversarial
//! churn compositions the estimator was *never* tuned for:
//!
//! * `stationary` — the control: the frozen i.i.d. world of the paper's
//!   own evaluation.
//! * `blackout` — Markov burstiness plus a scripted correlated blackout
//!   of region 0 for an eighth of the run: the regional estimator's
//!   worst case (its region goes entirely dark mid-estimate).
//! * `flashcrowd` — a batch of clients migrates into region 1 mid-run
//!   and the crowded region's drop-out rises: both regions' populations
//!   and reliabilities shift under the estimators simultaneously
//!   (virtual clock only — migration is rejected on the live backend).
//! * `compound` — diurnal availability cycles compounding with battery
//!   depletion: a slowly drifting, multi-timescale target.
//!
//! Every cell runs the mock engine on the virtual clock (the only
//! backend that admits the oracle and migration), from one shared base
//! world per seed. A cell reports the mean round length (what the
//! selection policy costs in time), best accuracy (whether aggressive
//! selection starves learning), the mean selected proportion (how much
//! of the fleet the policy wakes per round), mean per-device energy
//! (what that burden costs), and the deadline-round count (how often the
//! policy stalls to `T_lim`). The grid is complete by construction —
//! [`check_complete`] errors on a missing cell, and a cell that cannot
//! run must carry an explicit `skipped` marker rather than vanish.

use crate::churn::{ChurnModel, FaultEvent};
use crate::config::{ExperimentConfig, ProtocolKind};
use crate::jsonx::Json;
use crate::scenario::Scenario;
use crate::selection::SelectorKind;
use crate::Result;

/// One adversarial reliability scenario of the matrix.
pub struct MatrixScenario {
    pub name: &'static str,
    pub churn: ChurnModel,
}

/// The four matrix scenarios, with event windows placed relative to the
/// run length (`rounds`) so quick and full grids stress the same phases.
pub fn scenarios(rounds: usize) -> Vec<MatrixScenario> {
    let blackout_from = (rounds / 4).max(1);
    let blackout_until = blackout_from + (rounds / 8).max(2);
    let crowd_at = (rounds / 3).max(1);
    vec![
        MatrixScenario {
            name: "stationary",
            churn: ChurnModel::Stationary,
        },
        MatrixScenario {
            name: "blackout",
            churn: ChurnModel::Composed {
                layers: vec![
                    ChurnModel::MarkovOnOff {
                        p_fail: 0.08,
                        p_recover: 0.3,
                        down_dropout: 0.97,
                        region_scale: vec![],
                    },
                    ChurnModel::FaultScript {
                        events: vec![FaultEvent::RegionBlackout {
                            region: 0,
                            from_round: blackout_from,
                            until_round: blackout_until,
                        }],
                    },
                ],
            },
        },
        MatrixScenario {
            name: "flashcrowd",
            churn: ChurnModel::FaultScript {
                events: (0..6)
                    .map(|k| FaultEvent::Migrate {
                        client: k,
                        at_round: crowd_at,
                        to_region: 1,
                    })
                    .chain(std::iter::once(FaultEvent::DropoutShift {
                        region: Some(1),
                        at_round: crowd_at,
                        delta: 0.15,
                    }))
                    .collect(),
            },
        },
        MatrixScenario {
            name: "compound",
            churn: ChurnModel::Composed {
                layers: vec![
                    ChurnModel::Diurnal {
                        amplitude: 0.25,
                        period: 20,
                        region_phase: vec![],
                    },
                    ChurnModel::BatteryDrain {
                        drain_per_round: 0.02,
                        recharge_p: 0.1,
                        depleted_dropout: 0.9,
                    },
                ],
            },
        },
    ]
}

/// One evaluated grid cell.
pub struct MatrixCell {
    pub scenario: &'static str,
    pub protocol: ProtocolKind,
    pub selector: SelectorKind,
    pub rounds: usize,
    /// Mean core round length + protocol RTT, virtual seconds.
    pub avg_round_len: f64,
    pub best_accuracy: f64,
    /// Mean over rounds of (Σ_r |U_r|) / n — the fleet fraction woken
    /// per round.
    pub selected_proportion: f64,
    pub mean_device_energy_wh: f64,
    /// Rounds whose cutoff policy degraded to `T_lim`.
    pub deadline_rounds: usize,
    /// Why the cell did not run, if it did not. Every cell of the grid
    /// is present either way — skips are marked, never silent.
    pub skipped: Option<String>,
}

/// The shared base world: 40 clients over two heterogeneous regions
/// (drop-out means 0.2 / 0.4 — the regional imbalance the slack
/// estimator exists for), mock engine, C = 0.3.
pub fn base_cfg(rounds: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = crate::sim::test_support::hetero_two_region_cfg(0.2, 0.4);
    cfg.name = "scenario-matrix".into();
    cfg.t_max = rounds;
    cfg.seed = seed;
    cfg
}

/// Run one cell of the grid on the virtual clock.
fn run_cell(
    sc: &MatrixScenario,
    protocol: ProtocolKind,
    selector: SelectorKind,
    rounds: usize,
    seed: u64,
) -> Result<MatrixCell> {
    let mut cfg = base_cfg(rounds, seed);
    cfg.protocol = protocol;
    cfg.selector = selector;
    let result = Scenario::from_config(cfg).churn(sc.churn.clone()).run()?;
    let n = 40.0;
    let rows = &result.rounds;
    let selected_proportion = rows
        .iter()
        .map(|r| r.selected.iter().sum::<usize>() as f64 / n)
        .sum::<f64>()
        / rows.len().max(1) as f64;
    Ok(MatrixCell {
        scenario: sc.name,
        protocol,
        selector,
        rounds: rows.len(),
        avg_round_len: result.summary.avg_round_len,
        best_accuracy: result.summary.best_accuracy,
        selected_proportion,
        mean_device_energy_wh: result.summary.mean_device_energy_wh,
        deadline_rounds: rows.iter().filter(|r| r.deadline_hit).count(),
        skipped: None,
    })
}

/// Run the full scenario × protocol × selector grid (4 × 3 × 4 cells)
/// and verify completeness before returning.
pub fn run_matrix(rounds: usize, seed: u64) -> Result<Vec<MatrixCell>> {
    let mut cells = Vec::new();
    for sc in scenarios(rounds) {
        for protocol in ProtocolKind::ALL {
            for selector in SelectorKind::ALL {
                cells.push(run_cell(&sc, protocol, selector, rounds, seed)?);
            }
        }
    }
    check_complete(rounds, &cells)?;
    Ok(cells)
}

/// Error unless every grid combination is present exactly once — the
/// no-silently-skipped-cells guarantee (a skipped cell is still present,
/// with its `skipped` reason set).
pub fn check_complete(rounds: usize, cells: &[MatrixCell]) -> Result<()> {
    for sc in scenarios(rounds) {
        for protocol in ProtocolKind::ALL {
            for selector in SelectorKind::ALL {
                let hits = cells
                    .iter()
                    .filter(|c| {
                        c.scenario == sc.name && c.protocol == protocol && c.selector == selector
                    })
                    .count();
                anyhow::ensure!(
                    hits == 1,
                    "matrix cell {}/{}/{} appears {hits} times (expected exactly 1)",
                    sc.name,
                    protocol.as_str(),
                    selector.as_str()
                );
            }
        }
    }
    Ok(())
}

/// The `BENCH_matrix.json` payload: the grid axes plus one record per
/// cell, keyed for the CI regression gate.
pub fn report_json(rounds: usize, seed: u64, cells: &[MatrixCell]) -> Json {
    let scenario_names: Vec<&str> = scenarios(rounds).iter().map(|s| s.name).collect();
    let protocol_names: Vec<&str> = ProtocolKind::ALL.iter().map(|p| p.as_str()).collect();
    let selector_names: Vec<&str> = SelectorKind::ALL.iter().map(|s| s.as_str()).collect();
    let cell_rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj()
                .set("scenario", c.scenario)
                .set("protocol", c.protocol.as_str())
                .set("selector", c.selector.as_str())
                .set("rounds", c.rounds)
                .set("avg_round_len_s", c.avg_round_len)
                .set("best_accuracy", c.best_accuracy)
                .set("selected_proportion", c.selected_proportion)
                .set("mean_device_energy_wh", c.mean_device_energy_wh)
                .set("deadline_rounds", c.deadline_rounds)
                .set(
                    "skipped",
                    match &c.skipped {
                        Some(reason) => Json::Str(reason.clone()),
                        None => Json::Null,
                    },
                )
        })
        .collect();
    Json::obj()
        .set("bench", "scenario_matrix")
        .set("rounds", rounds)
        .set("seed", seed)
        .set(
            "grid",
            Json::obj()
                .set("scenarios", scenario_names)
                .set("protocols", protocol_names)
                .set("selectors", selector_names),
        )
        .set("cells", Json::Arr(cell_rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_scenario_validates_against_the_base_world() {
        for rounds in [8, 40, 160] {
            for sc in scenarios(rounds) {
                let mut cfg = base_cfg(rounds, 1);
                cfg.churn = sc.churn;
                cfg.validate()
                    .unwrap_or_else(|e| panic!("{} @ {rounds} rounds: {e}", sc.name));
            }
        }
    }

    #[test]
    fn single_cell_runs_and_reports_metrics() {
        let sc = &scenarios(6)[0];
        let cell = run_cell(sc, ProtocolKind::HybridFl, SelectorKind::Oracle, 6, 3).unwrap();
        assert_eq!(cell.rounds, 6);
        assert!(cell.avg_round_len > 0.0);
        assert!(cell.selected_proportion > 0.0 && cell.selected_proportion <= 1.0);
        assert!(cell.mean_device_energy_wh > 0.0);
        assert!(cell.skipped.is_none());
    }

    #[test]
    fn check_complete_rejects_missing_and_duplicate_cells() {
        let rounds = 6;
        let mut cells = Vec::new();
        for sc in scenarios(rounds) {
            for protocol in ProtocolKind::ALL {
                for selector in SelectorKind::ALL {
                    cells.push(MatrixCell {
                        scenario: sc.name,
                        protocol,
                        selector,
                        rounds,
                        avg_round_len: 1.0,
                        best_accuracy: 0.5,
                        selected_proportion: 0.3,
                        mean_device_energy_wh: 0.01,
                        deadline_rounds: 0,
                        skipped: None,
                    });
                }
            }
        }
        check_complete(rounds, &cells).unwrap();
        let dropped = cells.pop().unwrap();
        assert!(check_complete(rounds, &cells).is_err());
        cells.push(dropped);
        let dup = MatrixCell {
            scenario: cells[0].scenario,
            protocol: cells[0].protocol,
            selector: cells[0].selector,
            rounds,
            avg_round_len: 1.0,
            best_accuracy: 0.5,
            selected_proportion: 0.3,
            mean_device_energy_wh: 0.01,
            deadline_rounds: 0,
            skipped: None,
        };
        cells.push(dup);
        assert!(check_complete(rounds, &cells).is_err());
    }

    #[test]
    fn report_json_carries_every_cell_with_skip_marker() {
        let sc = &scenarios(6)[0];
        let cell = run_cell(sc, ProtocolKind::FedAvg, SelectorKind::Random, 6, 2).unwrap();
        let j = report_json(6, 2, &[cell]);
        let cells = j.req("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.req("selector").unwrap().as_str().unwrap(), "random");
        assert!(matches!(c.req("skipped").unwrap(), Json::Null));
        assert!(c.req("avg_round_len_s").unwrap().as_f64().unwrap() > 0.0);
    }
}
