//! Experiment harness (S18): one runner per table/figure of the paper's
//! evaluation section. See DESIGN.md §2 for the experiment index.
//!
//! * [`fig2`] — §III.A validation: slack/selection traces.
//! * [`sweep`] — the Table III / Table IV grids (E[dr] × C × protocol),
//!   which also emit the per-round accuracy traces of Figs. 4/6 and the
//!   energy numbers of Figs. 5/7.
//! * [`matrix`] — the adversarial scenario × protocol × selector grid
//!   behind `BENCH_matrix.json` and the CI regression gate.

pub mod ablation;
pub mod fig2;
pub mod matrix;
pub mod sweep;

pub use fig2::run_fig2;
pub use sweep::{run_task_sweep, SweepOpts, SweepResult};

use std::path::PathBuf;

/// Where harness output lands (tables as text, traces as CSV, summaries as
/// JSON).
pub fn default_out_dir() -> PathBuf {
    PathBuf::from("reports")
}
