//! The Table III / Table IV sweep: protocols × E[dr] × C over one task.
//!
//! One sweep regenerates everything the paper reports for a task:
//!
//! * **Table III/IV** — best accuracy + average round length at t_max
//!   ("Stop @t_max") and rounds/total-time to the accuracy target
//!   ("Stop @Acc"), derived from the same run's trace (the first round
//!   where the best-so-far accuracy crosses the target).
//! * **Figs. 4/6** — per-round accuracy traces, one CSV per
//!   (protocol, C, E[dr]) cell.
//! * **Figs. 5/7** — mean on-device energy (Wh) to reach the target.
//!
//! Grid cells share nothing but their config, so by default they execute
//! concurrently on scoped worker threads (one run per cell, each with its
//! own engine/world). Cell order, table rendering and every emitted
//! artifact are independent of the execution schedule: a parallel sweep is
//! byte-identical to `parallel: false`.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::{EngineKind, ExperimentConfig, ProtocolKind, TaskKind};
use crate::jsonx::Json;
use crate::metrics::{self, opt_cell, Table};
use crate::scenario::Scenario;
use crate::sim::RunResult;
use crate::Result;

/// Scale/grid options for a sweep.
#[derive(Clone, Debug)]
pub struct SweepOpts {
    /// Paper scale (full population / corpus / t_max) vs scaled presets.
    pub full: bool,
    /// Reduced grid for smoke runs: single E[dr]=0.3, C ∈ {0.1, 0.3}.
    pub quick: bool,
    /// Force the mock engine (protocol dynamics only; no artifacts).
    pub mock: bool,
    /// Override the accuracy target (defaults: 0.70 Task 1 / 0.90 Task 2).
    pub target: Option<f64>,
    /// Override t_max (budget control for the heavy LeNet sweeps).
    pub t_max: Option<usize>,
    pub seed: u64,
    /// Execute grid cells on scoped worker threads (results are identical
    /// to the serial schedule; only wall-clock changes).
    pub parallel: bool,
}

impl Default for SweepOpts {
    fn default() -> Self {
        SweepOpts {
            full: false,
            quick: false,
            mock: false,
            target: None,
            t_max: None,
            seed: 42,
            parallel: true,
        }
    }
}

/// One grid cell's outcome.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub protocol: ProtocolKind,
    pub e_dr: f64,
    pub c: f64,
    pub best_accuracy: f64,
    pub avg_round_len: f64,
    pub rounds_to_target: Option<usize>,
    pub time_to_target: Option<f64>,
    /// Mean device energy (Wh) to the target crossing (end of run if the
    /// target was never reached — documented in DESIGN.md).
    pub energy_to_target_wh: f64,
    pub result: RunResult,
}

pub struct SweepResult {
    pub task: TaskKind,
    pub target_accuracy: f64,
    pub cells: Vec<CellResult>,
}

/// The paper's grid: E[dr] ∈ {0.1, 0.3, 0.6}, C ∈ {0.1, 0.3, 0.5}.
fn grid(quick: bool) -> (Vec<f64>, Vec<f64>) {
    if quick {
        (vec![0.3], vec![0.1, 0.3])
    } else {
        (vec![0.1, 0.3, 0.6], vec![0.1, 0.3, 0.5])
    }
}

fn base_config(task: TaskKind, opts: &SweepOpts) -> ExperimentConfig {
    let mut cfg = match (task, opts.full) {
        (TaskKind::Aerofoil, true) => ExperimentConfig::task1_paper(),
        (TaskKind::Aerofoil, false) => ExperimentConfig::task1_scaled(),
        (TaskKind::Mnist, true) => ExperimentConfig::task2_paper(),
        (TaskKind::Mnist, false) => ExperimentConfig::task2_scaled(),
    };
    if opts.mock {
        cfg.engine = EngineKind::Mock;
    }
    if let Some(t) = opts.t_max {
        cfg.t_max = t;
    }
    cfg.seed = opts.seed;
    cfg
}

fn default_target(task: TaskKind, full: bool) -> f64 {
    match (task, full) {
        (TaskKind::Aerofoil, _) => 0.70,
        (TaskKind::Mnist, true) => 0.90,
        // The scaled synthetic corpus is easier; 0.90 still works.
        (TaskKind::Mnist, false) => 0.90,
    }
}

/// The fixed cell enumeration (outer E[dr], then C, then protocol). Table
/// rendering and artifact emission follow this order regardless of the
/// execution schedule.
fn cell_configs(task: TaskKind, opts: &SweepOpts) -> Vec<ExperimentConfig> {
    let (drs, cs) = grid(opts.quick);
    let mut cfgs = Vec::new();
    for &e_dr in &drs {
        for &c in &cs {
            for proto in ProtocolKind::ALL {
                let mut cfg = base_config(task, opts);
                cfg.protocol = proto;
                cfg.dropout.mean = e_dr;
                cfg.c_fraction = c;
                cfg.target_accuracy = None; // run to t_max; derive crossing
                cfg.name = format!(
                    "{}-{}-dr{:.1}-c{:.1}",
                    task.as_str(),
                    proto.as_str(),
                    e_dr,
                    c
                );
                cfgs.push(cfg);
            }
        }
    }
    cfgs
}

/// Execute every cell (independent runs), optionally on scoped worker
/// threads. Results come back in cell order either way.
fn run_cells(cfgs: &[ExperimentConfig], parallel: bool) -> Result<Vec<RunResult>> {
    let workers = if parallel {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(cfgs.len())
            .max(1)
    } else {
        1
    };
    if workers <= 1 {
        return cfgs
            .iter()
            .map(|cfg| {
                eprintln!("[sweep] running {}", cfg.name);
                Scenario::from_config(cfg.clone()).run()
            })
            .collect();
    }

    let mut slots: Vec<Option<Result<RunResult>>> = Vec::with_capacity(cfgs.len());
    slots.resize_with(cfgs.len(), || None);
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        let next = &next;
        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..workers {
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= cfgs.len() {
                    break;
                }
                eprintln!("[sweep] running {}", cfgs[i].name);
                let r = Scenario::from_config(cfgs[i].clone()).run();
                if tx.send((i, r)).is_err() {
                    break;
                }
            });
        }
        drop(tx);
        for (i, r) in rx {
            slots[i] = Some(r);
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.expect("every sweep cell delivers a result"))
        .collect()
}

/// Run the full sweep for one task. Emits per-cell trace CSVs (Figs. 4/6),
/// the rendered table (Tables III/IV), the energy table (Figs. 5/7), and
/// a machine-readable JSON, all under `out_dir`.
pub fn run_task_sweep(
    task: TaskKind,
    opts: &SweepOpts,
    out_dir: &Path,
) -> Result<SweepResult> {
    let target = opts.target.unwrap_or_else(|| default_target(task, opts.full));
    std::fs::create_dir_all(out_dir)?;

    let cfgs = cell_configs(task, opts);
    let results = run_cells(&cfgs, opts.parallel)?;

    let n_clients = base_config(task, opts).n_clients as f64;
    let mut cells = Vec::with_capacity(cfgs.len());
    for (cfg, result) in cfgs.iter().zip(results.into_iter()) {
        // Derive the "Stop @Acc" columns from the trace.
        let crossing = result.rounds.iter().find(|r| r.best_accuracy >= target);
        let (rt, tt, energy_j) = match crossing {
            Some(row) => (Some(row.t), Some(row.cum_time), row.cum_energy_j),
            None => (
                None,
                None,
                result.rounds.last().map_or(0.0, |r| r.cum_energy_j),
            ),
        };
        metrics::write_csv_with(
            &out_dir.join(format!("trace_{}.csv", cfg.name)),
            &metrics::CsvSchema::from_config(cfg),
            &result.rounds,
        )?;
        cells.push(CellResult {
            protocol: cfg.protocol,
            e_dr: cfg.dropout.mean,
            c: cfg.c_fraction,
            best_accuracy: result.summary.best_accuracy,
            avg_round_len: result.summary.avg_round_len,
            rounds_to_target: rt,
            time_to_target: tt,
            energy_to_target_wh: energy_j / 3600.0 / n_clients,
            result,
        });
    }

    let sweep = SweepResult { task, target_accuracy: target, cells };
    let table_txt = render_table(&sweep);
    let energy_txt = render_energy(&sweep);
    std::fs::write(out_dir.join(table_file_name(task)), &table_txt)?;
    std::fs::write(out_dir.join(energy_file_name(task)), &energy_txt)?;
    std::fs::write(
        out_dir.join(format!("sweep_{}.json", task.as_str())),
        sweep_to_json(&sweep).pretty(),
    )?;
    Ok(sweep)
}

pub fn table_file_name(task: TaskKind) -> &'static str {
    match task {
        TaskKind::Aerofoil => "table3.txt",
        TaskKind::Mnist => "table4.txt",
    }
}

pub fn energy_file_name(task: TaskKind) -> &'static str {
    match task {
        TaskKind::Aerofoil => "fig5_energy.txt",
        TaskKind::Mnist => "fig7_energy.txt",
    }
}

/// Render the paper-style table (Tables III / IV): per (E[dr], protocol)
/// row, the C-columns for best accuracy, round length, rounds needed and
/// total time.
pub fn render_table(sweep: &SweepResult) -> String {
    let mut drs: Vec<f64> = sweep.cells.iter().map(|c| c.e_dr).collect();
    drs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    drs.dedup();
    let mut cs: Vec<f64> = sweep.cells.iter().map(|c| c.c).collect();
    cs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    cs.dedup();

    let mut headers: Vec<String> = vec!["E[dr]".into(), "protocol".into()];
    for metric in ["acc", "len(s)", "rounds", "time(s)"] {
        for c in &cs {
            headers.push(format!("{metric}@C={c}"));
        }
    }
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hrefs);

    for &dr in &drs {
        for proto in ProtocolKind::ALL {
            let mut row = vec![format!("{dr:.1}"), proto.as_str().to_string()];
            let cell = |c: f64| {
                sweep
                    .cells
                    .iter()
                    .find(|x| x.protocol == proto && x.e_dr == dr && x.c == c)
            };
            for c in &cs {
                row.push(cell(*c).map_or("-".into(), |x| format!("{:.3}", x.best_accuracy)));
            }
            for c in &cs {
                row.push(cell(*c).map_or("-".into(), |x| format!("{:.2}", x.avg_round_len)));
            }
            for c in &cs {
                row.push(cell(*c).map_or("-".into(), |x| {
                    x.rounds_to_target.map_or("-".into(), |r| r.to_string())
                }));
            }
            for c in &cs {
                row.push(cell(*c).map_or("-".into(), |x| opt_cell(x.time_to_target, 1)));
            }
            table.row(row);
        }
    }
    format!(
        "{} — stop@t_max metrics + stop@acc={:.2} metrics\n{}",
        match sweep.task {
            TaskKind::Aerofoil => "Table III (Task 1: Aerofoil)",
            TaskKind::Mnist => "Table IV (Task 2: MNIST)",
        },
        sweep.target_accuracy,
        table.render()
    )
}

/// Render the Figs. 5/7 energy comparison (mean device Wh to target).
pub fn render_energy(sweep: &SweepResult) -> String {
    let mut table = Table::new(&["E[dr]", "C", "fedavg(Wh)", "hierfavg(Wh)", "hybridfl(Wh)"]);
    let mut keys: Vec<(u64, u64)> = sweep
        .cells
        .iter()
        .map(|c| (c.e_dr.to_bits(), c.c.to_bits()))
        .collect();
    keys.sort_unstable();
    keys.dedup();
    for (drb, cb) in keys {
        let (dr, c) = (f64::from_bits(drb), f64::from_bits(cb));
        let get = |p: ProtocolKind| {
            sweep
                .cells
                .iter()
                .find(|x| x.protocol == p && x.e_dr == dr && x.c == c)
                .map_or("-".into(), |x| format!("{:.3}", x.energy_to_target_wh))
        };
        table.row(vec![
            format!("{dr:.1}"),
            format!("{c:.1}"),
            get(ProtocolKind::FedAvg),
            get(ProtocolKind::HierFavg),
            get(ProtocolKind::HybridFl),
        ]);
    }
    format!(
        "{} — mean on-device energy to reach acc={:.2}\n{}",
        match sweep.task {
            TaskKind::Aerofoil => "Fig. 5 (Task 1)",
            TaskKind::Mnist => "Fig. 7 (Task 2)",
        },
        sweep.target_accuracy,
        table.render()
    )
}

fn sweep_to_json(sweep: &SweepResult) -> Json {
    let cells: Vec<Json> = sweep
        .cells
        .iter()
        .map(|c| {
            Json::obj()
                .set("protocol", c.protocol.as_str())
                .set("e_dr", c.e_dr)
                .set("c", c.c)
                .set("best_accuracy", c.best_accuracy)
                .set("avg_round_len", c.avg_round_len)
                .set(
                    "rounds_to_target",
                    c.rounds_to_target.map_or(Json::Null, |v| Json::Num(v as f64)),
                )
                .set(
                    "time_to_target",
                    c.time_to_target.map_or(Json::Null, Json::Num),
                )
                .set("energy_to_target_wh", c.energy_to_target_wh)
        })
        .collect();
    Json::obj()
        .set("task", sweep.task.as_str())
        .set("target_accuracy", sweep.target_accuracy)
        .set("cells", Json::Arr(cells))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mock-engine quick sweep: the full plumbing (grid, crossing
    /// derivation, table/energy/JSON/CSV emission) in seconds.
    #[test]
    fn quick_mock_sweep_emits_all_outputs() {
        let dir = std::env::temp_dir().join("hybridfl_sweep_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut opts = SweepOpts { quick: true, mock: true, ..Default::default() };
        opts.target = Some(0.3);
        let sweep = run_task_sweep(TaskKind::Aerofoil, &opts, &dir).unwrap();
        assert_eq!(sweep.cells.len(), 2 * 3); // 1 dr × 2 C × 3 protocols

        let table = render_table(&sweep);
        assert!(table.contains("hybridfl"));
        assert!(table.contains("acc@C=0.1"));
        assert!(dir.join("table3.txt").exists());
        assert!(dir.join("fig5_energy.txt").exists());
        assert!(dir.join("sweep_aerofoil.json").exists());
        // One trace CSV per cell.
        let traces = std::fs::read_dir(&dir)
            .unwrap()
            .filter(|e| {
                e.as_ref()
                    .unwrap()
                    .file_name()
                    .to_string_lossy()
                    .starts_with("trace_")
            })
            .count();
        assert_eq!(traces, 6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn grid_matches_paper() {
        let (drs, cs) = grid(false);
        assert_eq!(drs, vec![0.1, 0.3, 0.6]);
        assert_eq!(cs, vec![0.1, 0.3, 0.5]);
    }

    #[test]
    fn cell_order_is_schedule_independent() {
        let opts = SweepOpts { quick: true, mock: true, ..Default::default() };
        let cfgs = cell_configs(TaskKind::Aerofoil, &opts);
        assert_eq!(cfgs.len(), 6);
        // protocol cycles fastest, then C, then E[dr].
        assert_eq!(cfgs[0].protocol, ProtocolKind::FedAvg);
        assert_eq!(cfgs[2].protocol, ProtocolKind::HybridFl);
        assert!(cfgs[0].c_fraction < cfgs[3].c_fraction);
    }
}
