//! [`LiveClusterEnv`] — the live threaded cloud/edge/client cluster as an
//! [`FlEnvironment`] backend.
//!
//! The same seeded draws that parameterize the virtual-clock backend
//! (which clients drop, how long each survivor takes) parameterize the
//! world here too — but the round itself is *enacted*: every client is an
//! OS thread behind an mpsc channel, edges decode arriving codec frames
//! into their region's accumulator and relay model-free notices up, and the
//! cloud (the caller's thread, inside `run_round`) arbitrates quota vs
//! deadline from real notice arrivals in wall-clock time scaled by
//! `time_scale`. Out-of-order arrivals, racing edges and straggler
//! stop-signals are therefore real concurrency, not bookkeeping — and no
//! full model ever crosses the edge→cloud link during a round, only the
//! O(regions) end-of-round aggregates.
//!
//! Client compute uses the mock engine regardless of `cfg.engine`: the
//! PJRT client is not `Send` (Rc-based FFI handles), and the live backend
//! exists to prove *coordination*, not numerics — the virtual-clock
//! backend carries real training. Because both backends share the fate
//! draws and the mock training math, a live run reproduces a sim run's
//! per-round selection counts and quota behavior whenever wall-clock
//! jitter is small against the scaled completion-time gaps (use a
//! generous `time_scale`; see `tests/live_runtime.rs`).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use crate::churn::{FateTrace, FaultEvent};
use crate::comm::CommState;
use crate::config::{EngineKind, ExperimentConfig};
use crate::env::{
    charge_energy, draw_fates, draw_selection, ground_truth_avail, inject_world_fault,
    record_fates, region_histogram, resolve_cutoff, step_world, CutPlan, CutoffPolicy, EnvState,
    FlEnvironment, RoundOutcome, Selection, Starts, World,
};
use crate::live::cluster::ClusterFabric;
use crate::live::messages::RoundJob;
use crate::model::ModelParams;
use crate::rng::Rng;
use crate::runtime::{build_engine, Engine, EvalResult};
use crate::Result;

pub struct LiveClusterEnv {
    world: World,
    fabric: ClusterFabric,
    /// Cloud-side evaluation engine (mock — see module docs).
    eval_engine: Box<dyn Engine>,
    region_data: Vec<f64>,
    time_scale: f64,
}

impl LiveClusterEnv {
    /// Build the world and spawn the thread fabric (1 edge thread per
    /// region + 1 thread per client). `time_scale` is wall-clock seconds
    /// per virtual second (e.g. `1e-4` ⇒ a 90 s virtual deadline becomes
    /// 9 ms).
    pub fn new(cfg: ExperimentConfig, time_scale: f64) -> Result<LiveClusterEnv> {
        anyhow::ensure!(
            time_scale > 0.0 && time_scale.is_finite(),
            "time_scale must be positive and finite, got {time_scale}"
        );
        let mut cfg = cfg;
        // Live numerics are always mock (PJRT handles are not Send).
        cfg.engine = EngineKind::Mock;
        anyhow::ensure!(
            !cfg.churn.has_migrations(),
            "client-mobility (migrate) churn events are not supported on the \
             live backend: client threads are bound to their edge channels at \
             spawn — run migration scenarios on the virtual clock"
        );
        anyhow::ensure!(
            cfg.selector != crate::selection::SelectorKind::Oracle,
            "the oracle selector is not supported on the live backend: it \
             reads ground-truth client fates before selection, which exist \
             only as the virtual clock's pre-drawable fate table — run \
             oracle cells on the virtual clock"
        );
        anyhow::ensure!(
            !cfg.comm.codec.has_error_feedback(),
            "error-feedback residuals (+ef) are not supported on the live \
             backend: residuals are per-client state that must survive \
             rounds, and client threads are stateless between Train \
             messages — run +ef cells on the virtual clock"
        );
        let world = World::build(cfg)?;
        let fabric = ClusterFabric::spawn(&world, time_scale)?;
        let eval_engine = build_engine(&world.cfg, Arc::clone(&world.data))?;
        let region_data = world.region_data_sizes();
        Ok(LiveClusterEnv {
            world,
            fabric,
            eval_engine,
            region_data,
            time_scale,
        })
    }
}

impl FlEnvironment for LiveClusterEnv {
    fn cfg(&self) -> &ExperimentConfig {
        &self.world.cfg
    }

    fn n_regions(&self) -> usize {
        self.world.topo.n_regions()
    }

    fn n_clients(&self) -> usize {
        self.world.topo.n_clients()
    }

    fn region_size(&self, r: usize) -> usize {
        self.world.topo.region_size(r)
    }

    fn region_data_size(&self, r: usize) -> f64 {
        self.region_data[r]
    }

    fn t_c2e2c(&self) -> f64 {
        self.world.tm.t_c2e2c
    }

    fn init_model(&self) -> ModelParams {
        self.eval_engine.init_params()
    }

    fn run_round(
        &mut self,
        t: usize,
        selection: Selection,
        starts: Starts<'_>,
        policy: CutoffPolicy,
    ) -> Result<RoundOutcome> {
        // World dynamics first (contract point 6) — identical step to the
        // virtual-clock backend; migrations are rejected at construction,
        // so the fabric's client↔edge binding never goes stale. Spans
        // bracket each phase (contract point 8) exactly like the sim.
        self.world.tracer.begin_round(t);
        let sp = crate::trace::SpanStart::begin();
        step_world(&mut self.world, t);
        self.world
            .tracer
            .finish(sp, crate::trace::Phase::ChurnStep, None, 0.0);
        let m = self.world.topo.n_regions();
        let mut rng = self.world.rng.split(t as u64);

        // Same world derivation as the virtual clock backend. The oracle
        // selector is rejected at construction, so no ground-truth table
        // exists here.
        let sp = crate::trace::SpanStart::begin();
        let selected = draw_selection(&self.world, &selection, None, &mut rng);
        self.world
            .tracer
            .finish(sp, crate::trace::Phase::Selection, None, 0.0);
        let sp = crate::trace::SpanStart::begin();
        let fates = draw_fates(&self.world, t, &selected, None, &mut rng)?;
        record_fates(&mut self.world, t, &fates);
        self.world
            .tracer
            .finish(sp, crate::trace::Phase::FateDraw, None, 0.0);

        // Fan the jobs out to the edges (who relay to their clients).
        let mut jobs: Vec<Vec<RoundJob>> = vec![Vec::new(); m];
        for f in &fates {
            jobs[f.region].push(RoundJob {
                client: f.client,
                dropped: f.dropped,
                completion: f.completion,
            });
        }
        // The broadcast model: `ModelParams::clone` is an Arc bump over
        // the shared arena, so the fan-out ships references, not copies.
        let start_arcs: Vec<Arc<ModelParams>> = match starts {
            Starts::Global(mdl) => {
                let a = Arc::new(mdl.clone());
                (0..m).map(|_| Arc::clone(&a)).collect()
            }
            Starts::PerRegion(ms) => ms.iter().map(|mdl| Arc::new(mdl.clone())).collect(),
        };
        // How many submission notices end the collection loop early. For
        // the wait-all policies the cut point is already fully determined
        // by the fates (deadline, or last completion), so the environment
        // — which drew those fates — counts only the submissions that can
        // actually arrive; waiting out the full scaled deadline for
        // clients it knows dropped would change nothing but wall-clock.
        let target = match policy {
            CutoffPolicy::Quota(q) => q,
            CutoffPolicy::AllSelected | CutoffPolicy::AllPerRegion => fates
                .iter()
                .filter(|f| !f.dropped && f.completion <= self.world.tm.t_lim)
                .count(),
        };
        let deadline = Duration::from_secs_f64(self.world.tm.t_lim * self.time_scale);

        // The cloud leader loop: count model-free notices until the
        // target or the wall-clock deadline, broadcast the round-end
        // signal that stops straggling clients, then collect the folded
        // per-region reports. Models were folded at the edges in arrival
        // order; none were buffered. The reports are authoritative: what
        // each edge folded before the round-end signal reached it *is*
        // the round's submission set, so counts, cut time and energy are
        // all derived from the same set and cannot diverge.
        let train_sp = crate::trace::SpanStart::begin();
        let reports = self.fabric.round(t, &start_arcs, jobs, target, deadline)?;

        // Submission latencies (virtual seconds): each folded client's
        // drawn completion time, per its edge's report — the same values
        // that drive the quota cut below.
        let completion_of: HashMap<usize, f64> =
            fates.iter().map(|f| (f.client, f.completion)).collect();
        for rep in &reports {
            let region = rep.agg.region();
            for c in &rep.clients {
                if let Some(&comp) = completion_of.get(c) {
                    self.world.tracer.record_submission(region, comp);
                }
            }
        }

        // Accounting: for the wait-all policies the cut point is fully
        // determined by the fates; for the quota policy it is whatever
        // the wall clock actually delivered — the folded clients' maximum
        // completion time (looked up via the reports' opaque ids).
        let plan = match policy {
            CutoffPolicy::Quota(q) => {
                let folded: usize = reports.iter().map(|r| r.agg.count()).sum();
                if folded >= q {
                    let cut = reports
                        .iter()
                        .flat_map(|r| r.clients.iter())
                        .filter_map(|c| completion_of.get(c).copied())
                        .fold(0.0f64, f64::max)
                        .min(self.world.tm.t_lim);
                    CutPlan {
                        cuts: vec![cut; m],
                        round_len: cut,
                        deadline_hit: false,
                    }
                } else {
                    CutPlan {
                        cuts: vec![self.world.tm.t_lim; m],
                        round_len: self.world.tm.t_lim,
                        deadline_hit: true,
                    }
                }
            }
            CutoffPolicy::AllSelected | CutoffPolicy::AllPerRegion => {
                resolve_cutoff(&self.world.tm, m, &fates, policy)
            }
        };
        // The enacted round is the train+fold phase: virtual duration is
        // the resolved cut; wall time is what the fabric actually took.
        self.world.tracer.finish(
            train_sp,
            crate::trace::Phase::TrainFold,
            None,
            plan.round_len,
        );
        let energy_j = charge_energy(&self.world, &fates, &plan.cuts);

        let selected_h = region_histogram(m, fates.iter().map(|f| f.region));
        let alive = region_histogram(m, fates.iter().filter(|f| !f.dropped).map(|f| f.region));
        let regional: Vec<_> = reports.into_iter().map(|r| r.agg).collect();
        let submissions: Vec<usize> = regional.iter().map(|r| r.count()).collect();
        // Same accounting as the virtual clock: folded submissions times
        // the configured codec's per-update wire size, against the
        // *config-level* model size — identical on both backends.
        let folded: usize = submissions.iter().sum();
        let bytes_moved = folded as u64
            * self
                .world
                .cfg
                .comm
                .codec
                .wire_bytes(self.world.tm.n_model_values());
        let avail = ground_truth_avail(&self.world, &fates);

        Ok(RoundOutcome {
            selected: selected_h,
            alive,
            submissions,
            regional,
            avail,
            round_len: plan.round_len,
            deadline_hit: plan.deadline_hit,
            energy_j,
            bytes_moved,
        })
    }

    fn evaluate(&mut self, model: &ModelParams) -> Result<EvalResult> {
        self.eval_engine.evaluate(model)
    }

    fn capture_state(&self) -> EnvState {
        // No comm residuals here: the live backend rejects error-feedback
        // codecs at construction, so its comm state is always stateless.
        EnvState {
            rng: self.world.rng.state(),
            churn: self.world.dynamics.state(),
            comm: CommState::Stateless,
        }
    }

    fn restore_state(&mut self, state: EnvState) -> Result<()> {
        anyhow::ensure!(
            state.comm.is_stateless(),
            "snapshot carries error-feedback residuals but the live backend \
             holds no codec state"
        );
        self.world.rng = Rng::from_state(state.rng);
        self.world.dynamics.restore(state.churn)
    }

    fn inject_fault(&mut self, event: FaultEvent) -> Result<()> {
        if matches!(event, FaultEvent::Migrate { .. }) {
            return Err(MigrateInjectError.into());
        }
        inject_world_fault(&mut self.world, event)
    }

    fn set_fate_recording(&mut self, on: bool) {
        self.world.recorder = on.then(FateTrace::new);
    }

    fn take_fate_trace(&mut self) -> Option<FateTrace> {
        self.world.recorder.take()
    }

    fn tracer(&mut self) -> &mut crate::trace::SpanRecorder {
        &mut self.world.tracer
    }
}

/// A `Migrate` fault injected into the live backend — a sim-only event,
/// like the churn/oracle construction-time rejections. Typed so the ops
/// control plane's `inject` reply surfaces the virtual-clock constraint
/// verbatim instead of a generic error.
#[derive(Clone, Copy, Debug)]
pub struct MigrateInjectError;

impl std::fmt::Display for MigrateInjectError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "client-mobility (migrate) events cannot be injected into the \
             live backend: client threads are bound to their edge channels \
             at spawn — run migration scenarios on the virtual clock"
        )
    }
}

impl std::error::Error for MigrateInjectError {}
