//! Execution environments — one protocol implementation, every backend.
//!
//! [`FlEnvironment`] is the backend contract of the whole stack: a federated
//! round, as seen by a *protocol*, is "select so-many clients, have each
//! train from a start model, collect what comes back before the cutoff
//! policy fires". Everything below that line — whether client fates are
//! drawn on a virtual clock or played out by real threads over channels —
//! is an environment concern. The three protocols (`FedAvg`, `HierFAVG`,
//! `HybridFL`) are each written **once** against this trait and run
//! unchanged on every backend.
//!
//! Two implementations ship:
//!
//! * [`VirtualClockEnv`] — the deterministic MEC simulator (absorbs the old
//!   `sim::FlRun` round loop). Fates are drawn from the seeded RNG, time is
//!   arithmetic, training runs inline on the configured engine.
//! * [`LiveClusterEnv`] — the live threaded cluster: one edge thread per
//!   region, one client thread per device, mpsc channels as the network.
//!   The same seeded draws parameterize the world (who drops, how long a
//!   client takes), but the round cut — quota vs deadline — is arbitrated
//!   by the cloud in *wall-clock* time from real message arrivals, scaled
//!   by `time_scale`.
//!
//! # The backend contract
//!
//! A conforming environment must guarantee, for every `run_round` call:
//!
//! 1. **Reliability-agnosticism.** Protocols never see a `ClientProfile`,
//!    a drop-out probability, or a completion time. The only client-derived
//!    facts that cross the trait are the [`RoundOutcome`] observables: the
//!    per-region selection/submission counts and the streamed per-region
//!    aggregates (partial eq. 17 sums with their EDC weights and summed
//!    local losses). `RoundOutcome::alive` is simulator ground truth
//!    recorded *by the environment* for the metrics layer; protocol
//!    decision logic must not read it (and the shipped protocols do not).
//! 2. **Selection strategy.** The protocol chooses *how many* clients to
//!    select ([`Selection`]); the environment picks *which* ones according
//!    to the configured [`crate::selection::SelectorKind`]. For `slack`
//!    and `random` that is a uniform draw without replacement — the
//!    historical behavior, and no environment may bias those draws by
//!    hidden device state. `fedcs` ranks candidates by the shared timing
//!    model's estimated completion time (fastest first, client-id
//!    tie-break) — a *declared* use of static device estimates that needs
//!    no per-round ground truth, so it remains deployable on both
//!    backends. `oracle` selects only clients whose ground-truth fate for
//!    the round is survival, globally fastest first — a declared breach
//!    of reliability-agnosticism that exists purely to measure the
//!    achievable optimum; it is defined only on the virtual clock's
//!    pre-drawable fate table, and [`LiveClusterEnv`] rejects it loudly
//!    at construction. For a [`Selection::PerRegion`] request the oracle
//!    fills the *total* requested count from the whole fleet (it may
//!    reallocate across regions and selects fewer when fewer are alive);
//!    every other selector honors the per-region counts.
//! 3. **Cutoff semantics.** [`CutoffPolicy::Quota`] ends the round the
//!    moment the given number of submissions arrived globally (or at
//!    `T_lim`); the `All*` policies wait for every selected client, capped
//!    at `T_lim`. Submissions arriving after the cut are neither folded
//!    nor reported — on the virtual clock the cut is resolved
//!    analytically, on the live cluster it is *enacted* at each edge by
//!    the round-end signal, and the set folded before that signal is the
//!    authoritative submission set for counts, cut time and energy alike.
//! 4. **Streaming aggregation.** Environments never buffer submitted
//!    models: each in-time submission is folded into its region's
//!    [`RegionAccumulator`] *as it arrives* — true arrival order at the
//!    live edge threads; completion-time order with a stable client-id
//!    tie-break on the virtual clock, which is that order's deterministic
//!    image — and the trained model is dropped immediately after the
//!    fold. Peak resident model state per round is therefore O(regions),
//!    not O(selected clients). [`RoundOutcome::regional`] reports the
//!    accumulators (eq. 17 partial sums + eq. 18 EDC weights); protocols
//!    finish eq. 17's cache term and eq. 20's EDC weighting from that
//!    state alone.
//! 5. **Accounting.** `round_len` is the virtual core round length
//!    (protocols add cloud↔edge RTT per their own rules), and `energy_j`
//!    charges every selected client per eq. 35: dropped clients burn half
//!    their training energy, in-time finishers the full round, stragglers
//!    the `cutoff/completion` fraction.
//! 6. **Time-varying fates.** Client reliability is not assumed
//!    stationary: before each round's fate draw the environment runs one
//!    [`crate::churn::WorldDynamics`] step, which may rewrite per-client
//!    drop-out probabilities and bandwidth (and, on the virtual clock,
//!    client↔region attachment) as a deterministic function of the round
//!    index, the churn state and a dedicated RNG substream. The step
//!    happens strictly *below* the trait: protocols observe only its
//!    consequences through submission counts, so reliability-agnosticism
//!    is preserved verbatim. A [`ChurnModel::Stationary`] world draws
//!    nothing from the round stream and is byte-identical to the
//!    pre-churn behavior; under [`ChurnModel::Replay`] the fate draw is
//!    bypassed entirely and the recorded trace is the world. The
//!    environment also reports the per-region ground-truth availability
//!    (`RoundOutcome::avail`) for the metrics layer — like `alive`, it is
//!    simulator truth that protocol logic must not read.
//! 7. **Compressed submissions and the relay hop.** Device→edge uploads
//!    are framed by the configured [`crate::comm::UpdateCodec`]: a
//!    compressed frame's *exact* wire bytes drive the upload leg of the
//!    timing model ([`crate::timing::TimingModel::t_comm_with`]), the
//!    transmit energy, and the round's `RoundOutcome::bytes_moved`
//!    counter (folded submissions × per-update wire bytes — the
//!    device→edge traffic the bench compares across codecs). Encoded
//!    frames fold into the region accumulators via
//!    [`crate::aggregation::RegionAccumulator::fold_encoded`] without an
//!    intermediate dense model — the O(regions) arena peak holds under
//!    compression on both backends, and the live fabric ships the actual
//!    encoded frames over its channels. A malformed submission (shape or
//!    frame mismatch) is logged and skipped, never folded or counted.
//!    With `comm.relay = Some(q)`, each region's slowest `⌊q·survivors⌋`
//!    selected clients hand their encoded frame to the region's fastest
//!    survivor over a device-to-device hop; the relay uploads the
//!    combined frames and both parties' submissions land when the relay
//!    finishes. The transform is a deterministic post-pass over the
//!    drawn fates, shared by both backends and recorded into fate traces
//!    (so replayed traces reproduce relayed rounds verbatim and the
//!    transform is *not* re-applied under replay). Accounting draws the
//!    line at the radio: `bytes_moved` counts device→edge traffic only
//!    (the D2D handoff is not edge traffic), and per-client energy keeps
//!    eq. 35's own-upload charge — relay re-routing is a timing lever,
//!    not an energy transfer between devices. Error-feedback residuals
//!    (`topk+ef`) are coordinator-side state on the virtual clock,
//!    captured/restored through [`FlEnvironment::comm_state`]; the live
//!    backend rejects `+ef` at construction (client-thread state cannot
//!    honestly ride a coordinator snapshot). The dense default draws
//!    nothing from the comm RNG stream and is byte-identical to the
//!    pre-codec behavior.
//! 8. **Observability.** Round-boundary observers
//!    ([`crate::ops::RunObserver`], which the live metrics endpoint and
//!    the report sinks implement) see only what already crosses the trait:
//!    the [`RoundTrace`] aggregates (per-region selection/submission
//!    counts, availability means, slack telemetry, bytes moved) plus
//!    driver accumulators. No environment may hand an observer a
//!    `ClientProfile`, a per-client fate, a drop-out probability, or a
//!    device model — reliability-agnosticism holds on the wire exactly as
//!    it holds at the protocol boundary, so a scraped `/metrics` page can
//!    never leak more ground truth than the run's own trace artifact.
//!    Phase spans ([`crate::trace::SpanRecorder`], drained per round into
//!    [`crate::ops::RunEvent::RoundClosed`]) follow the same line: a
//!    span's **virtual-clock duration** and the per-region submission
//!    latencies are protocol-visible aggregates (deterministic in the
//!    seed, fair game for observers and scrape histograms), while its
//!    **host wall time** is profiling-only — it may vary freely between
//!    hosts and runs, and therefore never enters [`RoundTrace`],
//!    [`EnvState`], snapshots, or config fingerprints. Tracing consumes
//!    zero RNG draws, so a traced run is byte-identical to a plain one.
//!
//! # The data plane at fleet scale
//!
//! Below the trait, both backends share one `World`, engineered so a
//! round's cost scales with the *selected* set, not the fleet:
//!
//! * **Struct-of-arrays fleet.** The device fleet lives in a
//!   [`FleetState`] — `perf_ghz`, `bw_mhz`, `dropout_p` as parallel flat
//!   arrays (plus cached per-client partition sizes), with client ids as
//!   the index. Completion-time ranking, oracle tables and churn rewrites
//!   walk cache-linear `f64` arrays; `ClientProfile` survives only as the
//!   scalar row view where a single client's numbers are needed.
//! * **Lazy fate materialization.** The fate draw touches only the
//!   selected clients, and each per-client draw comes from the same
//!   substream discipline as ever — so the lazy path is byte-identical
//!   to a full-fleet sweep. The oracle selector is the one declared
//!   exception (its ground-truth table covers the fleet by definition).
//! * **O(dirty) world dynamics.** The churn step resets and rewrites only
//!   the regions its [`Touched`] outcome names, driven by a precomputed
//!   event-boundary schedule; the per-region availability series is a
//!   cache refreshed from the same outcome instead of an O(n) sweep.
//! * **Parallel per-region folds.** On the virtual clock, regions'
//!   select→train→fold work is independent (point 4 folds never cross
//!   regions), so [`VirtualClockEnv`] fans regions out across scoped
//!   worker threads when the engine permits — with within-region
//!   completion order preserved, the folded sums are byte-identical to
//!   the serial loop (pinned by test, like `harness::sweep`).
//!
//! [`FleetState`]: crate::devices::FleetState
//! [`Touched`]: crate::churn::Touched
//! [`ChurnModel::Stationary`]: crate::churn::ChurnModel::Stationary
//! [`ChurnModel::Replay`]: crate::churn::ChurnModel::Replay
//!
//! Drive a protocol to completion over any environment with
//! [`run_to_completion`], or use the [`crate::scenario::Scenario`] builder
//! which wraps environment construction, protocol construction and the
//! driver behind one fluent entry point.

pub mod live;
pub mod virtual_clock;

pub use live::LiveClusterEnv;
pub use virtual_clock::VirtualClockEnv;

use std::sync::Arc;

use crate::aggregation::RegionAccumulator;
use crate::churn::{ChurnModel, ChurnState, FateTrace, FaultEvent, Touched, WorldDynamics};
use crate::comm::CommState;
use crate::config::ExperimentConfig;
use crate::data::FederatedData;
use crate::devices::{self, FleetState};
use crate::energy::EnergyModel;
use crate::model::ModelParams;
use crate::protocols::Protocol;
use crate::rng::{Rng, RngState};
use crate::runtime::EvalResult;
use crate::selection::{select_clients, SelectorKind};
use crate::timing::TimingModel;
use crate::topology::Topology;
use crate::Result;

/// How many clients the protocol wants selected this round.
#[derive(Clone, Debug)]
pub enum Selection {
    /// `counts[r]` clients, uniformly without replacement within region r
    /// (HierFAVG, HybridFL).
    PerRegion(Vec<usize>),
    /// `count` clients uniformly across the whole fleet (FedAvg — no edge
    /// layer in the selection step).
    Uniform(usize),
}

/// Which model each selected client trains from.
#[derive(Clone, Copy)]
pub enum Starts<'a> {
    /// Every region trains from the same global model (FedAvg, HybridFL).
    Global(&'a ModelParams),
    /// Region r trains from `models[r]` (HierFAVG's regional models).
    PerRegion(&'a [ModelParams]),
}

impl<'a> Starts<'a> {
    pub fn for_region(&self, r: usize) -> &'a ModelParams {
        match *self {
            Starts::Global(m) => m,
            Starts::PerRegion(ms) => &ms[r],
        }
    }
}

/// When the environment ends the round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CutoffPolicy {
    /// End when this many submissions arrived globally, else at `T_lim`
    /// (HybridFL's quota trigger).
    Quota(usize),
    /// Wait for every selected client; a drop-out stalls the round to
    /// `T_lim` (FedAvg). One global cutoff.
    AllSelected,
    /// Each region waits for all of its selected clients, capped at
    /// `T_lim`; the round ends when the slowest region is done (HierFAVG).
    AllPerRegion,
}

/// Everything a protocol observes from one executed round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    /// |U_r(t)| per region.
    pub selected: Vec<usize>,
    /// |X_r(t)| per region — environment-side ground truth for the metrics
    /// layer; protocol logic must not consult it.
    pub alive: Vec<usize>,
    /// |S_r(t)| per region — submissions folded before the cut
    /// (`regional[r].count()`, denormalized for the metrics layer).
    pub submissions: Vec<usize>,
    /// The streamed per-region aggregates, indexed by region: eq. 17
    /// partial sums with EDC weights (eq. 18) and summed local losses.
    /// This replaces the old per-submission `arrivals` buffer — the
    /// environment folded every in-time model as it arrived, so no
    /// submitted model is resident here.
    pub regional: Vec<RegionAccumulator>,
    /// Per-region ground-truth availability this round: the mean no-abort
    /// probability `E[1 − dr_k]` over the region's fleet *after* the
    /// round's world-dynamics step — or, under fate replay, the realized
    /// alive/selected fraction of the replayed fates (NaN for a region
    /// with none selected). Environment-side truth for the metrics layer
    /// (churn analysis); protocol logic must not read it.
    pub avail: Vec<f64>,
    /// Core round length in virtual seconds (no cloud↔edge RTT).
    pub round_len: f64,
    /// True when the cutoff policy was *not* satisfied before `T_lim`.
    pub deadline_hit: bool,
    /// Device energy charged to the fleet this round (Joules).
    pub energy_j: f64,
    /// Device→edge bytes this round: folded submissions × the configured
    /// codec's exact per-update wire bytes (contract point 7).
    pub bytes_moved: u64,
}

/// The backend trait: capabilities for selection fan-out, client-fate
/// observation, local training, submission collection and round-cutoff /
/// energy accounting. See the module docs for the conformance contract.
pub trait FlEnvironment {
    fn cfg(&self) -> &ExperimentConfig;
    fn n_regions(&self) -> usize;
    fn n_clients(&self) -> usize;
    fn region_size(&self, r: usize) -> usize;
    /// |D^r| — total samples held by region r's clients.
    fn region_data_size(&self, r: usize) -> f64;
    /// Cloud↔edge round-trip time (eq. 32). Protocols with an edge layer
    /// add it to `round_len` per their own schedule.
    fn t_c2e2c(&self) -> f64;
    /// Initial global model w(0).
    fn init_model(&self) -> ModelParams;
    /// Execute one full round: select, fan out training, collect until the
    /// cutoff policy fires, account time and energy.
    fn run_round(
        &mut self,
        t: usize,
        selection: Selection,
        starts: Starts<'_>,
        policy: CutoffPolicy,
    ) -> Result<RoundOutcome>;
    /// Cloud-side evaluation of a model on the held-out set.
    fn evaluate(&mut self, model: &ModelParams) -> Result<EvalResult>;
    /// Capture the environment's entire cross-round state as one
    /// [`EnvState`] bundle at a round boundary: the round-stream RNG
    /// (both backends derive every per-round draw from it), the churn
    /// process state (together they pin the world's whole reliability
    /// trajectory), and the comm subsystem's cross-round residuals
    /// (`topk+ef`; [`CommState::Stateless`] for environments holding no
    /// codec state). This is the checkpoint path —
    /// [`crate::snapshot::RunSnapshot::capture`] and the ops
    /// `checkpoint-now` command both consume it. Capturing must not
    /// perturb the run.
    fn capture_state(&self) -> EnvState;
    /// Restore a bundle captured by [`Self::capture_state`] (resume
    /// path). Errors on churn state whose shape does not fit the
    /// configured model, and on residuals the environment cannot hold —
    /// an environment without codec state must refuse a snapshot that
    /// carries error-feedback mass rather than silently dropping it.
    fn restore_state(&mut self, state: EnvState) -> Result<()>;
    /// Splice a scripted fault into the running world (ops control
    /// plane). The event must only touch rounds that have not run yet;
    /// under that condition the continued run is byte-identical to one
    /// that scripted the event from round 1 (see
    /// [`crate::churn::WorldDynamics::inject`]). The injected script
    /// becomes part of the environment's effective config, so snapshots
    /// taken afterwards fingerprint — and resume under — the world that
    /// actually ran.
    fn inject_fault(&mut self, event: FaultEvent) -> Result<()>;
    /// Start (or stop) recording each round's ground-truth fates into an
    /// in-memory [`FateTrace`]. A control toggle, not captured state —
    /// deliberately outside [`EnvState`].
    fn set_fate_recording(&mut self, on: bool);
    /// Take the recorded fate trace (ends recording). `None` when
    /// recording was never enabled.
    fn take_fate_trace(&mut self) -> Option<FateTrace>;
    /// The environment's span recorder (contract point 8). Both backends
    /// record every round phase into it; the driver drains it at each
    /// round boundary. Observer-side state — deliberately outside
    /// [`EnvState`].
    fn tracer(&mut self) -> &mut crate::trace::SpanRecorder;
}

/// Everything an environment must persist across a process boundary for a
/// resumed run to be byte-identical: the round-stream RNG, the churn
/// process state, and cross-round comm residuals. One bundle instead of
/// three per-subsystem accessor pairs — [`crate::snapshot::RunSnapshot`]
/// and the ops `checkpoint-now` path both consume it whole.
///
/// Deliberately absent: phase spans and scrape histograms
/// ([`crate::trace`]). They are observer-side state — wall times would
/// make two captures of the same round differ — so they never ride in
/// snapshots or config fingerprints.
#[derive(Clone, Debug)]
pub struct EnvState {
    pub rng: RngState,
    pub churn: ChurnState,
    pub comm: CommState,
}

/// A selected client whose device parameters produce a non-finite
/// completion time (zero or NaN compute/bandwidth). Surfaced as a typed
/// error from the fate draw instead of letting the non-finite value
/// poison the survivor sorts downstream — all fate-path float comparisons
/// are `total_cmp` and therefore panic-free, so this error is the one
/// loud signal that the world itself is malformed.
#[derive(Clone, Debug)]
pub struct DegenerateProfileError {
    pub client: usize,
    pub completion: f64,
    pub perf_ghz: f64,
    pub bw_mhz: f64,
}

impl std::fmt::Display for DegenerateProfileError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "client {} has a degenerate device profile: completion time is {} \
             (perf_ghz={}, bw_mhz={})",
            self.client, self.completion, self.perf_ghz, self.bw_mhz
        )
    }
}

impl std::error::Error for DegenerateProfileError {}

/// A selected client's fate in one round — drop-out draw plus completion
/// time. Environment-internal ground truth: this type never crosses the
/// [`FlEnvironment`] trait into protocol code.
#[derive(Clone, Copy, Debug)]
pub struct ClientFate {
    pub client: usize,
    pub region: usize,
    /// True if the client dropped/opted out this round (never responds).
    pub dropped: bool,
    /// Completion time from round start (comm + training) when not
    /// dropped; `f64::INFINITY` when dropped.
    pub completion: f64,
}

/// The shared simulated world both backends are parameterized by:
/// topology, corpus, device fleet, timing/energy models, the RNG stream
/// rounds draw from, and the reliability dynamics that evolve the fleet at
/// round boundaries. Built identically (same split discipline) so a sim
/// and a live run with the same config inhabit the same random world.
pub(crate) struct World {
    pub cfg: ExperimentConfig,
    pub topo: Topology,
    pub data: Arc<FederatedData>,
    /// The device fleet in struct-of-arrays form: per-round sweeps
    /// (fastest-first ranking, oracle tables, churn rewrites) walk one
    /// cache-linear `f64` array instead of striding over profile structs.
    pub fleet: FleetState,
    /// `|D_k|` per client, cached as a flat array — the third hot operand
    /// of the completion-time sweep (`data.partitions[k].len()` chases a
    /// `Vec<Vec<_>>` indirection per lookup).
    pub psize: Vec<f64>,
    /// Per-region mean no-abort probability `E[1 − dr_k]` of the current
    /// fleet — the `RoundOutcome::avail` series. Maintained incrementally
    /// by [`step_world`] from the dynamics step's [`Touched`] set instead
    /// of an O(n) fleet sweep every round.
    pub avail: Vec<f64>,
    /// Debug/test knob: recompute availability from the fleet every round
    /// instead of trusting the incremental cache.
    pub eager_sweeps: bool,
    pub tm: TimingModel,
    pub em: EnergyModel,
    /// Base stream for per-round draws (`split(t)` per round).
    pub rng: Rng,
    /// Reliability dynamics (churn process + pristine base world).
    pub dynamics: WorldDynamics,
    /// Ground-truth trace replayed instead of fate draws
    /// ([`ChurnModel::Replay`]).
    pub replay: Option<FateTrace>,
    /// In-flight fate recording (`--record-fates`).
    pub recorder: Option<FateTrace>,
    /// Round-phase span log (contract point 8). Always on: recording is
    /// a `Vec` push per phase and consumes no RNG. Drained by the driver
    /// at round boundaries; never snapshotted.
    pub tracer: crate::trace::SpanRecorder,
}

impl World {
    pub fn build(cfg: ExperimentConfig) -> Result<World> {
        cfg.validate()?;
        let rng = Rng::new(cfg.seed);
        let topo = Topology::build(&cfg, &mut rng.split(1))?;
        let data = Arc::new(crate::data::build(&cfg, &mut rng.split(2)));
        let fleet = devices::sample_fleet(&cfg, &topo, &mut rng.split(3))?;
        let psize: Vec<f64> = data.partitions.iter().map(|p| p.len() as f64).collect();
        let avail = (0..topo.n_regions())
            .map(|r| region_avail(&topo, &fleet, r))
            .collect();
        let tm = TimingModel::new(&cfg);
        let em = EnergyModel::new(&cfg);
        let round_rng = rng.split(4);
        // Stream 5 seeds churn-process initialization (battery jitter).
        // Splitting never advances the parent, so stationary worlds are
        // bit-identical with or without this stream existing.
        let dynamics = WorldDynamics::new(cfg.churn.clone(), &fleet, &topo, &mut rng.split(5));
        let replay = match &cfg.churn {
            ChurnModel::Replay { path } => {
                Some(FateTrace::load(std::path::Path::new(path))?)
            }
            _ => None,
        };
        Ok(World {
            cfg,
            topo,
            data,
            fleet,
            psize,
            avail,
            eager_sweeps: false,
            tm,
            em,
            rng: round_rng,
            dynamics,
            replay,
            recorder: None,
            tracer: crate::trace::SpanRecorder::new(),
        })
    }

    /// |D^r| per region.
    pub fn region_data_sizes(&self) -> Vec<f64> {
        self.topo
            .regions
            .iter()
            .map(|cs| self.data.region_data_size(cs) as f64)
            .collect()
    }
}

/// Mean no-abort probability `E[1 − dr_k]` over region `r`'s fleet
/// (0.0 for an empty region). The summation order matches the historical
/// per-round sweep exactly, so the cached series is bit-identical to a
/// recompute.
pub(crate) fn region_avail(topo: &Topology, fleet: &FleetState, r: usize) -> f64 {
    let cs = &topo.regions[r];
    if cs.is_empty() {
        return 0.0;
    }
    cs.iter().map(|&k| 1.0 - fleet.dropout_p[k]).sum::<f64>() / cs.len() as f64
}

/// Pick the concrete client set per the [`Selection`] spec and the
/// configured selector (contract point 2). Both backends call this with
/// the round's RNG so the sampled sets are identical across backends.
///
/// The `slack` and `random` selectors consume exactly the RNG draws the
/// historical uniform path did, so default-configured runs stay
/// byte-identical; `fedcs` and `oracle` are deterministic ranks and
/// consume none. `oracle_drops` is the round's ground-truth drop table
/// ([`oracle_drop_table`]) and must be `Some` iff the oracle is
/// configured.
pub(crate) fn draw_selection(
    world: &World,
    selection: &Selection,
    oracle_drops: Option<&[bool]>,
    rng: &mut Rng,
) -> Vec<usize> {
    let topo = &world.topo;
    match world.cfg.selector {
        SelectorKind::Slack | SelectorKind::Random => match selection {
            Selection::PerRegion(counts) => {
                let mut out = Vec::new();
                for (r, &want) in counts.iter().enumerate() {
                    out.extend(select_clients(&topo.regions[r], want, rng));
                }
                out
            }
            Selection::Uniform(count) => {
                // Fleet-wide uniform draw over the identity index set —
                // sample directly instead of materializing `0..n` (the
                // sparse sampler keeps this O(selected) at fleet scale).
                rng.sample_indices(topo.n_clients(), *count)
            }
        },
        SelectorKind::FedCs => match selection {
            Selection::PerRegion(counts) => {
                let mut out = Vec::new();
                for (r, &want) in counts.iter().enumerate() {
                    out.extend(fastest_first(world, topo.regions[r].iter().copied(), want));
                }
                out
            }
            Selection::Uniform(count) => fastest_first(world, 0..topo.n_clients(), *count),
        },
        SelectorKind::Oracle => {
            let drops =
                oracle_drops.expect("oracle selector requires the round's ground-truth table");
            let total = match selection {
                Selection::PerRegion(counts) => counts.iter().sum(),
                Selection::Uniform(count) => *count,
            };
            fastest_first(
                world,
                (0..topo.n_clients()).filter(|&k| !drops[k]),
                total,
            )
        }
    }
}

/// Rank `candidates` by the timing model's estimated completion time
/// (ascending, client-id tie-break) and keep the first `count` — the
/// FedCS-style deadline-aware pick, also used by the oracle once the
/// candidate set is narrowed to ground-truth survivors.
///
/// Runs every round for the `fedcs` and `oracle` selectors, so it avoids
/// the full O(n log n) sort: `select_nth_unstable` partitions the `count`
/// fastest to the front in O(n), and only that prefix is sorted. The
/// comparator is `f64::total_cmp` (identical to `partial_cmp` for the
/// finite completions the timing model produces, and panic-free for
/// degenerate ones) with the same client-id tie-break as the historical
/// full sort — output ranks are pinned identical by test.
fn fastest_first(
    world: &World,
    candidates: impl Iterator<Item = usize>,
    count: usize,
) -> Vec<usize> {
    let cmp = |a: &(f64, usize), b: &(f64, usize)| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1));
    let mut ranked: Vec<(f64, usize)> = candidates
        .map(|k| {
            (
                world.tm.completion_with_of(
                    world.fleet.perf_ghz[k],
                    world.fleet.bw_mhz[k],
                    world.psize[k],
                    &world.cfg.comm,
                ),
                k,
            )
        })
        .collect();
    if count == 0 {
        return Vec::new();
    }
    if count < ranked.len() {
        ranked.select_nth_unstable_by(count - 1, cmp);
        ranked.truncate(count);
    }
    ranked.sort_unstable_by(cmp);
    ranked.into_iter().map(|(_, k)| k).collect()
}

/// Label of the oracle substream inside a round's RNG — like
/// [`CHURN_STREAM`], a child stream that never advances its parent, so
/// non-oracle runs are untouched by its existence.
const ORACLE_STREAM: u64 = 0x0A_AC_1E;

/// The oracle selector's ground-truth drop table for round `t`: one flag
/// per client in the *whole* fleet. `None` unless the oracle is
/// configured.
///
/// Normally the table is drawn from `round_rng.split(t).split(ORACLE_STREAM)`
/// and [`draw_fates`] then consumes this same table instead of fresh
/// Bernoulli draws — what the oracle foresaw is exactly what happens.
/// Under fate replay the recorded trace is the world, so the table is
/// read straight from it (a client the trace does not list for the round
/// is down). Recording an oracle run and replaying it is therefore a
/// fixed point: the oracle only selects survivors, so every recorded
/// fate is a survival and the replayed table marks exactly that set
/// alive again.
pub(crate) fn oracle_drop_table(world: &World, t: usize) -> Option<Vec<bool>> {
    if world.cfg.selector != SelectorKind::Oracle {
        return None;
    }
    let n = world.topo.n_clients();
    if let Some(trace) = &world.replay {
        return Some(
            (0..n)
                .map(|k| trace.get(t, k).map_or(true, |rec| rec.dropped))
                .collect(),
        );
    }
    let mut orng = world.rng.split(t as u64).split(ORACLE_STREAM);
    Some(
        (0..n)
            .map(|k| orng.bernoulli(world.fleet.dropout_p[k]))
            .collect(),
    )
}

/// Label of the churn substream inside a round's RNG: the dynamics step
/// draws from `round_rng.split(t).split(CHURN_STREAM)`, a child stream
/// that never advances its parent — so selection and fate draws are
/// bit-identical no matter how much (or little) the step consumed.
const CHURN_STREAM: u64 = 0xC0_0C_AA;

/// Run the round-`t` world-dynamics step (round boundary, before the fate
/// draw). Returns `true` when the topology changed (migration events) and
/// region-data caches must be refreshed. A no-op world (stationary /
/// replayed fates) returns immediately without touching anything.
///
/// The step's [`Touched`] outcome drives an incremental refresh of the
/// per-region availability cache: only regions the step rewrote (or reset
/// back to base) are re-summed, so a quiet script round costs O(1).
pub(crate) fn step_world(world: &mut World, t: usize) -> bool {
    if world.dynamics.is_noop() {
        return false;
    }
    let mut crng = world.rng.split(t as u64).split(CHURN_STREAM);
    let out = world
        .dynamics
        .step(t, &mut crng, &mut world.fleet, &mut world.topo);
    match &out.changed {
        Touched::None => {}
        Touched::All => {
            world.avail = (0..world.topo.n_regions())
                .map(|r| region_avail(&world.topo, &world.fleet, r))
                .collect();
        }
        Touched::Regions(rs) => {
            for &r in rs {
                world.avail[r] = region_avail(&world.topo, &world.fleet, r);
            }
        }
    }
    out.topo_changed
}

/// Shared [`FlEnvironment::inject_fault`] body: splice the event into the
/// running [`WorldDynamics`] and mirror the rewritten churn model into the
/// world's effective config, so every snapshot taken after the injection
/// fingerprints — and resumes under — the model that actually ran. With
/// the config updated, a `Stationary` run that injects a blackout is
/// indistinguishable, on disk and in its trace, from one configured with
/// the equivalent [`ChurnModel::FaultScript`] up front.
pub(crate) fn inject_world_fault(world: &mut World, event: FaultEvent) -> Result<()> {
    world.dynamics.inject(event)?;
    world.cfg.churn = world.dynamics.model().clone();
    Ok(())
}

/// Per-region ground-truth availability for this round.
///
/// * Normally: the mean no-abort probability `1 − dr_k` over each
///   region's fleet, as the world stands after the dynamics step — read
///   from the incrementally maintained `World::avail` cache (or re-summed
///   from the fleet under the `eager_sweeps` debug knob; the two are
///   bit-identical because the cache refresh uses the same summation
///   order).
/// * Under fate replay the base profiles say nothing about the replayed
///   world, so the series reports the *realized* availability of the
///   round's replayed fates instead (alive/selected per region; NaN for
///   a region with no selected clients — the trace is silent about it).
pub(crate) fn ground_truth_avail(world: &World, fates: &[ClientFate]) -> Vec<f64> {
    let m = world.topo.n_regions();
    if world.replay.is_some() {
        let selected = region_histogram(m, fates.iter().map(|f| f.region));
        let alive = region_histogram(m, fates.iter().filter(|f| !f.dropped).map(|f| f.region));
        return (0..m)
            .map(|r| {
                if selected[r] == 0 {
                    f64::NAN
                } else {
                    alive[r] as f64 / selected[r] as f64
                }
            })
            .collect();
    }
    if world.eager_sweeps {
        return (0..m)
            .map(|r| region_avail(&world.topo, &world.fleet, r))
            .collect();
    }
    world.avail.clone()
}

/// Resolve each selected client's fate for round `t`.
///
/// * Normally: independent drop-out draw (dr_k) plus deterministic
///   completion time from the timing model.
/// * Under [`ChurnModel::Replay`]: the recorded trace *is* the world —
///   each selected client takes its recorded fate verbatim (no RNG is
///   consumed), including its recorded region attachment (so traces
///   recorded under migration events keep the original routing; an
///   out-of-range recorded region falls back to the current topology).
///   A selected client the trace does not list for this round is
///   treated as unavailable (dropped).
/// * Under the oracle selector `oracle_drops` carries the round's
///   pre-drawn ground-truth table ([`oracle_drop_table`]) and replaces
///   the per-client Bernoulli draws — selection and fate resolution see
///   one consistent world.
///
/// Completion times run through [`TimingModel::completion_with`], so a
/// compressed codec shortens every surviving client's upload leg (dense
/// takes the exact legacy expression). With `comm.relay` set, the
/// [`apply_relay`] post-pass then re-routes each region's slowest
/// survivors through its fastest one — but only on freshly drawn fates:
/// a replayed trace already carries the transformed completions, so
/// replay stays a fixed point.
///
/// A device whose parameters yield a non-finite completion time surfaces
/// as a typed [`DegenerateProfileError`] instead of a downstream panic.
pub(crate) fn draw_fates(
    world: &World,
    t: usize,
    selected: &[usize],
    oracle_drops: Option<&[bool]>,
    rng: &mut Rng,
) -> Result<Vec<ClientFate>> {
    if let Some(trace) = &world.replay {
        let m = world.topo.n_regions();
        return Ok(selected
            .iter()
            .map(|&k| match trace.get(t, k) {
                Some(rec) => {
                    let region = if rec.region < m {
                        rec.region
                    } else {
                        world.topo.region_of[k]
                    };
                    ClientFate {
                        client: k,
                        region,
                        dropped: rec.dropped,
                        completion: if rec.dropped {
                            f64::INFINITY
                        } else {
                            rec.completion
                        },
                    }
                }
                None => ClientFate {
                    client: k,
                    region: world.topo.region_of[k],
                    dropped: true,
                    completion: f64::INFINITY,
                },
            })
            .collect());
    }
    let mut fates: Vec<ClientFate> = Vec::with_capacity(selected.len());
    for &k in selected {
        let dropped = match oracle_drops {
            Some(table) => table[k],
            None => rng.bernoulli(world.fleet.dropout_p[k]),
        };
        let completion = if dropped {
            f64::INFINITY
        } else {
            let c = world.tm.completion_with_of(
                world.fleet.perf_ghz[k],
                world.fleet.bw_mhz[k],
                world.psize[k],
                &world.cfg.comm,
            );
            if !c.is_finite() {
                return Err(DegenerateProfileError {
                    client: k,
                    completion: c,
                    perf_ghz: world.fleet.perf_ghz[k],
                    bw_mhz: world.fleet.bw_mhz[k],
                }
                .into());
            }
            c
        };
        fates.push(ClientFate {
            client: k,
            region: world.topo.region_of[k],
            dropped,
            completion,
        });
    }
    apply_relay(world, &mut fates);
    Ok(fates)
}

/// The relay post-pass (contract point 7): per region, the slowest
/// `⌊q·survivors⌋` selected clients hand their encoded frame to the
/// region's fastest survivor over a device-to-device hop, and the relay
/// uploads the combined frames.
///
/// Deterministic and RNG-free: survivors are ranked by completion time
/// with a client-id tie-break, weak client `i` pairs with strong client
/// `i mod |strong|`, and the timing algebra is
///
/// ```text
///   handoff_w  = completion_w − upload/bps_w      (1× D2D send replaces
///                                                  the 2×-weighted edge
///                                                  upload)
///   relay_done = max(completion_s, handoff_w) + 2·upload/bps_s
/// ```
///
/// after which *both* parties' submissions land at `relay_done` (the
/// weak frame reaches the edge inside the relay's combined upload).
/// Several weak clients mapped to one relay queue up: each handoff
/// extends the relay's completion in pairing order. No-op when relay is
/// unconfigured, and never applied to replayed fates (the recorded
/// trace already carries the transformed completions).
pub(crate) fn apply_relay(world: &World, fates: &mut [ClientFate]) {
    let Some(q) = world.cfg.comm.relay else {
        return;
    };
    let m = world.topo.n_regions();
    let upload_bits = world.tm.upload_bits(&world.cfg.comm);
    let mut by_region: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (i, f) in fates.iter().enumerate() {
        if !f.dropped {
            by_region[f.region].push(i);
        }
    }
    for members in by_region {
        let n_weak = ((members.len() as f64) * q).floor() as usize;
        if n_weak == 0 || members.len() < 2 {
            continue;
        }
        // Slowest first (tie: client id) — the region's straggler tail.
        let mut ranked = members;
        ranked.sort_by(|&a, &b| {
            fates[b]
                .completion
                .total_cmp(&fates[a].completion)
                .then(fates[a].client.cmp(&fates[b].client))
        });
        let (weak, strong) = ranked.split_at(n_weak);
        // Relay pool fastest first (tie: client id).
        let mut strong = strong.to_vec();
        strong.sort_by(|&a, &b| {
            fates[a]
                .completion
                .total_cmp(&fates[b].completion)
                .then(fates[a].client.cmp(&fates[b].client))
        });
        for (i, &w) in weak.iter().enumerate() {
            let s = strong[i % strong.len()];
            let bps_w = world.tm.effective_bps_of(world.fleet.bw_mhz[fates[w].client]);
            let bps_s = world.tm.effective_bps_of(world.fleet.bw_mhz[fates[s].client]);
            let handoff = fates[w].completion - upload_bits / bps_w;
            let relay_done =
                fates[s].completion.max(handoff) + 2.0 * upload_bits / bps_s;
            fates[s].completion = relay_done;
            fates[w].completion = relay_done;
        }
    }
}

/// Record the round's ground-truth fates when recording is on (both
/// backends call this right after the fate resolution).
pub(crate) fn record_fates(world: &mut World, t: usize, fates: &[ClientFate]) {
    if let Some(rec) = world.recorder.as_mut() {
        rec.record(t, fates);
    }
}

/// A resolved round cut: per-region cutoff times plus the round length and
/// whether the policy degraded to the deadline.
pub(crate) struct CutPlan {
    pub cuts: Vec<f64>,
    pub round_len: f64,
    pub deadline_hit: bool,
}

/// Resolve a cutoff policy analytically from the fates (virtual clock; the
/// live backend uses it for the `All*` policies whose cut point is fully
/// determined by the fates).
pub(crate) fn resolve_cutoff(
    tm: &TimingModel,
    m: usize,
    fates: &[ClientFate],
    policy: CutoffPolicy,
) -> CutPlan {
    match policy {
        CutoffPolicy::Quota(q) => {
            let mut completions: Vec<f64> = fates
                .iter()
                .filter(|f| !f.dropped)
                .map(|f| f.completion)
                .collect();
            completions.sort_unstable_by(f64::total_cmp);
            let (cut, met) = if completions.len() >= q && completions[q - 1] <= tm.t_lim {
                (completions[q - 1], true)
            } else {
                (tm.t_lim, false)
            };
            CutPlan {
                cuts: vec![cut; m],
                round_len: cut,
                deadline_hit: !met,
            }
        }
        CutoffPolicy::AllSelected => {
            let max_c = fates.iter().map(|f| f.completion).fold(0.0f64, f64::max);
            let cut = max_c.min(tm.t_lim);
            CutPlan {
                cuts: vec![cut; m],
                round_len: cut,
                deadline_hit: max_c > tm.t_lim,
            }
        }
        CutoffPolicy::AllPerRegion => {
            let mut cuts = vec![0.0f64; m];
            for f in fates {
                cuts[f.region] = cuts[f.region].max(f.completion);
            }
            for c in cuts.iter_mut() {
                *c = c.min(tm.t_lim);
            }
            let round_len = cuts.iter().copied().fold(0.0f64, f64::max);
            let deadline_hit = fates.iter().any(|f| f.completion > tm.t_lim);
            CutPlan {
                cuts,
                round_len,
                deadline_hit,
            }
        }
    }
}

/// Charge device energy for a round that ended at `cuts[region]`:
///
/// * dropped clients burn half their training energy (abort mid-epoch, no
///   upload);
/// * clients finishing before the cutoff burn the full eq. 35;
/// * stragglers are stopped by the round-end signal, burning only the
///   `cutoff/completion` fraction — precisely where the quota-triggered
///   protocols save device energy relative to deadline-bound baselines.
pub(crate) fn charge_energy(world: &World, fates: &[ClientFate], cuts: &[f64]) -> f64 {
    let mut total = 0.0;
    for f in fates {
        let p = world.fleet.profile(f.client);
        let psize = world.psize[f.client];
        let spend = if f.dropped {
            world.em.aborted_round(&p, &world.tm, psize).total_j()
        } else {
            let full = world
                .em
                .full_round_with(&p, &world.tm, psize, &world.cfg.comm)
                .total_j();
            let cut = cuts[f.region];
            if f.completion <= cut {
                full
            } else {
                full * (cut / f.completion).clamp(0.0, 1.0)
            }
        };
        total += spend;
    }
    total
}

/// Per-region histogram of region indices.
pub(crate) fn region_histogram(m: usize, regions: impl Iterator<Item = usize>) -> Vec<usize> {
    let mut out = vec![0usize; m];
    for r in regions {
        out[r] += 1;
    }
    out
}

// ---------------------------------------------------------------------------
// Run traces and the generic driver (formerly the body of `sim::FlRun::run`).
// ---------------------------------------------------------------------------

use crate::selection::slack::SlackState;

/// Per-round trace row — one per executed round. This is the substrate for
/// every figure: accuracy traces (Figs. 4/6), slack traces (Fig. 2), energy
/// accumulation (Figs. 5/7).
#[derive(Clone, Debug)]
pub struct RoundTrace {
    pub t: usize,
    pub round_len: f64,
    /// Virtual time at the end of this round.
    pub cum_time: f64,
    /// Global-model accuracy after this round (evaluated every
    /// `eval_every` rounds; in between, carries the last measured value).
    pub accuracy: f64,
    /// Best accuracy seen so far ("the cloud always keeps the best global
    /// model").
    pub best_accuracy: f64,
    pub eval_loss: f64,
    pub selected: Vec<usize>,
    pub alive: Vec<usize>,
    pub submissions: Vec<usize>,
    /// Per-region ground-truth availability this round (mean `1 − dr_k`
    /// after the world-dynamics step) — the churn-analysis series.
    pub avail: Vec<f64>,
    /// Cumulative device energy, Joules, across the fleet.
    pub cum_energy_j: f64,
    /// Device→edge bytes this round (folded submissions × the codec's
    /// per-update wire bytes).
    pub bytes_moved: u64,
    pub deadline_hit: bool,
    pub cloud_aggregated: bool,
    /// HybridFL slack telemetry (θ̂_r, C_r, q_r per region).
    pub slack: Option<Vec<SlackState>>,
}

/// End-of-run aggregates — the numbers the paper's tables report.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub protocol: String,
    pub rounds_run: usize,
    /// Best global-model accuracy over the run ("Best Accuracy").
    pub best_accuracy: f64,
    /// Mean T_round ("Round length (sec)").
    pub avg_round_len: f64,
    /// Rounds needed to reach `target_accuracy` ("Rounds needed"), if hit.
    pub rounds_to_target: Option<usize>,
    /// Virtual time to reach the target ("Total time (sec)"), if hit.
    pub time_to_target: Option<f64>,
    /// Mean per-device energy in Wh over the whole run (Figs. 5/7).
    pub mean_device_energy_wh: f64,
    /// Total virtual time of the run.
    pub total_time: f64,
    pub final_loss: f64,
}

/// A complete run: summary plus the full per-round trace. Identical shape
/// for every backend — this is what [`crate::scenario::Scenario::run`]
/// returns whether the rounds played out on the virtual clock or on the
/// live threaded cluster.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub summary: RunSummary,
    pub rounds: Vec<RoundTrace>,
}

/// The driver's mid-run accumulators — the part of a run that lives
/// *outside* the environment and the protocol, and must therefore travel
/// with them in a [`crate::snapshot::RunSnapshot`] for a resumed run to
/// reproduce the uninterrupted run bit for bit: virtual-clock and energy
/// sums, the evaluation carry (accuracy between `eval_every` boundaries),
/// the best-model watermark, and the full per-round trace so far.
#[derive(Clone, Debug)]
pub struct DriverState {
    /// Rounds completed; the next round executed is `rounds_done + 1`.
    pub rounds_done: usize,
    pub cum_time: f64,
    pub cum_energy: f64,
    /// Best accuracy watermark (`f64::MIN` before the first evaluation).
    pub best_acc: f64,
    /// Last measured accuracy (carried between `eval_every` boundaries).
    pub last_acc: f64,
    /// Last measured eval loss (NaN before the first evaluation).
    pub last_loss: f64,
    /// Trace rows of every completed round.
    pub rounds: Vec<RoundTrace>,
}

impl DriverState {
    /// The state a run starts from when not resuming.
    pub fn fresh() -> DriverState {
        DriverState {
            rounds_done: 0,
            cum_time: 0.0,
            cum_energy: 0.0,
            best_acc: f64::MIN,
            last_acc: 0.0,
            last_loss: f64::NAN,
            rounds: Vec::new(),
        }
    }
}

/// Drive a protocol for `t_max` rounds (or until `target_accuracy`) over
/// any backend, recording the full trace. This is the single round loop
/// shared by sim runs, live runs and the sweep harness.
pub fn run_to_completion(
    env: &mut dyn FlEnvironment,
    protocol: &mut dyn Protocol,
) -> Result<RunResult> {
    run_resumable(
        env,
        protocol,
        DriverState::fresh(),
        &mut crate::ops::RunControl::new(),
    )
}

/// [`run_to_completion`] with an explicit starting [`DriverState`] (fresh
/// or restored from a snapshot) and a [`crate::ops::RunControl`] serviced
/// after every completed round: observers receive the typed round-boundary
/// event stream ([`crate::ops::RunEvent`]), scheduled checkpoints are
/// written, and pending ops commands (pause/resume, `checkpoint-now`,
/// fault injection) are executed. On the live backend the boundary runs on
/// the cloud leader thread, between the round-end reports and the next
/// round's fan-out, so the fabric is quiescent while state is captured. An
/// observer or control error aborts the run.
pub fn run_resumable(
    env: &mut dyn FlEnvironment,
    protocol: &mut dyn Protocol,
    mut st: DriverState,
    ctl: &mut crate::ops::RunControl<'_>,
) -> Result<RunResult> {
    let t_max = env.cfg().t_max;
    let eval_every = env.cfg().eval_every;
    let target_accuracy = env.cfg().target_accuracy;
    let n_clients = env.cfg().n_clients;
    let protocol_name = env.cfg().protocol.as_str().to_string();

    anyhow::ensure!(
        st.rounds_done <= t_max,
        "driver state is {} rounds in but t_max is {t_max}",
        st.rounds_done
    );
    anyhow::ensure!(
        st.rounds.len() == st.rounds_done,
        "driver state carries {} trace rows for {} completed rounds",
        st.rounds.len(),
        st.rounds_done
    );

    // Recover target-crossing state from a restored trace: if the
    // interrupted run had already reached `target_accuracy`, the run was
    // over — replay its summary instead of executing extra rounds.
    let mut rounds_to_target = None;
    let mut time_to_target = None;
    if let Some(target) = target_accuracy {
        if let Some(row) = st.rounds.iter().find(|r| r.best_accuracy >= target) {
            rounds_to_target = Some(row.t);
            time_to_target = Some(row.cum_time);
        }
    }

    let start = if rounds_to_target.is_none() {
        st.rounds_done + 1
    } else {
        t_max + 1 // run already complete; skip the loop
    };
    for t in start..=t_max {
        let rec = protocol.run_round(t, env)?;
        st.cum_time += rec.round_len;
        st.cum_energy += rec.energy_j;

        if t % eval_every == 0 || t == t_max {
            let ev = env.evaluate(protocol.global_model())?;
            st.last_acc = ev.accuracy;
            st.last_loss = ev.loss;
        }
        st.best_acc = st.best_acc.max(st.last_acc);

        st.rounds.push(RoundTrace {
            t,
            round_len: rec.round_len,
            cum_time: st.cum_time,
            accuracy: st.last_acc,
            best_accuracy: st.best_acc,
            eval_loss: st.last_loss,
            selected: rec.selected,
            alive: rec.alive,
            submissions: rec.submissions,
            avail: rec.avail,
            cum_energy_j: st.cum_energy,
            bytes_moved: rec.bytes_moved,
            deadline_hit: rec.deadline_hit,
            cloud_aggregated: rec.cloud_aggregated,
            slack: protocol.slack_states(),
        });
        st.rounds_done = t;
        ctl.round_closed(env, protocol, &st)?;

        if let Some(target) = target_accuracy {
            if st.best_acc >= target && rounds_to_target.is_none() {
                rounds_to_target = Some(t);
                time_to_target = Some(st.cum_time);
                break; // "Stop @Acc" mode
            }
        }
    }

    let n_rounds = st.rounds.len().max(1);
    let summary = RunSummary {
        protocol: protocol_name,
        rounds_run: st.rounds.len(),
        best_accuracy: st.best_acc.max(0.0),
        avg_round_len: st.cum_time / n_rounds as f64,
        rounds_to_target,
        time_to_target,
        mean_device_energy_wh: st.cum_energy / 3600.0 / n_clients as f64,
        total_time: st.cum_time,
        final_loss: st.last_loss,
    };
    let result = RunResult {
        summary,
        rounds: st.rounds,
    };
    ctl.run_finished(&result)?;
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::churn::FaultEvent;

    fn world() -> World {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 24;
        cfg.n_edges = 3;
        World::build(cfg).unwrap()
    }

    /// The historical implementation: full sort, then truncate. The
    /// partial-selection rewrite must produce identical ranks.
    fn full_sort_reference(w: &World, cands: &[usize], count: usize) -> Vec<usize> {
        let mut ranked: Vec<(f64, usize)> = cands
            .iter()
            .map(|&k| {
                let p = w.fleet.profile(k);
                (w.tm.completion_with(&p, w.psize[k], &w.cfg.comm), k)
            })
            .collect();
        ranked.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ranked.truncate(count);
        ranked.into_iter().map(|(_, k)| k).collect()
    }

    #[test]
    fn fastest_first_matches_full_sort_rank() {
        let w = world();
        let all: Vec<usize> = (0..w.topo.n_clients()).collect();
        for count in [0usize, 1, 5, 12, 23, 24, 30] {
            assert_eq!(
                fastest_first(&w, all.iter().copied(), count),
                full_sort_reference(&w, &all, count),
                "count={count}"
            );
        }
        for r in 0..w.topo.n_regions() {
            let cs = &w.topo.regions[r];
            assert_eq!(
                fastest_first(&w, cs.iter().copied(), 3),
                full_sort_reference(&w, cs, 3),
                "region {r}"
            );
        }
    }

    #[test]
    fn degenerate_profile_surfaces_typed_error() {
        let mut w = world();
        // Zero compute → infinite training time; zero bandwidth → infinite
        // upload. Both must surface as the typed error, not a panic.
        for (client, zero_perf) in [(3usize, true), (4usize, false)] {
            if zero_perf {
                w.fleet.perf_ghz[client] = 0.0;
            } else {
                w.fleet.bw_mhz[client] = 0.0;
            }
            w.fleet.dropout_p[client] = 0.0; // guarantee a survival draw
            let err = draw_fates(&w, 1, &[client], None, &mut Rng::new(7)).unwrap_err();
            let d = err
                .downcast_ref::<DegenerateProfileError>()
                .expect("typed DegenerateProfileError");
            assert_eq!(d.client, client);
            assert!(!d.completion.is_finite());
        }
    }

    #[test]
    fn avail_cache_tracks_churn_exactly() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 24;
        cfg.n_edges = 3;
        cfg.churn = ChurnModel::FaultScript {
            events: vec![
                FaultEvent::RegionBlackout {
                    region: 1,
                    from_round: 2,
                    until_round: 4,
                },
                FaultEvent::DropoutShift {
                    region: Some(0),
                    at_round: 3,
                    delta: 0.3,
                },
            ],
        };
        let mut w = World::build(cfg).unwrap();
        for t in 1..=6 {
            step_world(&mut w, t);
            let eager: Vec<f64> = (0..w.topo.n_regions())
                .map(|r| region_avail(&w.topo, &w.fleet, r))
                .collect();
            assert_eq!(w.avail, eager, "cached avail diverged at round {t}");
        }
    }
}
