//! [`VirtualClockEnv`] — the deterministic MEC simulator as an
//! [`FlEnvironment`] backend.
//!
//! This absorbs the round mechanics that used to live inside
//! `sim::FlRun` + `protocols::RoundCtx`: selection sampling, fate draws,
//! cutoff resolution, energy charging, and inline local training on the
//! configured compute engine. Rounds are pure arithmetic on a virtual
//! clock; every draw comes from the seeded per-round RNG stream, so runs
//! are bitwise reproducible per seed.
//!
//! Aggregation is streamed: in-time survivors are trained and folded into
//! per-region [`RegionAccumulator`]s one at a time, in completion-time
//! order with a stable client-id tie-break — the deterministic image of
//! the live backend's arrival order. At no point does the environment
//! hold more than one trained model per worker plus the O(regions)
//! accumulators.
//!
//! When the round qualifies (mock engine, no error-feedback codec,
//! enough survivors), the per-region train→fold work fans out across
//! scoped worker threads. Folds never cross regions and within-region
//! order is preserved, so the parallel round is byte-identical to the
//! serial one — pinned by test, and forceable off via
//! [`VirtualClockEnv::set_serial_fold`].

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::aggregation::{RegionAccumulator, StreamingAggregator};
use crate::churn::{FateTrace, FaultEvent};
use crate::comm::{CommConfig, CommState, EncodeCtx, COMM_STREAM};
use crate::config::{EngineKind, ExperimentConfig};
use crate::data::FederatedData;
use crate::env::{
    charge_energy, draw_fates, draw_selection, ground_truth_avail, inject_world_fault,
    oracle_drop_table, record_fates, region_histogram, resolve_cutoff, step_world, ClientFate,
    CutoffPolicy, EnvState, FlEnvironment, RoundOutcome, Selection, Starts, World,
};
use crate::model::ModelParams;
use crate::rng::Rng;
use crate::runtime::{build_engine, Engine, EvalResult};
use crate::timing::TimingModel;
use crate::Result;

/// Below this many in-time survivors a round folds serially — the
/// thread-spawn overhead would dominate.
const MIN_PARALLEL_SURVIVORS: usize = 8;

pub struct VirtualClockEnv {
    world: World,
    engine: Box<dyn Engine>,
    region_data: Vec<f64>,
    /// Per-client error-feedback residuals (`topk+ef` only), shared by
    /// `Arc` so a checkpoint snapshots them by reference instead of
    /// deep-cloning every vector (copy-on-write via `Arc::make_mut` when
    /// the next round updates one). Deliberately outside the
    /// `ModelParams` arena accounting: they are device-side state, not
    /// in-flight models, and only clients that have actually submitted
    /// under `+ef` hold one.
    residuals: BTreeMap<usize, Arc<Vec<f32>>>,
    /// Debug/test knob: force the serial fold even when the round
    /// qualifies for the parallel per-region path.
    serial_fold: bool,
}

impl VirtualClockEnv {
    /// Build the full simulated world from a config (deterministic in
    /// `cfg.seed`).
    pub fn new(cfg: ExperimentConfig) -> Result<VirtualClockEnv> {
        let world = World::build(cfg)?;
        let engine = build_engine(&world.cfg, Arc::clone(&world.data))?;
        let region_data = world.region_data_sizes();
        Ok(VirtualClockEnv {
            world,
            engine,
            region_data,
            residuals: BTreeMap::new(),
            serial_fold: false,
        })
    }

    /// The timing model in effect (deadline `t_lim`, RTT, completions).
    pub fn timing(&self) -> &TimingModel {
        &self.world.tm
    }

    /// Force the serial fold path — the parallel path's byte-identity
    /// reference (identity is pinned by test against this knob).
    pub fn set_serial_fold(&mut self, on: bool) {
        self.serial_fold = on;
    }

    /// Recompute the availability sweep from the fleet every round
    /// instead of reading the incremental cache — the lazy path's
    /// byte-identity reference.
    pub fn set_eager_sweeps(&mut self, on: bool) {
        self.world.eager_sweeps = on;
    }
}

impl FlEnvironment for VirtualClockEnv {
    fn cfg(&self) -> &ExperimentConfig {
        &self.world.cfg
    }

    fn n_regions(&self) -> usize {
        self.world.topo.n_regions()
    }

    fn n_clients(&self) -> usize {
        self.world.topo.n_clients()
    }

    fn region_size(&self, r: usize) -> usize {
        self.world.topo.region_size(r)
    }

    fn region_data_size(&self, r: usize) -> f64 {
        self.region_data[r]
    }

    fn t_c2e2c(&self) -> f64 {
        self.world.tm.t_c2e2c
    }

    fn init_model(&self) -> ModelParams {
        self.engine.init_params()
    }

    fn run_round(
        &mut self,
        t: usize,
        selection: Selection,
        starts: Starts<'_>,
        policy: CutoffPolicy,
    ) -> Result<RoundOutcome> {
        // World dynamics first (contract point 6): churn may rewrite
        // per-client reliability — and, under migration events, the
        // topology — before anything about this round is drawn. Spans
        // bracket each phase (contract point 8); the bookkeeping phases
        // charge zero virtual time.
        self.world.tracer.begin_round(t);
        let sp = crate::trace::SpanStart::begin();
        if step_world(&mut self.world, t) {
            self.region_data = self.world.region_data_sizes();
        }
        self.world
            .tracer
            .finish(sp, crate::trace::Phase::ChurnStep, None, 0.0);
        let m = self.world.topo.n_regions();
        let mut rng = self.world.rng.split(t as u64);

        // Selection fan-out, then per-client fates — same RNG order as the
        // live backend so both inhabit the same random world. The oracle's
        // ground-truth table (when configured) is drawn once, from a child
        // stream, and feeds both steps so they agree on who survives.
        let sp = crate::trace::SpanStart::begin();
        let oracle_drops = oracle_drop_table(&self.world, t);
        let selected = draw_selection(&self.world, &selection, oracle_drops.as_deref(), &mut rng);
        self.world
            .tracer
            .finish(sp, crate::trace::Phase::Selection, None, 0.0);
        let sp = crate::trace::SpanStart::begin();
        let fates = draw_fates(&self.world, t, &selected, oracle_drops.as_deref(), &mut rng)?;
        record_fates(&mut self.world, t, &fates);
        self.world
            .tracer
            .finish(sp, crate::trace::Phase::FateDraw, None, 0.0);

        // Round cut per policy, then energy accounting against it.
        let plan = resolve_cutoff(&self.world.tm, m, &fates, policy);
        let energy_j = charge_energy(&self.world, &fates, &plan.cuts);

        // Stream the in-time survivors: train each and fold it into its
        // region's accumulator, in completion-time order with a stable
        // client-id tie-break (the deterministic stand-in for the live
        // backend's arrival order). The trained model is dropped right
        // after the fold — peak resident models stay O(regions).
        let mut survivors: Vec<&ClientFate> = fates
            .iter()
            .filter(|f| !f.dropped && f.completion <= plan.cuts[f.region])
            .collect();
        survivors.sort_by(|a, b| {
            a.completion
                .total_cmp(&b.completion)
                .then(a.client.cmp(&b.client))
        });

        let comm = self.world.cfg.comm.clone();
        let train_sp = crate::trace::SpanStart::begin();
        let use_parallel = !self.serial_fold
            && matches!(self.world.cfg.engine, EngineKind::Mock)
            && !comm.codec.has_error_feedback()
            && survivors.len() >= MIN_PARALLEL_SURVIVORS;
        let regional = if use_parallel {
            // Partition by region, preserving within-region completion
            // order — the only order the per-region f32 folds depend on.
            let mut by_region: Vec<Vec<ClientFate>> = vec![Vec::new(); m];
            for f in &survivors {
                by_region[f.region].push(**f);
            }
            fold_regions_parallel(
                &self.world.cfg,
                &self.world.data,
                &self.region_data,
                &by_region,
                starts,
                &rng,
                &comm,
            )?
        } else {
            self.fold_serial(&survivors, starts, &rng, &comm)?
        };
        // The train+fold phase is the round on the virtual clock: its
        // virtual duration is the cut's round length. Each survivor's
        // completion is its submission latency.
        self.world.tracer.finish(
            train_sp,
            crate::trace::Phase::TrainFold,
            None,
            plan.round_len,
        );
        for f in &survivors {
            self.world.tracer.record_submission(f.region, f.completion);
        }

        let selected_h = region_histogram(m, fates.iter().map(|f| f.region));
        let alive = region_histogram(m, fates.iter().filter(|f| !f.dropped).map(|f| f.region));
        let submissions: Vec<usize> = regional.iter().map(|r| r.count()).collect();
        let folded: usize = submissions.iter().sum();
        let bytes_moved = folded as u64 * comm.codec.wire_bytes(self.world.tm.n_model_values());
        let avail = ground_truth_avail(&self.world, &fates);

        Ok(RoundOutcome {
            selected: selected_h,
            alive,
            submissions,
            regional,
            avail,
            round_len: plan.round_len,
            deadline_hit: plan.deadline_hit,
            energy_j,
            bytes_moved,
        })
    }

    fn evaluate(&mut self, model: &ModelParams) -> Result<EvalResult> {
        self.engine.evaluate(model)
    }

    fn capture_state(&self) -> EnvState {
        let comm = if self.residuals.is_empty() {
            CommState::Stateless
        } else {
            // O(clients) Arc bumps — no residual vector is copied here,
            // so checkpointing a large `topk+ef` run never transiently
            // doubles residual memory (pinned by test).
            CommState::Residuals {
                clients: self
                    .residuals
                    .iter()
                    .map(|(k, v)| (*k, Arc::clone(v)))
                    .collect(),
            }
        };
        EnvState {
            rng: self.world.rng.state(),
            churn: self.world.dynamics.state(),
            comm,
        }
    }

    fn restore_state(&mut self, state: EnvState) -> Result<()> {
        self.world.rng = Rng::from_state(state.rng);
        self.world.dynamics.restore(state.churn)?;
        match state.comm {
            CommState::Stateless => {
                self.residuals.clear();
            }
            CommState::Residuals { clients } => {
                anyhow::ensure!(
                    self.world.cfg.comm.codec.has_error_feedback(),
                    "snapshot carries error-feedback residuals but the run's codec \
                     ({}) keeps none",
                    self.world.cfg.comm.codec.name()
                );
                self.residuals = clients.into_iter().collect();
            }
        }
        Ok(())
    }

    fn inject_fault(&mut self, event: FaultEvent) -> Result<()> {
        inject_world_fault(&mut self.world, event)
    }

    fn set_fate_recording(&mut self, on: bool) {
        self.world.recorder = on.then(FateTrace::new);
    }

    fn take_fate_trace(&mut self) -> Option<FateTrace> {
        self.world.recorder.take()
    }

    fn tracer(&mut self) -> &mut crate::trace::SpanRecorder {
        &mut self.world.tracer
    }
}

impl VirtualClockEnv {
    /// The serial fold: the historical single-threaded streaming loop in
    /// global completion order, and the only path that services
    /// error-feedback codecs (per-client residuals are sequential state)
    /// and non-mock engines (one engine instance per run).
    fn fold_serial(
        &mut self,
        survivors: &[&ClientFate],
        starts: Starts<'_>,
        rng: &Rng,
        comm: &CommConfig,
    ) -> Result<Vec<RegionAccumulator>> {
        // All regions run the same architecture, so region 0's start
        // model provides the zeros template for every accumulator.
        //
        // Under a compressed codec each trained model is framed exactly as
        // the device would frame it — delta vs the region's start model,
        // stochastic rounding from the client's own comm stream, error
        // feedback against its carried residual — and the frame decodes
        // straight into the accumulator (`fold_encoded`), never through an
        // intermediate dense model. Dense keeps the legacy fold verbatim.
        let codec = comm.codec.codec();
        let mut agg = StreamingAggregator::for_regions(&self.region_data, starts.for_region(0));
        for f in survivors {
            let indices = &self.world.data.partitions[f.client];
            let out = self.engine.train_local(
                starts.for_region(f.region),
                indices,
                self.world.cfg.local_epochs,
                self.world.cfg.lr as f32,
            )?;
            if comm.codec.is_dense() {
                agg.fold(f.region, &out.params, indices.len() as f64, out.loss)?;
                continue;
            }
            let start = starts.for_region(f.region);
            let mut delta = out.params;
            delta.axpy(-1.0, start);
            let mut crng = rng.split(COMM_STREAM).split(f.client as u64);
            let residual = if comm.codec.has_error_feedback() {
                let r = self
                    .residuals
                    .entry(f.client)
                    .or_insert_with(|| Arc::new(vec![0.0; delta.n_values()]));
                anyhow::ensure!(
                    r.len() == delta.n_values(),
                    "client {} carries a residual of {} values but the model has {}",
                    f.client,
                    r.len(),
                    delta.n_values()
                );
                Some(Arc::make_mut(r))
            } else {
                None
            };
            let frame = codec.encode(&delta, &mut EncodeCtx { rng: &mut crng, residual });
            agg.fold_encoded(f.region, start, &frame, indices.len() as f64, out.loss)?;
        }
        Ok(agg.into_regions())
    }
}

/// Fan the per-region train→fold work out across scoped worker threads,
/// regions chunked contiguously over up to `available_parallelism`
/// workers.
///
/// Byte-identical to [`VirtualClockEnv::fold_serial`] because (a) a fold
/// only ever touches its own region's accumulator, and within-region
/// completion order — the only order the f32 accumulation depends on — is
/// preserved by the partition; (b) the mock engine is a pure function of
/// its training inputs, and each worker builds its own instance; (c) each
/// client's comm substream is derived by *splitting* (never advancing)
/// the round RNG, so the draws are independent of scheduling. Pinned by
/// the parallel-vs-serial identity tests.
fn fold_regions_parallel(
    cfg: &ExperimentConfig,
    data: &Arc<FederatedData>,
    region_data: &[f64],
    by_region: &[Vec<ClientFate>],
    starts: Starts<'_>,
    rng: &Rng,
    comm: &CommConfig,
) -> Result<Vec<RegionAccumulator>> {
    let m = by_region.len();
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, m);
    let chunk = m.div_ceil(workers);
    let chunk_results: Vec<Result<Vec<RegionAccumulator>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let lo = (w * chunk).min(m);
                let hi = (lo + chunk).min(m);
                s.spawn(move || -> Result<Vec<RegionAccumulator>> {
                    let mut engine = build_engine(cfg, Arc::clone(data))?;
                    (lo..hi)
                        .map(|r| {
                            fold_one_region(
                                engine.as_mut(),
                                cfg,
                                data.as_ref(),
                                comm,
                                rng,
                                r,
                                region_data[r],
                                starts.for_region(r),
                                &by_region[r],
                            )
                        })
                        .collect()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("region fold worker panicked"))
            .collect()
    });
    let mut out = Vec::with_capacity(m);
    for res in chunk_results {
        out.extend(res?);
    }
    Ok(out)
}

/// One region's train→fold loop, in the given (completion-order) survivor
/// order — the unit of work a fold worker executes. Error-feedback codecs
/// never reach this path (gated in `run_round`), so no residual state is
/// threaded through.
#[allow(clippy::too_many_arguments)]
fn fold_one_region(
    engine: &mut dyn Engine,
    cfg: &ExperimentConfig,
    data: &FederatedData,
    comm: &CommConfig,
    rng: &Rng,
    r: usize,
    region_data: f64,
    start: &ModelParams,
    survivors: &[ClientFate],
) -> Result<RegionAccumulator> {
    let codec = comm.codec.codec();
    let mut acc = RegionAccumulator::new(r, region_data, start);
    for f in survivors {
        let indices = &data.partitions[f.client];
        let out = engine.train_local(start, indices, cfg.local_epochs, cfg.lr as f32)?;
        if comm.codec.is_dense() {
            acc.fold(&out.params, indices.len() as f64, out.loss)?;
            continue;
        }
        let mut delta = out.params;
        delta.axpy(-1.0, start);
        let mut crng = rng.split(COMM_STREAM).split(f.client as u64);
        let frame = codec.encode(
            &delta,
            &mut EncodeCtx {
                rng: &mut crng,
                residual: None,
            },
        );
        acc.fold_encoded(start, &frame, indices.len() as f64, out.loss)?;
    }
    Ok(acc)
}
