//! [`VirtualClockEnv`] — the deterministic MEC simulator as an
//! [`FlEnvironment`] backend.
//!
//! This absorbs the round mechanics that used to live inside
//! `sim::FlRun` + `protocols::RoundCtx`: selection sampling, fate draws,
//! cutoff resolution, energy charging, and inline local training on the
//! configured compute engine. Rounds are pure arithmetic on a virtual
//! clock; every draw comes from the seeded per-round RNG stream, so runs
//! are bitwise reproducible per seed.
//!
//! Aggregation is streamed: in-time survivors are trained and folded into
//! per-region [`RegionAccumulator`]s one at a time, in completion-time
//! order with a stable client-id tie-break — the deterministic image of
//! the live backend's arrival order. At no point does the environment
//! hold more than one trained model plus the O(regions) accumulators.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::aggregation::StreamingAggregator;
use crate::churn::{ChurnState, FateTrace};
use crate::comm::{CommState, EncodeCtx, COMM_STREAM};
use crate::config::ExperimentConfig;
use crate::env::{
    charge_energy, draw_fates, draw_selection, ground_truth_avail, oracle_drop_table,
    record_fates, region_histogram, resolve_cutoff, step_world, ClientFate, CutoffPolicy,
    FlEnvironment, RoundOutcome, Selection, Starts, World,
};
use crate::model::ModelParams;
use crate::rng::{Rng, RngState};
use crate::runtime::{build_engine, Engine, EvalResult};
use crate::timing::TimingModel;
use crate::Result;

pub struct VirtualClockEnv {
    world: World,
    engine: Box<dyn Engine>,
    region_data: Vec<f64>,
    /// Per-client error-feedback residuals (`topk+ef` only). Raw vectors,
    /// deliberately outside the `ModelParams` arena accounting: they are
    /// device-side state, not in-flight models, and only clients that have
    /// actually submitted under `+ef` hold one.
    residuals: BTreeMap<usize, Vec<f32>>,
}

impl VirtualClockEnv {
    /// Build the full simulated world from a config (deterministic in
    /// `cfg.seed`).
    pub fn new(cfg: ExperimentConfig) -> Result<VirtualClockEnv> {
        let world = World::build(cfg)?;
        let engine = build_engine(&world.cfg, Arc::clone(&world.data))?;
        let region_data = world.region_data_sizes();
        Ok(VirtualClockEnv {
            world,
            engine,
            region_data,
            residuals: BTreeMap::new(),
        })
    }

    /// The timing model in effect (deadline `t_lim`, RTT, completions).
    pub fn timing(&self) -> &TimingModel {
        &self.world.tm
    }
}

impl FlEnvironment for VirtualClockEnv {
    fn cfg(&self) -> &ExperimentConfig {
        &self.world.cfg
    }

    fn n_regions(&self) -> usize {
        self.world.topo.n_regions()
    }

    fn n_clients(&self) -> usize {
        self.world.topo.n_clients()
    }

    fn region_size(&self, r: usize) -> usize {
        self.world.topo.region_size(r)
    }

    fn region_data_size(&self, r: usize) -> f64 {
        self.region_data[r]
    }

    fn t_c2e2c(&self) -> f64 {
        self.world.tm.t_c2e2c
    }

    fn init_model(&self) -> ModelParams {
        self.engine.init_params()
    }

    fn run_round(
        &mut self,
        t: usize,
        selection: Selection,
        starts: Starts<'_>,
        policy: CutoffPolicy,
    ) -> Result<RoundOutcome> {
        // World dynamics first (contract point 6): churn may rewrite
        // per-client reliability — and, under migration events, the
        // topology — before anything about this round is drawn.
        if step_world(&mut self.world, t) {
            self.region_data = self.world.region_data_sizes();
        }
        let m = self.world.topo.n_regions();
        let mut rng = self.world.rng.split(t as u64);

        // Selection fan-out, then per-client fates — same RNG order as the
        // live backend so both inhabit the same random world. The oracle's
        // ground-truth table (when configured) is drawn once, from a child
        // stream, and feeds both steps so they agree on who survives.
        let oracle_drops = oracle_drop_table(&self.world, t);
        let selected = draw_selection(&self.world, &selection, oracle_drops.as_deref(), &mut rng);
        let fates = draw_fates(&self.world, t, &selected, oracle_drops.as_deref(), &mut rng);
        record_fates(&mut self.world, t, &fates);

        // Round cut per policy, then energy accounting against it.
        let plan = resolve_cutoff(&self.world.tm, m, &fates, policy);
        let energy_j = charge_energy(&self.world, &fates, &plan.cuts);

        // Stream the in-time survivors: train each and fold it into its
        // region's accumulator immediately, in completion-time order with
        // a stable client-id tie-break (the deterministic stand-in for
        // the live backend's arrival order). The trained model is dropped
        // right after the fold — peak resident models stay O(regions).
        let mut survivors: Vec<&ClientFate> = fates
            .iter()
            .filter(|f| !f.dropped && f.completion <= plan.cuts[f.region])
            .collect();
        survivors.sort_by(|a, b| {
            a.completion
                .partial_cmp(&b.completion)
                .expect("survivor completion times are finite")
                .then(a.client.cmp(&b.client))
        });

        // All regions run the same architecture, so region 0's start
        // model provides the zeros template for every accumulator.
        //
        // Under a compressed codec each trained model is framed exactly as
        // the device would frame it — delta vs the region's start model,
        // stochastic rounding from the client's own comm stream, error
        // feedback against its carried residual — and the frame decodes
        // straight into the accumulator (`fold_encoded`), never through an
        // intermediate dense model. Dense keeps the legacy fold verbatim.
        let comm = self.world.cfg.comm.clone();
        let codec = comm.codec.codec();
        let mut agg = StreamingAggregator::for_regions(&self.region_data, starts.for_region(0));
        for f in survivors {
            let indices = &self.world.data.partitions[f.client];
            let out = self.engine.train_local(
                starts.for_region(f.region),
                indices,
                self.world.cfg.local_epochs,
                self.world.cfg.lr as f32,
            )?;
            if comm.codec.is_dense() {
                agg.fold(f.region, &out.params, indices.len() as f64, out.loss)?;
                continue;
            }
            let start = starts.for_region(f.region);
            let mut delta = out.params;
            delta.axpy(-1.0, start);
            let mut crng = rng.split(COMM_STREAM).split(f.client as u64);
            let residual = if comm.codec.has_error_feedback() {
                let r = self
                    .residuals
                    .entry(f.client)
                    .or_insert_with(|| vec![0.0; delta.n_values()]);
                anyhow::ensure!(
                    r.len() == delta.n_values(),
                    "client {} carries a residual of {} values but the model has {}",
                    f.client,
                    r.len(),
                    delta.n_values()
                );
                Some(r)
            } else {
                None
            };
            let frame = codec.encode(&delta, &mut EncodeCtx { rng: &mut crng, residual });
            agg.fold_encoded(f.region, start, &frame, indices.len() as f64, out.loss)?;
        }

        let selected_h = region_histogram(m, fates.iter().map(|f| f.region));
        let alive = region_histogram(m, fates.iter().filter(|f| !f.dropped).map(|f| f.region));
        let regional = agg.into_regions();
        let submissions: Vec<usize> = regional.iter().map(|r| r.count()).collect();
        let folded: usize = submissions.iter().sum();
        let bytes_moved =
            folded as u64 * comm.codec.wire_bytes(self.world.tm.n_model_values());
        let avail = ground_truth_avail(&self.world, &fates);

        Ok(RoundOutcome {
            selected: selected_h,
            alive,
            submissions,
            regional,
            avail,
            round_len: plan.round_len,
            deadline_hit: plan.deadline_hit,
            energy_j,
            bytes_moved,
        })
    }

    fn evaluate(&mut self, model: &ModelParams) -> Result<EvalResult> {
        self.engine.evaluate(model)
    }

    fn rng_state(&self) -> RngState {
        self.world.rng.state()
    }

    fn restore_rng_state(&mut self, state: RngState) {
        self.world.rng = Rng::from_state(state);
    }

    fn churn_state(&self) -> ChurnState {
        self.world.dynamics.state()
    }

    fn restore_churn_state(&mut self, state: ChurnState) -> Result<()> {
        self.world.dynamics.restore(state)
    }

    fn comm_state(&self) -> CommState {
        if self.residuals.is_empty() {
            CommState::Stateless
        } else {
            CommState::Residuals {
                clients: self
                    .residuals
                    .iter()
                    .map(|(k, v)| (*k, v.clone()))
                    .collect(),
            }
        }
    }

    fn restore_comm_state(&mut self, state: CommState) -> Result<()> {
        match state {
            CommState::Stateless => {
                self.residuals.clear();
                Ok(())
            }
            CommState::Residuals { clients } => {
                anyhow::ensure!(
                    self.world.cfg.comm.codec.has_error_feedback(),
                    "snapshot carries error-feedback residuals but the run's codec \
                     ({}) keeps none",
                    self.world.cfg.comm.codec.name()
                );
                self.residuals = clients.into_iter().collect();
                Ok(())
            }
        }
    }

    fn set_fate_recording(&mut self, on: bool) {
        self.world.recorder = on.then(FateTrace::new);
    }

    fn take_fate_trace(&mut self) -> Option<FateTrace> {
        self.world.recorder.take()
    }
}
