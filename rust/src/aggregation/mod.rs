//! Model aggregation (S1, paper §III.B): FedAvg weighted averaging,
//! regional aggregation with model caching (eq. 17), Effective Data
//! Coverage (eqs. 18–19) and EDC-weighted cloud aggregation (eq. 20).
//!
//! Two forms of the same math live here:
//!
//! * **Batch** functions ([`fedavg`], [`regional_with_cache`],
//!   [`edc_cloud`]) over slices of already-materialized models — used by
//!   protocol-level recombination (m regional models at the cloud) and as
//!   the reference implementation in property tests.
//! * **Streaming** state ([`RegionAccumulator`], [`StreamingAggregator`])
//!   that folds each submitted model into a per-region partial sum *as it
//!   arrives*, so a round never holds more than O(regions) models
//!   resident — the data plane both [`crate::env::FlEnvironment`]
//!   backends run on. The fold is the Σ term of eq. 17; [`edc`] tracking
//!   (eq. 18) and the cache/EDC finishers (eqs. 17/20) complete the
//!   round from the accumulated state alone.
//!
//! [`edc`]: RegionAccumulator::edc

use crate::comm::{EncodedUpdate, Payload};
use crate::model::{weighted_average, ModelParams};
use crate::Result;
use std::fmt;

/// A submission the streaming fold cannot accept. Folding is the hot
/// path of both backends, fed by messages that crossed a (real or
/// simulated) network — a malformed submission must surface as a typed,
/// per-submission error the edge can log and skip, not a panic deep in
/// the chunked `axpy` kernel.
#[derive(Clone, Debug, PartialEq)]
pub enum FoldError {
    /// The submitted model's shape table differs from the accumulator's
    /// template.
    ShapeMismatch {
        region: usize,
        expected: Vec<Vec<usize>>,
        got: Vec<Vec<usize>>,
    },
    /// An encoded frame is internally inconsistent with the template
    /// (wrong value count, out-of-range sparse index, …).
    FrameMismatch { region: usize, detail: String },
}

impl fmt::Display for FoldError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FoldError::ShapeMismatch {
                region,
                expected,
                got,
            } => write!(
                f,
                "region {region}: submitted model shapes {got:?} do not match \
                 the accumulator template {expected:?}"
            ),
            FoldError::FrameMismatch { region, detail } => {
                write!(f, "region {region}: malformed encoded frame: {detail}")
            }
        }
    }
}

impl std::error::Error for FoldError {}

/// Per-submission fold outcome.
pub type FoldResult = std::result::Result<(), FoldError>;

/// Plain FedAvg: `w = Σ (|D_k|/Σ|D|) · w_k` over the received models.
/// Returns `None` if nothing was received (callers keep the old model).
pub fn fedavg(models: &[(&ModelParams, f64)]) -> Option<ModelParams> {
    weighted_average(models)
}

/// Coverage = covered / region_data, validated: submitted data exceeding
/// the region's total is an inconsistency in the caller's bookkeeping and
/// is reported as an error instead of being silently clamped away.
fn checked_coverage(covered: f64, region_data: f64) -> Result<f64> {
    anyhow::ensure!(
        region_data > 0.0,
        "region_data must be positive, got {region_data}"
    );
    let coverage = covered / region_data;
    anyhow::ensure!(
        coverage <= 1.0 + 1e-6,
        "covered data {covered} exceeds region total {region_data}: \
         inconsistent |D_k| vs |D^r| bookkeeping"
    );
    Ok(coverage.min(1.0))
}

/// Regional aggregation with the paper's cache rule (eq. 17).
///
/// Eq. 17 sums over *all* clients of the region, substituting the previous
/// regional model for clients without a successful update:
/// `w_k^r(t) = w^r(t−1) if k ∉ S_r(t)`. That sum algebraically reduces to
///
/// ```text
///   w^r(t) = Σ_{k∈S_r} (|D_k|/|D^r|)·w_k(t)  +  (1 − coverage_r)·w^r(t−1)
/// ```
///
/// with `coverage_r = Σ_{k∈S_r} |D_k| / |D^r|` — which is what we compute
/// (exactly equivalent, touches |S_r| models instead of n_r). Errors when
/// the submitted data sizes sum to more than `region_data` (beyond f64
/// rounding): that can only mean inconsistent data-size bookkeeping.
pub fn regional_with_cache(
    submitted: &[(&ModelParams, f64)],
    region_data: f64,
    prev_regional: &ModelParams,
) -> Result<ModelParams> {
    let covered: f64 = submitted.iter().map(|(_, d)| *d).sum();
    let coverage = checked_coverage(covered, region_data)?;
    let mut out = prev_regional.zeros_like();
    for (m, d) in submitted {
        out.axpy((*d / region_data) as f32, m);
    }
    out.axpy((1.0 - coverage) as f32, prev_regional);
    Ok(out)
}

/// EDC_r(t) — effective data coverage of a region (eq. 18): total samples
/// held by this round's successful submitters.
pub fn edc_region(submitted_partition_sizes: &[usize]) -> f64 {
    submitted_partition_sizes.iter().map(|&s| s as f64).sum()
}

/// Cloud aggregation (eq. 20): regional models weighted by EDC_r / EDC.
/// `None` when EDC(t) = 0 — no region received anything; the cloud keeps
/// w(t−1).
pub fn edc_cloud(regionals: &[(&ModelParams, f64)]) -> Option<ModelParams> {
    weighted_average(regionals)
}

/// Online per-region fold of eq. 17's Σ term: `Σ (|D_k|/|D^r|)·w_k` over
/// the in-time submissions, accumulated one model at a time. This is the
/// state an edge (or the virtual clock standing in for one) keeps during
/// a round — O(1) models per region, regardless of how many clients
/// submit.
#[derive(Clone, Debug)]
pub struct RegionAccumulator {
    region: usize,
    /// |D^r| — total samples held by the region's clients.
    region_data: f64,
    /// The partial weighted sum (zeros until the first fold).
    acc: ModelParams,
    /// Σ |D_k| over folded submissions = EDC_r(t) (eq. 18).
    covered: f64,
    /// |S_r(t)|.
    count: usize,
    /// Σ local losses (diagnostics).
    loss_sum: f64,
}

impl RegionAccumulator {
    /// Fresh accumulator for one region; `template` only provides the
    /// parameter structure (a zeros arena is allocated from it).
    pub fn new(region: usize, region_data: f64, template: &ModelParams) -> RegionAccumulator {
        debug_assert!(region_data > 0.0);
        RegionAccumulator {
            region,
            region_data,
            acc: template.zeros_like(),
            covered: 0.0,
            count: 0,
            loss_sum: 0.0,
        }
    }

    /// Fold one in-time submission into the partial sum. The caller can
    /// (and should) drop `model` right after — nothing is buffered.
    /// Validates the submission's shape table against the template first:
    /// a mismatch is a typed error, never a panic in the axpy kernel.
    pub fn fold(&mut self, model: &ModelParams, data_size: f64, loss: f64) -> FoldResult {
        debug_assert!(data_size >= 0.0);
        self.check_shapes(model.shapes())?;
        self.acc.axpy((data_size / self.region_data) as f32, model);
        self.covered += data_size;
        self.count += 1;
        self.loss_sum += loss;
        Ok(())
    }

    /// Fold one *encoded* submission (see [`crate::comm`]). A
    /// [`Payload::Dense`] frame carries the full trained model and folds
    /// exactly like [`Self::fold`]; every compressed variant carries the
    /// **delta** from the round's start model, so the submitting client's
    /// model is `start + decode(frame)` and the fold applies `α·start`
    /// plus the scaled decoded entries straight into the partial sum —
    /// no intermediate dense model is ever materialized, preserving the
    /// O(regions) arena peak under compression. All frame validation
    /// happens before the first write: a rejected submission leaves the
    /// accumulator untouched.
    pub fn fold_encoded(
        &mut self,
        start: &ModelParams,
        frame: &EncodedUpdate,
        data_size: f64,
        loss: f64,
    ) -> FoldResult {
        if let Payload::Dense(model) = &frame.payload {
            return self.fold(model, data_size, loss);
        }
        debug_assert!(data_size >= 0.0);
        self.check_shapes(start.shapes())?;
        let n = self.acc.n_values();
        match &frame.payload {
            Payload::Dense(_) => unreachable!("dense frames fold above"),
            Payload::F16(bits) => {
                if bits.len() != n {
                    return Err(self
                        .frame_err(format!("f16 frame has {} values, model has {n}", bits.len())));
                }
            }
            Payload::I8 { values, .. } => {
                if values.len() != n {
                    return Err(self.frame_err(format!(
                        "i8 frame has {} values, model has {n}",
                        values.len()
                    )));
                }
            }
            Payload::Sparse { indices, values } => {
                if indices.len() != values.len() {
                    return Err(self.frame_err(format!(
                        "sparse frame has {} indices but {} values",
                        indices.len(),
                        values.len()
                    )));
                }
                if let Some(&i) = indices.iter().find(|&&i| i as usize >= n) {
                    return Err(
                        self.frame_err(format!("sparse index {i} out of range for {n} values"))
                    );
                }
            }
        }
        let alpha = (data_size / self.region_data) as f32;
        self.acc.axpy(alpha, start);
        let dst = self.acc.values_mut();
        match &frame.payload {
            Payload::Dense(_) => unreachable!("dense frames fold above"),
            Payload::F16(bits) => {
                for (d, &b) in dst.iter_mut().zip(bits.iter()) {
                    *d += alpha * crate::comm::f16_to_f32(b);
                }
            }
            Payload::I8 { scale, values } => {
                for (d, &q) in dst.iter_mut().zip(values.iter()) {
                    *d += alpha * f32::from(q) * scale;
                }
            }
            Payload::Sparse { indices, values } => {
                for (&i, &v) in indices.iter().zip(values.iter()) {
                    dst[i as usize] += alpha * v;
                }
            }
        }
        self.covered += data_size;
        self.count += 1;
        self.loss_sum += loss;
        Ok(())
    }

    fn check_shapes(&self, got: &[Vec<usize>]) -> FoldResult {
        if got != self.acc.shapes() {
            return Err(FoldError::ShapeMismatch {
                region: self.region,
                expected: self.acc.shapes().to_vec(),
                got: got.to_vec(),
            });
        }
        Ok(())
    }

    fn frame_err(&self, detail: String) -> FoldError {
        FoldError::FrameMismatch {
            region: self.region,
            detail,
        }
    }

    pub fn region(&self) -> usize {
        self.region
    }

    pub fn region_data(&self) -> f64 {
        self.region_data
    }

    /// |S_r(t)| — submissions folded so far.
    pub fn count(&self) -> usize {
        self.count
    }

    pub fn loss_sum(&self) -> f64 {
        self.loss_sum
    }

    /// EDC_r(t) (eq. 18).
    pub fn edc(&self) -> f64 {
        self.covered
    }

    /// Fraction of the region's data covered by the folded submissions.
    pub fn coverage(&self) -> f64 {
        self.covered / self.region_data
    }

    /// The partial weighted sum `Σ (|D_k|/|D^r|)·w_k` accumulated so far.
    pub fn weighted_sum(&self) -> &ModelParams {
        &self.acc
    }

    /// Complete eq. 17 from the streamed state: partial sum plus the
    /// cached previous regional model weighted by the uncovered fraction.
    /// Errors (like [`regional_with_cache`]) when the folded data sizes
    /// exceed `region_data`.
    pub fn finish_cached(&self, prev_regional: &ModelParams) -> Result<ModelParams> {
        let coverage = checked_coverage(self.covered, self.region_data)?;
        let mut out = self.acc.clone();
        out.axpy((1.0 - coverage) as f32, prev_regional);
        Ok(out)
    }

    /// Plain FedAvg over the folded submissions only (the fresh-model
    /// ablation, and HierFAVG's edge aggregation): rescales the partial
    /// sum by `|D^r| / Σ|D_k|`. `None` when nothing was folded.
    pub fn fedavg(&self) -> Option<ModelParams> {
        if self.count == 0 || self.covered <= f64::EPSILON {
            return None;
        }
        let mut out = self.acc.clone();
        out.scale((self.region_data / self.covered) as f32);
        Some(out)
    }
}

/// All-regions streaming state for one round: eq. 17's Σ term per region,
/// folded in arrival order, plus the EDC weights eq. 20 needs. Peak
/// resident model state is O(regions) however many clients submit.
#[derive(Clone, Debug)]
pub struct StreamingAggregator {
    regions: Vec<RegionAccumulator>,
}

impl StreamingAggregator {
    pub fn new(regions: Vec<RegionAccumulator>) -> StreamingAggregator {
        debug_assert!(regions.iter().enumerate().all(|(i, r)| r.region() == i));
        StreamingAggregator { regions }
    }

    /// Convenience constructor: one accumulator per region with the given
    /// data sizes, all sharing one zero template structure.
    pub fn for_regions(region_data: &[f64], template: &ModelParams) -> StreamingAggregator {
        StreamingAggregator::new(
            region_data
                .iter()
                .enumerate()
                .map(|(r, &d)| RegionAccumulator::new(r, d, template))
                .collect(),
        )
    }

    /// Fold one in-time submission into its region.
    pub fn fold(
        &mut self,
        region: usize,
        model: &ModelParams,
        data_size: f64,
        loss: f64,
    ) -> FoldResult {
        self.regions[region].fold(model, data_size, loss)
    }

    /// Fold one encoded submission into its region (see
    /// [`RegionAccumulator::fold_encoded`]).
    pub fn fold_encoded(
        &mut self,
        region: usize,
        start: &ModelParams,
        frame: &EncodedUpdate,
        data_size: f64,
        loss: f64,
    ) -> FoldResult {
        self.regions[region].fold_encoded(start, frame, data_size, loss)
    }

    pub fn regions(&self) -> &[RegionAccumulator] {
        &self.regions
    }

    pub fn into_regions(self) -> Vec<RegionAccumulator> {
        self.regions
    }

    /// |S_r(t)| per region.
    pub fn counts(&self) -> Vec<usize> {
        self.regions.iter().map(|r| r.count()).collect()
    }

    /// Total submissions folded this round.
    pub fn total_count(&self) -> usize {
        self.regions.iter().map(|r| r.count()).sum()
    }

    /// HybridFL's full two-level aggregation (eqs. 17–20) from streamed
    /// state: finish each region with the cache rule against its previous
    /// regional model, then EDC-weight the regional results at the cloud.
    /// `Ok(None)` when total EDC is 0 (the cloud keeps w(t−1)).
    pub fn cloud_with_cache(
        &self,
        prev_regionals: &[ModelParams],
    ) -> Result<Option<ModelParams>> {
        debug_assert_eq!(prev_regionals.len(), self.regions.len());
        let mut regionals = Vec::with_capacity(self.regions.len());
        for (acc, prev) in self.regions.iter().zip(prev_regionals.iter()) {
            regionals.push((acc.finish_cached(prev)?, acc.edc()));
        }
        let refs: Vec<(&ModelParams, f64)> = regionals.iter().map(|(w, e)| (w, *e)).collect();
        Ok(edc_cloud(&refs))
    }
}

/// Global FedAvg recombined from per-region streamed partial sums:
/// `Σ_k |D_k|·w_k / Σ_k |D_k| = Σ_r |D^r|·sum_r / Σ_r EDC_r` where
/// `sum_r` is the accumulator's normalized partial sum. This lets FedAvg —
/// which has no edge layer in its aggregation rule — consume the same
/// streamed per-region state as the hierarchical protocols. `None` when
/// nothing was submitted anywhere.
pub fn fedavg_from_regions(regions: &[RegionAccumulator]) -> Option<ModelParams> {
    let total: f64 = regions.iter().map(|r| r.edc()).sum();
    if regions.is_empty() || total <= f64::EPSILON {
        return None;
    }
    let mut out = regions[0].weighted_sum().zeros_like();
    for r in regions {
        if r.count() > 0 {
            out.axpy((r.region_data() / total) as f32, r.weighted_sum());
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(vals: &[f32]) -> ModelParams {
        ModelParams::new(vec![vals.to_vec()], vec![vec![vals.len()]])
    }

    #[test]
    fn fedavg_weights_by_partition_size() {
        let a = p(&[1.0]);
        let b = p(&[4.0]);
        let w = fedavg(&[(&a, 100.0), (&b, 300.0)]).unwrap();
        assert!((w.values()[0] - 3.25).abs() < 1e-6);
        assert!(fedavg(&[]).is_none());
    }

    /// The reduced cache formula must equal the literal eq. 17 sum over all
    /// region clients with cached models substituted.
    #[test]
    fn cache_reduction_matches_literal_eq17() {
        let prev = p(&[10.0, -2.0]);
        let w1 = p(&[1.0, 1.0]); // client with |D|=30 submitted
        let w2 = p(&[5.0, 3.0]); // client with |D|=20 submitted
        // Region has 4 clients with |D| = 30, 20, 25, 25 (total 100).
        let out = regional_with_cache(&[(&w1, 30.0), (&w2, 20.0)], 100.0, &prev).unwrap();
        // Literal eq. 17: 0.3·w1 + 0.2·w2 + 0.25·prev + 0.25·prev
        let mut lit = prev.zeros_like();
        lit.axpy(0.3, &w1);
        lit.axpy(0.2, &w2);
        lit.axpy(0.25, &prev);
        lit.axpy(0.25, &prev);
        assert!(out.l2_distance(&lit) < 1e-6);
    }

    #[test]
    fn empty_submissions_keep_previous_regional() {
        let prev = p(&[3.0, 4.0]);
        let out = regional_with_cache(&[], 50.0, &prev).unwrap();
        assert!(out.l2_distance(&prev) < 1e-7);
    }

    #[test]
    fn full_coverage_ignores_previous() {
        let prev = p(&[100.0]);
        let w1 = p(&[2.0]);
        let out = regional_with_cache(&[(&w1, 50.0)], 50.0, &prev).unwrap();
        assert!((out.values()[0] - 2.0).abs() < 1e-5);
    }

    /// Satellite fix: submitted data sizes summing past |D^r| is an error,
    /// not a silent clamp.
    #[test]
    fn overcoverage_is_an_error_not_a_clamp() {
        let prev = p(&[1.0]);
        let w1 = p(&[2.0]);
        assert!(regional_with_cache(&[(&w1, 120.0)], 100.0, &prev).is_err());
        let mut acc = RegionAccumulator::new(0, 100.0, &prev);
        acc.fold(&w1, 120.0, 0.0).unwrap();
        assert!(acc.finish_cached(&prev).is_err());
    }

    #[test]
    fn edc_math() {
        assert_eq!(edc_region(&[100, 40, 10]), 150.0);
        assert_eq!(edc_region(&[]), 0.0);
        let a = p(&[0.0]);
        let b = p(&[6.0]);
        let w = edc_cloud(&[(&a, 100.0), (&b, 200.0)]).unwrap();
        assert!((w.values()[0] - 4.0).abs() < 1e-6);
        assert!(edc_cloud(&[(&a, 0.0), (&b, 0.0)]).is_none());
    }

    /// Weights in the combined two-level aggregation sum to 1 (the γ
    /// normalization in eq. 21 that the convergence proof relies on).
    #[test]
    fn two_level_weights_normalize() {
        let w1 = p(&[1.0]);
        let w2 = p(&[1.0]);
        let prev1 = p(&[1.0]);
        let r1 = regional_with_cache(&[(&w1, 60.0)], 100.0, &prev1).unwrap();
        let r2 = regional_with_cache(&[(&w2, 30.0)], 80.0, &prev1).unwrap();
        let cloud = edc_cloud(&[(&r1, 60.0), (&r2, 30.0)]).unwrap();
        // Every contributing model is all-ones → any convex combination is 1.
        assert!((cloud.values()[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn streaming_fold_matches_batch_cache_rule() {
        let prev = p(&[10.0, -2.0]);
        let w1 = p(&[1.0, 1.0]);
        let w2 = p(&[5.0, 3.0]);
        let batch = regional_with_cache(&[(&w1, 30.0), (&w2, 20.0)], 100.0, &prev).unwrap();
        let mut acc = RegionAccumulator::new(0, 100.0, &prev);
        acc.fold(&w1, 30.0, 0.1).unwrap();
        acc.fold(&w2, 20.0, 0.3).unwrap();
        assert_eq!(acc.count(), 2);
        assert_eq!(acc.edc(), 50.0);
        assert!((acc.loss_sum() - 0.4).abs() < 1e-12);
        let streamed = acc.finish_cached(&prev).unwrap();
        assert!(streamed.l2_distance(&batch) < 1e-6);
    }

    #[test]
    fn accumulator_fedavg_matches_batch_fedavg() {
        let w1 = p(&[1.0]);
        let w2 = p(&[4.0]);
        let batch = fedavg(&[(&w1, 100.0), (&w2, 300.0)]).unwrap();
        let mut acc = RegionAccumulator::new(0, 1000.0, &w1);
        acc.fold(&w1, 100.0, 0.0).unwrap();
        acc.fold(&w2, 300.0, 0.0).unwrap();
        let streamed = acc.fedavg().unwrap();
        assert!(streamed.l2_distance(&batch) < 1e-6);
        let empty = RegionAccumulator::new(0, 1000.0, &w1);
        assert!(empty.fedavg().is_none());
    }

    #[test]
    fn fedavg_from_regions_recombines_globally() {
        // Clients: (w=1, d=100) in region 0; (w=4, d=300) in region 1.
        // Global FedAvg = (100·1 + 300·4) / 400 = 3.25.
        let w1 = p(&[1.0]);
        let w2 = p(&[4.0]);
        let template = w1.zeros_like();
        let mut agg = StreamingAggregator::for_regions(&[500.0, 800.0], &template);
        agg.fold(0, &w1, 100.0, 0.0).unwrap();
        agg.fold(1, &w2, 300.0, 0.0).unwrap();
        let global = fedavg_from_regions(agg.regions()).unwrap();
        assert!((global.values()[0] - 3.25).abs() < 1e-5);
        assert_eq!(agg.counts(), vec![1, 1]);
        assert_eq!(agg.total_count(), 2);
        // Nothing submitted anywhere → None.
        let empty = StreamingAggregator::for_regions(&[500.0, 800.0], &template);
        assert!(fedavg_from_regions(empty.regions()).is_none());
    }

    /// Satellite fix: a shape-table mismatch is a typed, recoverable
    /// error — and the rejected fold leaves the accumulator untouched.
    #[test]
    fn fold_rejects_shape_mismatch_with_typed_error() {
        let template = p(&[0.0, 0.0]);
        let wrong = p(&[1.0, 2.0, 3.0]);
        let mut acc = RegionAccumulator::new(1, 100.0, &template);
        match acc.fold(&wrong, 10.0, 0.0).unwrap_err() {
            FoldError::ShapeMismatch {
                region,
                expected,
                got,
            } => {
                assert_eq!(region, 1);
                assert_eq!(expected, vec![vec![2]]);
                assert_eq!(got, vec![vec![3]]);
            }
            other => panic!("expected ShapeMismatch, got {other}"),
        }
        assert_eq!(acc.count(), 0);
        assert_eq!(acc.edc(), 0.0);
        let mut agg = StreamingAggregator::for_regions(&[100.0], &template);
        assert!(agg.fold(0, &wrong, 10.0, 0.0).is_err());
    }

    #[test]
    fn fold_encoded_rejects_inconsistent_frames_before_mutating() {
        let template = p(&[0.0, 0.0]);
        let start = p(&[1.0, 2.0]);
        let mut acc = RegionAccumulator::new(0, 100.0, &template);
        let short = EncodedUpdate {
            payload: Payload::F16(vec![0; 3]),
            wire_bytes: 6,
        };
        assert!(matches!(
            acc.fold_encoded(&start, &short, 10.0, 0.0),
            Err(FoldError::FrameMismatch { .. })
        ));
        let oob = EncodedUpdate {
            payload: Payload::Sparse {
                indices: vec![5],
                values: vec![1.0],
            },
            wire_bytes: 8,
        };
        assert!(acc.fold_encoded(&start, &oob, 10.0, 0.0).is_err());
        let wrong_start = p(&[1.0, 2.0, 3.0]);
        let ok_frame = EncodedUpdate {
            payload: Payload::Sparse {
                indices: vec![0],
                values: vec![1.0],
            },
            wire_bytes: 8,
        };
        assert!(matches!(
            acc.fold_encoded(&wrong_start, &ok_frame, 10.0, 0.0),
            Err(FoldError::ShapeMismatch { .. })
        ));
        // Every rejection happened before the first write.
        assert_eq!(acc.count(), 0);
        assert!(acc.weighted_sum().values().iter().all(|&v| v == 0.0));
    }

    /// Satellite coverage: folding encoded frames equals decoding each
    /// frame to a dense model (start + delta) and dense-folding it —
    /// within f32 tolerance, and independent of fold order.
    #[test]
    fn compressed_fold_matches_decode_then_dense_fold_any_order() {
        use crate::comm::{f16_to_f32, CodecSpec, EncodeCtx};
        use crate::rng::Rng;
        let start = p(&[0.5, -1.0, 2.0, 0.25]);
        let deltas = [
            p(&[0.1, -0.2, 0.05, 0.4]),
            p(&[-0.3, 0.12, 0.0, -0.08]),
            p(&[0.02, 0.5, -0.6, 0.01]),
        ];
        let specs = [
            CodecSpec::F16,
            CodecSpec::I8,
            CodecSpec::TopK {
                fraction: 0.5,
                error_feedback: false,
            },
        ];
        let sizes = [30.0, 20.0, 40.0];
        let mut rng = Rng::new(17);
        let frames: Vec<EncodedUpdate> = specs
            .iter()
            .zip(deltas.iter())
            .map(|(spec, delta)| {
                spec.codec().encode(
                    delta,
                    &mut EncodeCtx {
                        rng: &mut rng,
                        residual: None,
                    },
                )
            })
            .collect();
        // Reference: decode each frame to start + delta and dense-fold.
        let mut reference = RegionAccumulator::new(0, 100.0, &start);
        for (frame, &size) in frames.iter().zip(sizes.iter()) {
            let mut model = start.clone();
            let dst = model.values_mut();
            match &frame.payload {
                Payload::Dense(_) => unreachable!(),
                Payload::F16(bits) => {
                    for (d, &b) in dst.iter_mut().zip(bits.iter()) {
                        *d += f16_to_f32(b);
                    }
                }
                Payload::I8 { scale, values } => {
                    for (d, &q) in dst.iter_mut().zip(values.iter()) {
                        *d += f32::from(q) * scale;
                    }
                }
                Payload::Sparse { indices, values } => {
                    for (&i, &v) in indices.iter().zip(values.iter()) {
                        dst[i as usize] += v;
                    }
                }
            }
            reference.fold(&model, size, 0.0).unwrap();
        }
        for order in [[0usize, 1, 2], [2, 0, 1], [1, 2, 0]] {
            let mut acc = RegionAccumulator::new(0, 100.0, &start);
            for &i in &order {
                acc.fold_encoded(&start, &frames[i], sizes[i], 0.0).unwrap();
            }
            assert_eq!(acc.count(), 3);
            assert_eq!(acc.edc(), 90.0);
            assert!(
                acc.weighted_sum().l2_distance(reference.weighted_sum()) < 1e-5,
                "order {order:?} diverged from the decode-then-fold reference"
            );
        }
    }
}
