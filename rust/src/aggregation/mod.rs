//! Model aggregation (S1, paper §III.B): FedAvg weighted averaging,
//! regional aggregation with model caching (eq. 17), Effective Data
//! Coverage (eqs. 18–19) and EDC-weighted cloud aggregation (eq. 20).

use crate::model::{weighted_average, ModelParams};

/// Plain FedAvg: `w = Σ (|D_k|/Σ|D|) · w_k` over the received models.
/// Returns `None` if nothing was received (callers keep the old model).
pub fn fedavg(models: &[(&ModelParams, f64)]) -> Option<ModelParams> {
    weighted_average(models)
}

/// Regional aggregation with the paper's cache rule (eq. 17).
///
/// Eq. 17 sums over *all* clients of the region, substituting the previous
/// regional model for clients without a successful update:
/// `w_k^r(t) = w^r(t−1) if k ∉ S_r(t)`. That sum algebraically reduces to
///
/// ```text
///   w^r(t) = Σ_{k∈S_r} (|D_k|/|D^r|)·w_k(t)  +  (1 − coverage_r)·w^r(t−1)
/// ```
///
/// with `coverage_r = Σ_{k∈S_r} |D_k| / |D^r|` — which is what we compute
/// (exactly equivalent, touches |S_r| models instead of n_r).
pub fn regional_with_cache(
    submitted: &[(&ModelParams, f64)],
    region_data: f64,
    prev_regional: &ModelParams,
) -> ModelParams {
    debug_assert!(region_data > 0.0);
    let covered: f64 = submitted.iter().map(|(_, d)| *d).sum();
    let mut out = prev_regional.zeros_like();
    for (m, d) in submitted {
        out.axpy((*d / region_data) as f32, m);
    }
    out.axpy((1.0 - covered / region_data).max(0.0) as f32, prev_regional);
    out
}

/// EDC_r(t) — effective data coverage of a region (eq. 18): total samples
/// held by this round's successful submitters.
pub fn edc_region(submitted_partition_sizes: &[usize]) -> f64 {
    submitted_partition_sizes.iter().map(|&s| s as f64).sum()
}

/// Cloud aggregation (eq. 20): regional models weighted by EDC_r / EDC.
/// `None` when EDC(t) = 0 — no region received anything; the cloud keeps
/// w(t−1).
pub fn edc_cloud(regionals: &[(&ModelParams, f64)]) -> Option<ModelParams> {
    weighted_average(regionals)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(vals: &[f32]) -> ModelParams {
        ModelParams::new(vec![vals.to_vec()], vec![vec![vals.len()]])
    }

    #[test]
    fn fedavg_weights_by_partition_size() {
        let a = p(&[1.0]);
        let b = p(&[4.0]);
        let w = fedavg(&[(&a, 100.0), (&b, 300.0)]).unwrap();
        assert!((w.tensors[0][0] - 3.25).abs() < 1e-6);
        assert!(fedavg(&[]).is_none());
    }

    /// The reduced cache formula must equal the literal eq. 17 sum over all
    /// region clients with cached models substituted.
    #[test]
    fn cache_reduction_matches_literal_eq17() {
        let prev = p(&[10.0, -2.0]);
        let w1 = p(&[1.0, 1.0]); // client with |D|=30 submitted
        let w2 = p(&[5.0, 3.0]); // client with |D|=20 submitted
        // Region has 4 clients with |D| = 30, 20, 25, 25 (total 100).
        let out = regional_with_cache(&[(&w1, 30.0), (&w2, 20.0)], 100.0, &prev);
        // Literal eq. 17: 0.3·w1 + 0.2·w2 + 0.25·prev + 0.25·prev
        let mut lit = prev.zeros_like();
        lit.axpy(0.3, &w1);
        lit.axpy(0.2, &w2);
        lit.axpy(0.25, &prev);
        lit.axpy(0.25, &prev);
        assert!(out.l2_distance(&lit) < 1e-6);
    }

    #[test]
    fn empty_submissions_keep_previous_regional() {
        let prev = p(&[3.0, 4.0]);
        let out = regional_with_cache(&[], 50.0, &prev);
        assert!(out.l2_distance(&prev) < 1e-7);
    }

    #[test]
    fn full_coverage_ignores_previous() {
        let prev = p(&[100.0]);
        let w1 = p(&[2.0]);
        let out = regional_with_cache(&[(&w1, 50.0)], 50.0, &prev);
        assert!((out.tensors[0][0] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn edc_math() {
        assert_eq!(edc_region(&[100, 40, 10]), 150.0);
        assert_eq!(edc_region(&[]), 0.0);
        let a = p(&[0.0]);
        let b = p(&[6.0]);
        let w = edc_cloud(&[(&a, 100.0), (&b, 200.0)]).unwrap();
        assert!((w.tensors[0][0] - 4.0).abs() < 1e-6);
        assert!(edc_cloud(&[(&a, 0.0), (&b, 0.0)]).is_none());
    }

    /// Weights in the combined two-level aggregation sum to 1 (the γ
    /// normalization in eq. 21 that the convergence proof relies on).
    #[test]
    fn two_level_weights_normalize() {
        let w1 = p(&[1.0]);
        let w2 = p(&[1.0]);
        let prev1 = p(&[1.0]);
        let r1 = regional_with_cache(&[(&w1, 60.0)], 100.0, &prev1);
        let r2 = regional_with_cache(&[(&w2, 30.0)], 80.0, &prev1);
        let cloud = edc_cloud(&[(&r1, 60.0), (&r2, 30.0)]).unwrap();
        // Every contributing model is all-ones → any convex combination is 1.
        assert!((cloud.tensors[0][0] - 1.0).abs() < 1e-6);
    }
}
