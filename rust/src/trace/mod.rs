//! Round-phase tracing spans and latency histograms.
//!
//! The paper's headline claims are distributional — round length cut up
//! to 12×, device energy up to 58% — so point gauges are not enough to
//! see *where* a round spends its time or how the straggler tail
//! behaves. This module provides the observability substrate, with no
//! new dependencies:
//!
//! * **[`SpanRecorder`]** — a per-round span log owned by the
//!   environment's `World`. Both backends (and the protocols running on
//!   them) bracket every round phase — churn step, selection, fate
//!   draw, train+fold, regional aggregation, cloud aggregation,
//!   checkpoint — with a [`Phase`]-tagged [`Span`]. Each span carries
//!   two durations with very different contracts (env contract point
//!   8):
//!
//!   - `virtual_s` — the **virtual-clock** duration the protocol
//!     charges the phase (round length for train+fold, the cloud↔edge
//!     RTT for cloud aggregation, zero for bookkeeping phases). This
//!     is protocol-visible, deterministic in the seed, and identical
//!     across hosts.
//!   - `wall_s` / `start_wall_s` — **host wall time**, profiling-only.
//!     It never enters `RoundTrace`, `RunResult`, `EnvState`,
//!     snapshots, or fingerprints, so it can vary freely between runs
//!     without perturbing byte-identity.
//!
//! * **[`Histo`]** — a fixed log₂-bucket histogram: mergeable,
//!   quantile-queryable, rendered straight into Prometheus
//!   `histogram`-type exposition (`_bucket`/`_sum`/`_count` with
//!   cumulative `le` labels). The ops server aggregates round-length,
//!   per-region submission-latency, and per-phase duration histograms
//!   from the span stream.
//!
//! * **[`TraceWriter`]** — a [`RunObserver`] that renders every span as
//!   a Chrome trace-event *complete event* (`"ph":"X"`, microsecond
//!   timestamps, `pid` = region) and writes one JSON file on
//!   [`RunEvent::RunFinished`]. Load it in Perfetto / `chrome://tracing`
//!   for flamegraph-style round profiling. On the CLI: `--trace-out
//!   FILE`.
//!
//! Spans are recorded unconditionally (the recorder costs one `Vec`
//! push per phase and consumes **zero** RNG draws), then drained by the
//! driver at each round boundary and handed to observers via
//! [`RunEvent::RoundClosed`]. Nothing here feeds back into the run:
//! a traced, histogrammed, ops-attached run is byte-identical to a
//! plain one (pinned in `tests/ops_control.rs`).
//!
//! [`RunObserver`]: crate::ops::RunObserver
//! [`RunEvent::RoundClosed`]: crate::ops::RunEvent::RoundClosed
//! [`RunEvent::RunFinished`]: crate::ops::RunEvent::RunFinished

use std::path::PathBuf;
use std::time::Instant;

use crate::jsonx::Json;
use crate::ops::{RunEvent, RunObserver};
use crate::Result;

/// A round phase — the tracing vocabulary. Every span names one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// World dynamics step (churn) at the round boundary.
    ChurnStep,
    /// Client selection (strategy counts + pick rule).
    Selection,
    /// Ground-truth fate draw for the selected set.
    FateDraw,
    /// Local training + streaming fold (the bulk of the round).
    TrainFold,
    /// Regional (edge) aggregation finisher.
    RegionalAgg,
    /// Cloud aggregation (EDC-weighted or FedAvg recombination).
    CloudAgg,
    /// Snapshot capture + write (scheduled or `checkpoint-now`).
    Checkpoint,
}

impl Phase {
    /// Every phase, in fixed index order (the histogram-vector layout).
    pub const ALL: [Phase; 7] = [
        Phase::ChurnStep,
        Phase::Selection,
        Phase::FateDraw,
        Phase::TrainFold,
        Phase::RegionalAgg,
        Phase::CloudAgg,
        Phase::Checkpoint,
    ];

    /// Stable label — Prometheus `phase` label value and Chrome event name.
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::ChurnStep => "churn_step",
            Phase::Selection => "selection",
            Phase::FateDraw => "fate_draw",
            Phase::TrainFold => "train_fold",
            Phase::RegionalAgg => "regional_agg",
            Phase::CloudAgg => "cloud_agg",
            Phase::Checkpoint => "checkpoint",
        }
    }

    /// Position in [`Phase::ALL`].
    pub fn index(self) -> usize {
        Phase::ALL
            .iter()
            .position(|p| *p == self)
            .expect("Phase::ALL covers every variant")
    }
}

/// An open span: captured wall-clock start. Create with
/// [`SpanStart::begin`] *before* the phase runs, close with
/// [`SpanRecorder::finish`] after — the start handle deliberately does
/// not borrow the recorder, so phases that need `&mut` world access
/// (i.e. all of them) can hold one across the work.
#[derive(Clone, Copy, Debug)]
pub struct SpanStart {
    at: Instant,
}

impl SpanStart {
    pub fn begin() -> SpanStart {
        SpanStart { at: Instant::now() }
    }
}

/// One closed span.
#[derive(Clone, Debug)]
pub struct Span {
    pub phase: Phase,
    /// Region the phase ran for; `None` for fleet/coordinator scope.
    pub region: Option<usize>,
    /// Virtual-clock seconds the protocol charges this phase
    /// (protocol-visible, deterministic).
    pub virtual_s: f64,
    /// Host wall seconds the phase took (profiling-only).
    pub wall_s: f64,
    /// Host wall seconds from the recorder's epoch to the span start
    /// (profiling-only; the Chrome-trace `ts`).
    pub start_wall_s: f64,
}

/// Every span of one round, plus the round's per-region submission
/// latencies (virtual seconds from round start to each in-time model's
/// arrival at its edge) — the raw material for the ops histograms.
#[derive(Clone, Debug)]
pub struct RoundSpans {
    /// The round index the spans belong to.
    pub t: usize,
    pub spans: Vec<Span>,
    /// `submissions[r]` = completion time of every in-time submission
    /// from region `r`, in fold order.
    pub submissions: Vec<Vec<f64>>,
}

impl RoundSpans {
    /// An empty span set for round `t`.
    pub fn empty(t: usize) -> RoundSpans {
        RoundSpans {
            t,
            spans: Vec::new(),
            submissions: Vec::new(),
        }
    }
}

/// The per-`World` span log. Always on — recording costs one `Vec` push
/// per phase, consumes no RNG, and its contents are observer-side state:
/// they ride [`crate::ops::RunEvent::RoundClosed`] but never enter
/// `RoundTrace`, `EnvState`, snapshots, or fingerprints.
#[derive(Debug)]
pub struct SpanRecorder {
    /// Wall-clock epoch all `start_wall_s` offsets are relative to.
    epoch: Instant,
    round: RoundSpans,
}

impl Default for SpanRecorder {
    fn default() -> Self {
        SpanRecorder::new()
    }
}

impl SpanRecorder {
    pub fn new() -> SpanRecorder {
        SpanRecorder {
            epoch: Instant::now(),
            round: RoundSpans::empty(0),
        }
    }

    /// Start round `t`'s span set. Spans recorded since the last drain
    /// (a `checkpoint-now` serviced at the previous round boundary) are
    /// kept and attributed to round `t`; a checkpoint at the *final*
    /// boundary has no next round and is dropped — an accepted gap,
    /// since the profile it would describe is the run teardown.
    pub fn begin_round(&mut self, t: usize) {
        self.round.t = t;
    }

    /// Close a span opened with [`SpanStart::begin`].
    pub fn finish(&mut self, start: SpanStart, phase: Phase, region: Option<usize>, virtual_s: f64) {
        let now = Instant::now();
        self.round.spans.push(Span {
            phase,
            region,
            virtual_s,
            wall_s: now.saturating_duration_since(start.at).as_secs_f64(),
            start_wall_s: start.at.saturating_duration_since(self.epoch).as_secs_f64(),
        });
    }

    /// Record one in-time submission's completion latency for `region`.
    pub fn record_submission(&mut self, region: usize, latency_s: f64) {
        if self.round.submissions.len() <= region {
            self.round.submissions.resize(region + 1, Vec::new());
        }
        self.round.submissions[region].push(latency_s);
    }

    /// Drain the current round's spans (the driver calls this once per
    /// round boundary and hands the result to observers).
    pub fn take_round(&mut self) -> RoundSpans {
        let t = self.round.t;
        std::mem::replace(&mut self.round, RoundSpans::empty(t))
    }
}

/// Number of finite buckets in a [`Histo`]. Bounds span 2⁻²⁰ s (~1 µs)
/// to 2¹⁹ s (~6 days) in exact powers of two — wide enough for both
/// wall-time microprofiles and multi-hour virtual rounds.
pub const HISTO_BUCKETS: usize = 40;

/// Upper bounds (inclusive, `le` semantics) of the finite buckets.
/// Powers of two are exactly representable in f64, so bucket assignment
/// is deterministic across hosts — no float log, no libm.
pub const HISTO_BOUNDS: [f64; HISTO_BUCKETS] = histo_bounds();

const fn histo_bounds() -> [f64; HISTO_BUCKETS] {
    let mut b = [0.0; HISTO_BUCKETS];
    // 2^-20 exactly.
    let mut bound = 9.5367431640625e-7;
    let mut i = 0;
    while i < HISTO_BUCKETS {
        b[i] = bound;
        bound *= 2.0;
        i += 1;
    }
    b
}

/// A fixed log₂-bucket histogram: mergeable, quantile-queryable, and
/// renderable as a Prometheus `histogram` family. Values are seconds.
///
/// Semantics:
/// * `NaN` observations are ignored entirely (they are not a duration);
/// * negative observations clamp to `0.0` (land in the first bucket);
/// * `+∞` (and anything above the top bound) lands in the overflow
///   bucket and is excluded from `sum`, which stays finite.
#[derive(Clone, Debug)]
pub struct Histo {
    /// Finite buckets `..HISTO_BUCKETS`, then one overflow (+Inf) bucket.
    counts: [u64; HISTO_BUCKETS + 1],
    count: u64,
    sum: f64,
}

impl Default for Histo {
    fn default() -> Self {
        Histo::new()
    }
}

impl Histo {
    pub fn new() -> Histo {
        Histo {
            counts: [0; HISTO_BUCKETS + 1],
            count: 0,
            sum: 0.0,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Record one observation (seconds).
    pub fn record(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        let v = v.max(0.0);
        let idx = HISTO_BOUNDS.partition_point(|b| *b < v);
        self.counts[idx] += 1;
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
        }
    }

    /// Fold another histogram into this one. Merging is associative and
    /// commutative (integer counts; f64 sums agree to rounding).
    pub fn merge(&mut self, other: &Histo) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Upper bucket bound containing the `q`-quantile observation
    /// (`q` clamped to `[0, 1]`); `None` when empty, `+∞` when the
    /// rank lands in the overflow bucket. The true value is bracketed
    /// by the returned bound and the previous bucket's bound.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(if i < HISTO_BUCKETS {
                    HISTO_BOUNDS[i]
                } else {
                    f64::INFINITY
                });
            }
        }
        Some(f64::INFINITY)
    }

    /// Render as one Prometheus histogram series: cumulative
    /// `NAME_bucket{LABELS,le="..."}` lines (empty buckets elided except
    /// the mandatory `+Inf`), then `NAME_sum` / `NAME_count`. `labels`
    /// is either empty or a ready `key="value"` list without braces.
    pub fn render_into(&self, out: &mut String, name: &str, labels: &str) {
        use std::fmt::Write as _;
        let sep = if labels.is_empty() { "" } else { "," };
        let mut cum = 0u64;
        for (i, c) in self.counts[..HISTO_BUCKETS].iter().enumerate() {
            cum += c;
            if *c != 0 {
                let _ = writeln!(
                    out,
                    "{name}_bucket{{{labels}{sep}le=\"{}\"}} {cum}",
                    HISTO_BOUNDS[i]
                );
            }
        }
        let _ = writeln!(
            out,
            "{name}_bucket{{{labels}{sep}le=\"+Inf\"}} {}",
            self.count
        );
        if labels.is_empty() {
            let _ = writeln!(out, "{name}_sum {}", self.sum);
            let _ = writeln!(out, "{name}_count {}", self.count);
        } else {
            let _ = writeln!(out, "{name}_sum{{{labels}}} {}", self.sum);
            let _ = writeln!(out, "{name}_count{{{labels}}} {}", self.count);
        }
    }
}

/// A [`RunObserver`] that accumulates every round's spans as Chrome
/// trace-event *complete events* and writes one JSON file at run end —
/// loadable in Perfetto / `chrome://tracing`. `pid` 0 is the
/// coordinator; region-scoped spans get `pid` = region + 1 (named via
/// `process_name` metadata events). Timestamps are host wall time in
/// microseconds (profiling-only — the file is an artifact, never part
/// of the result).
pub struct TraceWriter {
    path: PathBuf,
    events: Vec<Json>,
    /// Highest region pid seen, for the process_name metadata.
    max_region: Option<usize>,
}

impl TraceWriter {
    pub fn new(path: impl Into<PathBuf>) -> TraceWriter {
        TraceWriter {
            path: path.into(),
            events: Vec::new(),
            max_region: None,
        }
    }

    fn push_span(&mut self, t: usize, span: &Span) {
        let pid = match span.region {
            Some(r) => {
                self.max_region = Some(self.max_region.map_or(r, |m| m.max(r)));
                r + 1
            }
            None => 0,
        };
        self.events.push(
            Json::obj()
                .set("name", span.phase.as_str())
                .set("ph", "X")
                .set("ts", span.start_wall_s * 1e6)
                .set("dur", (span.wall_s * 1e6).max(1.0))
                .set("pid", pid)
                .set("tid", 0usize)
                .set(
                    "args",
                    Json::obj()
                        .set("round", t)
                        .set("virtual_s", span.virtual_s),
                ),
        );
    }

    fn write(&self) -> Result<()> {
        let mut events = Vec::with_capacity(self.events.len() + 8);
        let meta = |pid: usize, name: &str| {
            Json::obj()
                .set("name", "process_name")
                .set("ph", "M")
                .set("pid", pid)
                .set("tid", 0usize)
                .set("args", Json::obj().set("name", name))
        };
        events.push(meta(0, "coordinator"));
        if let Some(max) = self.max_region {
            for r in 0..=max {
                events.push(meta(r + 1, &format!("region {r}")));
            }
        }
        events.extend(self.events.iter().cloned());
        let doc = Json::obj()
            .set("traceEvents", Json::Arr(events))
            .set("displayTimeUnit", "ms");
        std::fs::write(&self.path, doc.dump()).map_err(|e| {
            anyhow::anyhow!("writing trace file {}: {e}", self.path.display())
        })
    }
}

impl RunObserver for TraceWriter {
    fn observe(&mut self, ev: &RunEvent<'_>) -> Result<()> {
        match ev {
            RunEvent::RoundClosed { spans, .. } => {
                for span in &spans.spans {
                    self.push_span(spans.t, span);
                }
            }
            RunEvent::RunFinished { .. } => self.write()?,
            _ => {}
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn bounds_are_exact_powers_of_two() {
        assert_eq!(HISTO_BOUNDS[0], 2f64.powi(-20));
        assert_eq!(HISTO_BOUNDS[HISTO_BUCKETS - 1], 2f64.powi(19));
        for w in HISTO_BOUNDS.windows(2) {
            assert_eq!(w[1], w[0] * 2.0);
        }
    }

    #[test]
    fn record_places_values_on_le_boundaries() {
        let mut h = Histo::new();
        h.record(0.0); // first bucket (clamp floor)
        h.record(HISTO_BOUNDS[4]); // exactly on a bound ⇒ that bucket (le)
        h.record(HISTO_BOUNDS[4] * 1.0000001); // just above ⇒ next bucket
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[4], 1);
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn nan_ignored_negative_clamped_inf_overflows() {
        let mut h = Histo::new();
        h.record(f64::NAN);
        assert!(h.is_empty());
        h.record(-3.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.sum(), 0.0);
        h.record(f64::INFINITY);
        h.record(1e12); // above the top bound
        assert_eq!(h.counts[HISTO_BUCKETS], 2);
        assert_eq!(h.count(), 3);
        assert!(h.sum().is_finite(), "overflow values must not poison sum");
    }

    /// Merge is associative and agrees with recording everything into
    /// one histogram, over arbitrary (dyadic, exactly-representable)
    /// observation streams.
    #[test]
    fn merge_associativity_property() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let draws: Vec<f64> = (0..60)
                // Dyadic values: k / 2^10 with k ∈ [0, 2^24) — sums are
                // exact in f64, so equality (not approx) must hold.
                .map(|_| (rng.uniform() * (1 << 24) as f64).floor() / 1024.0)
                .collect();
            let (a, rest) = draws.split_at(20);
            let (b, c) = rest.split_at(20);
            let histo_of = |vals: &[f64]| {
                let mut h = Histo::new();
                for &v in vals {
                    h.record(v);
                }
                h
            };
            let (ha, hb, hc) = (histo_of(a), histo_of(b), histo_of(c));

            // (a ⊕ b) ⊕ c
            let mut left = ha.clone();
            left.merge(&hb);
            left.merge(&hc);
            // a ⊕ (b ⊕ c)
            let mut bc = hb.clone();
            bc.merge(&hc);
            let mut right = ha.clone();
            right.merge(&bc);
            // direct
            let all = histo_of(&draws);

            assert_eq!(left.counts, right.counts);
            assert_eq!(left.count(), right.count());
            assert_eq!(left.sum(), right.sum());
            assert_eq!(left.counts, all.counts);
            assert_eq!(left.sum(), all.sum());
        }
    }

    /// quantile() returns a bucket upper bound that brackets the true
    /// order statistic: value ≤ bound and value > previous bound.
    #[test]
    fn quantile_brackets_true_order_statistic() {
        let mut rng = Rng::new(7);
        for _ in 0..30 {
            let mut vals: Vec<f64> = (0..80).map(|_| rng.uniform() * 100.0).collect();
            let mut h = Histo::new();
            for &v in &vals {
                h.record(v);
            }
            vals.sort_by(f64::total_cmp);
            for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
                let bound = h.quantile(q).unwrap();
                let rank = ((q * vals.len() as f64).ceil() as usize).max(1);
                let true_v = vals[rank - 1];
                assert!(true_v <= bound, "q={q}: {true_v} > bound {bound}");
                let idx = HISTO_BOUNDS.partition_point(|b| *b < bound);
                if idx > 0 && bound.is_finite() {
                    assert!(
                        true_v > HISTO_BOUNDS[idx - 1] || true_v == 0.0 || idx == 0,
                        "q={q}: {true_v} not in ({}, {bound}]",
                        HISTO_BOUNDS[idx - 1]
                    );
                }
            }
        }
    }

    #[test]
    fn quantile_empty_and_overflow() {
        let h = Histo::new();
        assert_eq!(h.quantile(0.5), None);
        let mut h = Histo::new();
        h.record(1e12);
        assert_eq!(h.quantile(0.5), Some(f64::INFINITY));
    }

    #[test]
    fn render_is_cumulative_and_ends_with_inf() {
        let mut h = Histo::new();
        h.record(0.5);
        h.record(0.5);
        h.record(3.0);
        let mut out = String::new();
        h.render_into(&mut out, "x_seconds", "region=\"1\"");
        assert!(out.contains("x_seconds_bucket{region=\"1\",le=\"0.5\"} 2\n"), "{out}");
        assert!(out.contains("x_seconds_bucket{region=\"1\",le=\"4\"} 3\n"), "{out}");
        assert!(out.contains("x_seconds_bucket{region=\"1\",le=\"+Inf\"} 3\n"), "{out}");
        assert!(out.contains("x_seconds_sum{region=\"1\"} 4\n"), "{out}");
        assert!(out.contains("x_seconds_count{region=\"1\"} 3\n"), "{out}");

        let mut bare = String::new();
        h.render_into(&mut bare, "y_seconds", "");
        assert!(bare.contains("y_seconds_bucket{le=\"+Inf\"} 3\n"), "{bare}");
        assert!(bare.contains("y_seconds_sum 4\n"), "{bare}");
        assert!(bare.contains("y_seconds_count 3\n"), "{bare}");
    }

    #[test]
    fn recorder_drains_per_round_and_consumes_no_rng() {
        let mut rec = SpanRecorder::new();
        rec.begin_round(3);
        let sp = SpanStart::begin();
        rec.finish(sp, Phase::Selection, None, 0.0);
        rec.record_submission(1, 2.5);
        let round = rec.take_round();
        assert_eq!(round.t, 3);
        assert_eq!(round.spans.len(), 1);
        assert_eq!(round.spans[0].phase, Phase::Selection);
        assert_eq!(round.submissions.len(), 2);
        assert_eq!(round.submissions[1], vec![2.5]);
        // Drained: a second take is empty.
        assert!(rec.take_round().spans.is_empty());
    }

    #[test]
    fn phase_index_matches_all_order() {
        for (i, p) in Phase::ALL.iter().enumerate() {
            assert_eq!(p.index(), i);
        }
    }
}
