//! Client device heterogeneity & reliability model (S5, paper §III.D).
//!
//! Every end device gets a [`ClientProfile`] sampled from the Table II
//! distributions: compute performance `s_k ~ 𝓝` (GHz), bandwidth
//! `bw_k ~ 𝓝` (MHz) and a per-round drop-out probability `dr_k ~ 𝓝`.
//!
//! **Privacy boundary.** Profiles live on the *simulator* side of the
//! system. Protocol code (selection, slack estimation, aggregation) never
//! receives a `ClientProfile` — it only observes submission counts, exactly
//! as the paper's reliability-agnostic setting prescribes. The type is
//! deliberately not exported through the `protocols` API.

use anyhow::{bail, ensure, Result};

use crate::config::ExperimentConfig;
use crate::rng::Rng;
use crate::topology::Topology;

/// Static per-device truth (hidden from protocols).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientProfile {
    /// CPU performance s_k in GHz.
    pub perf_ghz: f64,
    /// Wireless bandwidth bw_k in MHz.
    pub bw_mhz: f64,
    /// Probability the client drops/opts out of a round (dr_k). The
    /// no-abort probability is P_k = 1 − dr_k.
    pub dropout_p: f64,
}

/// Floor on physical quantities so a pathological draw cannot produce a
/// zero/negative-speed device (𝓝 has unbounded support).
const PHYS_FLOOR_FRACTION: f64 = 0.05;
/// Drop-out probabilities clamp into [0, 0.99] — a 1.0 client would be
/// permanently dead, which the paper's Gaussian never intends.
const DROPOUT_MAX: f64 = 0.99;

/// Sample one profile given the config distributions and a per-region
/// drop-out mean (regions may override it, e.g. Fig. 2).
pub fn sample_profile(
    cfg: &ExperimentConfig,
    dropout_mean: f64,
    rng: &mut Rng,
) -> ClientProfile {
    let perf_floor = cfg.perf_ghz.mean * PHYS_FLOOR_FRACTION;
    let bw_floor = cfg.bw_mhz.mean * PHYS_FLOOR_FRACTION;
    ClientProfile {
        perf_ghz: rng.normal_clamped(cfg.perf_ghz.mean, cfg.perf_ghz.std, perf_floor, f64::MAX),
        bw_mhz: rng.normal_clamped(cfg.bw_mhz.mean, cfg.bw_mhz.std, bw_floor, f64::MAX),
        dropout_p: rng.normal_clamped(dropout_mean, cfg.dropout.std, 0.0, DROPOUT_MAX),
    }
}

/// Sample the whole fleet, honoring per-region drop-out overrides from the
/// topology (explicit `RegionSpec`s) or the global `cfg.dropout.mean`.
///
/// Every client must be covered by exactly one topology region: a client
/// left out would silently keep an all-zero placeholder profile, and its
/// zero `perf_ghz` later divides inside `TimingModel::t_train`. Incomplete
/// or overlapping coverage is therefore a hard error, not a latent NaN.
pub fn sample_fleet(
    cfg: &ExperimentConfig,
    topo: &Topology,
    rng: &mut Rng,
) -> Result<Vec<ClientProfile>> {
    let mut profiles = vec![
        ClientProfile {
            perf_ghz: 0.0,
            bw_mhz: 0.0,
            dropout_p: 0.0
        };
        cfg.n_clients
    ];
    let mut covered = vec![false; cfg.n_clients];
    let mut drng = rng.split(0xDE_01CE);
    for (r, clients) in topo.regions.iter().enumerate() {
        let mean = topo
            .dropout_mean_override(r)
            .unwrap_or(cfg.dropout.mean);
        for &k in clients {
            ensure!(
                k < cfg.n_clients,
                "topology region {r} names client {k} but the fleet has {} clients",
                cfg.n_clients
            );
            ensure!(
                !covered[k],
                "client {k} appears in more than one topology region"
            );
            covered[k] = true;
            profiles[k] = sample_profile(cfg, mean, &mut drng);
        }
    }
    if let Some(k) = covered.iter().position(|&c| !c) {
        bail!(
            "client {k} is not covered by any topology region — its profile \
             would stay the all-zero placeholder (zero perf_ghz divides in the \
             timing model)"
        );
    }
    Ok(profiles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RegionSpec;

    #[test]
    fn fleet_matches_population_and_bounds() {
        let cfg = ExperimentConfig::task2_scaled();
        let topo = Topology::build(&cfg, &mut Rng::new(1)).unwrap();
        let fleet = sample_fleet(&cfg, &topo, &mut Rng::new(2)).unwrap();
        assert_eq!(fleet.len(), cfg.n_clients);
        for p in &fleet {
            assert!(p.perf_ghz > 0.0);
            assert!(p.bw_mhz > 0.0);
            assert!((0.0..=DROPOUT_MAX).contains(&p.dropout_p));
        }
    }

    #[test]
    fn fleet_heterogeneity_sampled() {
        let cfg = ExperimentConfig::task2_scaled();
        let topo = Topology::build(&cfg, &mut Rng::new(1)).unwrap();
        let fleet = sample_fleet(&cfg, &topo, &mut Rng::new(2)).unwrap();
        let perf_min = fleet.iter().map(|p| p.perf_ghz).fold(f64::MAX, f64::min);
        let perf_max = fleet.iter().map(|p| p.perf_ghz).fold(0.0, f64::max);
        assert!(perf_max - perf_min > 0.1, "no heterogeneity sampled");
    }

    #[test]
    fn regional_dropout_override_respected() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 40;
        cfg.n_edges = 2;
        cfg.regions = vec![
            RegionSpec { n_clients: 20, dropout_mean: 0.1 },
            RegionSpec { n_clients: 20, dropout_mean: 0.8 },
        ];
        cfg.dropout.std = 0.02;
        let topo = Topology::build(&cfg, &mut Rng::new(3)).unwrap();
        let fleet = sample_fleet(&cfg, &topo, &mut Rng::new(4)).unwrap();
        let mean_r = |r: usize| -> f64 {
            let cs = &topo.regions[r];
            cs.iter().map(|&k| fleet[k].dropout_p).sum::<f64>() / cs.len() as f64
        };
        assert!(mean_r(0) < 0.2, "region 0 mean {}", mean_r(0));
        assert!(mean_r(1) > 0.7, "region 1 mean {}", mean_r(1));
    }

    #[test]
    fn sampling_is_deterministic() {
        let cfg = ExperimentConfig::task1_scaled();
        let topo = Topology::build(&cfg, &mut Rng::new(5)).unwrap();
        let a = sample_fleet(&cfg, &topo, &mut Rng::new(6)).unwrap();
        let b = sample_fleet(&cfg, &topo, &mut Rng::new(6)).unwrap();
        assert_eq!(a, b);
    }

    /// The coverage guard: a topology that leaves a client out of every
    /// region (or lists one twice) is a hard error, never a silent
    /// all-zero profile.
    #[test]
    fn uncovered_client_is_a_hard_error() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 10;
        let topo = Topology::build(&cfg, &mut Rng::new(7)).unwrap();
        cfg.n_clients = 11; // client 10 exists but no region names it
        let err = sample_fleet(&cfg, &topo, &mut Rng::new(8))
            .unwrap_err()
            .to_string();
        assert!(err.contains("client 10"), "{err}");
        assert!(err.contains("not covered"), "{err}");
    }

    #[test]
    fn duplicated_client_is_a_hard_error() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 10;
        let mut topo = Topology::build(&cfg, &mut Rng::new(9)).unwrap();
        let dup = topo.regions[0][0];
        topo.regions[1].push(dup);
        let err = sample_fleet(&cfg, &topo, &mut Rng::new(10))
            .unwrap_err()
            .to_string();
        assert!(err.contains("more than one"), "{err}");
    }

    #[test]
    fn out_of_range_client_is_a_hard_error() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 10;
        let mut topo = Topology::build(&cfg, &mut Rng::new(11)).unwrap();
        topo.regions[0].push(42);
        assert!(sample_fleet(&cfg, &topo, &mut Rng::new(12)).is_err());
    }
}
