//! Client device heterogeneity & reliability model (S5, paper §III.D).
//!
//! Every end device gets a profile sampled from the Table II
//! distributions: compute performance `s_k ~ 𝓝` (GHz), bandwidth
//! `bw_k ~ 𝓝` (MHz) and a per-round drop-out probability `dr_k ~ 𝓝`.
//!
//! The fleet is stored as a struct-of-arrays [`FleetState`] — three
//! parallel flat `f64` arrays indexed by global client id — so the
//! per-round sweeps that dominate at fleet scale (availability means,
//! oracle drop tables, completion-time ranking) walk one cache-linear
//! array instead of striding over an array of structs, and churn resets
//! copy contiguous slices. [`ClientProfile`] remains as the per-client
//! *view* (`Copy`, three scalars) for the timing/energy call sites that
//! reason about a single device.
//!
//! **Privacy boundary.** Profiles live on the *simulator* side of the
//! system. Protocol code (selection, slack estimation, aggregation) never
//! receives a `ClientProfile` or a `FleetState` — it only observes
//! submission counts, exactly as the paper's reliability-agnostic setting
//! prescribes. Neither type is exported through the `protocols` API.

use anyhow::{bail, ensure, Result};

use crate::config::ExperimentConfig;
use crate::rng::Rng;
use crate::topology::Topology;

/// Static per-device truth (hidden from protocols) — the scalar view of
/// one [`FleetState`] row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientProfile {
    /// CPU performance s_k in GHz.
    pub perf_ghz: f64,
    /// Wireless bandwidth bw_k in MHz.
    pub bw_mhz: f64,
    /// Probability the client drops/opts out of a round (dr_k). The
    /// no-abort probability is P_k = 1 − dr_k.
    pub dropout_p: f64,
}

/// Struct-of-arrays per-client state of the whole fleet: `perf_ghz`,
/// `bw_mhz` and `dropout_p` as parallel flat arrays indexed by global
/// client id. Topology regions assign contiguous id ranges, so per-region
/// sweeps and churn rewrites touch contiguous memory.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetState {
    /// CPU performance s_k in GHz, per client.
    pub perf_ghz: Vec<f64>,
    /// Wireless bandwidth bw_k in MHz, per client.
    pub bw_mhz: Vec<f64>,
    /// Per-round drop-out probability dr_k, per client.
    pub dropout_p: Vec<f64>,
}

impl FleetState {
    /// An all-zero fleet of `n` clients (placeholder rows; a zero
    /// `perf_ghz` divides in the timing model, so every row must be
    /// written before use — [`sample_fleet`] enforces that).
    pub fn zeros(n: usize) -> FleetState {
        FleetState {
            perf_ghz: vec![0.0; n],
            bw_mhz: vec![0.0; n],
            dropout_p: vec![0.0; n],
        }
    }

    /// Assemble a fleet from an array-of-structs profile list (tests,
    /// migration of older call sites).
    pub fn from_profiles(profiles: &[ClientProfile]) -> FleetState {
        FleetState {
            perf_ghz: profiles.iter().map(|p| p.perf_ghz).collect(),
            bw_mhz: profiles.iter().map(|p| p.bw_mhz).collect(),
            dropout_p: profiles.iter().map(|p| p.dropout_p).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.perf_ghz.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perf_ghz.is_empty()
    }

    /// The scalar view of client `k`'s row (`Copy` — three loads).
    pub fn profile(&self, k: usize) -> ClientProfile {
        ClientProfile {
            perf_ghz: self.perf_ghz[k],
            bw_mhz: self.bw_mhz[k],
            dropout_p: self.dropout_p[k],
        }
    }

    /// Overwrite client `k`'s row from a scalar profile.
    pub fn set_profile(&mut self, k: usize, p: ClientProfile) {
        self.perf_ghz[k] = p.perf_ghz;
        self.bw_mhz[k] = p.bw_mhz;
        self.dropout_p[k] = p.dropout_p;
    }

    /// Restore every row from `base` (full pristine reset).
    pub fn copy_all_from(&mut self, base: &FleetState) {
        self.perf_ghz.copy_from_slice(&base.perf_ghz);
        self.bw_mhz.copy_from_slice(&base.bw_mhz);
        self.dropout_p.copy_from_slice(&base.dropout_p);
    }

    /// Restore the contiguous id range `[start, start + len)` from `base`
    /// — the O(dirty-region) churn reset for regions whose clients hold a
    /// contiguous id span (every region straight out of
    /// [`Topology::build`]).
    pub fn copy_range_from(&mut self, base: &FleetState, start: usize, len: usize) {
        let end = start + len;
        self.perf_ghz[start..end].copy_from_slice(&base.perf_ghz[start..end]);
        self.bw_mhz[start..end].copy_from_slice(&base.bw_mhz[start..end]);
        self.dropout_p[start..end].copy_from_slice(&base.dropout_p[start..end]);
    }

    /// Restore one client's row from `base` (non-contiguous regions, e.g.
    /// after migration events).
    pub fn copy_client_from(&mut self, base: &FleetState, k: usize) {
        self.perf_ghz[k] = base.perf_ghz[k];
        self.bw_mhz[k] = base.bw_mhz[k];
        self.dropout_p[k] = base.dropout_p[k];
    }
}

/// Floor on physical quantities so a pathological draw cannot produce a
/// zero/negative-speed device (𝓝 has unbounded support).
const PHYS_FLOOR_FRACTION: f64 = 0.05;
/// Drop-out probabilities clamp into [0, 0.99] — a 1.0 client would be
/// permanently dead, which the paper's Gaussian never intends.
const DROPOUT_MAX: f64 = 0.99;

/// Sample one profile given the config distributions and a per-region
/// drop-out mean (regions may override it, e.g. Fig. 2).
pub fn sample_profile(
    cfg: &ExperimentConfig,
    dropout_mean: f64,
    rng: &mut Rng,
) -> ClientProfile {
    let perf_floor = cfg.perf_ghz.mean * PHYS_FLOOR_FRACTION;
    let bw_floor = cfg.bw_mhz.mean * PHYS_FLOOR_FRACTION;
    ClientProfile {
        perf_ghz: rng.normal_clamped(cfg.perf_ghz.mean, cfg.perf_ghz.std, perf_floor, f64::MAX),
        bw_mhz: rng.normal_clamped(cfg.bw_mhz.mean, cfg.bw_mhz.std, bw_floor, f64::MAX),
        dropout_p: rng.normal_clamped(dropout_mean, cfg.dropout.std, 0.0, DROPOUT_MAX),
    }
}

/// Sample the whole fleet, honoring per-region drop-out overrides from the
/// topology (explicit `RegionSpec`s) or the global `cfg.dropout.mean`.
/// Draw order is regions in order, clients in region order — byte-for-byte
/// the order the array-of-structs fleet used, so seeded worlds are
/// unchanged by the SoA layout.
///
/// Every client must be covered by exactly one topology region: a client
/// left out would silently keep an all-zero placeholder row, and its
/// zero `perf_ghz` later divides inside `TimingModel::t_train`. Incomplete
/// or overlapping coverage is therefore a hard error, not a latent NaN.
pub fn sample_fleet(
    cfg: &ExperimentConfig,
    topo: &Topology,
    rng: &mut Rng,
) -> Result<FleetState> {
    let mut fleet = FleetState::zeros(cfg.n_clients);
    let mut covered = vec![false; cfg.n_clients];
    let mut drng = rng.split(0xDE_01CE);
    for (r, clients) in topo.regions.iter().enumerate() {
        let mean = topo
            .dropout_mean_override(r)
            .unwrap_or(cfg.dropout.mean);
        for &k in clients {
            ensure!(
                k < cfg.n_clients,
                "topology region {r} names client {k} but the fleet has {} clients",
                cfg.n_clients
            );
            ensure!(
                !covered[k],
                "client {k} appears in more than one topology region"
            );
            covered[k] = true;
            fleet.set_profile(k, sample_profile(cfg, mean, &mut drng));
        }
    }
    if let Some(k) = covered.iter().position(|&c| !c) {
        bail!(
            "client {k} is not covered by any topology region — its profile \
             would stay the all-zero placeholder (zero perf_ghz divides in the \
             timing model)"
        );
    }
    Ok(fleet)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RegionSpec;

    #[test]
    fn fleet_matches_population_and_bounds() {
        let cfg = ExperimentConfig::task2_scaled();
        let topo = Topology::build(&cfg, &mut Rng::new(1)).unwrap();
        let fleet = sample_fleet(&cfg, &topo, &mut Rng::new(2)).unwrap();
        assert_eq!(fleet.len(), cfg.n_clients);
        for k in 0..fleet.len() {
            assert!(fleet.perf_ghz[k] > 0.0);
            assert!(fleet.bw_mhz[k] > 0.0);
            assert!((0.0..=DROPOUT_MAX).contains(&fleet.dropout_p[k]));
        }
    }

    #[test]
    fn fleet_heterogeneity_sampled() {
        let cfg = ExperimentConfig::task2_scaled();
        let topo = Topology::build(&cfg, &mut Rng::new(1)).unwrap();
        let fleet = sample_fleet(&cfg, &topo, &mut Rng::new(2)).unwrap();
        let perf_min = fleet.perf_ghz.iter().cloned().fold(f64::MAX, f64::min);
        let perf_max = fleet.perf_ghz.iter().cloned().fold(0.0, f64::max);
        assert!(perf_max - perf_min > 0.1, "no heterogeneity sampled");
    }

    #[test]
    fn regional_dropout_override_respected() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 40;
        cfg.n_edges = 2;
        cfg.regions = vec![
            RegionSpec { n_clients: 20, dropout_mean: 0.1 },
            RegionSpec { n_clients: 20, dropout_mean: 0.8 },
        ];
        cfg.dropout.std = 0.02;
        let topo = Topology::build(&cfg, &mut Rng::new(3)).unwrap();
        let fleet = sample_fleet(&cfg, &topo, &mut Rng::new(4)).unwrap();
        let mean_r = |r: usize| -> f64 {
            let cs = &topo.regions[r];
            cs.iter().map(|&k| fleet.dropout_p[k]).sum::<f64>() / cs.len() as f64
        };
        assert!(mean_r(0) < 0.2, "region 0 mean {}", mean_r(0));
        assert!(mean_r(1) > 0.7, "region 1 mean {}", mean_r(1));
    }

    #[test]
    fn sampling_is_deterministic() {
        let cfg = ExperimentConfig::task1_scaled();
        let topo = Topology::build(&cfg, &mut Rng::new(5)).unwrap();
        let a = sample_fleet(&cfg, &topo, &mut Rng::new(6)).unwrap();
        let b = sample_fleet(&cfg, &topo, &mut Rng::new(6)).unwrap();
        assert_eq!(a, b);
    }

    /// The SoA layout is only a layout: sampling into `FleetState` row by
    /// row must equal sampling profiles from the same stream one at a
    /// time.
    #[test]
    fn soa_sampling_matches_profile_draw_order() {
        let cfg = ExperimentConfig::task1_scaled();
        let topo = Topology::build(&cfg, &mut Rng::new(5)).unwrap();
        let fleet = sample_fleet(&cfg, &topo, &mut Rng::new(6)).unwrap();
        let mut drng = Rng::new(6).split(0xDE_01CE);
        let mut reference = vec![
            ClientProfile { perf_ghz: 0.0, bw_mhz: 0.0, dropout_p: 0.0 };
            cfg.n_clients
        ];
        for (r, clients) in topo.regions.iter().enumerate() {
            let mean = topo.dropout_mean_override(r).unwrap_or(cfg.dropout.mean);
            for &k in clients {
                reference[k] = sample_profile(&cfg, mean, &mut drng);
            }
        }
        assert_eq!(fleet, FleetState::from_profiles(&reference));
        for k in 0..fleet.len() {
            assert_eq!(fleet.profile(k), reference[k]);
        }
    }

    #[test]
    fn range_and_client_resets_restore_base_rows() {
        let cfg = ExperimentConfig::task1_scaled();
        let topo = Topology::build(&cfg, &mut Rng::new(5)).unwrap();
        let base = sample_fleet(&cfg, &topo, &mut Rng::new(6)).unwrap();
        let mut fleet = base.clone();
        for k in 0..fleet.len() {
            fleet.dropout_p[k] = 1.0;
            fleet.bw_mhz[k] *= 0.5;
        }
        fleet.copy_range_from(&base, 2, 5);
        for k in 2..7 {
            assert_eq!(fleet.profile(k), base.profile(k));
        }
        assert_ne!(fleet.profile(0), base.profile(0));
        fleet.copy_client_from(&base, 0);
        assert_eq!(fleet.profile(0), base.profile(0));
        fleet.copy_all_from(&base);
        assert_eq!(fleet, base);
    }

    /// The coverage guard: a topology that leaves a client out of every
    /// region (or lists one twice) is a hard error, never a silent
    /// all-zero row.
    #[test]
    fn uncovered_client_is_a_hard_error() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 10;
        let topo = Topology::build(&cfg, &mut Rng::new(7)).unwrap();
        cfg.n_clients = 11; // client 10 exists but no region names it
        let err = sample_fleet(&cfg, &topo, &mut Rng::new(8))
            .unwrap_err()
            .to_string();
        assert!(err.contains("client 10"), "{err}");
        assert!(err.contains("not covered"), "{err}");
    }

    #[test]
    fn duplicated_client_is_a_hard_error() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 10;
        let mut topo = Topology::build(&cfg, &mut Rng::new(9)).unwrap();
        let dup = topo.regions[0][0];
        topo.regions[1].push(dup);
        let err = sample_fleet(&cfg, &topo, &mut Rng::new(10))
            .unwrap_err()
            .to_string();
        assert!(err.contains("more than one"), "{err}");
    }

    #[test]
    fn out_of_range_client_is_a_hard_error() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.n_clients = 10;
        let mut topo = Topology::build(&cfg, &mut Rng::new(11)).unwrap();
        topo.regions[0].push(42);
        assert!(sample_fleet(&cfg, &topo, &mut Rng::new(12)).is_err());
    }
}
