//! Device energy model (S7): equation (35) of the paper.
//!
//! `E_k = P_trans · T_k^comm + P_comp^base · s_k³ · T_k^train`
//!
//! with `P_trans = 0.5 W` and `P_comp^base = 0.7 W` (benchmarking numbers
//! the paper takes from Carroll & Heiser and the frequency-cube power
//! model of Lin et al.). `s_k` is the CPU frequency in GHz, so the compute
//! power of an average Task-1 device (0.5 GHz) is 0.7·0.125 ≈ 0.0875 W.
//!
//! Accounting policy (the paper does not spell one out — documented in
//! DESIGN.md): a client that completes its round consumes the full
//! `E_k`; a client that drops out mid-round consumes half of its training
//! energy and no transmission energy (it aborts before uploading).

use crate::config::ExperimentConfig;
use crate::devices::ClientProfile;
use crate::timing::TimingModel;

/// Per-experiment energy coefficients.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    p_trans_w: f64,
    p_comp_base_w: f64,
}

/// Energy outcome of one client-round, in Joules (converted to Wh by the
/// metrics layer: 1 Wh = 3600 J).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergySpend {
    pub comm_j: f64,
    pub comp_j: f64,
}

impl EnergySpend {
    pub fn total_j(&self) -> f64 {
        self.comm_j + self.comp_j
    }

    pub fn total_wh(&self) -> f64 {
        self.total_j() / 3600.0
    }
}

impl EnergyModel {
    pub fn new(cfg: &ExperimentConfig) -> EnergyModel {
        EnergyModel {
            p_trans_w: cfg.p_trans_w,
            p_comp_base_w: cfg.p_comp_base_w,
        }
    }

    /// Compute power for a device: P_comp^base · s_k³ (frequency-cube model).
    pub fn comp_power_w(&self, p: &ClientProfile) -> f64 {
        self.p_comp_base_w * p.perf_ghz.powi(3)
    }

    /// Eq. (35) for a client that finishes the round (trains + uploads).
    pub fn full_round(
        &self,
        p: &ClientProfile,
        tm: &TimingModel,
        partition_size: f64,
    ) -> EnergySpend {
        EnergySpend {
            comm_j: self.p_trans_w * tm.t_comm(p),
            comp_j: self.comp_power_w(p) * tm.t_train(p, partition_size),
        }
    }

    /// Eq. (35) under an update codec: a compressed upload shortens the
    /// transmit window, so `comm_j` scales with [`TimingModel::
    /// t_comm_with`]. The dense codec takes the exact legacy expression
    /// (bit-identical to [`Self::full_round`]); training energy is
    /// codec-independent.
    pub fn full_round_with(
        &self,
        p: &ClientProfile,
        tm: &TimingModel,
        partition_size: f64,
        comm: &crate::comm::CommConfig,
    ) -> EnergySpend {
        if comm.codec.is_dense() {
            return self.full_round(p, tm, partition_size);
        }
        EnergySpend {
            comm_j: self.p_trans_w * tm.t_comm_with(p, comm),
            comp_j: self.comp_power_w(p) * tm.t_train(p, partition_size),
        }
    }

    /// A client that drops out mid-round: half the training burn, no upload.
    pub fn aborted_round(
        &self,
        p: &ClientProfile,
        tm: &TimingModel,
        partition_size: f64,
    ) -> EnergySpend {
        EnergySpend {
            comm_j: 0.0,
            comp_j: 0.5 * self.comp_power_w(p) * tm.t_train(p, partition_size),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (ExperimentConfig, TimingModel, EnergyModel, ClientProfile) {
        let cfg = ExperimentConfig::task1_paper();
        let tm = TimingModel::new(&cfg);
        let em = EnergyModel::new(&cfg);
        let p = ClientProfile { perf_ghz: 0.5, bw_mhz: 0.5, dropout_p: 0.0 };
        (cfg, tm, em, p)
    }

    #[test]
    fn frequency_cube_power() {
        let (_, _, em, p) = setup();
        assert!((em.comp_power_w(&p) - 0.7 * 0.125).abs() < 1e-12);
    }

    #[test]
    fn full_round_magnitudes() {
        let (_, tm, em, p) = setup();
        let e = em.full_round(&p, &tm, 100.0);
        // comm: 0.5 W for ~36 s ≈ 18 J; comp: 0.0875 W for ~0.115 s ≈ 0.01 J
        assert!((e.comm_j - 18.0).abs() < 1.0, "comm={}", e.comm_j);
        assert!(e.comp_j > 0.0 && e.comp_j < 0.1, "comp={}", e.comp_j);
        assert!((e.total_wh() - e.total_j() / 3600.0).abs() < 1e-15);
    }

    #[test]
    fn aborted_round_burns_half_compute_no_comm() {
        let (_, tm, em, p) = setup();
        let full = em.full_round(&p, &tm, 100.0);
        let abort = em.aborted_round(&p, &tm, 100.0);
        assert_eq!(abort.comm_j, 0.0);
        assert!((abort.comp_j - 0.5 * full.comp_j).abs() < 1e-12);
    }

    #[test]
    fn compressed_uploads_cut_comm_energy_dense_is_identical() {
        let (_, tm, em, p) = setup();
        let dense = crate::comm::CommConfig::default();
        let base = em.full_round(&p, &tm, 100.0);
        let via = em.full_round_with(&p, &tm, 100.0, &dense);
        assert_eq!(base.comm_j.to_bits(), via.comm_j.to_bits());
        assert_eq!(base.comp_j.to_bits(), via.comp_j.to_bits());
        let topk = crate::comm::CommConfig::parse_spec("topk:0.05").unwrap();
        let e = em.full_round_with(&p, &tm, 100.0, &topk);
        assert!(e.comm_j < base.comm_j / 2.0, "comm={} vs {}", e.comm_j, base.comm_j);
        assert_eq!(e.comp_j.to_bits(), base.comp_j.to_bits());
    }

    #[test]
    fn faster_cpu_burns_more_power_but_less_time() {
        let (_, tm, em, _) = setup();
        let slow = ClientProfile { perf_ghz: 0.4, bw_mhz: 0.5, dropout_p: 0.0 };
        let fast = ClientProfile { perf_ghz: 1.0, bw_mhz: 0.5, dropout_p: 0.0 };
        assert!(em.comp_power_w(&fast) > em.comp_power_w(&slow));
        // Net: cube power × linear time → faster CPU costs more energy for
        // the same work (s³·t ∝ s²).
        let es = em.full_round(&slow, &tm, 100.0);
        let ef = em.full_round(&fast, &tm, 100.0);
        assert!(ef.comp_j > es.comp_j);
    }
}
