//! Experiment configuration: every knob of the paper's Table II plus the
//! runtime/engine switches, with JSON load/save, CLI overrides, and presets
//! for each experiment (paper scale and laptop scale).

mod io;
mod presets;

pub use io::apply_overrides;

use anyhow::{bail, Result};

use crate::churn::ChurnModel;
use crate::comm::CommConfig;
use crate::selection::SelectorKind;

/// Which of the paper's two ML tasks drives on-device training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Task 1 — Aerofoil self-noise regression (FCN, MSE).
    Aerofoil,
    /// Task 2 — MNIST-like image classification (LeNet-5, NLL, non-IID).
    Mnist,
}

impl TaskKind {
    pub fn as_str(self) -> &'static str {
        match self {
            TaskKind::Aerofoil => "aerofoil",
            TaskKind::Mnist => "mnist",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "aerofoil" => Ok(TaskKind::Aerofoil),
            "mnist" => Ok(TaskKind::Mnist),
            _ => bail!("unknown task '{s}' (aerofoil|mnist)"),
        }
    }
}

/// FL control protocol under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtocolKind {
    /// McMahan et al. — two-layer client/cloud, wait-for-all-selected.
    FedAvg,
    /// Liu et al. — three-layer, edge aggregation every round, cloud
    /// aggregation every `hier_kappa2` rounds, wait-for-all per region.
    HierFavg,
    /// This paper — regional slack factors + quota-triggered regional
    /// aggregation + EDC-weighted immediate cloud aggregation.
    HybridFl,
}

impl ProtocolKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ProtocolKind::FedAvg => "fedavg",
            ProtocolKind::HierFavg => "hierfavg",
            ProtocolKind::HybridFl => "hybridfl",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "fedavg" => Ok(ProtocolKind::FedAvg),
            "hierfavg" => Ok(ProtocolKind::HierFavg),
            "hybridfl" => Ok(ProtocolKind::HybridFl),
            _ => bail!("unknown protocol '{s}' (fedavg|hierfavg|hybridfl)"),
        }
    }

    pub const ALL: [ProtocolKind; 3] = [
        ProtocolKind::FedAvg,
        ProtocolKind::HierFavg,
        ProtocolKind::HybridFl,
    ];
}

/// Which compute engine executes local training.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Real training: AOT HLO artifacts executed on the PJRT CPU client.
    Pjrt,
    /// Analytic learning-curve proxy — protocol dynamics only (Fig. 2,
    /// property tests, quick smoke runs). No artifacts needed.
    Mock,
}

impl EngineKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EngineKind::Pjrt => "pjrt",
            EngineKind::Mock => "mock",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "pjrt" => Ok(EngineKind::Pjrt),
            "mock" => Ok(EngineKind::Mock),
            _ => bail!("unknown engine '{s}' (pjrt|mock)"),
        }
    }
}

/// A Gaussian 𝓝(mean, std²) — Table II samples every heterogeneity
/// parameter from one of these.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dist {
    pub mean: f64,
    pub std: f64,
}

impl Dist {
    pub const fn new(mean: f64, std: f64) -> Dist {
        Dist { mean, std }
    }
}

/// HybridFL regional-aggregation cache rule.
///
/// The paper's eq. 17 taken literally averages *all* region clients with
/// `w^r(t−1)` substituted for non-submitters — an EMA whose inertia
/// measurably *slows* per-round convergence below both baselines (see the
/// ablation bench + EXPERIMENTS.md), contradicting the paper's own Tables
/// III/IV. The default is therefore [`CacheMode::Fresh`], which reproduces
/// the paper's reported behaviour; `Regional` keeps the literal equation
/// available for the ablation. DESIGN.md §Deviations has the analysis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheMode {
    /// Literal eq. 17: aggregate over *all* region clients, substituting
    /// w^r(t−1) for non-submitters — an EMA over rounds.
    Regional,
    /// Default: aggregate only the round's submitted models (FedAvg-style
    /// regional average); EDC cloud weighting unchanged.
    Fresh,
}

impl CacheMode {
    pub fn as_str(self) -> &'static str {
        match self {
            CacheMode::Regional => "regional",
            CacheMode::Fresh => "fresh",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "regional" => Ok(CacheMode::Regional),
            "fresh" => Ok(CacheMode::Fresh),
            _ => bail!("unknown cache mode '{s}' (regional|fresh)"),
        }
    }
}

/// How training data is spread over clients.
#[derive(Clone, Debug, PartialEq)]
pub enum PartitionScheme {
    /// Partition sizes drawn from 𝓝 (Task 1): "data distribution
    /// 𝓝(100, 30²)".
    GaussianSize(Dist),
    /// Label-skewed non-IID (Task 2): sample of class y goes to a client
    /// with index ≡ y (mod classes) with probability `skew`, else uniform.
    NonIid { skew: f64 },
}

/// Explicit per-region override used by the Fig. 2 experiment, where the
/// two regions have different client counts and reliability means.
#[derive(Clone, Debug, PartialEq)]
pub struct RegionSpec {
    pub n_clients: usize,
    /// Mean drop-out probability for this region's clients (std comes from
    /// `dropout.std`).
    pub dropout_mean: f64,
}

/// The full experiment configuration. Field names follow the paper's
/// symbols (Table I/II) where one exists.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    /// Human-readable run label (used in report files).
    pub name: String,
    pub task: TaskKind,
    pub protocol: ProtocolKind,
    pub engine: EngineKind,

    // --- population -------------------------------------------------------
    /// n — number of clients.
    pub n_clients: usize,
    /// m — number of edge nodes (regions). Ignored if `regions` is set.
    pub n_edges: usize,
    /// Region populations n_r ~ 𝓝 (normalized to sum to n). Ignored if
    /// `regions` is set.
    pub region_pop: Dist,
    /// Explicit regions (Fig. 2 style); empty = sample from `region_pop`.
    pub regions: Vec<RegionSpec>,

    // --- FL control ---------------------------------------------------------
    /// C — desired proportion of clients with successful submissions.
    pub c_fraction: f64,
    /// t_max — maximum number of federated rounds.
    pub t_max: usize,
    /// tau — local epochs per round.
    pub local_epochs: usize,
    /// eta — learning rate of local GD.
    pub lr: f64,
    /// Stop early once the global model reaches this accuracy ("Stop @Acc").
    pub target_accuracy: Option<f64>,
    /// theta_r(1) — initial regional slack factor (HybridFL).
    pub theta_init: f64,
    /// kappa_2 — cloud aggregation interval for HierFAVG (paper uses 10).
    pub hier_kappa2: usize,
    /// HybridFL cache rule (eq. 17 literal vs fresh-only ablation).
    pub cache_mode: CacheMode,
    /// Client-selection strategy (the selection zoo; see
    /// [`crate::selection`]). `Slack` — the paper's estimator — is the
    /// default and reproduces pre-zoo behavior bit for bit. `Oracle` is
    /// sim-only; the live backend rejects it at construction.
    pub selector: SelectorKind,

    // --- device heterogeneity (Table II) ------------------------------------
    /// s_k ~ 𝓝, in GHz.
    pub perf_ghz: Dist,
    /// bw_k ~ 𝓝, in MHz.
    pub bw_mhz: Dist,
    /// dr_k ~ 𝓝 — drop-out probability per round.
    pub dropout: Dist,
    /// Time-varying reliability dynamics layered on top of the sampled
    /// base fleet (churn processes, scripted fault events, fate replay).
    /// [`ChurnModel::Stationary`] — the default — reproduces the
    /// historical frozen-world behavior bit for bit.
    pub churn: ChurnModel,
    /// Wireless signal-to-noise ratio (linear, not dB).
    pub snr: f64,
    /// Device→edge submission path: update codec (quantization /
    /// sparsification, see [`crate::comm`]) plus the optional relay
    /// quantile. The default — dense, no relay — reproduces the
    /// historical submission path bit for bit.
    pub comm: CommConfig,

    // --- network / workload constants ---------------------------------------
    /// BR — cloud-edge throughput, Mbps.
    pub cloud_edge_mbps: f64,
    /// msize — model size in MB (5 for Task 1, 10 for Task 2).
    pub model_size_mb: f64,
    /// BPS — bits per training sample.
    pub bits_per_sample: f64,
    /// CPB — CPU cycles per bit of training data per epoch.
    pub cycles_per_bit: f64,

    // --- energy model ---------------------------------------------------------
    /// P_trans — transmitter power, Watt.
    pub p_trans_w: f64,
    /// Base compute power coefficient: P_comp = p_comp_base * s_k^3, Watt.
    pub p_comp_base_w: f64,

    // --- data -------------------------------------------------------------
    /// |D| — training corpus size.
    pub dataset_size: usize,
    /// Held-out evaluation set size (cloud-side metric only).
    pub eval_size: usize,
    pub partition: PartitionScheme,

    // --- runtime ------------------------------------------------------------
    pub seed: u64,
    /// Directory with the AOT artifacts (`make artifacts`).
    pub artifacts_dir: String,
    /// Evaluate the global model every k rounds (1 = every round).
    pub eval_every: usize,
}

impl ExperimentConfig {
    // ---- unit conversions used by the timing/energy models ------------------

    /// msize in bits.
    pub fn model_size_bits(&self) -> f64 {
        self.model_size_mb * 8.0e6
    }

    /// BR in bits/second.
    pub fn cloud_edge_bps(&self) -> f64 {
        self.cloud_edge_mbps * 1.0e6
    }

    /// Mean partition size |D|/n — the paper's "average partition" used for
    /// the straggler limit T_lim.
    pub fn mean_partition(&self) -> f64 {
        self.dataset_size as f64 / self.n_clients as f64
    }

    /// Quota = C · n, the number of global submissions that triggers
    /// aggregation in HybridFL (at least 1).
    pub fn quota(&self) -> usize {
        ((self.c_fraction * self.n_clients as f64).round() as usize).max(1)
    }

    /// Sanity-check invariants before a run.
    pub fn validate(&self) -> Result<()> {
        if self.n_clients == 0 {
            bail!("n_clients must be > 0");
        }
        if self.regions.is_empty() && self.n_edges == 0 {
            bail!("n_edges must be > 0 (or provide explicit regions)");
        }
        if !self.regions.is_empty() {
            let total: usize = self.regions.iter().map(|r| r.n_clients).sum();
            if total != self.n_clients {
                bail!(
                    "explicit regions sum to {total} clients but n_clients={}",
                    self.n_clients
                );
            }
        }
        if !(0.0 < self.c_fraction && self.c_fraction <= 1.0) {
            bail!("c_fraction must be in (0, 1], got {}", self.c_fraction);
        }
        if self.local_epochs == 0 {
            bail!("local_epochs must be >= 1");
        }
        if self.t_max == 0 {
            bail!("t_max must be >= 1");
        }
        if !(0.0..1.0).contains(&self.dropout.mean) {
            bail!("dropout.mean must be in [0,1), got {}", self.dropout.mean);
        }
        if self.theta_init <= 0.0 || self.theta_init > 1.0 {
            bail!("theta_init must be in (0,1], got {}", self.theta_init);
        }
        if self.hier_kappa2 == 0 {
            bail!("hier_kappa2 must be >= 1");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be >= 1");
        }
        if self.dataset_size < self.n_clients {
            bail!(
                "dataset_size {} smaller than n_clients {}",
                self.dataset_size,
                self.n_clients
            );
        }
        let n_regions = if self.regions.is_empty() {
            self.n_edges
        } else {
            self.regions.len()
        };
        self.churn.validate(n_regions, self.n_clients)?;
        self.comm.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for cfg in [
            ExperimentConfig::task1_paper(),
            ExperimentConfig::task1_scaled(),
            ExperimentConfig::task2_paper(),
            ExperimentConfig::task2_scaled(),
            ExperimentConfig::fig2(),
        ] {
            cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
        }
    }

    #[test]
    fn quota_rounds_and_floors() {
        let mut cfg = ExperimentConfig::task1_paper();
        cfg.n_clients = 15;
        cfg.c_fraction = 0.1;
        assert_eq!(cfg.quota(), 2); // 1.5 rounds to 2
        cfg.c_fraction = 0.01;
        assert_eq!(cfg.quota(), 1); // floor at 1
    }

    #[test]
    fn unit_conversions() {
        let cfg = ExperimentConfig::task1_paper();
        assert!((cfg.model_size_bits() - 40.0e6).abs() < 1.0);
        assert!((cfg.cloud_edge_bps() - 1.0e9).abs() < 1.0);
        assert!((cfg.mean_partition() - 100.2).abs() < 0.01);
    }

    #[test]
    fn validate_rejects_bad_fields() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.c_fraction = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.dropout.mean = 1.0;
        assert!(cfg.validate().is_err());
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.regions = vec![RegionSpec { n_clients: 3, dropout_mean: 0.1 }];
        assert!(cfg.validate().is_err()); // doesn't sum to n_clients
    }

    #[test]
    fn validate_checks_churn_against_topology() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.churn = ChurnModel::MarkovOnOff {
            p_fail: 0.1,
            p_recover: 0.3,
            down_dropout: 0.95,
            region_scale: vec![1.0], // 1 entry, but n_edges = 3
        };
        assert!(cfg.validate().is_err());
        cfg.churn = ChurnModel::MarkovOnOff {
            p_fail: 0.1,
            p_recover: 0.3,
            down_dropout: 0.95,
            region_scale: vec![1.0, 2.0, 0.5],
        };
        cfg.validate().unwrap();
    }

    #[test]
    fn enum_parse_roundtrip() {
        for p in ProtocolKind::ALL {
            assert_eq!(ProtocolKind::parse(p.as_str()).unwrap(), p);
        }
        for s in SelectorKind::ALL {
            assert_eq!(SelectorKind::parse(s.as_str()).unwrap(), s);
        }
        assert!(TaskKind::parse("nope").is_err());
        assert!(EngineKind::parse("tpu").is_err());
    }
}
