//! Experiment presets.
//!
//! `task1_paper` / `task2_paper` replicate Table II exactly. The `_scaled`
//! variants shrink the population / corpus / round counts so a full
//! protocol-comparison sweep finishes in minutes on a single CPU core while
//! preserving the paper's *shape* (who wins, where, by what factor) — the
//! benches default to scaled and accept `--full` for paper scale.

use super::{
    CacheMode, Dist, EngineKind, ExperimentConfig, PartitionScheme, ProtocolKind,
    RegionSpec, TaskKind,
};
use crate::churn::ChurnModel;
use crate::comm::CommConfig;
use crate::selection::SelectorKind;

impl ExperimentConfig {
    /// Task 1 — Aerofoil, exact Table II column.
    pub fn task1_paper() -> ExperimentConfig {
        ExperimentConfig {
            name: "task1-aerofoil".into(),
            task: TaskKind::Aerofoil,
            protocol: ProtocolKind::HybridFl,
            engine: EngineKind::Pjrt,
            n_clients: 15,
            n_edges: 3,
            region_pop: Dist::new(5.0, 1.5),
            regions: vec![],
            c_fraction: 0.3,
            t_max: 600,
            local_epochs: 5,
            lr: 1.0e-4,
            target_accuracy: None,
            theta_init: 0.5,
            hier_kappa2: 10,
            cache_mode: CacheMode::Fresh,
            selector: SelectorKind::Slack,
            perf_ghz: Dist::new(0.5, 0.1),
            bw_mhz: Dist::new(0.5, 0.1),
            dropout: Dist::new(0.3, 0.05),
            churn: ChurnModel::Stationary,
            comm: CommConfig::default(),
            snr: 1.0e2,
            cloud_edge_mbps: 1.0e3,
            model_size_mb: 5.0,
            bits_per_sample: (6 * 8 * 8) as f64, // 384
            cycles_per_bit: 300.0,
            p_trans_w: 0.5,
            p_comp_base_w: 0.7,
            dataset_size: 1503,
            eval_size: 301, // 20% held out, paper uses the UCI set's scale
            partition: PartitionScheme::GaussianSize(Dist::new(100.0, 30.0)),
            seed: 42,
            artifacts_dir: "artifacts".into(),
            eval_every: 1,
        }
    }

    /// Task 1 scaled: same population (already laptop-scale), fewer rounds.
    pub fn task1_scaled() -> ExperimentConfig {
        let mut cfg = Self::task1_paper();
        cfg.name = "task1-aerofoil-scaled".into();
        cfg.t_max = 400;
        // The paper's 1e-4 suits raw UCI magnitudes; the standardized
        // synthetic surrogate needs a usable GD step.
        cfg.lr = 0.1;
        cfg
    }

    /// Task 2 — MNIST, exact Table II column. The corpus is the synthetic
    /// MNIST surrogate (see DESIGN.md §Substitutions) at full 70k scale.
    pub fn task2_paper() -> ExperimentConfig {
        ExperimentConfig {
            name: "task2-mnist".into(),
            task: TaskKind::Mnist,
            protocol: ProtocolKind::HybridFl,
            engine: EngineKind::Pjrt,
            n_clients: 500,
            n_edges: 10,
            region_pop: Dist::new(50.0, 15.0),
            regions: vec![],
            c_fraction: 0.3,
            t_max: 400,
            local_epochs: 5,
            lr: 1.0e-3,
            target_accuracy: None,
            theta_init: 0.5,
            hier_kappa2: 10,
            cache_mode: CacheMode::Fresh,
            selector: SelectorKind::Slack,
            perf_ghz: Dist::new(1.0, 0.3),
            bw_mhz: Dist::new(1.0, 0.3),
            dropout: Dist::new(0.3, 0.05),
            churn: ChurnModel::Stationary,
            comm: CommConfig::default(),
            snr: 1.0e2,
            cloud_edge_mbps: 1.0e3,
            model_size_mb: 10.0,
            bits_per_sample: (28 * 28 * 8) as f64, // 6272
            cycles_per_bit: 400.0,
            p_trans_w: 0.5,
            p_comp_base_w: 0.7,
            dataset_size: 60_000,
            eval_size: 10_000,
            partition: PartitionScheme::NonIid { skew: 0.75 },
            seed: 42,
            artifacts_dir: "artifacts".into(),
            eval_every: 1,
        }
    }

    /// Task 2 scaled: 50 clients / 5 edges / 2.5k-sample corpus / 60 rounds.
    /// Partition sizes (~50 samples) fit the 64-capacity train bucket so the
    /// whole sweep runs real PJRT training in minutes.
    pub fn task2_scaled() -> ExperimentConfig {
        let mut cfg = Self::task2_paper();
        cfg.name = "task2-mnist-scaled".into();
        cfg.n_clients = 50;
        cfg.n_edges = 5;
        cfg.region_pop = Dist::new(10.0, 3.0);
        cfg.t_max = 60;
        cfg.dataset_size = 2_500;
        cfg.eval_size = 1_000;
        cfg.lr = 0.1; // full-batch GD on the standardized synthetic corpus
        cfg.eval_every = 2; // LeNet eval is ~0.5 s; halve the cadence
        cfg
    }

    /// §III.A validation experiment (Fig. 2): 20 clients in two regions of
    /// 11 and 9 clients with reliability means 0.43 / 0.57 drop-out
    /// *no-abort* probabilities E[P] — i.e. drop-out means 1-0.43 and
    /// 1-0.57 — sigma 0.15, C = 0.3, 100 rounds, protocol dynamics only.
    pub fn fig2() -> ExperimentConfig {
        let mut cfg = Self::task1_paper();
        cfg.name = "fig2-slack-traces".into();
        cfg.engine = EngineKind::Mock;
        cfg.protocol = ProtocolKind::HybridFl;
        cfg.n_clients = 20;
        cfg.n_edges = 2;
        cfg.regions = vec![
            // Paper: E[P_i] = 0.43 -> E[dr] = 0.57 for region 1,
            //        E[P_i] = 0.57 -> E[dr] = 0.43 for region 2.
            RegionSpec { n_clients: 11, dropout_mean: 0.57 },
            RegionSpec { n_clients: 9, dropout_mean: 0.43 },
        ];
        cfg.dropout = Dist::new(0.5, 0.15); // mean overridden per region
        cfg.perf_ghz = Dist::new(0.5, 0.1);
        cfg.c_fraction = 0.3;
        cfg.t_max = 100;
        cfg.local_epochs = 5;
        cfg.dataset_size = 2_000;
        cfg.eval_size = 200;
        cfg
    }

    /// Preset lookup by name (CLI `--preset`).
    pub fn preset(name: &str) -> anyhow::Result<ExperimentConfig> {
        match name {
            "task1" | "task1-paper" => Ok(Self::task1_paper()),
            "task1-scaled" => Ok(Self::task1_scaled()),
            "task2" | "task2-paper" => Ok(Self::task2_paper()),
            "task2-scaled" => Ok(Self::task2_scaled()),
            "fig2" => Ok(Self::fig2()),
            _ => anyhow::bail!(
                "unknown preset '{name}' \
                 (task1|task1-scaled|task2|task2-scaled|fig2)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_constants_match_paper() {
        let t1 = ExperimentConfig::task1_paper();
        assert_eq!(t1.n_clients, 15);
        assert_eq!(t1.n_edges, 3);
        assert_eq!(t1.bits_per_sample, 384.0);
        assert_eq!(t1.cycles_per_bit, 300.0);
        assert_eq!(t1.t_max, 600);
        assert_eq!(t1.model_size_mb, 5.0);
        assert!((t1.lr - 1e-4).abs() < 1e-12);

        let t2 = ExperimentConfig::task2_paper();
        assert_eq!(t2.n_clients, 500);
        assert_eq!(t2.n_edges, 10);
        assert_eq!(t2.bits_per_sample, 6272.0);
        assert_eq!(t2.cycles_per_bit, 400.0);
        assert_eq!(t2.t_max, 400);
        assert_eq!(t2.model_size_mb, 10.0);
        assert_eq!(t2.partition, PartitionScheme::NonIid { skew: 0.75 });
    }

    #[test]
    fn fig2_regions_match_paper() {
        let cfg = ExperimentConfig::fig2();
        assert_eq!(cfg.regions.len(), 2);
        assert_eq!(cfg.regions[0].n_clients, 11);
        assert_eq!(cfg.regions[1].n_clients, 9);
        // no-abort means 0.43/0.57 expressed as drop-out probabilities
        assert!((cfg.regions[0].dropout_mean - 0.57).abs() < 1e-12);
        assert!((cfg.regions[1].dropout_mean - 0.43).abs() < 1e-12);
        assert_eq!(cfg.t_max, 100);
    }

    #[test]
    fn preset_lookup() {
        assert!(ExperimentConfig::preset("task2-scaled").is_ok());
        assert!(ExperimentConfig::preset("bogus").is_err());
    }
}
