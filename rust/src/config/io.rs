//! Config serialization (JSON) and `key=value` CLI overrides.

use anyhow::{bail, Context, Result};

use super::{
    CacheMode, Dist, EngineKind, ExperimentConfig, PartitionScheme, ProtocolKind,
    RegionSpec, TaskKind,
};
use crate::churn::ChurnModel;
use crate::comm::CommConfig;
use crate::jsonx::Json;
use crate::selection::SelectorKind;

impl Dist {
    fn to_json(self) -> Json {
        Json::obj().set("mean", self.mean).set("std", self.std)
    }

    fn from_json(j: &Json) -> Result<Dist> {
        Ok(Dist {
            mean: j.req("mean")?.as_f64()?,
            std: j.req("std")?.as_f64()?,
        })
    }
}

impl PartitionScheme {
    fn to_json(&self) -> Json {
        match self {
            PartitionScheme::GaussianSize(d) => {
                Json::obj().set("kind", "gaussian").set("size", d.to_json())
            }
            PartitionScheme::NonIid { skew } => {
                Json::obj().set("kind", "noniid").set("skew", *skew)
            }
        }
    }

    fn from_json(j: &Json) -> Result<PartitionScheme> {
        match j.req("kind")?.as_str()? {
            "gaussian" => Ok(PartitionScheme::GaussianSize(Dist::from_json(
                j.req("size")?,
            )?)),
            "noniid" => Ok(PartitionScheme::NonIid {
                skew: j.req("skew")?.as_f64()?,
            }),
            k => bail!("unknown partition kind '{k}'"),
        }
    }
}

impl ExperimentConfig {
    /// Serialize to JSON (stable key order; suitable for committing).
    pub fn to_json(&self) -> Json {
        let regions: Vec<Json> = self
            .regions
            .iter()
            .map(|r| {
                Json::obj()
                    .set("n_clients", r.n_clients)
                    .set("dropout_mean", r.dropout_mean)
            })
            .collect();
        Json::obj()
            .set("name", self.name.as_str())
            .set("task", self.task.as_str())
            .set("protocol", self.protocol.as_str())
            .set("engine", self.engine.as_str())
            .set("n_clients", self.n_clients)
            .set("n_edges", self.n_edges)
            .set("region_pop", self.region_pop.to_json())
            .set("regions", Json::Arr(regions))
            .set("c_fraction", self.c_fraction)
            .set("t_max", self.t_max)
            .set("local_epochs", self.local_epochs)
            .set("lr", self.lr)
            .set(
                "target_accuracy",
                match self.target_accuracy {
                    Some(a) => Json::Num(a),
                    None => Json::Null,
                },
            )
            .set("theta_init", self.theta_init)
            .set("hier_kappa2", self.hier_kappa2)
            .set("cache_mode", self.cache_mode.as_str())
            .set("selector", self.selector.as_str())
            .set("perf_ghz", self.perf_ghz.to_json())
            .set("bw_mhz", self.bw_mhz.to_json())
            .set("dropout", self.dropout.to_json())
            .set("churn", self.churn.to_json())
            .set("comm", self.comm.to_json())
            .set("snr", self.snr)
            .set("cloud_edge_mbps", self.cloud_edge_mbps)
            .set("model_size_mb", self.model_size_mb)
            .set("bits_per_sample", self.bits_per_sample)
            .set("cycles_per_bit", self.cycles_per_bit)
            .set("p_trans_w", self.p_trans_w)
            .set("p_comp_base_w", self.p_comp_base_w)
            .set("dataset_size", self.dataset_size)
            .set("eval_size", self.eval_size)
            .set("partition", self.partition.to_json())
            .set("seed", self.seed)
            .set("artifacts_dir", self.artifacts_dir.as_str())
            .set("eval_every", self.eval_every)
    }

    /// Deserialize from JSON produced by [`Self::to_json`].
    pub fn from_json(j: &Json) -> Result<ExperimentConfig> {
        let regions = j
            .req("regions")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(RegionSpec {
                    n_clients: r.req("n_clients")?.as_usize()?,
                    dropout_mean: r.req("dropout_mean")?.as_f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ExperimentConfig {
            name: j.req("name")?.as_str()?.to_string(),
            task: TaskKind::parse(j.req("task")?.as_str()?)?,
            protocol: ProtocolKind::parse(j.req("protocol")?.as_str()?)?,
            engine: EngineKind::parse(j.req("engine")?.as_str()?)?,
            n_clients: j.req("n_clients")?.as_usize()?,
            n_edges: j.req("n_edges")?.as_usize()?,
            region_pop: Dist::from_json(j.req("region_pop")?)?,
            regions,
            c_fraction: j.req("c_fraction")?.as_f64()?,
            t_max: j.req("t_max")?.as_usize()?,
            local_epochs: j.req("local_epochs")?.as_usize()?,
            lr: j.req("lr")?.as_f64()?,
            target_accuracy: match j.req("target_accuracy")? {
                Json::Null => None,
                v => Some(v.as_f64()?),
            },
            theta_init: j.req("theta_init")?.as_f64()?,
            hier_kappa2: j.req("hier_kappa2")?.as_usize()?,
            cache_mode: CacheMode::parse(j.req("cache_mode")?.as_str()?)?,
            // Absent in configs written before the selection zoo: those
            // runs always used the slack estimator.
            selector: match j.get("selector") {
                Some(s) => SelectorKind::parse(s.as_str()?)?,
                None => SelectorKind::Slack,
            },
            perf_ghz: Dist::from_json(j.req("perf_ghz")?)?,
            bw_mhz: Dist::from_json(j.req("bw_mhz")?)?,
            dropout: Dist::from_json(j.req("dropout")?)?,
            // Absent in configs written before the churn subsystem: those
            // runs were stationary by construction.
            churn: match j.get("churn") {
                Some(c) => ChurnModel::from_json(c)?,
                None => ChurnModel::Stationary,
            },
            // Absent in configs written before the comm subsystem: those
            // runs always submitted dense updates, no relay.
            comm: match j.get("comm") {
                Some(c) => CommConfig::from_json(c)?,
                None => CommConfig::default(),
            },
            snr: j.req("snr")?.as_f64()?,
            cloud_edge_mbps: j.req("cloud_edge_mbps")?.as_f64()?,
            model_size_mb: j.req("model_size_mb")?.as_f64()?,
            bits_per_sample: j.req("bits_per_sample")?.as_f64()?,
            cycles_per_bit: j.req("cycles_per_bit")?.as_f64()?,
            p_trans_w: j.req("p_trans_w")?.as_f64()?,
            p_comp_base_w: j.req("p_comp_base_w")?.as_f64()?,
            dataset_size: j.req("dataset_size")?.as_usize()?,
            eval_size: j.req("eval_size")?.as_usize()?,
            partition: PartitionScheme::from_json(j.req("partition")?)?,
            seed: j.req("seed")?.as_f64()? as u64,
            artifacts_dir: j.req("artifacts_dir")?.as_str()?.to_string(),
            eval_every: j.req("eval_every")?.as_usize()?,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<ExperimentConfig> {
        Self::from_json(&Json::parse_file(path)?)
    }
}

/// Apply `key=value` overrides (the CLI's `--set` flags) to a config.
/// Covers the knobs experiments sweep; unknown keys error loudly.
pub fn apply_overrides(cfg: &mut ExperimentConfig, overrides: &[String]) -> Result<()> {
    for ov in overrides {
        let (key, val) = ov
            .split_once('=')
            .with_context(|| format!("override '{ov}' is not key=value"))?;
        apply_one(cfg, key.trim(), val.trim())
            .with_context(|| format!("applying override '{ov}'"))?;
    }
    Ok(())
}

fn apply_one(cfg: &mut ExperimentConfig, key: &str, val: &str) -> Result<()> {
    match key {
        "name" => cfg.name = val.to_string(),
        "task" => cfg.task = TaskKind::parse(val)?,
        "protocol" => cfg.protocol = ProtocolKind::parse(val)?,
        "engine" => cfg.engine = EngineKind::parse(val)?,
        "n_clients" => cfg.n_clients = val.parse()?,
        "n_edges" => cfg.n_edges = val.parse()?,
        "c" | "c_fraction" => cfg.c_fraction = val.parse()?,
        "t_max" => cfg.t_max = val.parse()?,
        "tau" | "local_epochs" => cfg.local_epochs = val.parse()?,
        "lr" => cfg.lr = val.parse()?,
        "target_accuracy" => {
            cfg.target_accuracy = if val == "none" { None } else { Some(val.parse()?) }
        }
        "theta_init" => cfg.theta_init = val.parse()?,
        "hier_kappa2" => cfg.hier_kappa2 = val.parse()?,
        "cache_mode" => cfg.cache_mode = CacheMode::parse(val)?,
        "selector" => cfg.selector = SelectorKind::parse(val)?,
        "dropout_mean" | "e_dr" => cfg.dropout.mean = val.parse()?,
        "dropout_std" => cfg.dropout.std = val.parse()?,
        "churn" => cfg.churn = ChurnModel::parse_spec(val)?,
        "comm" => cfg.comm = CommConfig::parse_spec(val)?,
        "perf_mean" => cfg.perf_ghz.mean = val.parse()?,
        "perf_std" => cfg.perf_ghz.std = val.parse()?,
        "bw_mean" => cfg.bw_mhz.mean = val.parse()?,
        "bw_std" => cfg.bw_mhz.std = val.parse()?,
        "snr" => cfg.snr = val.parse()?,
        "cloud_edge_mbps" => cfg.cloud_edge_mbps = val.parse()?,
        "model_size_mb" => cfg.model_size_mb = val.parse()?,
        "dataset_size" => cfg.dataset_size = val.parse()?,
        "eval_size" => cfg.eval_size = val.parse()?,
        "seed" => cfg.seed = val.parse()?,
        "artifacts_dir" => cfg.artifacts_dir = val.to_string(),
        "eval_every" => cfg.eval_every = val.parse()?,
        _ => bail!("unknown config key '{key}'"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_all_presets() {
        for cfg in [
            ExperimentConfig::task1_paper(),
            ExperimentConfig::task2_paper(),
            ExperimentConfig::task2_scaled(),
            ExperimentConfig::fig2(),
        ] {
            let j = cfg.to_json();
            let back = ExperimentConfig::from_json(&j).unwrap();
            assert_eq!(cfg, back, "roundtrip mismatch for {}", cfg.name);
        }
    }

    #[test]
    fn roundtrip_with_target_accuracy() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.target_accuracy = Some(0.7);
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.target_accuracy, Some(0.7));
    }

    #[test]
    fn overrides_apply() {
        let mut cfg = ExperimentConfig::task1_scaled();
        apply_overrides(
            &mut cfg,
            &[
                "c=0.5".into(),
                "e_dr=0.6".into(),
                "protocol=fedavg".into(),
                "t_max=10".into(),
            ],
        )
        .unwrap();
        assert_eq!(cfg.c_fraction, 0.5);
        assert_eq!(cfg.dropout.mean, 0.6);
        assert_eq!(cfg.protocol, ProtocolKind::FedAvg);
        assert_eq!(cfg.t_max, 10);
    }

    #[test]
    fn churn_roundtrips_and_defaults_to_stationary() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.churn = ChurnModel::MarkovOnOff {
            p_fail: 0.05,
            p_recover: 0.25,
            down_dropout: 0.95,
            region_scale: vec![1.0, 2.0, 0.5],
        };
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);

        // A pre-churn config file (no "churn" key) loads as stationary.
        let mut j = cfg.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("churn");
        }
        let legacy = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(legacy.churn, ChurnModel::Stationary);
    }

    #[test]
    fn churn_override_parses_spec() {
        let mut cfg = ExperimentConfig::task1_scaled();
        apply_overrides(&mut cfg, &["churn=diurnal:amplitude=0.4,period=24".into()]).unwrap();
        assert_eq!(
            cfg.churn,
            ChurnModel::Diurnal {
                amplitude: 0.4,
                period: 24,
                region_phase: vec![],
            }
        );
        assert!(apply_overrides(&mut cfg, &["churn=bogus".into()]).is_err());
    }

    #[test]
    fn comm_roundtrips_and_defaults_to_dense() {
        use crate::comm::CodecSpec;
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.comm = CommConfig::parse_spec("topk:0.05+ef+relay:0.25").unwrap();
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);

        // A pre-comm config file (no "comm" key) loads as dense/no-relay.
        let mut j = cfg.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("comm");
        }
        let legacy = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(legacy.comm, CommConfig::default());

        let mut cfg = ExperimentConfig::task1_scaled();
        apply_overrides(&mut cfg, &["comm=i8+relay:0.3".into()]).unwrap();
        assert_eq!(cfg.comm.codec, CodecSpec::I8);
        assert_eq!(cfg.comm.relay, Some(0.3));
        assert!(apply_overrides(&mut cfg, &["comm=zip".into()]).is_err());
    }

    #[test]
    fn selector_roundtrips_and_defaults_to_slack() {
        let mut cfg = ExperimentConfig::task1_scaled();
        cfg.selector = SelectorKind::FedCs;
        let back = ExperimentConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back, cfg);

        // A pre-zoo config file (no "selector" key) loads as slack.
        let mut j = cfg.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("selector");
        }
        let legacy = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(legacy.selector, SelectorKind::Slack);

        let mut cfg = ExperimentConfig::task1_scaled();
        apply_overrides(&mut cfg, &["selector=oracle".into()]).unwrap();
        assert_eq!(cfg.selector, SelectorKind::Oracle);
        assert!(apply_overrides(&mut cfg, &["selector=psychic".into()]).is_err());
    }

    #[test]
    fn overrides_reject_unknown_key() {
        let mut cfg = ExperimentConfig::task1_scaled();
        assert!(apply_overrides(&mut cfg, &["bogus=1".into()]).is_err());
        assert!(apply_overrides(&mut cfg, &["no_equals".into()]).is_err());
    }

    #[test]
    fn save_load_file() {
        let cfg = ExperimentConfig::task2_scaled();
        let path = std::env::temp_dir().join("hybridfl_cfg_test.json");
        cfg.save(&path).unwrap();
        let back = ExperimentConfig::load(&path).unwrap();
        assert_eq!(cfg, back);
        let _ = std::fs::remove_file(&path);
    }
}
