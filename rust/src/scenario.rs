//! `Scenario` — the fluent entry point for running experiments on any
//! backend.
//!
//! One protocol, every backend: a scenario describes *what* to run (task,
//! protocol, reliability, scale) and *where* to run it ([`Backend::Sim`]
//! on the virtual clock, [`Backend::Live`] on the threaded cluster), and
//! returns the same [`RunResult`] either way.
//!
//! ```no_run
//! use hybridfl::config::ProtocolKind;
//! use hybridfl::scenario::{Backend, Scenario};
//!
//! let result = Scenario::task1()
//!     .protocol(ProtocolKind::HybridFl)
//!     .dropout(0.3)
//!     .backend(Backend::Live)
//!     .seed(42)
//!     .run()?;
//! println!("best accuracy: {:.3}", result.summary.best_accuracy);
//! # Ok::<(), anyhow::Error>(())
//! ```

use crate::config::{CacheMode, EngineKind, ExperimentConfig, ProtocolKind};
use crate::env::{run_to_completion, LiveClusterEnv, RunResult, VirtualClockEnv};
use crate::protocols::protocol_for;
use crate::Result;

/// Which [`crate::env::FlEnvironment`] implementation executes the rounds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Deterministic MEC simulator on the virtual clock (default).
    Sim,
    /// Live threaded cloud/edge/client cluster (mock numerics, real
    /// concurrency; virtual durations scaled by
    /// [`Scenario::time_scale`]).
    Live,
}

impl Backend {
    pub fn as_str(self) -> &'static str {
        match self {
            Backend::Sim => "sim",
            Backend::Live => "live",
        }
    }

    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "sim" => Ok(Backend::Sim),
            "live" => Ok(Backend::Live),
            _ => anyhow::bail!("unknown backend '{s}' (sim|live)"),
        }
    }
}

/// Builder for one experiment run. Start from a preset, chain overrides,
/// pick a backend, `run()`.
#[derive(Clone, Debug)]
pub struct Scenario {
    cfg: ExperimentConfig,
    backend: Backend,
    time_scale: f64,
}

impl Scenario {
    /// Default wall-clock seconds per virtual second for the live backend
    /// (a ~90 s virtual deadline plays out in ~9 ms).
    pub const DEFAULT_TIME_SCALE: f64 = 1e-4;

    /// Wrap an existing config (the escape hatch for fully custom setups).
    pub fn from_config(cfg: ExperimentConfig) -> Scenario {
        Scenario {
            cfg,
            backend: Backend::Sim,
            time_scale: Self::DEFAULT_TIME_SCALE,
        }
    }

    /// Task 1 (Aerofoil) at laptop scale.
    pub fn task1() -> Scenario {
        Self::from_config(ExperimentConfig::task1_scaled())
    }

    /// Task 1 (Aerofoil) at exact Table II scale.
    pub fn task1_paper() -> Scenario {
        Self::from_config(ExperimentConfig::task1_paper())
    }

    /// Task 2 (MNIST) at laptop scale.
    pub fn task2() -> Scenario {
        Self::from_config(ExperimentConfig::task2_scaled())
    }

    /// Task 2 (MNIST) at exact Table II scale.
    pub fn task2_paper() -> Scenario {
        Self::from_config(ExperimentConfig::task2_paper())
    }

    /// The Fig. 2 slack-trace experiment (mock engine, two regions).
    pub fn fig2() -> Scenario {
        Self::from_config(ExperimentConfig::fig2())
    }

    /// Any named preset (`task1|task1-scaled|task2|task2-scaled|fig2`).
    pub fn preset(name: &str) -> Result<Scenario> {
        Ok(Self::from_config(ExperimentConfig::preset(name)?))
    }

    // --- config overrides ---------------------------------------------------

    pub fn protocol(mut self, p: ProtocolKind) -> Scenario {
        self.cfg.protocol = p;
        self
    }

    pub fn engine(mut self, e: EngineKind) -> Scenario {
        self.cfg.engine = e;
        self
    }

    /// Shorthand for the analytic mock engine (no artifacts needed).
    pub fn mock(self) -> Scenario {
        self.engine(EngineKind::Mock)
    }

    /// E[dr] — mean per-round drop-out probability of the fleet.
    pub fn dropout(mut self, mean: f64) -> Scenario {
        self.cfg.dropout.mean = mean;
        self
    }

    /// C — desired proportion of clients with successful submissions.
    pub fn c_fraction(mut self, c: f64) -> Scenario {
        self.cfg.c_fraction = c;
        self
    }

    pub fn seed(mut self, seed: u64) -> Scenario {
        self.cfg.seed = seed;
        self
    }

    /// t_max — number of federated rounds to run.
    pub fn rounds(mut self, t_max: usize) -> Scenario {
        self.cfg.t_max = t_max;
        self
    }

    pub fn clients(mut self, n: usize) -> Scenario {
        self.cfg.n_clients = n;
        self
    }

    pub fn edges(mut self, m: usize) -> Scenario {
        self.cfg.n_edges = m;
        self
    }

    pub fn dataset_size(mut self, n: usize) -> Scenario {
        self.cfg.dataset_size = n;
        self
    }

    pub fn local_epochs(mut self, tau: usize) -> Scenario {
        self.cfg.local_epochs = tau;
        self
    }

    pub fn theta_init(mut self, theta: f64) -> Scenario {
        self.cfg.theta_init = theta;
        self
    }

    pub fn cache_mode(mut self, mode: CacheMode) -> Scenario {
        self.cfg.cache_mode = mode;
        self
    }

    /// Stop early once the global model reaches this accuracy.
    pub fn target_accuracy(mut self, acc: f64) -> Scenario {
        self.cfg.target_accuracy = Some(acc);
        self
    }

    /// Arbitrary config surgery for knobs without a dedicated method.
    pub fn tune(mut self, f: impl FnOnce(&mut ExperimentConfig)) -> Scenario {
        f(&mut self.cfg);
        self
    }

    /// Apply CLI-style `key=value` overrides (see `config::apply_overrides`).
    pub fn apply_sets(mut self, overrides: &[String]) -> Result<Scenario> {
        crate::config::apply_overrides(&mut self.cfg, overrides)?;
        Ok(self)
    }

    // --- execution ----------------------------------------------------------

    pub fn backend(mut self, backend: Backend) -> Scenario {
        self.backend = backend;
        self
    }

    /// Wall-clock seconds per virtual second for [`Backend::Live`].
    pub fn time_scale(mut self, scale: f64) -> Scenario {
        self.time_scale = scale;
        self
    }

    /// The resolved config (inspection / serialization).
    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Validate the config, build the backend and the protocol, and drive
    /// the run to completion. Identical [`RunResult`] shape on every
    /// backend.
    pub fn run(self) -> Result<RunResult> {
        self.cfg.validate()?;
        match self.backend {
            Backend::Sim => {
                let mut env = VirtualClockEnv::new(self.cfg)?;
                let mut protocol = protocol_for(&env);
                run_to_completion(&mut env, protocol.as_mut())
            }
            Backend::Live => {
                let mut env = LiveClusterEnv::new(self.cfg, self.time_scale)?;
                let mut protocol = protocol_for(&env);
                run_to_completion(&mut env, protocol.as_mut())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chains_and_exposes_config() {
        let sc = Scenario::task1()
            .mock()
            .protocol(ProtocolKind::FedAvg)
            .dropout(0.4)
            .c_fraction(0.2)
            .seed(7)
            .rounds(12);
        assert_eq!(sc.config().protocol, ProtocolKind::FedAvg);
        assert_eq!(sc.config().engine, EngineKind::Mock);
        assert_eq!(sc.config().dropout.mean, 0.4);
        assert_eq!(sc.config().c_fraction, 0.2);
        assert_eq!(sc.config().seed, 7);
        assert_eq!(sc.config().t_max, 12);
    }

    // Validation rejection cases live in tests/scenario_api.rs
    // (builder_rejects_invalid_fraction_and_quota_combos).

    #[test]
    fn sim_run_matches_flrun() {
        let sc = Scenario::task1().mock().rounds(8).clients(16).edges(2);
        let cfg = sc.config().clone();
        let a = sc.run().unwrap();
        let b = crate::sim::FlRun::new(cfg).unwrap().run().unwrap();
        assert_eq!(a.summary.best_accuracy, b.summary.best_accuracy);
        assert_eq!(a.summary.total_time, b.summary.total_time);
    }
}
